//! Fig. 9: performance + strong scaling vs the DistGNN-like baseline on
//! the ABCI profile (Xeon + InfiniBand EDR).
//!
//! Baseline = DistGNN analogue: pre-aggregation-only remote graphs +
//! delayed halo exchange (cd-5), FP32. SuperGCN = MVC hybrid + Int2 + LP,
//! synchronous.
//!
//! Expected shape (paper): SuperGCN speedup 0.9–6.0×, growing with P as
//! communication becomes the bottleneck.

use supergcn::coordinator::planner::partition_for;
use supergcn::run::RunConfig;
use supergcn::datasets;
use supergcn::exp::{steady_epoch_secs, train_native, Table};
use supergcn::hier::remote_pairs;
use supergcn::hier::volume::{volume, RemoteStrategy, ALL_STRATEGIES};
use supergcn::perfmodel::{
    flat_pair_messages, inter_group_messages, t_comm, t_comm_two_tier, MachineProfile,
};
use supergcn::quant::Bits;

fn main() {
    let epochs = 6;
    for name in ["reddit-s", "products-s", "proteins-s"] {
        let spec = datasets::by_name(name).unwrap();
        let mut t = Table::new(
            &format!("Fig 9: {} on ABCI profile (modeled epoch seconds)", name),
            &["procs", "DistGNN(cd-5)", "SuperGCN", "speedup"],
        );
        let mut prev_speedup = 0.0f64;
        for k in [4usize, 8, 16, 32] {
            let distgnn = RunConfig {
                strategy: RemoteStrategy::PreOnly,
                delay_comm: 5,
                quant: None,
                machine: MachineProfile::abci(),
                ..Default::default()
            };
            let supergcn = RunConfig {
                strategy: RemoteStrategy::Hybrid,
                quant: Some(Bits::Int2),
                label_prop: true,
                machine: MachineProfile::abci(),
                ..Default::default()
            };
            let (s0, _) = train_native(&spec, k, distgnn.train_config(), Some(epochs)).unwrap();
            let (s1, _) = train_native(&spec, k, supergcn.train_config(), Some(epochs)).unwrap();
            // DistGNN amortizes comm over cd epochs — average includes
            // both exchange and silent epochs, like the paper measures.
            let t0 = s0.iter().map(|s| s.modeled_secs).sum::<f64>() / s0.len() as f64;
            let t1 = steady_epoch_secs(&s1, epochs);
            let sp = t0 / t1;
            t.row(vec![
                k.to_string(),
                format!("{t0:.4}"),
                format!("{t1:.4}"),
                format!("{sp:.2}x"),
            ]);
            prev_speedup = sp;
        }
        t.print();
        let _ = prev_speedup;

        // Two-level transport view (DESIGN.md §12) at the largest
        // executed scale: exact per-pair volumes per strategy, modeled
        // flat vs leader-staged (g = ranks per ABCI node) inter-node
        // wire time and message counts.
        let machine = MachineProfile::abci();
        let g = machine.ranks_per_node;
        let k = 32usize;
        let lg = spec.build();
        let part = partition_for(&lg, k, 42);
        let pairs = remote_pairs(&lg.graph, &part);
        let mut ht = Table::new(
            &format!(
                "{name} @ P={k}: inter-node model per strategy (g={g}; \
                 msgs {} flat vs {} two-level per exchange)",
                flat_pair_messages(k),
                inter_group_messages(k, g)
            ),
            &["strategy", "rows", "flat wire s", "two-level wire s"],
        );
        for s in ALL_STRATEGIES {
            let v = volume(k, &pairs, s);
            let vals: Vec<Vec<usize>> = v
                .rows
                .iter()
                .map(|r| r.iter().map(|&x| x * spec.feat_dim).collect())
                .collect();
            ht.row(vec![
                s.name().into(),
                v.total_rows().to_string(),
                format!("{:.6}", t_comm(&vals, &machine)),
                format!("{:.6}", t_comm_two_tier(&vals, g, &machine)),
            ]);
        }
        ht.print();
    }
    println!(
        "\n(per-worker compute measured on this core; wire time from the Eqn-2/5 \
         ABCI model — see DESIGN.md §1; two-level = leader-staged node groups, §12)"
    );
}
