//! Graph IO: text edge lists (interoperability) and a compact binary CSR
//! format (fast reload of generated datasets between bench runs).
//!
//! Both loaders are hardened to the `model::checkpoint` v2 Reader
//! contract: truncated, corrupt, or shape-inconsistent inputs return a
//! descriptive `Err` naming the offending field — never a panic, never a
//! bare "failed to fill whole buffer" — and every loaded graph passes
//! [`CsrGraph::validate`] before it is handed to callers.

use super::CsrGraph;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write `src dst` lines (CSR order). Lines starting with `#` or `%` are
/// comments on read.
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "# supergcn edge list: n={} m={}", g.n, g.m())?;
    // Lazy edge scan: no O(m) edge-list materialization on write.
    for (s, d) in g.edges_iter() {
        writeln!(w, "{s} {d}")?;
    }
    Ok(())
}

/// Read an edge list; `n` is inferred as max id + 1 unless given. Every
/// malformed line errors with its line number and the offending field.
pub fn read_edge_list(path: &Path, n: Option<usize>) -> Result<CsrGraph> {
    let r = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening edge list {path:?}"))?,
    );
    let mut edges = Vec::new();
    let mut max_id = 0u32;
    for (lineno, line) in r.lines().enumerate() {
        let line = line.with_context(|| format!("edge list {path:?} unreadable at line {}", lineno + 1))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let s: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: src is not a node id", lineno + 1))?;
        let d: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing dst", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: dst is not a node id", lineno + 1))?;
        max_id = max_id.max(s).max(d);
        edges.push((s, d));
    }
    let n = n.unwrap_or(if edges.is_empty() { 0 } else { max_id as usize + 1 });
    if let Some((s, d)) = edges.iter().find(|&&(s, d)| s as usize >= n || d as usize >= n) {
        anyhow::bail!("edge ({s}, {d}) out of range for declared n={n}");
    }
    let g = CsrGraph::from_edges(n, &edges);
    g.validate()
        .with_context(|| format!("edge list {path:?} built an invalid graph"))?;
    Ok(g)
}

const MAGIC: &[u8; 8] = b"SGCNCSR1";

/// Checked little-endian reader: every failed read names what was being
/// read (the `model::checkpoint` v2 Reader contract).
struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn bytes8(&mut self, what: &str) -> Result<[u8; 8]> {
        let mut b = [0u8; 8];
        self.r
            .read_exact(&mut b)
            .with_context(|| format!("graph file truncated or unreadable while reading {what}"))?;
        Ok(b)
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes8(what)?))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r
            .read_exact(&mut b)
            .with_context(|| format!("graph file truncated or unreadable while reading {what}"))?;
        Ok(u32::from_le_bytes(b))
    }

    fn expect_eof(&mut self) -> Result<()> {
        let mut b = [0u8; 1];
        match self.r.read(&mut b) {
            Ok(0) => Ok(()),
            Ok(_) => anyhow::bail!("graph file has trailing bytes past the declared payload"),
            Err(e) => Err(e).context("checking graph file end"),
        }
    }
}

/// Compact binary CSR dump.
pub fn write_binary(g: &CsrGraph, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(g.n as u64).to_le_bytes())?;
    w.write_all(&(g.m() as u64).to_le_bytes())?;
    for &p in &g.row_ptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in &g.col_idx {
        w.write_all(&c.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> Result<CsrGraph> {
    let mut r = Reader {
        r: BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening graph file {path:?}"))?,
        ),
    };
    let magic = r.bytes8("magic")?;
    anyhow::ensure!(&magic == MAGIC, "bad magic: not a supergcn CSR file");
    let n = r.u64("node count")? as usize;
    let m = r.u64("edge count")? as usize;
    let mut row_ptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        row_ptr.push(r.u64("row_ptr")? as usize);
    }
    let mut col_idx = Vec::with_capacity(m);
    for _ in 0..m {
        col_idx.push(r.u32("col_idx")?);
    }
    r.expect_eof()?;
    let g = CsrGraph { n, row_ptr, col_idx };
    g.validate()
        .with_context(|| format!("graph file {path:?} fails CSR validation"))?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::erdos_renyi;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("supergcn_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = erdos_renyi(40, 200, 1);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, Some(40)).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_infers_n_and_skips_comments() {
        let p = tmp("el2.txt");
        std::fs::write(&p, "# hi\n0 1\n% c\n2 3\n\n1 2\n").unwrap();
        let g = read_edge_list(&p, None).unwrap();
        assert_eq!(g.n, 4);
        assert_eq!(g.m(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_names_the_bad_field() {
        let p = tmp("el_bad.txt");
        std::fs::write(&p, "0 1\n2 frog\n").unwrap();
        let err = read_edge_list(&p, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("dst"), "{msg}");
        std::fs::write(&p, "0\n").unwrap();
        let err = read_edge_list(&p, None).unwrap_err();
        assert!(format!("{err:#}").contains("missing dst"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_rejects_out_of_range_ids() {
        let p = tmp("el_oor.txt");
        std::fs::write(&p, "0 1\n5 1\n").unwrap();
        let err = read_edge_list(&p, Some(3)).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let g = erdos_renyi(100, 700, 2);
        let p = tmp("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(err.to_string().contains("not a supergcn CSR file"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_truncation_names_the_field() {
        let g = erdos_renyi(30, 120, 3);
        let p = tmp("trunc.bin");
        write_binary(&g, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        // Cuts landing in the header, row_ptr, and col_idx sections.
        for (cut, field) in [
            (4usize, "magic"),
            (12, "node count"),
            (20, "edge count"),
            (24 + 8 * 10, "row_ptr"),
            (24 + 8 * 31 + 4 * 5, "col_idx"),
        ] {
            std::fs::write(&p, &full[..cut]).unwrap();
            let err = read_binary(&p).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") && msg.contains(field),
                "cut {cut}: expected field {field} in {msg}"
            );
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_trailing_garbage_rejected() {
        let g = erdos_renyi(10, 30, 4);
        let p = tmp("trail.bin");
        write_binary(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.push(0x5A);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_shape_inconsistency_rejected() {
        let g = erdos_renyi(10, 30, 5);
        let p = tmp("shape.bin");
        write_binary(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Corrupt row_ptr[1] to a value past m: validation must name it.
        let off = 8 + 8 + 8 + 8; // magic, n, m, row_ptr[0]
        bytes[off..off + 8].copy_from_slice(&(10_000u64).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(format!("{err:#}").contains("CSR validation"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }
}
