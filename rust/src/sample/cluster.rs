//! Cluster-GCN batching: partition the graph once into many small
//! METIS-like clusters (`partition::multilevel`, the same family the
//! SPMD workers are partitioned with), then train on unions of randomly
//! ordered clusters per batch.
//!
//! Each epoch shuffles the cluster order and visits every cluster exactly
//! once, so an epoch covers all nodes; aggregation inside a batch is the
//! exact mean over retained (intra-batch) neighbors — Cluster-GCN's
//! approximation is dropping the cut arcs, which is precisely what makes
//! its communication cheap in the distributed setting (MG-GCN's
//! partition-aligned batching observation).

use super::minibatch::{mean_edge_weights, MiniBatch};
use super::{epoch_rng, mix2, Sampler};
use crate::graph::generate::LabelledGraph;
use crate::partition::multilevel::{multilevel, MultilevelOpts};
use crate::partition::vertex_weights;
use std::sync::Arc;

pub struct ClusterSampler {
    lg: Arc<LabelledGraph>,
    /// Nodes of each cluster (ascending global ids).
    clusters: Vec<Vec<u32>>,
    clusters_per_batch: usize,
    seed: u64,
}

impl ClusterSampler {
    /// `num_clusters == 0` picks `~n/512` clusters, clamped to `[4, 64]`
    /// (and to `n`).
    pub fn new(
        lg: Arc<LabelledGraph>,
        num_clusters: usize,
        clusters_per_batch: usize,
        seed: u64,
    ) -> Self {
        let n = lg.n();
        let nc = if num_clusters == 0 {
            (n / 512).clamp(4, 64).min(n.max(1))
        } else {
            num_clusters.min(n.max(1))
        };
        let w = vertex_weights(&lg.graph, None, 0);
        let part = multilevel(
            &lg.graph,
            nc,
            &w,
            &MultilevelOpts {
                seed: mix2(seed, 0xC1_05_7E4),
                ..Default::default()
            },
        );
        let clusters: Vec<Vec<u32>> = part
            .part_nodes()
            .into_iter()
            .filter(|c| !c.is_empty())
            .collect();
        assert!(!clusters.is_empty(), "partitioner returned no clusters");
        Self {
            lg,
            clusters,
            clusters_per_batch: clusters_per_batch.max(1),
            seed,
        }
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }
}

impl Sampler for ClusterSampler {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn batches_per_epoch(&self) -> usize {
        self.clusters.len().div_ceil(self.clusters_per_batch)
    }

    fn sample(&mut self, epoch: usize, batch: usize) -> MiniBatch {
        let nc = self.clusters.len();
        let mut order: Vec<usize> = (0..nc).collect();
        epoch_rng(self.seed ^ 0xC1u64, epoch).shuffle(&mut order);
        let lo = (batch * self.clusters_per_batch).min(nc);
        let hi = ((batch + 1) * self.clusters_per_batch).min(nc);
        let mut n_id: Vec<u32> = Vec::new();
        for &ci in &order[lo..hi] {
            n_id.extend_from_slice(&self.clusters[ci]);
        }
        n_id.sort_unstable();
        let adj = self.lg.graph.induced(&n_id);
        let edge_weight = mean_edge_weights(&adj);
        MiniBatch {
            sampler: "cluster",
            n_target: n_id.len(),
            node_weight: vec![1.0; n_id.len()],
            n_id,
            adj,
            edge_weight,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;

    fn lg() -> Arc<LabelledGraph> {
        Arc::new(sbm(600, 4, 8.0, 0.85, 8, 0.5, 31))
    }

    #[test]
    fn epoch_covers_every_node_exactly_once() {
        let mut s = ClusterSampler::new(lg(), 8, 1, 3);
        let nb = s.batches_per_epoch();
        assert_eq!(nb, s.num_clusters());
        let mut seen: Vec<u32> = Vec::new();
        for b in 0..nb {
            let mb = s.sample(5, b);
            mb.validate(600).unwrap();
            assert_eq!(mb.n_target, mb.n());
            seen.extend_from_slice(&mb.n_id);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..600u32).collect::<Vec<_>>());
    }

    #[test]
    fn multi_cluster_batches_union() {
        let mut s = ClusterSampler::new(lg(), 9, 4, 3);
        let nb = s.batches_per_epoch();
        assert!(nb >= 2 && nb <= 3, "unexpected batch count {nb}");
        let sizes: usize = (0..nb).map(|b| s.sample(0, b).n()).sum();
        assert_eq!(sizes, 600);
    }

    #[test]
    fn batches_keep_only_intra_arcs() {
        let lg = lg();
        let mut s = ClusterSampler::new(lg.clone(), 8, 1, 3);
        let mb = s.sample(0, 0);
        // Every kept arc maps back to a global arc inside the batch set.
        let set: std::collections::HashSet<u32> = mb.n_id.iter().copied().collect();
        for (ls, ld) in mb.adj.edges() {
            let gs = mb.n_id[ls as usize];
            let gd = mb.n_id[ld as usize];
            assert!(set.contains(&gs) && set.contains(&gd));
            assert!(lg.graph.in_neighbors(gd as usize).contains(&gs));
        }
        // Cluster batches drop some cut arcs (otherwise clustering is moot).
        let total_kept: usize = (0..s.batches_per_epoch())
            .map(|b| s.sample(0, b).m())
            .sum();
        assert!(total_kept < lg.graph.m());
    }

    #[test]
    fn deterministic_and_epoch_shuffled() {
        let mut a = ClusterSampler::new(lg(), 8, 1, 7);
        let mut b = ClusterSampler::new(lg(), 8, 1, 7);
        let x = a.sample(0, 0);
        let y = b.sample(0, 0);
        assert_eq!(x.n_id, y.n_id);
        assert_eq!(x.adj, y.adj);
        // Some epoch reorders the cluster sequence.
        let e0: Vec<u32> = a.sample(0, 0).n_id;
        let reordered = (1..6).any(|e| a.sample(e, 0).n_id != e0);
        assert!(reordered, "epoch shuffle never changed batch 0");
    }
}
