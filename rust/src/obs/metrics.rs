//! One typed metrics registry for the whole runtime (DESIGN.md §13).
//!
//! Counters, gauges, and histograms keyed by dotted
//! `subsystem.metric.unit` names (at least three segments — the last is
//! always the unit, e.g. `comm.data.bytes`, `exec.stage_aggr.secs`).
//! The registry is epoch-structured: [`MetricsRegistry::begin_epoch`]
//! opens a record, writes land there, [`MetricsRegistry::end_epoch`]
//! seals it and folds counters/histograms into the run totals. Writes
//! outside an open epoch go straight to the totals.
//!
//! The scattered accounting structs (`StageClock`, `CommStats` +
//! `TierStats`, `OverlapLedger`) stay the authoritative per-epoch
//! accumulators — the trainers *publish* their merged views into this
//! registry at epoch end, so `--metrics-json` replaces the ad-hoc
//! summary printing with one machine-readable report.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// Running histogram summary (count/sum/min/max — enough for the
/// modeled-vs-measured report without bucket bookkeeping).
#[derive(Clone, Copy, Debug, Default)]
pub struct Hist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Hist {
    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    fn absorb(&mut self, other: &Hist) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// One registered metric value.
#[derive(Clone, Copy, Debug)]
pub enum Metric {
    /// Monotone accumulator (`counter_add`).
    Counter(f64),
    /// Last-write-wins level (`gauge_set`).
    Gauge(f64),
    /// Distribution summary (`observe`).
    Hist(Hist),
}

impl Metric {
    fn to_json(self) -> Json {
        match self {
            Metric::Counter(v) => Json::obj(vec![
                ("type", Json::Str("counter".into())),
                ("value", Json::Num(v)),
            ]),
            Metric::Gauge(v) => Json::obj(vec![
                ("type", Json::Str("gauge".into())),
                ("value", Json::Num(v)),
            ]),
            Metric::Hist(h) => Json::obj(vec![
                ("type", Json::Str("hist".into())),
                ("count", Json::Num(h.count as f64)),
                ("sum", Json::Num(h.sum)),
                ("min", Json::Num(h.min)),
                ("max", Json::Num(h.max)),
            ]),
        }
    }
}

/// Per-exchange modeled-vs-measured row (`perfmodel::estimate_exchange`
/// beside the `OverlapLedger`'s measured lane maxes).
#[derive(Clone, Debug)]
pub struct ExchangeRow {
    /// Exchange label (`fwd halo L0`, `fetch req`, ...).
    pub label: String,
    /// Measured interior-compute seconds (max over lanes).
    pub interior_secs: f64,
    /// Measured boundary-compute seconds (max over lanes).
    pub boundary_secs: f64,
    /// Modeled wire seconds for the exchange (max over lanes).
    pub comm_secs: f64,
    /// `perfmodel::t_layer_overlap` over the three columns.
    pub modeled_overlap_secs: f64,
    /// `perfmodel::t_layer_serial` over the three columns.
    pub modeled_serial_secs: f64,
}

impl ExchangeRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("interior_secs", Json::Num(self.interior_secs)),
            ("boundary_secs", Json::Num(self.boundary_secs)),
            ("comm_secs", Json::Num(self.comm_secs)),
            ("modeled_overlap_secs", Json::Num(self.modeled_overlap_secs)),
            ("modeled_serial_secs", Json::Num(self.modeled_serial_secs)),
        ])
    }
}

/// One sealed epoch of metrics.
#[derive(Clone, Debug, Default)]
struct EpochRecord {
    epoch: usize,
    metrics: BTreeMap<String, Metric>,
    exchanges: Vec<ExchangeRow>,
}

#[derive(Default)]
struct RegInner {
    current: Option<EpochRecord>,
    epochs: Vec<EpochRecord>,
    totals: BTreeMap<String, Metric>,
}

/// The shared, clonable registry handle (one `Arc`; hand clones to the
/// trainers and the CLI writer).
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<RegInner>>,
}

/// Enforce the §13 naming contract: `subsystem.metric.unit`, at least
/// three dot-separated non-empty segments.
fn check_name(name: &str) {
    let ok = name.split('.').filter(|s| !s.is_empty()).count() >= 3
        && !name.split('.').any(|s| s.is_empty());
    assert!(ok, "metric name '{name}' must be dotted subsystem.metric.unit");
}

fn apply(map: &mut BTreeMap<String, Metric>, name: &str, m: Metric) {
    match (map.get_mut(name), m) {
        (Some(Metric::Counter(acc)), Metric::Counter(v)) => *acc += v,
        (Some(Metric::Gauge(g)), Metric::Gauge(v)) => *g = v,
        (Some(Metric::Hist(h)), Metric::Hist(o)) => h.absorb(&o),
        (Some(_), _) => panic!("metric '{name}' re-registered with a different type"),
        (None, m) => {
            map.insert(name.to_string(), m);
        }
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, RegInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Open epoch `epoch` (seals any epoch left open).
    pub fn begin_epoch(&self, epoch: usize) {
        let mut g = self.lock();
        if g.current.is_some() {
            seal(&mut g);
        }
        g.current = Some(EpochRecord {
            epoch,
            ..Default::default()
        });
    }

    /// Seal the open epoch, folding its counters/hists into the totals.
    pub fn end_epoch(&self) {
        seal(&mut self.lock());
    }

    /// Add `v` to counter `name` (current epoch if open, else totals).
    pub fn counter_add(&self, name: &str, v: f64) {
        check_name(name);
        let mut g = self.lock();
        let map = g.current.as_mut().map(|c| &mut c.metrics);
        match map {
            Some(m) => apply(m, name, Metric::Counter(v)),
            None => apply(&mut g.totals, name, Metric::Counter(v)),
        }
    }

    /// Set gauge `name` to `v`.
    pub fn gauge_set(&self, name: &str, v: f64) {
        check_name(name);
        let mut g = self.lock();
        let map = g.current.as_mut().map(|c| &mut c.metrics);
        match map {
            Some(m) => apply(m, name, Metric::Gauge(v)),
            None => apply(&mut g.totals, name, Metric::Gauge(v)),
        }
    }

    /// Record one observation into histogram `name`.
    pub fn observe(&self, name: &str, v: f64) {
        check_name(name);
        let mut h = Hist::default();
        h.observe(v);
        let mut g = self.lock();
        let map = g.current.as_mut().map(|c| &mut c.metrics);
        match map {
            Some(m) => apply(m, name, Metric::Hist(h)),
            None => apply(&mut g.totals, name, Metric::Hist(h)),
        }
    }

    /// Attach one modeled-vs-measured exchange row to the open epoch
    /// (dropped when no epoch is open — exchanges are per-epoch data).
    pub fn push_exchange(&self, row: ExchangeRow) {
        if let Some(c) = self.lock().current.as_mut() {
            c.exchanges.push(row);
        }
    }

    /// Sealed epochs so far.
    pub fn epoch_count(&self) -> usize {
        self.lock().epochs.len()
    }

    /// Snapshot a metric from the run totals.
    pub fn total(&self, name: &str) -> Option<Metric> {
        self.lock().totals.get(name).copied()
    }

    /// The `--metrics-json` report: every sealed epoch plus run totals.
    pub fn to_json(&self) -> Json {
        let mut g = self.lock();
        if g.current.is_some() {
            seal(&mut g);
        }
        let epochs: Vec<Json> = g
            .epochs
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("epoch", Json::Num(e.epoch as f64)),
                    (
                        "metrics",
                        Json::Obj(
                            e.metrics
                                .iter()
                                .map(|(k, v)| (k.clone(), v.to_json()))
                                .collect(),
                        ),
                    ),
                    (
                        "exchanges",
                        Json::Arr(e.exchanges.iter().map(|x| x.to_json()).collect()),
                    ),
                ])
            })
            .collect();
        let totals = Json::Obj(
            g.totals
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::Str("supergcn.metrics.v1".into())),
            ("epochs", Json::Arr(epochs)),
            ("totals", totals),
        ])
    }

    /// Write the report to `path`.
    pub fn write(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, crate::util::json::to_pretty(&self.to_json()))
            .map_err(|e| anyhow::anyhow!("cannot write metrics {path}: {e}"))
    }
}

fn seal(g: &mut RegInner) {
    if let Some(cur) = g.current.take() {
        for (k, v) in &cur.metrics {
            // Counters and hists fold; gauges keep the last epoch's level.
            apply(&mut g.totals, k, *v);
        }
        g.epochs.push(cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_accumulate_and_fold_into_totals() {
        let m = MetricsRegistry::new();
        m.begin_epoch(0);
        m.counter_add("comm.data.bytes", 10.0);
        m.counter_add("comm.data.bytes", 5.0);
        m.gauge_set("train.loss.nats", 1.5);
        m.observe("exec.stage.secs", 2.0);
        m.end_epoch();
        m.begin_epoch(1);
        m.counter_add("comm.data.bytes", 1.0);
        m.gauge_set("train.loss.nats", 0.5);
        m.observe("exec.stage.secs", 4.0);
        m.end_epoch();

        assert_eq!(m.epoch_count(), 2);
        match m.total("comm.data.bytes") {
            Some(Metric::Counter(v)) => assert!((v - 16.0).abs() < 1e-12),
            other => panic!("unexpected {other:?}"),
        }
        match m.total("train.loss.nats") {
            Some(Metric::Gauge(v)) => assert!((v - 0.5).abs() < 1e-12),
            other => panic!("unexpected {other:?}"),
        }
        match m.total("exec.stage.secs") {
            Some(Metric::Hist(h)) => {
                assert_eq!(h.count, 2);
                assert!((h.sum - 6.0).abs() < 1e-12);
                assert!((h.min - 2.0).abs() < 1e-12);
                assert!((h.max - 4.0).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn report_shape_is_epoch_structured() {
        let m = MetricsRegistry::new();
        m.begin_epoch(0);
        m.counter_add("comm.msgs.count", 3.0);
        m.push_exchange(ExchangeRow {
            label: "fwd halo L0".into(),
            interior_secs: 1.0,
            boundary_secs: 0.25,
            comm_secs: 2.0,
            modeled_overlap_secs: 2.25,
            modeled_serial_secs: 3.25,
        });
        m.end_epoch();
        let j = m.to_json();
        let epochs = j.get("epochs").unwrap().as_arr().unwrap();
        assert_eq!(epochs.len(), 1);
        let ex = epochs[0].get("exchanges").unwrap().as_arr().unwrap();
        assert_eq!(ex[0].get("label").unwrap().as_str().unwrap(), "fwd halo L0");
        assert!(j.get("totals").unwrap().get("comm.msgs.count").is_some());
        // The report itself must round-trip through the parser.
        assert!(Json::parse(&crate::util::json::to_pretty(&j)).is_ok());
    }

    #[test]
    #[should_panic(expected = "subsystem.metric.unit")]
    fn short_names_are_rejected() {
        MetricsRegistry::new().counter_add("comm.bytes", 1.0);
    }

    #[test]
    fn writes_outside_epochs_land_in_totals() {
        let m = MetricsRegistry::new();
        m.counter_add("run.span.count", 7.0);
        assert!(matches!(m.total("run.span.count"), Some(Metric::Counter(v)) if v == 7.0));
        assert_eq!(m.epoch_count(), 0);
    }
}
