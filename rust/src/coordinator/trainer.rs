//! The distributed full-batch training driver (paper Fig. 2) — a thin
//! loop over the unified layer-execution engine (`exec::Engine`,
//! DESIGN.md §9).
//!
//! All layer math (LayerNorm, aggregation, SAGE update, loss,
//! label-propagation embedding, the exact backward) lives in the engine;
//! this driver owns only *policy and state*: the per-epoch label-prop
//! selection, the `delay_comm` staleness decision, the gradient
//! allreduce + optimizer step, and the Eqn-2 / Fig-12 time accounting.
//! Neighbor halos move through [`exec::FullBatchCtx`] (hierarchical
//! pre/post exchange with optional `quant::fused` payloads).
//!
//! The backward pass is exact: cotangents of received halo tensors are
//! shipped back to their producers every exchange epoch (the reverse of
//! the forward halo pattern), so the distributed gradient equals the
//! single-machine gradient to f32 round-off — property-checked in
//! `rust/tests/trainer_equivalence.rs`.

use super::planner::WorkerCtx;
use crate::comm::{collective, CommStats};
use crate::exec::{
    AggDispatch, Engine, FullBatchCtx, FullBatchState, LossSpec, LossTotals, LpInputs, StageClock,
    Tapes, SPLIT_NONE,
};
use crate::graph::generate::{SPLIT_TEST, SPLIT_TRAIN, SPLIT_VAL};
use crate::hier::volume::RemoteStrategy;
use crate::model::labelprop::{self, LpSelection};
use crate::model::optimizer::{OptKind, Optimizer};
use crate::model::ModelParams;
use crate::perfmodel::MachineProfile;
use crate::quant::Bits;
use crate::runtime::ShapeConfig;
use crate::util::rng::Rng;
use crate::util::timer::{Breakdown, Category};
use anyhow::Result;

/// Training-run configuration (one Fig. 11 curve = one of these).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub opt: OptKind,
    /// Forward halo quantization (None = FP32; the paper fixes Int2).
    pub quant: Option<Bits>,
    /// Masked label propagation (§6.1(1)).
    pub label_prop: bool,
    pub lp_frac: f64,
    pub strategy: RemoteStrategy,
    /// Exchange halos every `delay_comm` epochs (1 = synchronous SuperGCN;
    /// 5 = the DistGNN cd-5 baseline's staleness).
    pub delay_comm: usize,
    pub machine: MachineProfile,
    /// §4 aggregation-kernel dispatch (CLI: `--agg-kernel`).
    pub agg: AggDispatch,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 100,
            lr: 0.01,
            opt: OptKind::Adam,
            quant: None,
            label_prop: false,
            lp_frac: 0.5,
            strategy: RemoteStrategy::Hybrid,
            delay_comm: 1,
            machine: MachineProfile::abci(),
            agg: AggDispatch::default(),
            seed: 42,
        }
    }
}

/// Per-epoch observables.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    /// Modeled epoch seconds: Σ_stage max_w compute + modeled comm.
    pub modeled_secs: f64,
    /// Measured wall seconds (all workers run on this one core).
    pub measured_secs: f64,
    pub breakdown: Breakdown,
    pub comm_data_bytes: f64,
    pub comm_param_bytes: f64,
}

pub struct Trainer {
    pub shapes: ShapeConfig,
    pub tc: TrainConfig,
    pub workers: Vec<WorkerCtx>,
    pub engine: Engine,
    pub params: ModelParams,
    opt: Optimizer,
    tapes: Tapes,
    fb: FullBatchState,
    lp_sels: Vec<LpSelection>,
    pub comm_stats: CommStats,
    epoch: usize,
    rng: Rng,
}

impl Trainer {
    pub fn new(workers: Vec<WorkerCtx>, shapes: ShapeConfig, tc: TrainConfig) -> Self {
        let params = ModelParams::init(&shapes, tc.seed);
        let opt = Optimizer::new(tc.opt, tc.lr, params.n_params());
        let k = workers.len();
        let engine = Engine::new(&shapes, true, tc.agg.clone());
        let rows = vec![shapes.n_pad; k];
        let tapes = engine.tapes(&rows, &params);
        let fb = FullBatchState::new(&shapes, k);
        let lp_sels = (0..k)
            .map(|_| LpSelection {
                embedded: vec![],
                loss_mask: vec![0.0; shapes.n_pad],
            })
            .collect();
        let rng = Rng::new(tc.seed ^ 0x7A13);
        Self {
            shapes,
            comm_stats: CommStats::new(k),
            tc,
            workers,
            engine,
            params,
            opt,
            tapes,
            fb,
            lp_sels,
            epoch: 0,
            rng,
        }
    }

    pub fn k(&self) -> usize {
        self.workers.len()
    }

    fn is_exchange_epoch(&self) -> bool {
        self.tc.delay_comm <= 1 || self.epoch % self.tc.delay_comm == 0
    }

    /// Run one epoch; returns the stats.
    pub fn epoch(&mut self) -> Result<EpochStats> {
        let wall = std::time::Instant::now();
        let k = self.k();
        let n = self.shapes.n_pad;
        let mut breakdown = Breakdown::new();
        let mut epoch_comm = CommStats::new(k);
        let exchange = self.is_exchange_epoch();

        // ---- step 3: per-epoch label-prop selection (driver policy) ----
        for w in 0..k {
            let frac = if self.tc.label_prop { self.tc.lp_frac } else { 0.0 };
            self.lp_sels[w] = labelprop::select(&self.workers[w].train_mask, frac, &mut self.rng);
        }
        self.tapes.clear_grads();

        // ---- engine: forward / loss / backward over the halo context ----
        let mut clock = StageClock::new(k);
        let mut ctx = FullBatchCtx::new(
            &self.workers,
            &self.shapes,
            &mut self.fb,
            &self.tc.machine,
            self.tc.quant,
            self.tc.seed,
            self.epoch,
            exchange,
            &mut epoch_comm,
        );
        let lp = LpInputs {
            sel: &self.lp_sels,
            labels: self.workers.iter().map(|c| c.labels.as_slice()).collect(),
        };
        let lp_opt = if self.tc.label_prop { Some(&lp) } else { None };
        self.engine
            .forward(&self.params, &mut ctx, &mut self.tapes, lp_opt, &mut clock)?;

        let tags: Vec<Vec<u8>> = (0..k)
            .map(|w| {
                let wc = &self.workers[w];
                let lm = &self.lp_sels[w].loss_mask;
                (0..n)
                    .map(|i| {
                        if lm[i] > 0.0 {
                            SPLIT_TRAIN
                        } else if wc.val_mask[i] > 0.0 {
                            SPLIT_VAL
                        } else if wc.test_mask[i] > 0.0 {
                            SPLIT_TEST
                        } else {
                            SPLIT_NONE
                        }
                    })
                    .collect()
            })
            .collect();
        let specs: Vec<LossSpec> = (0..k)
            .map(|w| LossSpec {
                score_rows: n,
                labels: &self.workers[w].labels,
                split: &tags[w],
                loss_w: &self.lp_sels[w].loss_mask,
            })
            .collect();
        let lane_totals = self.engine.loss_all(&mut self.tapes, &specs, &mut clock);
        let mut totals = LossTotals::default();
        for t in &lane_totals {
            totals.accumulate(t);
        }
        // Scale the loss gradient to the global mean.
        let inv_mask = if totals.wsum > 0.0 {
            (1.0 / totals.wsum) as f32
        } else {
            0.0
        };
        let scales = vec![inv_mask; k];
        self.engine.scale_loss_grad(&mut self.tapes, &scales);

        self.engine
            .backward(&self.params, &mut ctx, &mut self.tapes, lp_opt, true, &mut clock)?;
        drop(ctx);

        // ---- gradient allreduce + optimizer step -----------------------
        let t = std::time::Instant::now();
        let mut flats: Vec<Vec<f32>> = self.tapes.grads.iter().map(|g| g.flatten()).collect();
        let ar_secs = collective::allreduce_sum(&mut flats, &self.tc.machine);
        epoch_comm
            .modeled_send_secs
            .iter_mut()
            .for_each(|s| *s += ar_secs);
        let mut flat_params = self.params.flatten();
        self.opt.step(&mut flat_params, &flats[0]);
        self.params.unflatten_into(&flat_params);
        breakdown.add(Category::Other, t.elapsed().as_secs_f64());

        // ---- time accounting -------------------------------------------
        // Compute was measured on this container's single core; a rank of
        // the modeled machine has `cores_per_rank` of them (DESIGN.md §1),
        // so the modeled epoch divides compute-side categories by that.
        let cscale = self.tc.machine.cores_per_rank.max(1.0);
        let (compute, sync) = clock.bottleneck();
        let modeled_compute = compute / cscale;
        for (cat, mx) in clock.category_maxes() {
            breakdown.add(cat, mx);
        }
        breakdown.add(Category::Quant, clock.quant_bottleneck());
        for c in [Category::Aggr, Category::Quant, Category::Other] {
            let v = breakdown.get(c);
            breakdown.add(c, v / cscale - v);
        }
        breakdown.add(Category::Sync, sync / k as f64 / cscale);
        let comm_secs = epoch_comm.modeled_comm_secs();
        breakdown.add(Category::Comm, comm_secs);
        // Accumulate into run totals.
        for i in 0..k {
            for j in 0..k {
                self.comm_stats.data_bits[i][j] += epoch_comm.data_bits[i][j];
                self.comm_stats.param_bits[i][j] += epoch_comm.param_bits[i][j];
                self.comm_stats.messages[i][j] += epoch_comm.messages[i][j];
            }
            self.comm_stats.modeled_send_secs[i] += epoch_comm.modeled_send_secs[i];
        }

        let stats = EpochStats {
            epoch: self.epoch,
            train_loss: (totals.loss_sum / totals.wsum.max(1.0)) as f32,
            train_acc: (totals.train_correct / totals.train_cnt.max(1.0)) as f32,
            val_acc: (totals.val_correct / totals.val_cnt.max(1.0)) as f32,
            test_acc: (totals.test_correct / totals.test_cnt.max(1.0)) as f32,
            modeled_secs: modeled_compute + comm_secs,
            measured_secs: wall.elapsed().as_secs_f64(),
            breakdown,
            comm_data_bytes: epoch_comm.total_data_bytes(),
            comm_param_bytes: epoch_comm.total_param_bytes(),
        };
        self.epoch += 1;
        Ok(stats)
    }

    /// Train for the configured number of epochs, returning per-epoch stats.
    pub fn run(&mut self, log: bool) -> Result<Vec<EpochStats>> {
        let mut out = Vec::with_capacity(self.tc.epochs);
        for e in 0..self.tc.epochs {
            let s = self.epoch()?;
            if log && (e % 10 == 0 || e + 1 == self.tc.epochs) {
                eprintln!(
                    "epoch {:4}  loss {:.4}  train {:.4}  val {:.4}  test {:.4}  modeled {:.4}s",
                    s.epoch, s.train_loss, s.train_acc, s.val_acc, s.test_acc, s.modeled_secs
                );
            }
            out.push(s);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::prepare;
    use crate::exec::AggKernel;
    use crate::graph::generate::sbm;

    fn train(k: usize, tc: TrainConfig, n: usize) -> Vec<EpochStats> {
        let lg = sbm(n, 4, 8.0, 0.85, 16, 0.6, 11);
        let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, None, 5).unwrap();
        let mut tr = Trainer::new(ctxs, cfg, tc);
        tr.run(false).unwrap()
    }

    #[test]
    fn single_worker_learns_sbm() {
        let tc = TrainConfig {
            epochs: 30,
            lr: 0.01,
            ..Default::default()
        };
        let stats = train(1, tc, 400);
        let first = &stats[0];
        let last = stats.last().unwrap();
        assert!(last.train_loss < first.train_loss, "loss must decrease");
        assert!(last.test_acc > 0.5, "test acc {} too low", last.test_acc);
    }

    #[test]
    fn distributed_matches_single_worker_loss_curve() {
        // Full-batch + exact reverse halos ⇒ identical-to-roundoff training
        // trajectories regardless of partitioning.
        let tc = TrainConfig {
            epochs: 8,
            ..Default::default()
        };
        let s1 = train(1, tc.clone(), 300);
        let s3 = train(3, tc, 300);
        for (a, b) in s1.iter().zip(s3.iter()) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 2e-3,
                "epoch {}: {} vs {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
    }

    #[test]
    fn agg_kernel_override_preserves_numerics() {
        // The dispatcher's kernel choice is an algorithm-preserving
        // transformation: every §4 kernel trains the same trajectory.
        let base = train(2, TrainConfig { epochs: 4, ..Default::default() }, 300);
        for kernel in [AggKernel::Vanilla, AggKernel::Parallel, AggKernel::Spmm] {
            let tc = TrainConfig {
                epochs: 4,
                agg: AggDispatch::default().with_kernel(kernel).with_threads(2),
                ..Default::default()
            };
            let got = train(2, tc, 300);
            for (a, b) in base.iter().zip(got.iter()) {
                assert!(
                    (a.train_loss - b.train_loss).abs() < 2e-3,
                    "{}: epoch {}: {} vs {}",
                    kernel.name(),
                    a.epoch,
                    a.train_loss,
                    b.train_loss
                );
            }
        }
    }

    #[test]
    fn int2_with_lp_still_learns() {
        let tc = TrainConfig {
            epochs: 30,
            quant: Some(Bits::Int2),
            label_prop: true,
            ..Default::default()
        };
        let stats = train(3, tc, 400);
        assert!(stats.last().unwrap().test_acc > 0.5);
        // Quant bytes ≈ fp32/16.
        let s = &stats[5];
        assert!(s.comm_data_bytes > 0.0);
        assert!(s.comm_param_bytes > 0.0);
    }

    #[test]
    fn delayed_comm_runs_and_skips_exchanges() {
        let tc = TrainConfig {
            epochs: 10,
            delay_comm: 5,
            strategy: RemoteStrategy::PreOnly,
            ..Default::default()
        };
        let stats = train(3, tc, 300);
        // Comm happens only on epochs 0 and 5.
        let active: Vec<usize> = stats
            .iter()
            .filter(|s| s.comm_data_bytes > 0.0)
            .map(|s| s.epoch)
            .collect();
        assert_eq!(active, vec![0, 5]);
    }

    #[test]
    fn quant_reduces_forward_wire_bytes_16x() {
        // Forward halos are quantized (γ=16); the reverse cotangent
        // exchange stays FP32 (the paper quantizes the forward feature
        // communication). With equal fwd/bwd volumes the total ratio is
        // 2 / (1 + 1/16) ≈ 1.88.
        let tc_fp = TrainConfig {
            epochs: 2,
            ..Default::default()
        };
        let tc_q = TrainConfig {
            epochs: 2,
            quant: Some(Bits::Int2),
            ..Default::default()
        };
        let fp = train(3, tc_fp, 400);
        let q = train(3, tc_q, 400);
        let r = fp[1].comm_data_bytes / q[1].comm_data_bytes;
        assert!(r > 1.7 && r < 2.0, "total ratio {r}");
        // Isolating the forward half: fwd_q = total_q − bwd (= fwd_fp/2).
        let bwd = fp[1].comm_data_bytes / 2.0;
        let fwd_ratio = bwd / (q[1].comm_data_bytes - bwd);
        assert!(fwd_ratio > 15.0 && fwd_ratio < 17.0, "forward ratio {fwd_ratio}");
    }
}
