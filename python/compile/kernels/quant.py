"""L1 Pallas kernel: stochastic integer quantization / dequantization.

Mirrors the paper's §7.3 fused kernel on TPU terms: one grid step loads a
4-row group into VMEM, computes (zero, scale) from the group min/max,
quantizes with a *precomputed noise tensor* — the paper's optimization of
eliminating RNG from the kernel's dependency chain; the Rust coordinator
generates the noise stream — and emits integer codes. Bit-packing is a
byte-level concern of the wire and stays on the host (Rust), where the
paper also does it.

These kernels are the compile-path twins of `rust/src/quant/fused.rs`
(which owns the runtime comm path); pytest checks both against
`ref.quantize_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP_ROWS = 4


def _quant_kernel(max_code: int, x_ref, noise_ref, codes_ref, zero_ref, scale_ref):
    x = x_ref[...]  # [GROUP_ROWS, f]
    mn = jnp.min(x)
    mx = jnp.max(x)
    scale = (mx - mn) / max_code
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    t = (x - mn) * inv + noise_ref[...]
    codes_ref[...] = jnp.clip(jnp.floor(t), 0, max_code).astype(jnp.int32)
    zero_ref[...] = jnp.full((1,), mn, dtype=x.dtype)
    scale_ref[...] = jnp.full((1,), scale, dtype=x.dtype)


def quantize(x, noise, bits: int):
    """x, noise: [rows, f] with rows % 4 == 0. Returns (codes i32, zero
    [rows//4], scale [rows//4])."""
    rows, f = x.shape
    assert rows % GROUP_ROWS == 0
    ng = rows // GROUP_ROWS
    max_code = (1 << bits) - 1
    kernel = functools.partial(_quant_kernel, max_code)
    return pl.pallas_call(
        kernel,
        grid=(ng,),
        in_specs=[
            pl.BlockSpec((GROUP_ROWS, f), lambda i: (i, 0)),
            pl.BlockSpec((GROUP_ROWS, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((GROUP_ROWS, f), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, f), jnp.int32),
            jax.ShapeDtypeStruct((ng,), x.dtype),
            jax.ShapeDtypeStruct((ng,), x.dtype),
        ],
        interpret=True,
    )(x, noise)


def _dequant_kernel(codes_ref, zero_ref, scale_ref, y_ref):
    y_ref[...] = codes_ref[...].astype(jnp.float32) * scale_ref[0] + zero_ref[0]


def dequantize(codes, zero, scale):
    """codes: [rows, f] int32; zero/scale: [rows//4]. Returns f32 [rows,f]."""
    rows, f = codes.shape
    ng = rows // GROUP_ROWS
    return pl.pallas_call(
        _dequant_kernel,
        grid=(ng,),
        in_specs=[
            pl.BlockSpec((GROUP_ROWS, f), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((GROUP_ROWS, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, f), jnp.float32),
        interpret=True,
    )(codes, zero, scale)
