//! Simulated interconnect: `MPI_Alltoallv`-style halo exchange and ring
//! allreduce between the SPMD workers of the trainer, with byte-exact
//! volume accounting and modeled wire time (paper Eqn 2/5 via
//! `perfmodel`).
//!
//! Workers execute as SPMD ranks inside one process (the hardware gate —
//! see DESIGN.md §1) under one of two transports ([`transport`],
//! DESIGN.md §10): *sequential* (ranks step inside the driver thread —
//! modeled parallel time only) or *threaded* (one OS thread per rank,
//! payloads rendezvous through per-pair mailbox slots). In both,
//! payloads move by memcpy (so numerics are bit-exact end to end), while
//! *time* is charged analytically from the machine profile. `CommStats`
//! keeps both the measured local cost (pack/unpack, quantize) and the
//! modeled wire cost.

pub mod collective;
pub mod transport;

pub use transport::Topology;

use crate::perfmodel::MachineProfile;
use crate::quant::Quantized;

/// One message on the simulated wire.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw FP32 rows (values).
    F32(Vec<f32>),
    /// Quantized rows + params.
    Quant(Quantized),
    /// Empty marker (no data between this pair).
    Empty,
}

impl Payload {
    /// Payload size in *bits* on the wire, split (data_bits, param_bits).
    pub fn wire_bits(&self) -> (f64, f64) {
        match self {
            Payload::F32(v) => (v.len() as f64 * 32.0, 0.0),
            Payload::Quant(q) => (
                q.payload_bytes() as f64 * 8.0,
                q.param_bytes() as f64 * 8.0,
            ),
            Payload::Empty => (0.0, 0.0),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            Payload::F32(v) => v.is_empty(),
            Payload::Quant(q) => q.rows == 0,
            Payload::Empty => true,
        }
    }
}

/// Two-level (intra-node vs inter-node) accounting of the physical path
/// payloads take under a hierarchical [`Topology`] (DESIGN.md §12). All
/// vectors are indexed by the payload's *original sender* rank, so the
/// threaded transport's per-rank shards each populate only their own
/// entry and [`CommStats::merge`] reproduces the sequential totals
/// bit-for-bit (the same trick `modeled_send_secs` uses).
///
/// Conventions (mirrored by `perfmodel::t_comm_two_tier`):
/// * a same-group payload is one intra message;
/// * a cross-group payload crosses the inter link once (bandwidth term),
///   plus one intra delivery hop at the destination unless the
///   destination *is* its group leader;
/// * a non-leader sender with any cross-group bytes pays one coalesced
///   intra staging hop to its leader per exchange;
/// * each leader posts the dense inter-group exchange — `n_groups − 1`
///   inter messages (and latencies) per exchange, payload or not — the
///   O((P/g)²) headline count.
///
/// All entries stay zero on the flat topology.
#[derive(Clone, Debug, Default)]
pub struct TierStats {
    /// Intra-node wire bits (direct local deliveries + staging hops).
    pub intra_bits: Vec<f64>,
    /// Inter-node wire bits (the coalesced leader exchange's payload).
    pub inter_bits: Vec<f64>,
    /// Intra-node message count.
    pub intra_msgs: Vec<usize>,
    /// Inter-node (group-pair) message count.
    pub inter_msgs: Vec<usize>,
    /// Modeled intra-tier seconds (`bw_local` / `latency_local`).
    pub modeled_intra_secs: Vec<f64>,
    /// Modeled inter-tier seconds (`bw_comm` / `latency`).
    pub modeled_inter_secs: Vec<f64>,
}

impl TierStats {
    pub fn new(k: usize) -> Self {
        Self {
            intra_bits: vec![0.0; k],
            inter_bits: vec![0.0; k],
            intra_msgs: vec![0; k],
            inter_msgs: vec![0; k],
            modeled_intra_secs: vec![0.0; k],
            modeled_inter_secs: vec![0.0; k],
        }
    }

    pub fn total_intra_bits(&self) -> f64 {
        self.intra_bits.iter().sum()
    }

    pub fn total_inter_bits(&self) -> f64 {
        self.inter_bits.iter().sum()
    }

    pub fn total_intra_msgs(&self) -> usize {
        self.intra_msgs.iter().sum()
    }

    pub fn total_inter_msgs(&self) -> usize {
        self.inter_msgs.iter().sum()
    }

    /// Eqn-2-style bottleneck over the two-tier physical path: slowest
    /// sender's intra + inter wire seconds.
    pub fn modeled_two_tier_secs(&self) -> f64 {
        self.modeled_intra_secs
            .iter()
            .zip(self.modeled_inter_secs.iter())
            .map(|(a, b)| a + b)
            .fold(0.0, f64::max)
    }

    /// Any hierarchical traffic recorded? (Always `false` under `g = 1`.)
    pub fn is_active(&self) -> bool {
        self.total_intra_msgs() + self.total_inter_msgs() > 0
    }

    fn merge(&mut self, other: &TierStats) {
        let k = self.intra_bits.len();
        assert_eq!(other.intra_bits.len(), k, "TierStats rank-count mismatch");
        for i in 0..k {
            self.intra_bits[i] += other.intra_bits[i];
            self.inter_bits[i] += other.inter_bits[i];
            self.intra_msgs[i] += other.intra_msgs[i];
            self.inter_msgs[i] += other.inter_msgs[i];
            self.modeled_intra_secs[i] += other.modeled_intra_secs[i];
            self.modeled_inter_secs[i] += other.modeled_inter_secs[i];
        }
    }
}

/// Remote-feature cache accounting for the mini-batch fetch (DESIGN.md
/// §16). Vectors are indexed by the *requesting* rank — the rank whose
/// cache produced the hit/miss — mirroring the sender-indexed
/// [`TierStats`] convention, so the threaded transport's per-rank shards
/// each populate one entry and the merge reproduces the sequential
/// totals bit-for-bit. All entries stay zero when the cache is disabled
/// (`--feature-cache-ttl 0`): the fetch never touches this struct.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    /// Probe hits (rows served from cache, skipping both fetch legs).
    pub hits: Vec<usize>,
    /// Probe misses (rows fetched over the wire as before).
    pub misses: Vec<usize>,
    /// Residents displaced by frequency-ranked admission.
    pub evictions: Vec<usize>,
    /// Wire bits the hits avoided (request-leg id + reply-leg row share;
    /// analytic for quantized replies).
    pub saved_bits: Vec<f64>,
}

impl CacheStats {
    pub fn new(k: usize) -> Self {
        Self {
            hits: vec![0; k],
            misses: vec![0; k],
            evictions: vec![0; k],
            saved_bits: vec![0.0; k],
        }
    }

    pub fn total_hits(&self) -> usize {
        self.hits.iter().sum()
    }

    pub fn total_misses(&self) -> usize {
        self.misses.iter().sum()
    }

    pub fn total_evictions(&self) -> usize {
        self.evictions.iter().sum()
    }

    pub fn total_saved_bytes(&self) -> f64 {
        self.saved_bits.iter().sum::<f64>() / 8.0
    }

    /// Hits over probes; `0.0` before any probe.
    pub fn hit_rate(&self) -> f64 {
        let probes = self.total_hits() + self.total_misses();
        if probes == 0 {
            0.0
        } else {
            self.total_hits() as f64 / probes as f64
        }
    }

    /// Any cache activity recorded? (Always `false` at TTL 0.)
    pub fn is_active(&self) -> bool {
        self.total_hits() + self.total_misses() > 0
    }

    /// Fold one rank's round counters under its requester index.
    pub fn charge(&mut self, from: usize, r: crate::exec::featcache::CacheRound) {
        self.hits[from] += r.hits;
        self.misses[from] += r.misses;
        self.evictions[from] += r.evictions;
        self.saved_bits[from] += r.saved_bits;
    }

    fn merge(&mut self, other: &CacheStats) {
        let k = self.hits.len();
        assert_eq!(other.hits.len(), k, "CacheStats rank-count mismatch");
        for i in 0..k {
            self.hits[i] += other.hits[i];
            self.misses[i] += other.misses[i];
            self.evictions[i] += other.evictions[i];
            self.saved_bits[i] += other.saved_bits[i];
        }
    }
}

/// Accumulated communication accounting for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Wire bits per (src, dst) pair, data payload.
    pub data_bits: Vec<Vec<f64>>,
    /// Wire bits per (src, dst) pair, quantization params.
    pub param_bits: Vec<Vec<f64>>,
    /// Number of messages per pair.
    pub messages: Vec<Vec<usize>>,
    /// Modeled wire seconds (Eqn 2/5), accumulated per *sender*.
    pub modeled_send_secs: Vec<f64>,
    /// Two-level physical-path accounting (populated only when the
    /// exchanges run over a hierarchical [`Topology`]; the *logical*
    /// fields above are charged identically either way — the bit-exactness
    /// contract of DESIGN.md §12).
    pub tiers: TierStats,
    /// Remote-feature cache accounting (populated only when the
    /// mini-batch fetch runs with `--feature-cache-ttl > 0`; the logical
    /// wire fields above then shrink by exactly the traffic the hits
    /// skipped — DESIGN.md §16).
    pub cache: CacheStats,
}

impl CommStats {
    pub fn new(k: usize) -> Self {
        Self {
            data_bits: vec![vec![0.0; k]; k],
            param_bits: vec![vec![0.0; k]; k],
            messages: vec![vec![0; k]; k],
            modeled_send_secs: vec![0.0; k],
            tiers: TierStats::new(k),
            cache: CacheStats::new(k),
        }
    }

    pub fn k(&self) -> usize {
        self.modeled_send_secs.len()
    }

    pub fn total_data_bytes(&self) -> f64 {
        self.data_bits.iter().flatten().sum::<f64>() / 8.0
    }

    pub fn total_param_bytes(&self) -> f64 {
        self.param_bits.iter().flatten().sum::<f64>() / 8.0
    }

    /// Eqn-2-style bottleneck time: slowest sender's accumulated wire time.
    pub fn modeled_comm_secs(&self) -> f64 {
        self.modeled_send_secs.iter().fold(0.0, |a, &b| a.max(b))
    }

    /// Fold another accounting matrix into this one (sequential epoch
    /// totals; merging per-rank shards of the threaded transport — each
    /// shard only ever populates its own sender row, so the merge of all
    /// k shards is bit-identical to the sequential accounting). Thin
    /// wrapper over the shared [`crate::obs::Mergeable`] contract
    /// (DESIGN.md §13).
    pub fn merge(&mut self, other: &CommStats) {
        use crate::obs::Mergeable;
        self.merge_from(other);
    }

    pub(crate) fn charge(&mut self, from: usize, to: usize, p: &Payload, profile: &MachineProfile) {
        let (db, pb) = p.wire_bits();
        if db + pb <= 0.0 {
            return;
        }
        self.data_bits[from][to] += db;
        self.param_bits[from][to] += pb;
        self.messages[from][to] += 1;
        self.modeled_send_secs[from] += (db + pb) / profile.bw_comm + profile.latency;
    }

    /// Charge one rank's send row against the two-level physical path of
    /// `topo` (no-op on the flat topology — the grouped accounting is
    /// *additional*; logical charges stay with [`CommStats::charge`]).
    /// Every entry lands in the sender's own index of [`TierStats`], so
    /// the charge is deterministic per (row, topology) and the threaded
    /// shards merge to exactly the sequential totals. See [`TierStats`]
    /// for the hop conventions.
    pub(crate) fn charge_row_tiers(
        &mut self,
        topo: &Topology,
        from: usize,
        sends: &[Payload],
        profile: &MachineProfile,
    ) {
        if !topo.is_hierarchical() {
            return;
        }
        let t = &mut self.tiers;
        let mut out_bits = 0.0f64;
        for (to, p) in sends.iter().enumerate() {
            let (db, pb) = p.wire_bits();
            let bits = db + pb;
            if bits <= 0.0 {
                continue;
            }
            if topo.same_group(from, to) {
                // Direct local delivery over the mailbox tier.
                t.intra_msgs[from] += 1;
                t.intra_bits[from] += bits;
                t.modeled_intra_secs[from] += bits / profile.bw_local + profile.latency_local;
            } else {
                // Rides the coalesced leader exchange across the inter
                // link (bandwidth term here; the per-group-pair latency is
                // the leader's, below)...
                t.inter_bits[from] += bits;
                t.modeled_inter_secs[from] += bits / profile.bw_comm;
                out_bits += bits;
                // ...then one intra delivery hop from the destination
                // group's leader, unless the destination is that leader.
                if to != topo.leader_of(topo.group_of(to)) {
                    t.intra_msgs[from] += 1;
                    t.intra_bits[from] += bits;
                    t.modeled_intra_secs[from] +=
                        bits / profile.bw_local + profile.latency_local;
                }
            }
        }
        // Coalesced member→leader staging hop for all cross-group bytes.
        if out_bits > 0.0 && !topo.is_leader(from) {
            t.intra_msgs[from] += 1;
            t.intra_bits[from] += out_bits;
            t.modeled_intra_secs[from] += out_bits / profile.bw_local + profile.latency_local;
        }
        // The leader posts the dense inter-group alltoallv for its whole
        // group every exchange: n_groups − 1 messages/latencies, payload
        // or not — summed over leaders, O((P/g)²) per exchange.
        if topo.is_leader(from) {
            let ng = topo.n_groups();
            t.inter_msgs[from] += ng - 1;
            t.modeled_inter_secs[from] += (ng - 1) as f64 * profile.latency;
        }
    }
}

impl crate::obs::Mergeable for CommStats {
    /// Element-wise additive fold (pair matrices, sender rows, tier
    /// entries) — the shard-merge semantics [`CommStats::merge`] always
    /// had, now under the shared DESIGN.md §13 contract.
    fn merge_from(&mut self, other: &Self) {
        let k = self.k();
        assert_eq!(other.k(), k, "CommStats rank-count mismatch");
        for i in 0..k {
            for j in 0..k {
                self.data_bits[i][j] += other.data_bits[i][j];
                self.param_bits[i][j] += other.param_bits[i][j];
                self.messages[i][j] += other.messages[i][j];
            }
            self.modeled_send_secs[i] += other.modeled_send_secs[i];
        }
        self.tiers.merge(&other.tiers);
        self.cache.merge(&other.cache);
    }
}

/// All-to-all personalized exchange: `sends[i][j]` is i's payload for j.
/// Returns `recvs` with `recvs[j][i]` = what j received from i, and charges
/// modeled wire time to `stats`.
pub fn alltoallv(
    sends: Vec<Vec<Payload>>,
    profile: &MachineProfile,
    stats: &mut CommStats,
) -> Vec<Vec<Payload>> {
    alltoallv_routed(sends, Topology::flat(stats.k()), profile, stats)
}

/// [`alltoallv`] over an explicit rank [`Topology`] (DESIGN.md §12):
/// payload routing and the logical `CommStats` charges are identical to
/// the flat exchange — bit-exact by construction — while a hierarchical
/// topology additionally charges [`TierStats`] with the two-level
/// physical path (leader staging, coalesced inter-group messages). The
/// sequential-transport counterpart of the grouped
/// [`transport::Fabric::post_alltoallv`].
pub fn alltoallv_routed(
    sends: Vec<Vec<Payload>>,
    topo: Topology,
    profile: &MachineProfile,
    stats: &mut CommStats,
) -> Vec<Vec<Payload>> {
    let k = sends.len();
    assert!(sends.iter().all(|row| row.len() == k), "square send matrix required");
    assert_eq!(topo.k(), k, "topology rank count must match the send matrix");
    let mut recvs: Vec<Vec<Payload>> = (0..k)
        .map(|_| (0..k).map(|_| Payload::Empty).collect())
        .collect();
    for (i, row) in sends.into_iter().enumerate() {
        stats.charge_row_tiers(&topo, i, &row, profile);
        for (j, p) in row.into_iter().enumerate() {
            stats.charge(i, j, &p, profile);
            recvs[j][i] = p;
        }
    }
    recvs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fused, Bits};
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn alltoallv_routes_correctly() {
        let p = MachineProfile::abci();
        let mut stats = CommStats::new(3);
        let sends: Vec<Vec<Payload>> = (0..3)
            .map(|i| {
                (0..3)
                    .map(|j| Payload::F32(vec![(i * 10 + j) as f32]))
                    .collect()
            })
            .collect();
        let recvs = alltoallv(sends, &p, &mut stats);
        for j in 0..3 {
            for i in 0..3 {
                match &recvs[j][i] {
                    Payload::F32(v) => assert_eq!(v[0], (i * 10 + j) as f32),
                    _ => panic!("wrong payload"),
                }
            }
        }
        assert_eq!(stats.messages.iter().flatten().sum::<usize>(), 9);
    }

    #[test]
    fn conservation_bytes_sent_equals_received() {
        propcheck(16, |gen| {
            let k = gen.usize(1, 5);
            let p = MachineProfile::fugaku();
            let mut stats = CommStats::new(k);
            let mut sent_total = 0usize;
            let sends: Vec<Vec<Payload>> = (0..k)
                .map(|_| {
                    (0..k)
                        .map(|_| {
                            let n = gen.usize(0, 50);
                            sent_total += n;
                            Payload::F32(gen.vec_f32(n, -1.0, 1.0))
                        })
                        .collect()
                })
                .collect();
            let recvs = alltoallv(sends, &p, &mut stats);
            let recv_total: usize = recvs
                .iter()
                .flatten()
                .map(|p| match p {
                    Payload::F32(v) => v.len(),
                    _ => 0,
                })
                .sum();
            prop_assert(recv_total == sent_total, "value conservation")?;
            prop_assert(
                (stats.total_data_bytes() - sent_total as f64 * 4.0).abs() < 1e-9,
                "byte accounting",
            )
        });
    }

    #[test]
    fn quant_payload_is_16x_cheaper_on_wire() {
        let p = MachineProfile::abci();
        let x = vec![0.5f32; 64 * 128];
        let mut s_fp = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::F32(x.clone())],
                vec![Payload::Empty, Payload::Empty],
            ],
            &p,
            &mut s_fp,
        );
        let q = fused::quantize(&x, 64, 128, Bits::Int2, 1);
        let mut s_q = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::Quant(q)],
                vec![Payload::Empty, Payload::Empty],
            ],
            &p,
            &mut s_q,
        );
        let ratio = s_fp.total_data_bytes() / (s_q.total_data_bytes() + s_q.total_param_bytes());
        assert!(ratio > 14.0 && ratio <= 16.0, "ratio {ratio}");
        assert!(s_q.modeled_comm_secs() < s_fp.modeled_comm_secs());
    }

    #[test]
    fn hierarchical_routing_is_bit_exact_and_charges_tiers() {
        // k=4, g=2: groups {0,1} / {2,3}, leaders 0 and 2. Every ordered
        // pair ships one f32 (32 bits); diagonal empty.
        let p = MachineProfile::abci();
        let k = 4;
        let mk_sends = || -> Vec<Vec<Payload>> {
            (0..k)
                .map(|i| {
                    (0..k)
                        .map(|j| {
                            if i == j {
                                Payload::Empty
                            } else {
                                Payload::F32(vec![(i * 10 + j) as f32])
                            }
                        })
                        .collect()
                })
                .collect()
        };
        let mut s_flat = CommStats::new(k);
        let flat_recvs = alltoallv(mk_sends(), &p, &mut s_flat);
        let mut s_hier = CommStats::new(k);
        let hier_recvs = alltoallv_routed(mk_sends(), Topology::new(k, 2), &p, &mut s_hier);

        // Routing and the logical accounting are topology-invariant.
        for i in 0..k {
            for j in 0..k {
                match (&flat_recvs[i][j], &hier_recvs[i][j]) {
                    (Payload::F32(a), Payload::F32(b)) => assert_eq!(a, b),
                    (Payload::Empty, Payload::Empty) => {}
                    (a, b) => panic!("payload mismatch: {a:?} vs {b:?}"),
                }
            }
        }
        assert_eq!(s_flat.data_bits, s_hier.data_bits);
        assert_eq!(s_flat.messages, s_hier.messages);
        assert_eq!(s_flat.modeled_send_secs, s_hier.modeled_send_secs);

        // Flat records no tier traffic; the grouped run records exactly
        // the leader-staged path (see TierStats conventions).
        assert!(!s_flat.tiers.is_active());
        let t = &s_hier.tiers;
        // One coalesced inter message per ordered group pair: 2·1 = 2 —
        // the O((P/g)²) count, < the 12 flat pair messages.
        assert_eq!(t.total_inter_msgs(), 2);
        assert!(t.total_inter_msgs() < s_flat.messages.iter().flatten().sum::<usize>());
        // Inter payload = the 8 cross-group payloads (32 bits each).
        assert_eq!(t.total_inter_bits(), 8.0 * 32.0);
        // Per leader (0, 2): 1 same-group delivery + 1 delivery hop to the
        // non-leader dst = 2 intra msgs, 64 bits. Per non-leader (1, 3):
        // those two plus the coalesced 64-bit staging hop = 3 msgs, 128
        // bits.
        assert_eq!(t.intra_msgs, vec![2, 3, 2, 3]);
        assert_eq!(t.intra_bits, vec![64.0, 128.0, 64.0, 128.0]);
        assert_eq!(t.total_intra_msgs(), 10);
        assert_eq!(t.total_intra_bits(), 384.0);
        assert!(t.modeled_two_tier_secs() > 0.0);
    }

    #[test]
    fn tier_charges_match_the_perfmodel_closed_form() {
        // `charge_row_tiers` (per-exchange accounting) and
        // `perfmodel::t_comm_two_tier` (the Eqn-2-style closed form over a
        // volume matrix) implement the same four hop conventions — pin
        // them against each other on grouped exchanges, ragged groups
        // included, so the two implementations cannot silently drift.
        let p = MachineProfile::fugaku();
        for (k, g) in [(4usize, 2usize), (5, 2), (6, 3)] {
            let volume: Vec<Vec<usize>> = (0..k)
                .map(|i| {
                    (0..k)
                        .map(|j| if i == j { 0 } else { (i * k + j) % 7 * 5 })
                        .collect()
                })
                .collect();
            let sends: Vec<Vec<Payload>> = volume
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&v| {
                            if v == 0 {
                                Payload::Empty
                            } else {
                                Payload::F32(vec![0.25; v])
                            }
                        })
                        .collect()
                })
                .collect();
            let mut stats = CommStats::new(k);
            alltoallv_routed(sends, Topology::new(k, g), &p, &mut stats);
            let want = crate::perfmodel::t_comm_two_tier(&volume, g, &p);
            let got = stats.tiers.modeled_two_tier_secs();
            assert!(want > 0.0, "k={k} g={g}: vacuous volume matrix");
            assert!(
                (got - want).abs() <= want * 1e-9,
                "k={k} g={g}: TierStats {got} vs closed form {want}"
            );
        }
    }

    #[test]
    fn single_group_topology_keeps_everything_intra() {
        let p = MachineProfile::fugaku();
        let k = 3;
        let sends: Vec<Vec<Payload>> = (0..k)
            .map(|i| {
                (0..k)
                    .map(|j| {
                        if i == j {
                            Payload::Empty
                        } else {
                            Payload::F32(vec![1.0; 2])
                        }
                    })
                    .collect()
            })
            .collect();
        // g = k ⇒ one group: hierarchical but with no inter tier at all.
        let mut stats = CommStats::new(k);
        alltoallv_routed(sends, Topology::new(k, k), &p, &mut stats);
        let t = &stats.tiers;
        assert_eq!(t.total_inter_msgs(), 0);
        assert_eq!(t.total_inter_bits(), 0.0);
        assert_eq!(t.total_intra_msgs(), 6);
        assert_eq!(t.total_intra_bits(), 6.0 * 64.0);
    }

    #[test]
    fn empty_payloads_charge_nothing() {
        let p = MachineProfile::abci();
        let mut stats = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::Empty],
                vec![Payload::Empty, Payload::F32(vec![])],
            ],
            &p,
            &mut stats,
        );
        assert_eq!(stats.modeled_comm_secs(), 0.0);
        assert_eq!(stats.messages.iter().flatten().sum::<usize>(), 0);
    }
}
