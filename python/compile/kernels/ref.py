"""Pure-jnp oracles for every Pallas kernel (correctness references).

The pytest suite asserts the Pallas kernels (interpret=True) match these
references across shapes, dtypes and edge distributions (Hypothesis), and
the AOT'd L2 model is built on the kernels, so agreement here is what makes
the Rust-side artifacts trustworthy.
"""

import jax.numpy as jnp


def segment_sum_ref(h, gather, seg, n_seg):
    """out[seg[i]] += h[gather[i]] — the aggregation operator of paper §4.

    h: [n, f]; gather, seg: [e] int32; returns [n_seg, f].
    """
    rows = h[gather]
    out = jnp.zeros((n_seg, h.shape[1]), dtype=h.dtype)
    return out.at[seg].add(rows)


def layernorm_ref(x, eps=1e-5):
    """Row-wise LayerNorm without affine params (paper §6.1(2): outlier
    removal before quantization)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps)


def quantize_ref(x, noise, bits):
    """Stochastic integer quantization (paper §2.4) over 4-row groups.

    x: [rows, cols] with rows % 4 == 0; noise: same shape, U[0,1).
    Returns (codes int32 [rows, cols], zero [rows//4], scale [rows//4]).
    """
    rows, cols = x.shape
    assert rows % 4 == 0
    g = x.reshape(rows // 4, 4 * cols)
    mn = jnp.min(g, axis=1)
    mx = jnp.max(g, axis=1)
    max_code = (1 << bits) - 1
    scale = (mx - mn) / max_code
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    t = (g - mn[:, None]) * inv[:, None] + noise.reshape(rows // 4, 4 * cols)
    codes = jnp.clip(jnp.floor(t), 0, max_code).astype(jnp.int32)
    return codes.reshape(rows, cols), mn, scale


def dequantize_ref(codes, zero, scale):
    """codes: [rows, cols] int32 grouped by 4 rows; zero/scale: [rows//4]."""
    rows, cols = codes.shape
    g = codes.reshape(rows // 4, 4 * cols).astype(jnp.float32)
    out = g * scale[:, None] + zero[:, None]
    return out.reshape(rows, cols)
