//! Telemetry acceptance tests (DESIGN.md §13): the observability
//! subsystem must be strictly read-only and strictly opt-in.
//!
//! 1. **Overhead guard** — with telemetry off (the no-`--trace` path),
//!    a 2-epoch `arxiv-xs` run is bit-identical — per-epoch loss bits
//!    and `CommStats` wire bits — to a run where the tracer was never
//!    constructed; and attaching the tracer + registry must *still* be
//!    bit-identical, because spans and metrics only read state.
//! 2. **Trace export** — the emitted Chrome/Perfetto JSON parses, every
//!    event carries `ph`/`ts`/`pid`/`tid`/`cat`, `ts` is monotone per
//!    `(pid, tid)`, complete spans nest properly per lane, every rank
//!    thread contributes spans, and a panicking rank still flushes a
//!    valid (truncated) trace.
//! 3. **Metrics report** — one sealed epoch record per training epoch,
//!    run totals consistent with the trainer's own `CommStats`, and a
//!    parseable `supergcn.metrics.v1` document.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use supergcn::comm::transport::TransportKind;
use supergcn::comm::CommStats;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::prepare;
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::datasets;
use supergcn::obs::{span, Metric, MetricsRegistry, Telemetry, TraceCategory, Tracer};
use supergcn::quant::Bits;
use supergcn::sample::{SamplerConfig, SamplerKind};
use supergcn::util::json::{to_pretty, Json};

fn assert_loss_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: epoch counts diverged");
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: epoch {e} loss diverged: {x} vs {y}"
        );
    }
}

fn assert_comm_equal(a: &CommStats, b: &CommStats, what: &str) {
    assert_eq!(a.data_bits, b.data_bits, "{what}: data bits diverged");
    assert_eq!(a.param_bits, b.param_bits, "{what}: param bits diverged");
    assert_eq!(a.messages, b.messages, "{what}: message counts diverged");
    assert_eq!(
        a.modeled_send_secs, b.modeled_send_secs,
        "{what}: modeled wire seconds diverged"
    );
    assert!(a.total_data_bytes() > 0.0, "{what}: no traffic — vacuous test");
}

/// A 2-epoch `arxiv-xs` full-batch run (int4 + overlap, so the quant
/// pack/unpack and split-phase spans are all on the path), with the
/// given telemetry attached.
fn full_batch(transport: TransportKind, telemetry: Telemetry) -> (Vec<f32>, CommStats) {
    let spec = datasets::by_name("arxiv-xs").unwrap();
    let lg = spec.build();
    let tc = TrainConfig {
        epochs: 2,
        lr: spec.lr,
        quant: Some(Bits::Int4),
        transport,
        overlap: true,
        seed: 42,
        ..Default::default()
    };
    let (ctxs, mut cfg, _) = prepare(&lg, 4, tc.strategy, None, tc.seed).unwrap();
    cfg.hidden = spec.hidden;
    let mut tr = Trainer::new(ctxs, cfg, tc);
    tr.telemetry = telemetry;
    let losses = tr
        .run(false)
        .unwrap()
        .iter()
        .map(|s| s.train_loss)
        .collect();
    (losses, tr.comm_stats.clone())
}

/// A 2-epoch `arxiv-xs` neighbor-sampled mini-batch run with the given
/// telemetry attached (covers the fetch request/reply spans).
fn mini_batch(transport: TransportKind, telemetry: Telemetry) -> (Vec<f32>, CommStats) {
    let spec = datasets::by_name("arxiv-xs").unwrap();
    let lg = Arc::new(spec.build());
    let mc = MiniBatchConfig {
        epochs: 2,
        lr: spec.lr,
        hidden: spec.hidden,
        quant: Some(Bits::Int4),
        transport,
        seed: 42,
        ..Default::default()
    };
    let scfg = SamplerConfig {
        batch_size: 128,
        fanouts: vec![10, 5, 5],
        seed: 42,
        ..Default::default()
    };
    let mut tr = MiniBatchTrainer::new(lg, 3, SamplerKind::Neighbor, &scfg, mc).unwrap();
    tr.telemetry = telemetry;
    let losses = tr
        .run(false)
        .unwrap()
        .iter()
        .map(|s| s.train_loss)
        .collect();
    (losses, tr.comm_stats.clone())
}

#[test]
fn full_batch_telemetry_off_and_on_are_bit_identical() {
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        // (a) Tracer never constructed: the trainer keeps its default
        //     (both sinks None) — the exact no-CLI-flags build.
        let (base_loss, base_comm) = full_batch(transport, Telemetry::default());
        // (b) Both sinks attached: spans + metrics are read-only, so the
        //     numerics must not move by a single bit.
        let tracer = Tracer::new();
        let metrics = MetricsRegistry::new();
        let on = Telemetry {
            tracer: Some(tracer.clone()),
            metrics: Some(metrics.clone()),
        };
        let (on_loss, on_comm) = full_batch(transport, on);
        let what = format!("full-batch telemetry {}", transport.name());
        assert_loss_bits(&base_loss, &on_loss, &what);
        assert_comm_equal(&base_comm, &on_comm, &what);
        assert!(tracer.span_count() > 0, "{what}: enabled run recorded no spans");
        assert_eq!(metrics.epoch_count(), 2, "{what}: epoch records");
    }
}

#[test]
fn mini_batch_telemetry_off_and_on_are_bit_identical() {
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        let (base_loss, base_comm) = mini_batch(transport, Telemetry::default());
        let tracer = Tracer::new();
        let on = Telemetry {
            tracer: Some(tracer.clone()),
            metrics: None,
        };
        let (on_loss, on_comm) = mini_batch(transport, on);
        let what = format!("mini-batch telemetry {}", transport.name());
        assert_loss_bits(&base_loss, &on_loss, &what);
        assert_comm_equal(&base_comm, &on_comm, &what);
        assert!(tracer.span_count() > 0, "{what}: enabled run recorded no spans");
    }
}

#[test]
fn threaded_trace_covers_every_rank_with_properly_nested_spans() {
    let tracer = Tracer::new();
    let telemetry = Telemetry {
        tracer: Some(tracer.clone()),
        metrics: None,
    };
    let _ = full_batch(TransportKind::Threaded, telemetry);
    assert!(tracer.span_count() > 0);
    assert_eq!(tracer.dropped_count(), 0, "a 2-epoch run must fit the ring");

    let doc = tracer.to_chrome_json();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());

    // Interval containment slack for f64 µs round-off; real spans are
    // strictly RAII-nested per thread.
    const EPS_US: f64 = 1e-3;
    let mut pids: BTreeSet<usize> = BTreeSet::new();
    let mut last_ts: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    // Per-lane stack of enclosing span end times.
    let mut stacks: BTreeMap<(usize, usize), Vec<f64>> = BTreeMap::new();
    for e in events {
        for key in ["ph", "ts", "pid", "tid", "cat", "name"] {
            assert!(e.get(key).is_some(), "event missing `{key}`: {e:?}");
        }
        let pid = e.get("pid").unwrap().as_usize().unwrap();
        let tid = e.get("tid").unwrap().as_usize().unwrap();
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        pids.insert(pid);
        let lane = (pid, tid);
        if let Some(prev) = last_ts.get(&lane) {
            assert!(ts >= *prev, "ts not monotone on lane {lane:?}");
        }
        last_ts.insert(lane, ts);
        if e.get("ph").unwrap().as_str() == Some("X") {
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            assert!(dur >= 0.0);
            let stack = stacks.entry(lane).or_default();
            // Pop parents that ended before this span started...
            while let Some(&end) = stack.last() {
                if ts >= end - EPS_US {
                    stack.pop();
                } else {
                    break;
                }
            }
            // ...then this span must fit inside the surviving parent.
            if let Some(&end) = stack.last() {
                assert!(
                    ts + dur <= end + EPS_US,
                    "span [{ts}, {}] on lane {lane:?} escapes its parent (ends {end})",
                    ts + dur
                );
            }
            stack.push(ts + dur);
        }
    }
    for rank in 0..4 {
        assert!(pids.contains(&rank), "no spans flushed from rank {rank}");
    }
}

#[test]
fn trace_write_roundtrips_as_valid_chrome_json() {
    let tracer = Tracer::new();
    {
        let _scope = tracer.lane_scope(0, 0);
        let _sp = span(TraceCategory::Phase, "roundtrip");
    }
    let mut p = std::env::temp_dir();
    p.push(format!("supergcn-obs-roundtrip-{}.json", std::process::id()));
    let path = p.to_string_lossy().into_owned();
    tracer.write(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).expect("trace file must be valid JSON");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 1);
    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let _ = std::fs::remove_file(&p);
}

#[test]
fn panicking_rank_thread_still_flushes_a_valid_truncated_trace() {
    let tracer = Tracer::new();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        std::thread::scope(|scope| {
            for rank in 0..2 {
                let t = tracer.clone();
                scope.spawn(move || {
                    let _scope = t.lane_scope(rank, 0);
                    let _sp = span(TraceCategory::Agg, "work");
                    if rank == 1 {
                        panic!("injected rank failure");
                    }
                });
            }
        });
    }));
    assert!(result.is_err(), "rank 1 must have panicked");
    // Both lanes flush — the healthy one on normal drop, the unwound one
    // via LaneScope's Drop during the panic.
    assert!(
        tracer.span_count() >= 2,
        "unwound lane lost its spans: {}",
        tracer.span_count()
    );
    let text = to_pretty(&tracer.to_chrome_json());
    let parsed = Json::parse(&text).expect("post-panic trace must still parse");
    for e in parsed.get("traceEvents").unwrap().as_arr().unwrap() {
        for key in ["ph", "ts", "pid", "tid", "cat"] {
            assert!(e.get(key).is_some(), "event missing `{key}`");
        }
    }
}

#[test]
fn metrics_registry_reports_epochs_totals_and_exchanges() {
    let metrics = MetricsRegistry::new();
    let telemetry = Telemetry {
        tracer: None,
        metrics: Some(metrics.clone()),
    };
    let (losses, comm) = full_batch(TransportKind::Threaded, telemetry);
    assert_eq!(metrics.epoch_count(), losses.len());

    // Run-total counter vs the trainer's own accounting: same data, two
    // summation orders, so compare with a relative tolerance.
    let total = comm.total_data_bytes();
    match metrics.total("comm.data.bytes") {
        Some(Metric::Counter(v)) => {
            assert!(v > 0.0);
            assert!(
                (v - total).abs() <= 1e-6 * total.max(1.0),
                "registry {v} vs CommStats {total}"
            );
        }
        other => panic!("comm.data.bytes missing or mistyped: {other:?}"),
    }
    match metrics.total("train.loss.nats") {
        Some(Metric::Gauge(v)) => assert!(v.is_finite()),
        other => panic!("train.loss.nats missing or mistyped: {other:?}"),
    }

    let text = to_pretty(&metrics.to_json());
    let doc = Json::parse(&text).expect("metrics report must be valid JSON");
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("supergcn.metrics.v1"));
    let epochs = doc.get("epochs").unwrap().as_arr().unwrap();
    assert_eq!(epochs.len(), losses.len());
    for e in epochs {
        assert!(e.get("metrics").unwrap().as_obj().is_some());
        // Overlap was on, so every epoch carries modeled-vs-measured
        // exchange rows.
        let ex = e.get("exchanges").unwrap().as_arr().unwrap();
        assert!(!ex.is_empty(), "epoch without exchange rows");
        for row in ex {
            let i = row.get("interior_secs").unwrap().as_f64().unwrap();
            let c = row.get("comm_secs").unwrap().as_f64().unwrap();
            let b = row.get("boundary_secs").unwrap().as_f64().unwrap();
            let ov = row.get("modeled_overlap_secs").unwrap().as_f64().unwrap();
            let se = row.get("modeled_serial_secs").unwrap().as_f64().unwrap();
            assert!(ov <= se + 1e-12, "overlap model exceeds serial model");
            assert!(se <= i + c + b + 1e-9, "serial model inconsistent");
        }
    }
    assert!(doc.get("totals").unwrap().as_obj().is_some());
}
