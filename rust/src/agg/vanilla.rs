//! Vanilla scatter-style aggregation — the PyG-equivalent baseline of
//! Fig. 3(a)/Fig. 8 ("Base"): iterate contributions in given order, add
//! each source row into its destination row. No sorting, no clustering, no
//! destination reuse — the destination row is re-loaded from memory for
//! every contribution.

/// `out[seg[i]] += h[gather[i]]` for all i, any `seg` order.
pub fn segment_sum(h: &[f32], f: usize, gather: &[u32], seg: &[u32], out: &mut [f32]) {
    assert_eq!(gather.len(), seg.len());
    for (&g, &s) in gather.iter().zip(seg.iter()) {
        let src = &h[g as usize * f..(g as usize + 1) * f];
        let dst = &mut out[s as usize * f..(s as usize + 1) * f];
        for (d, &x) in dst.iter_mut().zip(src.iter()) {
            *d += x;
        }
    }
}

/// Vanilla `index_add`: rows of `src` (m × f) are added into `dst` (n × f)
/// at positions `idx` (unordered) — the operator of Fig. 3(a) verbatim.
pub fn index_add(dst: &mut [f32], f: usize, src: &[f32], idx: &[u32]) {
    assert_eq!(src.len(), idx.len() * f);
    for (i, &d) in idx.iter().enumerate() {
        let s = &src[i * f..(i + 1) * f];
        let o = &mut dst[d as usize * f..(d as usize + 1) * f];
        for (a, &b) in o.iter_mut().zip(s.iter()) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_sum_known() {
        // h rows: [1,10], [2,20], [3,30]
        let h = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0];
        let gather = vec![0, 2, 1];
        let seg = vec![1, 1, 0];
        let mut out = vec![0.0; 4];
        segment_sum(&h, 2, &gather, &seg, &mut out);
        assert_eq!(out, vec![2.0, 20.0, 4.0, 40.0]);
    }

    #[test]
    fn index_add_known() {
        let mut dst = vec![0.0; 4];
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        index_add(&mut dst, 2, &src, &[1, 0, 1]);
        assert_eq!(dst, vec![3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn accumulates_into_existing() {
        let h = vec![1.0];
        let mut out = vec![5.0];
        segment_sum(&h, 1, &[0], &[0], &mut out);
        assert_eq!(out, vec![6.0]);
    }

    #[test]
    fn empty_is_noop() {
        let mut out = vec![1.0, 2.0];
        segment_sum(&[], 2, &[], &[], &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }
}
