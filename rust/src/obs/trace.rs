//! Low-overhead per-rank span tracing with Chrome/Perfetto
//! `trace_event` export (DESIGN.md §13).
//!
//! The tracer is strictly opt-in: spans are emitted through the free
//! functions [`span`]/[`instant`], which consult a thread-local slot
//! installed by [`Tracer::lane_scope`]. When no scope is installed (the
//! default — no `--trace` flag), both functions return immediately
//! without allocating, so instrumented hot paths cost one thread-local
//! read when tracing is off. Numerics are never touched either way —
//! the disabled-mode bit-exactness is pinned by
//! `tests/obs_telemetry.rs` and `tests/spmd_parity.rs`.
//!
//! Each `(rank, lane)` scope buffers its spans in a fixed-capacity ring
//! (oldest spans are dropped on overflow, never the newest) and flushes
//! into the shared [`Tracer`] sink when the scope drops — including
//! drops during a panic unwind, which is how poison/panic paths still
//! produce a valid (truncated) trace.

use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Static span taxonomy — one variant per instrumented subsystem phase
/// (DESIGN.md §13). Categories are `&'static str`-backed so emitting a
/// span never allocates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceCategory {
    /// Aggregation kernels (local/interior/boundary sweeps).
    Agg,
    /// Quantization pack (encode before the wire).
    QuantPack,
    /// Dequantization unpack (decode after the wire).
    QuantUnpack,
    /// Split-phase halo exchange: the non-blocking post half.
    HaloPost,
    /// Split-phase halo exchange: the blocking complete half.
    HaloComplete,
    /// Barrier waits inside the mailbox fabric (load-imbalance time).
    Barrier,
    /// Whole-fabric collectives (ring allreduce, allgather).
    Collective,
    /// Optimizer steps.
    OptStep,
    /// Mini-batch remote-row fetch legs (request/reply).
    Fetch,
    /// Coarse engine phases (forward/backward/loss stages).
    Phase,
    /// Fault-tolerance events: chaos kills, elastic re-plans, resumes
    /// (DESIGN.md §15).
    Recovery,
}

pub const ALL_TRACE_CATEGORIES: [TraceCategory; 11] = [
    TraceCategory::Agg,
    TraceCategory::QuantPack,
    TraceCategory::QuantUnpack,
    TraceCategory::HaloPost,
    TraceCategory::HaloComplete,
    TraceCategory::Barrier,
    TraceCategory::Collective,
    TraceCategory::OptStep,
    TraceCategory::Fetch,
    TraceCategory::Phase,
    TraceCategory::Recovery,
];

impl TraceCategory {
    pub fn name(&self) -> &'static str {
        match self {
            TraceCategory::Agg => "agg",
            TraceCategory::QuantPack => "quant_pack",
            TraceCategory::QuantUnpack => "quant_unpack",
            TraceCategory::HaloPost => "halo_post",
            TraceCategory::HaloComplete => "halo_complete",
            TraceCategory::Barrier => "barrier",
            TraceCategory::Collective => "collective",
            TraceCategory::OptStep => "opt_step",
            TraceCategory::Fetch => "fetch",
            TraceCategory::Phase => "phase",
            TraceCategory::Recovery => "recovery",
        }
    }
}

/// One recorded event: a complete span (`dur_us = Some`) or an instant
/// (`dur_us = None`). Timestamps are µs since the tracer's creation.
#[derive(Clone, Copy, Debug)]
struct SpanRec {
    cat: TraceCategory,
    name: &'static str,
    ts_us: f64,
    dur_us: Option<f64>,
}

/// The flushed span log of one `(rank, lane)` scope.
struct LaneLog {
    rank: usize,
    lane: usize,
    spans: Vec<SpanRec>,
    /// Ring-overflow count (oldest spans evicted).
    dropped: usize,
}

struct TraceInner {
    epoch: Instant,
    /// Per-scope ring capacity.
    cap: usize,
    lanes: Mutex<Vec<LaneLog>>,
}

/// Poison-tolerant lock: a scope flushing during a panic unwind must
/// never double-panic, and flushed span data is append-only anyway.
fn lock_lanes(inner: &TraceInner) -> std::sync::MutexGuard<'_, Vec<LaneLog>> {
    inner.lanes.lock().unwrap_or_else(|e| e.into_inner())
}

/// The per-run span sink. Cheap to clone (one `Arc`); hand clones to
/// every rank thread and call [`Tracer::lane_scope`] there.
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TraceInner>,
}

/// Default per-scope ring capacity: enough for every span of a bench
/// epoch at 8 ranks while bounding a runaway loop's memory.
const DEFAULT_CAP: usize = 1 << 16;

struct Active {
    inner: Arc<TraceInner>,
    rank: usize,
    lane: usize,
    epoch: Instant,
    buf: Vec<SpanRec>,
    /// Ring write index once `buf` is full.
    next: usize,
    dropped: usize,
}

thread_local! {
    static ACTIVE: RefCell<Option<Active>> = const { RefCell::new(None) };
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CAP)
    }

    /// A tracer whose scopes keep at most `cap` spans each (ring
    /// semantics: newest always survive).
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            inner: Arc::new(TraceInner {
                epoch: Instant::now(),
                cap: cap.max(1),
                lanes: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Install this thread's span destination as `(rank, lane)` until
    /// the returned scope drops (which flushes the buffered spans into
    /// the tracer — also on panic unwind). Scopes nest: an inner scope
    /// stashes and restores the outer one.
    pub fn lane_scope(&self, rank: usize, lane: usize) -> LaneScope {
        let prev = ACTIVE.with(|a| {
            a.borrow_mut().replace(Active {
                inner: self.inner.clone(),
                rank,
                lane,
                epoch: self.inner.epoch,
                buf: Vec::new(),
                next: 0,
                dropped: 0,
            })
        });
        LaneScope { prev: Some(prev) }
    }

    /// Total spans + instants flushed so far.
    pub fn span_count(&self) -> usize {
        lock_lanes(&self.inner).iter().map(|l| l.spans.len()).sum()
    }

    /// Spans evicted by ring overflow across all flushed scopes.
    pub fn dropped_count(&self) -> usize {
        lock_lanes(&self.inner).iter().map(|l| l.dropped).sum()
    }

    /// Render every flushed scope as Chrome/Perfetto `trace_event` JSON:
    /// `pid` = rank, `tid` = lane, complete (`ph:"X"`) spans plus
    /// thread-scoped (`ph:"i"`) instants, sorted so `ts` is monotone per
    /// tid (parents sort before equal-timestamp children via the longer
    /// duration).
    pub fn to_chrome_json(&self) -> Json {
        let lanes = lock_lanes(&self.inner);
        let mut recs: Vec<(usize, usize, SpanRec)> = Vec::new();
        for l in lanes.iter() {
            for r in &l.spans {
                recs.push((l.rank, l.lane, *r));
            }
        }
        drop(lanes);
        recs.sort_by(|a, b| {
            (a.0, a.1)
                .cmp(&(b.0, b.1))
                .then(a.2.ts_us.total_cmp(&b.2.ts_us))
                .then(b.2.dur_us.unwrap_or(0.0).total_cmp(&a.2.dur_us.unwrap_or(0.0)))
        });
        let events: Vec<Json> = recs
            .into_iter()
            .map(|(rank, lane, r)| {
                let mut pairs = vec![
                    ("name", Json::Str(r.name.to_string())),
                    ("cat", Json::Str(r.cat.name().to_string())),
                    ("ts", Json::Num(r.ts_us)),
                    ("pid", Json::Num(rank as f64)),
                    ("tid", Json::Num(lane as f64)),
                ];
                match r.dur_us {
                    Some(d) => {
                        pairs.push(("ph", Json::Str("X".to_string())));
                        pairs.push(("dur", Json::Num(d)));
                    }
                    None => {
                        pairs.push(("ph", Json::Str("i".to_string())));
                        pairs.push(("s", Json::Str("t".to_string())));
                    }
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }

    /// Write the Chrome JSON to `path` (load via `chrome://tracing` or
    /// <https://ui.perfetto.dev>).
    pub fn write(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, crate::util::json::to_pretty(&self.to_chrome_json()))
            .map_err(|e| anyhow::anyhow!("cannot write trace {path}: {e}"))
    }
}

/// RAII guard installing a thread's `(rank, lane)` span destination;
/// flushes on drop (see [`Tracer::lane_scope`]).
pub struct LaneScope {
    prev: Option<Option<Active>>,
}

impl Drop for LaneScope {
    fn drop(&mut self) {
        let prev = self.prev.take().unwrap_or(None);
        let cur = ACTIVE.with(|a| a.borrow_mut().take());
        if let Some(mut act) = cur {
            // Restore ring order: the write index points at the oldest
            // surviving span once the ring has wrapped.
            if act.dropped > 0 {
                act.buf.rotate_left(act.next);
            }
            lock_lanes(&act.inner).push(LaneLog {
                rank: act.rank,
                lane: act.lane,
                spans: act.buf,
                dropped: act.dropped,
            });
        }
        ACTIVE.with(|a| *a.borrow_mut() = prev);
    }
}

/// Append to the active scope's ring (oldest evicted on overflow).
fn ring_push(act: &mut Active, rec: SpanRec) {
    if act.buf.len() < act.inner.cap {
        act.buf.push(rec);
    } else {
        act.buf[act.next] = rec;
        act.next = (act.next + 1) % act.buf.len();
        act.dropped += 1;
    }
}

/// Open a span; the returned guard records a complete event on drop
/// (including drops during panic unwind). Returns `None` — without
/// allocating or reading the clock — when the thread has no installed
/// lane scope, i.e. tracing is off.
#[must_use = "the span measures until the guard drops"]
pub fn span(cat: TraceCategory, name: &'static str) -> Option<SpanGuard> {
    let enabled = ACTIVE.with(|a| a.borrow().is_some());
    if !enabled {
        return None;
    }
    Some(SpanGuard {
        cat,
        name,
        start: Instant::now(),
    })
}

/// Record a zero-duration instant event (poison notices, one-shot
/// markers). No-op without an installed lane scope.
pub fn instant(cat: TraceCategory, name: &'static str) {
    ACTIVE.with(|a| {
        let mut b = a.borrow_mut();
        if let Some(act) = b.as_mut() {
            let ts_us = act.epoch.elapsed().as_secs_f64() * 1e6;
            ring_push(act, SpanRec { cat, name, ts_us, dur_us: None });
        }
    });
}

/// Open-span RAII guard returned by [`span`].
pub struct SpanGuard {
    cat: TraceCategory,
    name: &'static str,
    start: Instant,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur = self.start.elapsed().as_secs_f64() * 1e6;
        ACTIVE.with(|a| {
            let mut b = a.borrow_mut();
            if let Some(act) = b.as_mut() {
                let ts_us = self.start.duration_since(act.epoch).as_secs_f64() * 1e6;
                let rec = SpanRec {
                    cat: self.cat,
                    name: self.name,
                    ts_us,
                    dur_us: Some(dur),
                };
                ring_push(act, rec);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::to_pretty;

    #[test]
    fn disabled_thread_records_nothing() {
        assert!(span(TraceCategory::Agg, "noop").is_none());
        instant(TraceCategory::Barrier, "noop");
        // No tracer exists, so nothing observable happened; the calls
        // above must simply not panic.
    }

    #[test]
    fn spans_flush_on_scope_drop_with_rank_lane_identity() {
        let t = Tracer::new();
        {
            let _scope = t.lane_scope(3, 1);
            {
                let _outer = span(TraceCategory::Phase, "outer");
                let _inner = span(TraceCategory::Agg, "inner");
            }
            instant(TraceCategory::Barrier, "mark");
            assert_eq!(t.span_count(), 0, "spans buffer until the scope drops");
        }
        assert_eq!(t.span_count(), 3);
        let j = t.to_chrome_json();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        for e in events {
            assert_eq!(e.get("pid").unwrap().as_usize().unwrap(), 3);
            assert_eq!(e.get("tid").unwrap().as_usize().unwrap(), 1);
            assert!(e.get("ph").is_some() && e.get("ts").is_some() && e.get("cat").is_some());
        }
    }

    #[test]
    fn ring_keeps_newest_spans_in_order() {
        let t = Tracer::with_capacity(4);
        {
            let _scope = t.lane_scope(0, 0);
            for _ in 0..10 {
                let _s = span(TraceCategory::Agg, "tick");
            }
        }
        assert_eq!(t.span_count(), 4);
        assert_eq!(t.dropped_count(), 6);
        let j = t.to_chrome_json();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        let ts: Vec<f64> = events.iter().map(|e| e.get("ts").unwrap().as_f64().unwrap()).collect();
        for w in ts.windows(2) {
            assert!(w[0] <= w[1], "ring flush must stay time-ordered");
        }
    }

    #[test]
    fn nested_scopes_restore_the_outer_destination() {
        let t = Tracer::new();
        let u = Tracer::new();
        {
            let _outer = t.lane_scope(0, 0);
            {
                let _inner = u.lane_scope(1, 0);
                let _s = span(TraceCategory::Agg, "inner");
            }
            let _s = span(TraceCategory::Agg, "outer");
        }
        assert_eq!(t.span_count(), 1);
        assert_eq!(u.span_count(), 1);
    }

    #[test]
    fn export_parses_and_ts_is_monotone_per_tid() {
        let t = Tracer::new();
        for rank in 0..2 {
            let _scope = t.lane_scope(rank, 0);
            for _ in 0..5 {
                let _s = span(TraceCategory::Collective, "step");
            }
        }
        let text = to_pretty(&t.to_chrome_json());
        let parsed = Json::parse(&text).expect("trace must be valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 10);
        let mut last: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for e in events {
            let key = (
                e.get("pid").unwrap().as_usize().unwrap(),
                e.get("tid").unwrap().as_usize().unwrap(),
            );
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            if let Some(prev) = last.get(&key) {
                assert!(ts >= *prev, "ts must be monotone per (pid, tid)");
            }
            last.insert(key, ts);
        }
    }

    #[test]
    fn unwinding_scope_still_flushes() {
        let t = Tracer::new();
        let t2 = t.clone();
        let r = std::panic::catch_unwind(move || {
            let _scope = t2.lane_scope(0, 0);
            let _s = span(TraceCategory::Barrier, "doomed");
            panic!("die mid-span");
        });
        assert!(r.is_err());
        assert_eq!(t.span_count(), 1, "unwind must flush the truncated log");
        assert!(Json::parse(&to_pretty(&t.to_chrome_json())).is_ok());
    }
}
