//! Regime-equivalence acceptance tests for the unified execution engine
//! (DESIGN.md §9):
//!
//! 1. **distributed == single-machine** — the full-batch trainer's exact
//!    reverse halos make the k-worker gradient equal the 1-worker
//!    gradient, so loss curves match to f32 round-off;
//! 2. **full-sampler mini-batch == full-batch** — running the mini-batch
//!    trainer with the degenerate `full` sampler and the engine's
//!    LayerNorm architecture reproduces the full-batch trainer's
//!    per-epoch losses on `arxiv-xs`;
//! 3. **exact backward** — the shared finite-difference helper
//!    (`util::propcheck::grad_check`) pins the engine's backward in the
//!    full-batch regime (the mini-batch twin lives in
//!    `exec::minibatch`'s unit tests).

use std::sync::Arc;
use supergcn::comm::CommStats;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::prepare;
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::datasets;
use supergcn::exec::{
    AggDispatch, Engine, FullBatchCtx, FullBatchState, LossSpec, StageClock, SPLIT_NONE,
};
use supergcn::graph::generate::{sbm, SPLIT_TRAIN};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::model::ModelParams;
use supergcn::perfmodel::MachineProfile;
use supergcn::sample::{SamplerConfig, SamplerKind};
use supergcn::util::propcheck::grad_check;

#[test]
fn distributed_grad_matches_single_machine() {
    let train = |k: usize| -> Vec<f32> {
        let lg = sbm(350, 4, 8.0, 0.85, 16, 0.6, 13);
        let tc = TrainConfig {
            epochs: 8,
            ..Default::default()
        };
        let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, None, 7).unwrap();
        Trainer::new(ctxs, cfg, tc)
            .run(false)
            .unwrap()
            .iter()
            .map(|s| s.train_loss)
            .collect()
    };
    let s1 = train(1);
    let s4 = train(4);
    for (e, (a, b)) in s1.iter().zip(s4.iter()).enumerate() {
        assert!((a - b).abs() < 2e-3, "epoch {e}: k=1 {a} vs k=4 {b}");
    }
}

#[test]
fn full_sampler_minibatch_matches_full_batch() {
    let spec = datasets::by_name("arxiv-xs").unwrap();
    let lg = Arc::new(spec.build());
    let epochs = 6;
    let seed = 42;

    // Full-batch trainer.
    let tc = TrainConfig {
        epochs,
        lr: spec.lr,
        seed,
        ..Default::default()
    };
    let (ctxs, mut cfg, _) = prepare(&lg, 2, tc.strategy, None, seed).unwrap();
    cfg.hidden = spec.hidden;
    let mut full = Trainer::new(ctxs, cfg, tc);
    let full_stats = full.run(false).unwrap();

    // Mini-batch trainer, degenerate full sampler, engine LayerNorm on —
    // the identical architecture through the other GraphContext.
    let mc = MiniBatchConfig {
        epochs,
        lr: spec.lr,
        hidden: spec.hidden,
        layernorm: true,
        seed,
        ..Default::default()
    };
    let scfg = SamplerConfig {
        seed,
        ..Default::default()
    };
    let mut mb = MiniBatchTrainer::new(lg, 2, SamplerKind::Full, &scfg, mc).unwrap();
    let mb_stats = mb.run(false).unwrap();

    for (a, b) in full_stats.iter().zip(mb_stats.iter()) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 3e-3,
            "epoch {}: full-batch {} vs full-sampler {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
    }
    // Same accuracy trajectory too (identical predictions up to round-off).
    let la = full_stats.last().unwrap();
    let lb = mb_stats.last().unwrap();
    assert!((la.test_acc - lb.test_acc).abs() < 0.02, "{} vs {}", la.test_acc, lb.test_acc);
}

#[test]
fn full_batch_engine_gradient_matches_finite_differences() {
    let lg = sbm(120, 3, 5.0, 0.85, 8, 0.4, 21);
    let (ctxs, cfg, _) = prepare(&lg, 1, RemoteStrategy::Hybrid, None, 3).unwrap();
    let engine = Engine::new(&cfg, true, AggDispatch::default());
    let machine = MachineProfile::abci();
    let n = cfg.n_pad;
    let wc = &ctxs[0];
    let tags: Vec<u8> = (0..n)
        .map(|i| {
            if wc.train_mask_f[i] > 0.0 {
                SPLIT_TRAIN
            } else {
                SPLIT_NONE
            }
        })
        .collect();

    let run = |p: &ModelParams, want_grads: bool| -> (f64, Vec<f32>) {
        let mut st = FullBatchState::new(&cfg, 1);
        let mut comm = CommStats::new(1);
        let mut ctx = FullBatchCtx::new(
            &ctxs, &cfg, &mut st, &machine, None, 3, 0, true, false, &mut comm,
        );
        let mut tapes = engine.tapes(&[n], p);
        let mut clock = StageClock::new(1);
        engine
            .forward(p, &mut ctx, &mut tapes, None, &mut clock)
            .unwrap();
        let spec = LossSpec {
            score_rows: n,
            labels: &wc.labels,
            split: &tags,
            loss_w: &wc.train_mask_f,
        };
        let tot = engine.loss_all(&mut tapes, &[spec], &mut clock)[0];
        let loss = tot.loss_sum / tot.wsum;
        if !want_grads {
            return (loss, Vec::new());
        }
        engine.scale_loss_grad(&mut tapes, &[(1.0 / tot.wsum) as f32]);
        engine
            .backward(p, &mut ctx, &mut tapes, None, true, &mut clock)
            .unwrap();
        (loss, tapes.grads[0].flatten())
    };

    let params = ModelParams::init(&cfg, 9);
    let (_, analytic) = run(&params, true);
    let flat = params.flatten();
    let dims = cfg.layer_dims();
    let layer_off =
        |l: usize| -> usize { dims[..l].iter().map(|&(a, b, _)| 2 * a * b + b).sum() };
    let probes = [
        layer_off(0),                                  // layer0 w_self
        layer_off(0) + dims[0].0 * dims[0].1 + 1,      // layer0 w_neigh
        layer_off(0) + 2 * dims[0].0 * dims[0].1 + 1,  // layer0 b
        layer_off(1) + 2,                              // layer1 w_self
        layer_off(2) + 3,                              // layer2 w_self
        layer_off(2) + dims[2].0 * dims[2].1 + 1,      // layer2 w_neigh
    ];
    grad_check(&flat, &analytic, &probes, 1e-2, |p| {
        let mut pp = ModelParams::init(&cfg, 9);
        pp.unflatten_into(p);
        run(&pp, false).0
    });
}
