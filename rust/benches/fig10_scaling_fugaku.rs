//! Fig. 10: performance and scaling on the Fugaku profile (A64FX +
//! Tofu-D), SuperGCN w/o vs w/ the communication optimizations.
//!
//! Small/medium P points are *executed* (simulated workers, measured
//! compute + modeled wire). Large-P points run the full preprocessing
//! (partition → MVC plans → exact per-pair volumes) and combine modeled
//! comm with compute scaled from the largest executed run — the honest
//! extension of the simulator to thousands-of-ranks territory.
//!
//! Expected shape (paper): comm-opt speedup is largest at medium scale
//! (throughput-bound), shrinking at large scale (latency-bound) but never
//! negative; w/ comm-opt always ≥ w/o.

use supergcn::coordinator::planner::prepare;
use supergcn::run::RunConfig;
use supergcn::datasets;
use supergcn::exp::{steady_epoch_secs, train_native, Table};
use supergcn::hier::remote_pairs;
use supergcn::hier::volume::{volume, RemoteStrategy};
use supergcn::partition::{multilevel, vertex_weights};
use supergcn::perfmodel::{
    flat_pair_messages, inter_group_messages, t_comm, t_comm_two_tier, t_quant_comm_total,
    MachineProfile,
};
use supergcn::quant::Bits;

fn main() {
    let machine = MachineProfile::fugaku();
    let epochs = 5;
    for name in ["papers100m-s", "uk2007-s"] {
        let spec = datasets::by_name(name).unwrap();
        let lg = spec.build();
        let f = spec.feat_dim;
        let mut t = Table::new(
            &format!("Fig 10: {} on Fugaku profile (modeled epoch seconds)", name),
            &["procs", "w/o comm opt", "w/ comm opt", "speedup", "mode"],
        );

        // Executed points.
        let mut compute_ref: Option<(usize, f64)> = None; // (P, epoch compute secs)
        for k in [4usize, 16, 64] {
            let base = RunConfig {
                strategy: RemoteStrategy::PostOnly,
                quant: None,
                machine: machine.clone(),
                ..Default::default()
            };
            let opt = RunConfig {
                strategy: RemoteStrategy::Hybrid,
                quant: Some(Bits::Int2),
                label_prop: true,
                machine: machine.clone(),
                ..Default::default()
            };
            let (s0, _) = train_native(&spec, k, base.train_config(), Some(epochs)).unwrap();
            let (s1, _) = train_native(&spec, k, opt.train_config(), Some(epochs)).unwrap();
            let t0 = steady_epoch_secs(&s0, epochs);
            let t1 = steady_epoch_secs(&s1, epochs);
            t.row(vec![
                k.to_string(),
                format!("{t0:.4}"),
                format!("{t1:.4}"),
                format!("{:.2}x", t0 / t1),
                "executed".into(),
            ]);
            // Compute share of the epoch (subtract modeled comm).
            let comm1: f64 = s1.iter().map(|s| s.breakdown.get(supergcn::util::timer::Category::Comm)).sum::<f64>() / s1.len() as f64;
            compute_ref = Some((k, (t1 - comm1).max(1e-6)));
        }

        // Volume-modeled large-P points: full preprocessing, modeled wire,
        // compute ∝ 1/P from the P=64 measurement.
        let (k_ref, comp_ref) = compute_ref.unwrap();
        let w = vertex_weights(&lg.graph, None, 4);
        // Two-level transport view of the same exact volumes (DESIGN.md
        // §12): g = ranks per A64FX, leader-staged inter-node exchange.
        let mut hier_lines: Vec<String> = Vec::new();
        for k in [256usize, 1024, 2048] {
            if lg.n() / k < 16 {
                break;
            }
            let part = multilevel::multilevel(
                &lg.graph,
                k,
                &w,
                &multilevel::MultilevelOpts::default(),
            );
            let pairs = remote_pairs(&lg.graph, &part);
            // 3 layers, forward halo each + equal-volume reverse (FP32).
            let post = volume(k, &pairs, RemoteStrategy::PostOnly);
            let hyb = volume(k, &pairs, RemoteStrategy::Hybrid);
            let vals = |v: &supergcn::hier::volume::VolumeReport| -> Vec<Vec<usize>> {
                v.rows.iter().map(|r| r.iter().map(|&x| x * f).collect()).collect()
            };
            let params: Vec<Vec<usize>> = hyb
                .rows
                .iter()
                .map(|r| r.iter().map(|&x| x.div_ceil(4) * 2).collect())
                .collect();
            let sub = vec![(lg.n() / k * f) as f64; k];
            let comm0 = 6.0 * t_comm(&vals(&post), &machine);
            let comm1 = 3.0 * t_quant_comm_total(&vals(&hyb), &params, &sub, 2.0, &machine)
                + 3.0 * t_comm(&vals(&hyb), &machine);
            let comp = comp_ref * k_ref as f64 / k as f64;
            let t0 = comp + comm0;
            let t1 = comp + comm1;
            t.row(vec![
                k.to_string(),
                format!("{t0:.4}"),
                format!("{t1:.4}"),
                format!("{:.2}x", t0 / t1),
                "volume-modeled".into(),
            ]);
            let g = machine.ranks_per_node;
            let vv = vals(&hyb);
            hier_lines.push(format!(
                "  P={k} (g={g}): inter-node msgs {} vs flat {}; per-layer halo wire \
                 {:.4}s two-level vs {:.4}s flat",
                inter_group_messages(k, g),
                flat_pair_messages(k),
                t_comm_two_tier(&vv, g, &machine),
                t_comm(&vv, &machine),
            ));
        }
        t.print();
        if !hier_lines.is_empty() {
            println!("two-level transport model (hybrid volumes, DESIGN.md §12):");
            for l in &hier_lines {
                println!("{l}");
            }
        }
    }
    println!(
        "\n(executed = simulated workers with measured compute; volume-modeled = \
         exact MVC plans + Eqn 2/5 wire model + 1/P-scaled compute)"
    );
}
