//! Per-rank remote-feature cache with bounded staleness for the
//! mini-batch fetch path (DESIGN.md §16).
//!
//! The fetch in `exec/minibatch.rs` pays full wire cost for every remote
//! feature row every round, even though batch frontiers overlap heavily
//! round to round (the skew observation behind Min et al.'s GPU feature
//! caching, PAPERS.md) and the full-batch regime already tolerates
//! bounded staleness via `delay_comm`. [`FeatCache`] closes that gap: a
//! rank consults its cache before issuing id requests, and a hit skips
//! *both* fetch legs — the id never rides the request exchange and the
//! owner never packs (or quantizes) the reply row.
//!
//! Contract highlights (the full rules live in DESIGN.md §16):
//!
//! * **TTL gate** — `ttl == 0` disables the cache *structurally*: no
//!   probe, no insert, no counter ever runs, so the disabled
//!   configuration is byte-for-byte the uncached fetch (the identity the
//!   parity suite pins).
//! * **Round-scoped TTL** — an entry fetched at round `g` (the cache's
//!   own monotone fetch-round counter, ticked once per `load_inputs`,
//!   spanning epochs) hits while `cur_round − g <= ttl`; on the probe
//!   after that it is dropped and refetched.
//! * **Frequency-ranked admission** — every probe bumps the id's request
//!   frequency; a fetched row is admitted when there is free capacity,
//!   or by displacing the resident with the strictly smallest
//!   `(frequency, fetch_round, id)` key — a total order, so eviction is
//!   deterministic regardless of map iteration order.
//! * **Post-decode values** — rows are cached *after* dequantization, so
//!   a hit reproduces the decoded bits of the round that fetched it
//!   exactly; staleness (and, under quantization, the round-salted
//!   `qseed` plus reply regrouping) is the only numerical difference a
//!   TTL > 0 run can observe.
//!
//! [`PayloadPool`] is the satellite buffer recycler: the fetch's
//! request/reply `Vec<f32>` bodies are grabbed from and recycled into a
//! per-rank free list across rounds (the `Fabric::allreduce_sum` scratch
//! trick), instead of reallocating every round. Recycled buffers are
//! cleared before reuse, so pooling is bit-invisible. [`FetchScratch`]
//! bundles one rank's cache + pool; the mini-batch trainer owns one per
//! rank across rounds and rebuilds them on elastic re-plan (recovery
//! changes ownership, so every cached row is invalidated wholesale).

use crate::comm::Payload;
use std::collections::HashMap;

/// Cache knobs as they arrive from `--feature-cache-rows` /
/// `--feature-cache-ttl` (via `run::RunConfig` and
/// `coordinator::minibatch::MiniBatchConfig`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeatCacheConfig {
    /// Capacity in feature rows per rank (`--feature-cache-rows`). With
    /// `ttl > 0` and zero capacity the cache probes (and counts misses)
    /// but can never admit — the degenerate sweep point.
    pub rows: usize,
    /// Time-to-live in fetch rounds (`--feature-cache-ttl`); `0` disables
    /// the cache entirely.
    pub ttl: usize,
}

impl FeatCacheConfig {
    /// The structural gate: when `false`, callers skip every cache code
    /// path, making the disabled run byte-for-byte identical to a build
    /// without the cache.
    pub fn enabled(&self) -> bool {
        self.ttl > 0
    }
}

/// Per-round cache counters, drained into
/// [`CacheStats`](crate::comm::CacheStats) by the fetch after each round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheRound {
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    /// Wire bits a hit avoided: the 32-bit id on the request leg plus the
    /// row's share of the reply leg (exact for fp32; analytic — packed
    /// element bits plus the amortized group-param share — for quantized
    /// replies, whose grouping depends on the rows that *are* sent).
    pub saved_bits: f64,
}

struct Entry {
    row: Vec<f32>,
    fetch_round: u64,
}

/// One rank's remote-feature cache (frequency-ranked admission, bounded
/// capacity, round-scoped TTL). All state is rank-private and every
/// operation is deterministic in the probe/admit call order, so the
/// sequential transport (lane `w` driving `scratch[w]`) and the threaded
/// transport (rank `w` driving its own scratch) evolve bit-identically.
pub struct FeatCache {
    cfg: FeatCacheConfig,
    /// Resident rows by global node id.
    map: HashMap<u32, Entry>,
    /// Request frequency per remote id (admission ranking); bumped on
    /// every probe, monotone over the cache's lifetime.
    freq: HashMap<u32, u64>,
    /// Monotone fetch-round counter (ticks once per `load_inputs`,
    /// spanning epochs — TTL windows do not reset at epoch boundaries).
    round: u64,
    stats: CacheRound,
}

impl FeatCache {
    pub fn new(cfg: FeatCacheConfig) -> Self {
        Self {
            cfg,
            map: HashMap::new(),
            freq: HashMap::new(),
            round: 0,
            stats: CacheRound::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Resident row count (capacity-bounded).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Advance the fetch-round counter; call exactly once per
    /// `load_inputs` (idle lanes included — every lane participates in
    /// every round, so counters stay aligned across ranks).
    pub fn begin_round(&mut self) {
        self.round += 1;
    }

    /// Look up `id`, bumping its request frequency. A fresh entry
    /// (`cur_round − fetch_round <= ttl`) is a hit; a stale entry is
    /// dropped (freeing its slot before this round's admissions) and, like
    /// an absent id, counted as a miss.
    pub fn probe(&mut self, id: u32) -> Option<&[f32]> {
        *self.freq.entry(id).or_insert(0) += 1;
        let fresh = match self.map.get(&id) {
            Some(e) => self.round - e.fetch_round <= self.cfg.ttl as u64,
            None => false,
        };
        if fresh {
            self.stats.hits += 1;
            self.map.get(&id).map(|e| e.row.as_slice())
        } else {
            self.stats.misses += 1;
            self.map.remove(&id);
            None
        }
    }

    /// Offer a freshly decoded row for admission. Admits into free
    /// capacity, or displaces the resident with the smallest
    /// `(frequency, fetch_round, id)` key — but only when the candidate's
    /// frequency is *strictly* higher (frequency-ranked admission: a
    /// cold row never churns out an equally warm resident).
    pub fn admit(&mut self, id: u32, row: &[f32]) {
        if self.cfg.rows == 0 {
            return;
        }
        if self.map.len() >= self.cfg.rows && !self.map.contains_key(&id) {
            let victim = match self.victim() {
                Some(v) => v,
                None => return,
            };
            let cand_freq = self.freq.get(&id).copied().unwrap_or(0);
            if cand_freq <= self.freq_of(victim) {
                return;
            }
            self.map.remove(&victim);
            self.stats.evictions += 1;
        }
        self.map.insert(
            id,
            Entry {
                row: row.to_vec(),
                fetch_round: self.round,
            },
        );
    }

    /// Charge wire bits a hit avoided (computed by the fetch, which knows
    /// the feature width and quantization level).
    pub fn add_saved_bits(&mut self, bits: f64) {
        self.stats.saved_bits += bits;
    }

    /// Drain this round's counters (the fetch charges them into
    /// `CommStats::cache` under the rank's sender index).
    pub fn take_round_stats(&mut self) -> CacheRound {
        std::mem::take(&mut self.stats)
    }

    /// The deterministic eviction candidate: minimum
    /// `(frequency, fetch_round, id)` over the residents — a total order
    /// (id breaks every tie), so the choice is independent of `HashMap`
    /// iteration order.
    fn victim(&self) -> Option<u32> {
        self.map
            .iter()
            .map(|(&id, e)| (self.freq_of(id), e.fetch_round, id))
            .min()
            .map(|(_, _, id)| id)
    }

    fn freq_of(&self, id: u32) -> u64 {
        self.freq.get(&id).copied().unwrap_or(0)
    }
}

/// Free list of `Vec<f32>` bodies for the fetch's request/reply payloads
/// (the `Fabric::allreduce_sum` scratch-pool idiom, but rank-private — no
/// lock). Buffers are cleared on grab, so a warm pool produces the exact
/// bytes a fresh allocation would; under the threaded transport a buffer
/// sent to a peer is simply recycled into the *receiver's* pool.
#[derive(Default)]
pub struct PayloadPool {
    free: Vec<Vec<f32>>,
}

impl PayloadPool {
    /// Take an empty buffer (recycled capacity when the pool is warm).
    pub fn grab(&mut self) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v
    }

    pub fn recycle(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }

    /// Recycle the body of a consumed payload (quantized payloads own no
    /// `Vec<f32>` body; they drop as usual).
    pub fn recycle_payload(&mut self, p: Payload) {
        if let Payload::F32(v) = p {
            self.free.push(v);
        }
    }
}

/// One rank's persistent fetch scratch: feature cache + payload pool.
/// Owned by the mini-batch trainer across rounds and epochs; rebuilt
/// from scratch on elastic recovery (ownership changed — every cached
/// row is invalid).
pub struct FetchScratch {
    pub cache: FeatCache,
    pub pool: PayloadPool,
}

impl FetchScratch {
    pub fn new(cfg: FeatCacheConfig) -> Self {
        Self {
            cache: FeatCache::new(cfg),
            pool: PayloadPool::default(),
        }
    }

    /// One scratch per rank (the trainer's per-rank fleet).
    pub fn fleet(k: usize, cfg: FeatCacheConfig) -> Vec<FetchScratch> {
        (0..k).map(|_| FetchScratch::new(cfg)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(rows: usize, ttl: usize) -> FeatCache {
        FeatCache::new(FeatCacheConfig { rows, ttl })
    }

    #[test]
    fn ttl_zero_is_disabled() {
        assert!(!FeatCacheConfig { rows: 64, ttl: 0 }.enabled());
        assert!(FeatCacheConfig { rows: 64, ttl: 1 }.enabled());
    }

    #[test]
    fn hit_within_ttl_then_expires() {
        let mut c = cache(4, 2);
        c.begin_round();
        assert!(c.probe(7).is_none());
        c.admit(7, &[1.0, 2.0]);
        // Rounds +1 and +2 are within the window; +3 is stale.
        c.begin_round();
        assert_eq!(c.probe(7), Some(&[1.0, 2.0][..]));
        c.begin_round();
        assert_eq!(c.probe(7), Some(&[1.0, 2.0][..]));
        c.begin_round();
        assert!(c.probe(7).is_none());
        let s = c.take_round_stats();
        assert_eq!((s.hits, s.misses), (2, 2));
    }

    #[test]
    fn eviction_is_deterministic_lowest_freq_oldest_round_smallest_id() {
        let mut c = cache(2, 8);
        c.begin_round();
        // id 3 requested twice, id 5 once — 3 is warmer.
        c.probe(3);
        c.probe(3);
        c.probe(5);
        c.admit(3, &[3.0]);
        c.admit(5, &[5.0]);
        // id 9 at freq 2 displaces the lowest-freq resident (5, freq 1).
        c.begin_round();
        c.probe(9);
        c.probe(9);
        c.admit(9, &[9.0]);
        assert!(c.probe(3).is_some());
        assert!(c.probe(9).is_some());
        c.begin_round();
        assert!(c.probe(5).is_none());
        // Tie on frequency and round falls through to the smallest id:
        // fill a fresh cache with equally warm residents and displace.
        let mut c = cache(2, 8);
        c.begin_round();
        c.probe(10);
        c.probe(11);
        c.admit(10, &[1.0]);
        c.admit(11, &[1.1]);
        c.begin_round();
        c.probe(12);
        c.probe(12); // freq 2 > freq 1: admit by displacing id 10 (smallest).
        c.admit(12, &[1.2]);
        c.begin_round();
        assert!(c.probe(11).is_some());
        assert!(c.probe(12).is_some());
        c.begin_round();
        assert!(c.probe(10).is_none());
    }

    #[test]
    fn cold_candidate_never_displaces_a_warmer_resident() {
        let mut c = cache(1, 8);
        c.begin_round();
        c.probe(1);
        c.probe(1);
        c.admit(1, &[1.0]);
        c.begin_round();
        c.probe(2); // freq 1 vs resident freq 2: rejected.
        c.admit(2, &[2.0]);
        assert!(c.probe(1).is_some());
        c.begin_round();
        assert!(c.probe(2).is_none());
        assert_eq!(c.take_round_stats().evictions, 0);
    }

    #[test]
    fn zero_capacity_counts_misses_but_never_admits() {
        let mut c = cache(0, 4);
        for _ in 0..3 {
            c.begin_round();
            assert!(c.probe(42).is_none());
            c.admit(42, &[0.5]);
        }
        assert!(c.is_empty());
        let s = c.take_round_stats();
        assert_eq!((s.hits, s.misses), (0, 3));
    }

    #[test]
    fn pool_grab_is_cleared_and_reuses_capacity() {
        let mut p = PayloadPool::default();
        let mut v = p.grab();
        v.extend_from_slice(&[1.0, 2.0, 3.0]);
        let cap = v.capacity();
        p.recycle(v);
        let v2 = p.grab();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap);
        p.recycle_payload(Payload::F32(vec![9.0]));
        assert!(p.grab().is_empty());
    }
}
