//! Fig. 11: test-accuracy-vs-epoch curves for the five training settings —
//! DistGNN (cd-5), SuperGCN FP32/Int2 × w/o-LP/w-LP.
//!
//! Expected shape (paper): Int2 ≈ FP32 on easy datasets; on harder ones
//! Int2 w/o LP converges lower; enabling masked label propagation closes
//! the gap (and speeds convergence); DistGNN's staleness converges lower /
//! noisier.

use supergcn::run::RunConfig;
use supergcn::datasets;
use supergcn::exp::{best_test_acc, train_native, Table};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::quant::Bits;

fn settings() -> Vec<(&'static str, RunConfig)> {
    vec![
        (
            "DistGNN(cd-5)",
            RunConfig {
                strategy: RemoteStrategy::PreOnly,
                delay_comm: 5,
                ..Default::default()
            },
        ),
        ("FP32 w/o LP", RunConfig::default()),
        (
            "Int2 w/o LP",
            RunConfig {
                quant: Some(Bits::Int2),
                ..Default::default()
            },
        ),
        (
            "FP32 w/ LP",
            RunConfig {
                label_prop: true,
                ..Default::default()
            },
        ),
        (
            "Int2 w/ LP",
            RunConfig {
                quant: Some(Bits::Int2),
                label_prop: true,
                ..Default::default()
            },
        ),
    ]
}

fn main() {
    for (name, epochs, k) in [("arxiv-s", 64usize, 4usize), ("products-s", 32, 4)] {
        let spec = datasets::by_name(name).unwrap();
        let every = epochs / 8;
        let mut headers: Vec<String> = vec!["setting".into()];
        headers.extend((0..8).map(|i| format!("ep{}", (i + 1) * every)));
        headers.push("best".into());
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 11: test accuracy vs epoch — {} ({k} procs)", name),
            &hdr_refs,
        );
        for (label, tc) in settings() {
            let (stats, _) = train_native(&spec, k, tc.train_config(), Some(epochs)).unwrap();
            let mut row = vec![label.to_string()];
            for i in 0..8 {
                let e = ((i + 1) * every - 1).min(stats.len() - 1);
                row.push(format!("{:.3}", stats[e].test_acc));
            }
            row.push(format!("{:.3}", best_test_acc(&stats)));
            t.row(row);
        }
        t.print();
    }
}
