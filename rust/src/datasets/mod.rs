//! Dataset catalog mirroring the paper's Table 2, scaled to this testbed
//! (DESIGN.md §1: synthetic substitutes with matching *shape* — vertex/edge
//! ratio, feature width, class count, split — not matching absolute size).
//!
//! Accuracy experiments use SBM (homophilous, learnable); communication
//! experiments use R-MAT (power-law, partition-stressing). Each config
//! carries the model hyperparameters of Table 2.

use crate::graph::generate::{attach_labels, rmat, sbm, LabelledGraph};

/// Generator family behind a catalog entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Stochastic block model (accuracy-bearing).
    Sbm,
    /// R-MAT power law with attached labels (comm-stressing).
    Rmat,
}

/// One Table-2-style dataset description.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Which paper dataset this stands in for.
    pub paper_analog: &'static str,
    pub family: Family,
    pub n: usize,
    pub avg_deg: f64,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl DatasetSpec {
    /// Materialize the dataset (deterministic per seed).
    pub fn build(&self) -> LabelledGraph {
        match self.family {
            // Harder settings (lower homophily, heavy feature noise) keep
            // accuracy off the ceiling so quantization/LP effects are
            // visible in the Fig-11 analogues.
            Family::Sbm => sbm(
                self.n,
                self.num_classes,
                self.avg_deg,
                0.72,
                self.feat_dim,
                3.0,
                self.seed,
            ),
            Family::Rmat => {
                let scale = (self.n as f64).log2().ceil() as u32;
                let g = rmat(scale, self.avg_deg / 2.0, 0.57, 0.19, 0.19, true, self.seed);
                attach_labels(g, self.num_classes, self.feat_dim, self.seed)
            }
        }
    }
}

/// The catalog. Names mirror Table 2; sizes are scaled by ~10³ so every
/// experiment runs on one core while preserving edge/vertex ratios.
pub fn catalog() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "arxiv-xs",
            paper_analog: "Ogbn-arxiv (CI-sized cut)",
            family: Family::Sbm,
            n: 800,
            avg_deg: 7.0,
            feat_dim: 32,
            num_classes: 8,
            hidden: 32,
            epochs: 60,
            lr: 0.01,
            seed: 1000,
        },
        DatasetSpec {
            name: "arxiv-s",
            paper_analog: "Ogbn-arxiv (169K nodes, deg~6.9)",
            family: Family::Sbm,
            n: 4_000,
            avg_deg: 7.0,
            feat_dim: 64,
            num_classes: 16,
            hidden: 64,
            epochs: 200,
            lr: 0.01,
            seed: 1001,
        },
        DatasetSpec {
            name: "reddit-s",
            paper_analog: "Reddit (233K nodes, deg~492)",
            family: Family::Sbm,
            n: 3_000,
            avg_deg: 60.0,
            feat_dim: 96,
            num_classes: 16,
            hidden: 64,
            epochs: 200,
            lr: 0.01,
            seed: 1002,
        },
        DatasetSpec {
            name: "products-s",
            paper_analog: "Ogbn-products (2.4M nodes, deg~25)",
            family: Family::Sbm,
            n: 12_000,
            avg_deg: 25.0,
            feat_dim: 64,
            num_classes: 24,
            hidden: 64,
            epochs: 200,
            lr: 0.01,
            seed: 1003,
        },
        DatasetSpec {
            name: "proteins-s",
            paper_analog: "Proteins (8.7M nodes, deg~150)",
            family: Family::Rmat,
            n: 16_384,
            avg_deg: 60.0,
            feat_dim: 64,
            num_classes: 16,
            hidden: 64,
            epochs: 100,
            lr: 0.01,
            seed: 1004,
        },
        DatasetSpec {
            name: "papers100m-s",
            paper_analog: "Ogbn-papers100M (111M nodes, deg~14.5)",
            family: Family::Rmat,
            n: 65_536,
            avg_deg: 15.0,
            feat_dim: 64,
            num_classes: 32,
            hidden: 64,
            epochs: 100,
            lr: 0.005,
            seed: 1005,
        },
        DatasetSpec {
            name: "mag240m-s",
            paper_analog: "Ogb-lsc-mag240M (122M nodes, deg~21, feat 768)",
            family: Family::Rmat,
            n: 65_536,
            avg_deg: 21.0,
            feat_dim: 128,
            num_classes: 32,
            hidden: 64,
            epochs: 100,
            lr: 0.005,
            seed: 1006,
        },
        DatasetSpec {
            name: "uk2007-s",
            paper_analog: "UK-2007-05 (106M nodes, deg~35)",
            family: Family::Rmat,
            n: 32_768,
            avg_deg: 35.0,
            feat_dim: 64,
            num_classes: 32,
            hidden: 32,
            epochs: 100,
            lr: 0.01,
            seed: 1007,
        },
        DatasetSpec {
            name: "igb260m-s",
            paper_analog: "IGB260M (269M nodes, deg~15, feat 1024)",
            family: Family::Rmat,
            n: 131_072,
            avg_deg: 15.0,
            feat_dim: 128,
            num_classes: 19,
            hidden: 64,
            epochs: 100,
            lr: 0.01,
            seed: 1008,
        },
    ]
}

/// Look up a spec by name.
pub fn by_name(name: &str) -> anyhow::Result<DatasetSpec> {
    catalog()
        .into_iter()
        .find(|d| d.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown dataset '{name}'; available: {}",
                catalog().iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_entries_build_and_validate() {
        // Build the small ones; big R-MATs are exercised by benches.
        for spec in catalog().into_iter().filter(|d| d.n <= 8_000) {
            let g = spec.build();
            g.validate().unwrap();
            assert_eq!(g.feat_dim, spec.feat_dim);
            assert_eq!(g.num_classes, spec.num_classes);
            let avg = g.graph.m() as f64 / g.n() as f64;
            assert!(
                avg > spec.avg_deg * 0.4 && avg < spec.avg_deg * 3.0,
                "{}: avg deg {avg} vs spec {}",
                spec.name,
                spec.avg_deg
            );
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("arxiv-s").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn names_are_unique() {
        let names: Vec<_> = catalog().iter().map(|d| d.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }
}
