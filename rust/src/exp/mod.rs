//! Experiment support: table printers and the shared run helpers used by
//! the bench harnesses (one per paper table/figure) and examples.

use crate::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use crate::coordinator::planner::prepare;
use crate::coordinator::trainer::{EpochStats, TrainConfig, Trainer};
use crate::datasets::DatasetSpec;
use crate::sample::{SamplerConfig, SamplerKind};
use anyhow::Result;
use std::sync::Arc;

/// A fixed-width console table (benches print paper-style rows).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let s: Vec<String> = cells
                .iter()
                .zip(widths.iter())
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("| {} |", s.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Markdown rendering (for EXPERIMENTS.md capture).
    pub fn markdown(&self) -> String {
        let mut s = format!("### {}\n\n| {} |\n|{}|\n",
            self.title,
            self.headers.join(" | "),
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

/// Train `spec` on `k` simulated workers with the native engine.
pub fn train_native(
    spec: &DatasetSpec,
    k: usize,
    mut tc: TrainConfig,
    epochs_override: Option<usize>,
) -> Result<(Vec<EpochStats>, Trainer)> {
    let lg = spec.build();
    tc.lr = spec.lr;
    if let Some(e) = epochs_override {
        tc.epochs = e;
    }
    let (ctxs, mut cfg, _) = prepare(&lg, k, tc.strategy, None, tc.seed)?;
    // `prepare` fit used hidden=64 default; refit classes/hidden widths.
    cfg.hidden = spec.hidden;
    let mut tr = Trainer::new(ctxs, cfg, tc);
    let stats = tr.run(false)?;
    Ok((stats, tr))
}

/// Train `spec` with the mini-batch engine on `k` simulated workers
/// (sampling-regime twin of [`train_native`], used by the
/// `sampling_regimes` bench; the CLI wires its own config for per-epoch
/// logging). Like `train_native`, the dataset spec wins: `mc.lr` and
/// `mc.hidden` are overwritten with `spec.lr` / `spec.hidden`.
pub fn train_minibatch(
    spec: &DatasetSpec,
    k: usize,
    kind: SamplerKind,
    scfg: &SamplerConfig,
    mut mc: MiniBatchConfig,
    epochs_override: Option<usize>,
) -> Result<(Vec<EpochStats>, MiniBatchTrainer)> {
    let lg = Arc::new(spec.build());
    mc.lr = spec.lr;
    mc.hidden = spec.hidden;
    if let Some(e) = epochs_override {
        mc.epochs = e;
    }
    let mut tr = MiniBatchTrainer::new(lg, k, kind, scfg, mc)?;
    let stats = tr.run(false)?;
    Ok((stats, tr))
}

/// Mean of the last `n` epochs' modeled seconds (steady-state epoch time).
pub fn steady_epoch_secs(stats: &[EpochStats], n: usize) -> f64 {
    let tail = &stats[stats.len().saturating_sub(n)..];
    tail.iter().map(|s| s.modeled_secs).sum::<f64>() / tail.len().max(1) as f64
}

/// Best (max) test accuracy over a run.
pub fn best_test_acc(stats: &[EpochStats]) -> f32 {
    stats.iter().map(|s| s.test_acc).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2 |"));
        t.print();
    }

    #[test]
    fn steady_state_helpers() {
        let mk = |m: f64, acc: f32| EpochStats {
            epoch: 0,
            train_loss: 0.0,
            train_acc: 0.0,
            val_acc: 0.0,
            test_acc: acc,
            modeled_secs: m,
            measured_secs: m,
            breakdown: Default::default(),
            comm_data_bytes: 0.0,
            comm_param_bytes: 0.0,
            overlap: Default::default(),
        };
        let stats = vec![mk(10.0, 0.1), mk(2.0, 0.5), mk(4.0, 0.4)];
        assert!((steady_epoch_secs(&stats, 2) - 3.0).abs() < 1e-12);
        assert_eq!(best_test_acc(&stats), 0.5);
    }
}
