//! The distributed full-batch training loop (paper Fig. 2).
//!
//! Workers execute SPMD stages sequentially inside one process (hardware
//! substitution, DESIGN.md §1): payload bytes move for real through
//! `comm::alltoallv` (numerics are exactly those of a cluster run), wire
//! *time* is charged by the Eqn 2/5 model, and per-worker compute is
//! measured on the local CPU and combined as `Σ_stage max_w t(stage, w)`.
//!
//! The backward pass is exact: cotangents of received halo tensors are
//! shipped back to their producers every exchange epoch (the reverse of
//! the forward halo pattern), so the distributed gradient equals the
//! single-machine gradient to f32 round-off — property-checked in
//! `rust/tests/trainer_equivalence.rs`.

use super::planner::WorkerCtx;
use crate::backend::Backend;
use crate::comm::{alltoallv, collective, CommStats, Payload};
use crate::hier::volume::RemoteStrategy;
use crate::model::labelprop::{self, LpSelection};
use crate::model::optimizer::{OptKind, Optimizer};
use crate::model::{ModelGrads, ModelParams};
use crate::perfmodel::MachineProfile;
use crate::quant::{fused, Bits};
use crate::runtime::ShapeConfig;
use crate::util::rng::Rng;
use crate::util::timer::{Breakdown, Category};
use anyhow::Result;

/// Training-run configuration (one Fig. 11 curve = one of these).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub opt: OptKind,
    /// Forward halo quantization (None = FP32; the paper fixes Int2).
    pub quant: Option<Bits>,
    /// Masked label propagation (§6.1(1)).
    pub label_prop: bool,
    pub lp_frac: f64,
    pub strategy: RemoteStrategy,
    /// Exchange halos every `delay_comm` epochs (1 = synchronous SuperGCN;
    /// 5 = the DistGNN cd-5 baseline's staleness).
    pub delay_comm: usize,
    pub machine: MachineProfile,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 100,
            lr: 0.01,
            opt: OptKind::Adam,
            quant: None,
            label_prop: false,
            lp_frac: 0.5,
            strategy: RemoteStrategy::Hybrid,
            delay_comm: 1,
            machine: MachineProfile::abci(),
            seed: 42,
        }
    }
}

/// Per-epoch observables.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    /// Modeled epoch seconds: Σ_stage max_w compute + modeled comm.
    pub modeled_secs: f64,
    /// Measured wall seconds (all workers run on this one core).
    pub measured_secs: f64,
    pub breakdown: Breakdown,
    pub comm_data_bytes: f64,
    pub comm_param_bytes: f64,
}

/// Per-worker activation / gradient storage.
struct WorkerBufs {
    /// Activations entering each layer (widths f_in, h, h) + final logits.
    h: Vec<Vec<f32>>,
    /// LayerNorm outputs per layer (kept for backward).
    h_norm: Vec<Vec<f32>>,
    /// Received halo tensors per layer (kept for backward & staleness).
    recv_pre: Vec<Vec<f32>>,
    recv_post: Vec<Vec<f32>>,
    /// Scratch.
    partials: Vec<f32>,
    d_cur: Vec<f32>,
    d_next: Vec<f32>,
    d_h_norm: Vec<f32>,
    d_recv_pre: Vec<f32>,
    d_recv_post: Vec<f32>,
    d_partials: Vec<f32>,
    lp_sel: LpSelection,
    grads: ModelGrads,
}

pub struct Trainer {
    pub shapes: ShapeConfig,
    pub tc: TrainConfig,
    pub workers: Vec<WorkerCtx>,
    backend: Box<dyn Backend>,
    pub params: ModelParams,
    opt: Optimizer,
    bufs: Vec<WorkerBufs>,
    pub comm_stats: CommStats,
    epoch: usize,
    rng: Rng,
    /// Last epoch whose halos were exchanged (staleness bookkeeping).
    last_exchange: Option<usize>,
}

impl Trainer {
    pub fn new(workers: Vec<WorkerCtx>, backend: Box<dyn Backend>, tc: TrainConfig) -> Self {
        let shapes = backend.config().clone();
        let params = ModelParams::init(&shapes, tc.seed);
        let opt = Optimizer::new(tc.opt, tc.lr, params.n_params());
        let k = workers.len();
        let dims = shapes.layer_dims();
        let maxf = shapes.f_in.max(shapes.hidden).max(shapes.classes);
        let n = shapes.n_pad;
        let bufs = (0..k)
            .map(|_| WorkerBufs {
                h: vec![
                    vec![0f32; n * dims[0].0],
                    vec![0f32; n * dims[1].0],
                    vec![0f32; n * dims[2].0],
                    vec![0f32; n * dims[2].1],
                ],
                h_norm: (0..3).map(|l| vec![0f32; n * dims[l].0]).collect(),
                recv_pre: (0..3).map(|l| vec![0f32; shapes.r_pre * dims[l].0]).collect(),
                recv_post: (0..3).map(|l| vec![0f32; shapes.r_post * dims[l].0]).collect(),
                partials: vec![0f32; shapes.p_pre * maxf],
                d_cur: vec![0f32; n * maxf],
                d_next: vec![0f32; n * maxf],
                d_h_norm: vec![0f32; n * maxf],
                d_recv_pre: vec![0f32; shapes.r_pre * maxf],
                d_recv_post: vec![0f32; shapes.r_post * maxf],
                d_partials: vec![0f32; shapes.p_pre * maxf],
                lp_sel: LpSelection {
                    embedded: vec![],
                    loss_mask: vec![0.0; n],
                },
                grads: ModelGrads::zeros(&params),
            })
            .collect();
        let rng = Rng::new(tc.seed ^ 0x7A13);
        Self {
            shapes,
            comm_stats: CommStats::new(k),
            tc,
            workers,
            backend,
            params,
            opt,
            bufs,
            epoch: 0,
            rng,
            last_exchange: None,
        }
    }

    pub fn k(&self) -> usize {
        self.workers.len()
    }

    fn is_exchange_epoch(&self) -> bool {
        self.tc.delay_comm <= 1 || self.epoch % self.tc.delay_comm == 0
    }

    /// Run one epoch; returns the stats.
    pub fn epoch(&mut self) -> Result<EpochStats> {
        let wall = std::time::Instant::now();
        let k = self.k();
        let dims = self.shapes.layer_dims();
        let n = self.shapes.n_pad;
        let mut breakdown = Breakdown::new();
        let mut stage_times: Vec<Vec<f64>> = Vec::new();
        let mut epoch_comm = CommStats::new(k);
        let exchange = self.is_exchange_epoch();
        if exchange {
            self.last_exchange = Some(self.epoch);
        }

        // ---- step 3: masked label propagation -----------------------------
        let f_in = dims[0].0;
        for w in 0..k {
            let ctx = &self.workers[w];
            let b = &mut self.bufs[w];
            b.h[0].copy_from_slice(&ctx.features);
            if self.tc.label_prop {
                b.lp_sel = labelprop::select(&ctx.train_mask, self.tc.lp_frac, &mut self.rng);
                labelprop::embed_into(&mut b.h[0], f_in, &b.lp_sel, &ctx.labels, &self.params.w_embed);
            } else {
                b.lp_sel = labelprop::select(&ctx.train_mask, 0.0, &mut self.rng);
            }
            b.grads.clear();
        }

        // ---- forward ------------------------------------------------------
        for l in 0..3 {
            let fin = dims[l].0;
            // Stage: pre_fwd.
            let mut st = vec![0f64; k];
            for w in 0..k {
                let t = std::time::Instant::now();
                let h = self.bufs[w].h[l].clone();
                let b = &mut self.bufs[w];
                // Disjoint-field borrows within one &mut b.
                let (h_norm, partials) = (&mut b.h_norm[l], &mut b.partials);
                self.backend.pre_fwd(
                    fin,
                    &h,
                    &self.workers[w].pre,
                    h_norm,
                    &mut partials[..self.shapes.p_pre * fin],
                )?;
                st[w] = t.elapsed().as_secs_f64();
            }
            // Eqn-2 bottleneck view: the slowest worker defines the stage cost.
            breakdown.add(Category::Aggr, st.iter().fold(0.0f64, |a, &b| a.max(b)));
            stage_times.push(st);

            // Stage: halo exchange (quantize → wire → dequantize).
            if exchange {
                let mut quant_secs = vec![0f64; k];
                let sends = self.build_sends(l, fin, &mut quant_secs);
                let recvs = alltoallv(sends, &self.tc.machine, &mut epoch_comm);
                self.apply_recvs(l, fin, recvs, &mut quant_secs)?;
                // Bottleneck view, like the compute stages.
                breakdown.add(Category::Quant, quant_secs.iter().fold(0.0f64, |a, &b| a.max(b)));
            }

            // Stage: layer_fwd.
            let mut st = vec![0f64; k];
            for w in 0..k {
                let t = std::time::Instant::now();
                let b = &mut self.bufs[w];
                let (h_norm, recv_pre, recv_post, out) = (
                    b.h_norm[l].clone(),
                    b.recv_pre[l].clone(),
                    b.recv_post[l].clone(),
                    &mut b.h[l + 1],
                );
                self.backend.layer_fwd(
                    l,
                    &h_norm,
                    &recv_pre,
                    &recv_post,
                    &self.params.layers[l],
                    &self.workers[w].spec,
                    out,
                )?;
                st[w] = t.elapsed().as_secs_f64();
            }
            // Eqn-2 bottleneck view: the slowest worker defines the stage cost.
            breakdown.add(Category::Aggr, st.iter().fold(0.0f64, |a, &b| a.max(b)));
            stage_times.push(st);
        }

        // ---- loss + metrics ------------------------------------------------
        let c = self.shapes.classes;
        let mut train_loss_sum = 0f64;
        let mut train_mask_sum = 0f64;
        let mut train_correct = 0f64;
        let mut val_correct = 0f64;
        let mut val_mask = 0f64;
        let mut test_correct = 0f64;
        let mut test_mask = 0f64;
        let mut st = vec![0f64; k];
        for w in 0..k {
            let t = std::time::Instant::now();
            let logits = self.bufs[w].h[3].clone();
            let labels = self.workers[w].labels_i32.clone();
            let loss_mask = self.bufs[w].lp_sel.loss_mask.clone();
            let out = self.backend.loss_head(&logits, &labels, &loss_mask)?;
            train_loss_sum += out.loss_sum as f64;
            train_mask_sum += out.mask_sum as f64;
            train_correct += out.correct as f64;
            self.bufs[w].d_cur[..n * c].copy_from_slice(&out.d_logits);
            // Val / test metrics from the same full-batch logits.
            let vo = self
                .backend
                .loss_head(&logits, &labels, &self.workers[w].val_mask)?;
            val_correct += vo.correct as f64;
            val_mask += vo.mask_sum as f64;
            let to = self
                .backend
                .loss_head(&logits, &labels, &self.workers[w].test_mask)?;
            test_correct += to.correct as f64;
            test_mask += to.mask_sum as f64;
            st[w] = t.elapsed().as_secs_f64();
        }
        // Eqn-2 bottleneck view: the slowest worker defines the stage cost.
        breakdown.add(Category::Other, st.iter().fold(0.0f64, |a, &b| a.max(b)));
        stage_times.push(st);

        // Scale loss gradient to the global mean.
        let inv_mask = if train_mask_sum > 0.0 {
            1.0 / train_mask_sum as f32
        } else {
            0.0
        };
        for b in &mut self.bufs {
            for v in &mut b.d_cur[..n * c] {
                *v *= inv_mask;
            }
        }

        // ---- backward ------------------------------------------------------
        for l in (0..3).rev() {
            let (fin, fout, _) = dims[l];
            // Stage: layer_bwd.
            let mut st = vec![0f64; k];
            for w in 0..k {
                let t = std::time::Instant::now();
                let (h_norm, recv_pre, recv_post, out, d_out) = {
                    let b = &self.bufs[w];
                    (
                        b.h_norm[l].clone(),
                        b.recv_pre[l].clone(),
                        b.recv_post[l].clone(),
                        b.h[l + 1].clone(),
                        b.d_cur[..n * fout].to_vec(),
                    )
                };
                let b = &mut self.bufs[w];
                let (d_h_norm, d_recv_pre, d_recv_post) = (
                    &mut b.d_h_norm[..n * fin],
                    &mut b.d_recv_pre[..self.shapes.r_pre * fin],
                    &mut b.d_recv_post[..self.shapes.r_post * fin],
                );
                self.backend.layer_bwd(
                    l,
                    &h_norm,
                    &recv_pre,
                    &recv_post,
                    &self.params.layers[l],
                    &self.workers[w].spec,
                    &out,
                    &d_out,
                    d_h_norm,
                    d_recv_pre,
                    d_recv_post,
                    &mut b.grads.layers[l],
                )?;
                st[w] = t.elapsed().as_secs_f64();
            }
            // Eqn-2 bottleneck view: the slowest worker defines the stage cost.
            breakdown.add(Category::Aggr, st.iter().fold(0.0f64, |a, &b| a.max(b)));
            stage_times.push(st);

            // Reverse halo exchange (cotangents back to producers, FP32).
            for b in &mut self.bufs {
                b.d_partials[..self.shapes.p_pre * fin]
                    .iter_mut()
                    .for_each(|x| *x = 0.0);
            }
            if exchange {
                let sends = self.build_reverse_sends(fin);
                let recvs = alltoallv(sends, &self.tc.machine, &mut epoch_comm);
                self.apply_reverse_recvs(fin, recvs)?;
            }

            // Stage: pre_bwd.
            let mut st = vec![0f64; k];
            for w in 0..k {
                let t = std::time::Instant::now();
                let (h, d_h_norm, d_partials) = {
                    let b = &self.bufs[w];
                    (
                        b.h[l].clone(),
                        b.d_h_norm[..n * fin].to_vec(),
                        b.d_partials[..self.shapes.p_pre * fin].to_vec(),
                    )
                };
                let b = &mut self.bufs[w];
                let d_h = &mut b.d_next[..n * fin];
                self.backend
                    .pre_bwd(fin, &h, &self.workers[w].pre, &d_h_norm, &d_partials, d_h)?;
                st[w] = t.elapsed().as_secs_f64();
                std::mem::swap(&mut b.d_cur, &mut b.d_next);
            }
            // Eqn-2 bottleneck view: the slowest worker defines the stage cost.
            breakdown.add(Category::Aggr, st.iter().fold(0.0f64, |a, &b| a.max(b)));
            stage_times.push(st);
        }

        // ---- label-embedding gradient + allreduce + update ------------------
        if self.tc.label_prop {
            for w in 0..k {
                let b = &mut self.bufs[w];
                labelprop::grad_embed(
                    &mut b.grads.w_embed,
                    f_in,
                    &b.lp_sel,
                    &self.workers[w].labels,
                    &b.d_cur[..n * f_in],
                );
            }
        }
        let t = std::time::Instant::now();
        let mut flats: Vec<Vec<f32>> = self.bufs.iter().map(|b| b.grads.flatten()).collect();
        let ar_secs = collective::allreduce_sum(&mut flats, &self.tc.machine);
        epoch_comm.modeled_send_secs.iter_mut().for_each(|s| *s += ar_secs);
        let mut flat_params = self.params.flatten();
        self.opt.step(&mut flat_params, &flats[0]);
        self.params.unflatten_into(&flat_params);
        breakdown.add(Category::Other, t.elapsed().as_secs_f64());

        // ---- time accounting -------------------------------------------------
        // Compute was measured on this container's single core; a rank of
        // the modeled machine has `cores_per_rank` of them (DESIGN.md §1),
        // so the modeled epoch divides compute-side categories by that.
        let cscale = self.tc.machine.cores_per_rank.max(1.0);
        let mut modeled_compute = 0f64;
        let mut sync = 0f64;
        for st in &stage_times {
            let mx = st.iter().fold(0.0f64, |a, &b| a.max(b));
            modeled_compute += mx;
            for &t in st {
                sync += mx - t;
            }
        }
        modeled_compute /= cscale;
        for c in [Category::Aggr, Category::Quant, Category::Other] {
            let v = breakdown.get(c);
            breakdown.add(c, v / cscale - v);
        }
        breakdown.add(Category::Sync, sync / k as f64 / cscale);
        let comm_secs = epoch_comm.modeled_comm_secs();
        breakdown.add(Category::Comm, comm_secs);
        // Accumulate into run totals.
        for i in 0..k {
            for j in 0..k {
                self.comm_stats.data_bits[i][j] += epoch_comm.data_bits[i][j];
                self.comm_stats.param_bits[i][j] += epoch_comm.param_bits[i][j];
                self.comm_stats.messages[i][j] += epoch_comm.messages[i][j];
            }
            self.comm_stats.modeled_send_secs[i] += epoch_comm.modeled_send_secs[i];
        }

        let stats = EpochStats {
            epoch: self.epoch,
            train_loss: (train_loss_sum / train_mask_sum.max(1.0)) as f32,
            train_acc: (train_correct / train_mask_sum.max(1.0)) as f32,
            val_acc: (val_correct / val_mask.max(1.0)) as f32,
            test_acc: (test_correct / test_mask.max(1.0)) as f32,
            modeled_secs: modeled_compute + comm_secs,
            measured_secs: wall.elapsed().as_secs_f64(),
            breakdown,
            comm_data_bytes: epoch_comm.total_data_bytes(),
            comm_param_bytes: epoch_comm.total_param_bytes(),
        };
        self.epoch += 1;
        Ok(stats)
    }

    /// Train for the configured number of epochs, returning per-epoch stats.
    pub fn run(&mut self, log: bool) -> Result<Vec<EpochStats>> {
        let mut out = Vec::with_capacity(self.tc.epochs);
        for e in 0..self.tc.epochs {
            let s = self.epoch()?;
            if log && (e % 10 == 0 || e + 1 == self.tc.epochs) {
                eprintln!(
                    "epoch {:4}  loss {:.4}  train {:.4}  val {:.4}  test {:.4}  modeled {:.4}s",
                    s.epoch, s.train_loss, s.train_acc, s.val_acc, s.test_acc, s.modeled_secs
                );
            }
            out.push(s);
        }
        Ok(out)
    }

    /// Assemble the forward halo payload matrix for layer `l`.
    fn build_sends(&mut self, l: usize, fin: usize, quant_secs: &mut [f64]) -> Vec<Vec<Payload>> {
        let k = self.k();
        let mut sends: Vec<Vec<Payload>> = (0..k)
            .map(|_| (0..k).map(|_| Payload::Empty).collect())
            .collect();
        for w in 0..k {
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                let ctx = &self.workers[w];
                let b = &self.bufs[w];
                let (plo, phi) = ctx.send_pre_range[peer];
                let post = &ctx.send_post_rows[peer];
                let rows = (phi - plo) + post.len();
                if rows == 0 {
                    continue;
                }
                let mut buf = Vec::with_capacity(rows * fin);
                buf.extend_from_slice(&b.partials[plo * fin..phi * fin]);
                for &r in post {
                    buf.extend_from_slice(&b.h_norm[l][r as usize * fin..(r as usize + 1) * fin]);
                }
                sends[w][peer] = match self.tc.quant {
                    Some(bits) => {
                        let t = std::time::Instant::now();
                        let seed = (self.epoch as u64) << 32
                            | (w as u64) << 16
                            | (peer as u64) << 8
                            | l as u64;
                        let q = fused::quantize(&buf, rows, fin, bits, seed ^ self.tc.seed);
                        quant_secs[w] += t.elapsed().as_secs_f64();
                        Payload::Quant(q)
                    }
                    None => Payload::F32(buf),
                };
            }
        }
        sends
    }

    /// Scatter received forward payloads into recv_pre / recv_post buffers.
    fn apply_recvs(
        &mut self,
        l: usize,
        fin: usize,
        recvs: Vec<Vec<Payload>>,
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        for w in 0..k {
            // Reset to zeros so stale pads never leak.
            self.bufs[w].recv_pre[l].iter_mut().for_each(|x| *x = 0.0);
            self.bufs[w].recv_post[l].iter_mut().for_each(|x| *x = 0.0);
            for peer in 0..k {
                let payload = &recvs[w][peer];
                if payload.is_empty() {
                    continue;
                }
                let ctx = &self.workers[w];
                let (plo, phi) = ctx.recv_pre_range[peer];
                let (qlo, qhi) = ctx.recv_post_range[peer];
                let rows = (phi - plo) + (qhi - qlo);
                let data: Vec<f32> = match payload {
                    Payload::F32(v) => v.clone(),
                    Payload::Quant(q) => {
                        let t = std::time::Instant::now();
                        let d = fused::dequantize(q);
                        quant_secs[w] += t.elapsed().as_secs_f64();
                        d
                    }
                    Payload::Empty => continue,
                };
                anyhow::ensure!(
                    data.len() == rows * fin,
                    "halo payload from {peer} to {w}: {} values, expected {}",
                    data.len(),
                    rows * fin
                );
                let b = &mut self.bufs[w];
                b.recv_pre[l][plo * fin..phi * fin]
                    .copy_from_slice(&data[..(phi - plo) * fin]);
                b.recv_post[l][qlo * fin..qhi * fin]
                    .copy_from_slice(&data[(phi - plo) * fin..]);
            }
        }
        Ok(())
    }

    /// Reverse exchange: consumers return halo cotangents to producers.
    fn build_reverse_sends(&self, fin: usize) -> Vec<Vec<Payload>> {
        let k = self.k();
        let mut sends: Vec<Vec<Payload>> = (0..k)
            .map(|_| (0..k).map(|_| Payload::Empty).collect())
            .collect();
        for w in 0..k {
            let ctx = &self.workers[w];
            let b = &self.bufs[w];
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                let (plo, phi) = ctx.recv_pre_range[peer];
                let (qlo, qhi) = ctx.recv_post_range[peer];
                let rows = (phi - plo) + (qhi - qlo);
                if rows == 0 {
                    continue;
                }
                let mut buf = Vec::with_capacity(rows * fin);
                buf.extend_from_slice(&b.d_recv_pre[plo * fin..phi * fin]);
                buf.extend_from_slice(&b.d_recv_post[qlo * fin..qhi * fin]);
                sends[w][peer] = Payload::F32(buf);
            }
        }
        sends
    }

    /// Producers fold returned cotangents into d_partials / d_h_norm.
    fn apply_reverse_recvs(&mut self, fin: usize, recvs: Vec<Vec<Payload>>) -> Result<()> {
        let k = self.k();
        for w in 0..k {
            for peer in 0..k {
                let payload = match &recvs[w][peer] {
                    Payload::F32(v) if !v.is_empty() => v.clone(),
                    _ => continue,
                };
                let ctx = &self.workers[w];
                let (plo, phi) = ctx.send_pre_range[peer];
                let post = ctx.send_post_rows[peer].clone();
                let pre_vals = (phi - plo) * fin;
                anyhow::ensure!(
                    payload.len() == pre_vals + post.len() * fin,
                    "reverse payload size mismatch"
                );
                let b = &mut self.bufs[w];
                b.d_partials[plo * fin..phi * fin].copy_from_slice(&payload[..pre_vals]);
                // d_h_norm[post_row] += returned post cotangent.
                for (i, &r) in post.iter().enumerate() {
                    let src = &payload[pre_vals + i * fin..pre_vals + (i + 1) * fin];
                    let dst =
                        &mut b.d_h_norm[r as usize * fin..(r as usize + 1) * fin];
                    for (a, &x) in dst.iter_mut().zip(src.iter()) {
                        *a += x;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::coordinator::planner::prepare;
    use crate::graph::generate::sbm;

    fn train(k: usize, tc: TrainConfig, n: usize) -> Vec<EpochStats> {
        let lg = sbm(n, 4, 8.0, 0.85, 16, 0.6, 11);
        let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, None, 5).unwrap();
        let backend = Box::new(NativeBackend::new(cfg));
        let mut tr = Trainer::new(ctxs, backend, tc);
        tr.run(false).unwrap()
    }

    #[test]
    fn single_worker_learns_sbm() {
        let tc = TrainConfig {
            epochs: 30,
            lr: 0.01,
            ..Default::default()
        };
        let stats = train(1, tc, 400);
        let first = &stats[0];
        let last = stats.last().unwrap();
        assert!(last.train_loss < first.train_loss, "loss must decrease");
        assert!(last.test_acc > 0.5, "test acc {} too low", last.test_acc);
    }

    #[test]
    fn distributed_matches_single_worker_loss_curve() {
        // Full-batch + exact reverse halos ⇒ identical-to-roundoff training
        // trajectories regardless of partitioning.
        let tc = TrainConfig {
            epochs: 8,
            ..Default::default()
        };
        let s1 = train(1, tc.clone(), 300);
        let s3 = train(3, tc, 300);
        for (a, b) in s1.iter().zip(s3.iter()) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 2e-3,
                "epoch {}: {} vs {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
    }

    #[test]
    fn int2_with_lp_still_learns() {
        let tc = TrainConfig {
            epochs: 30,
            quant: Some(Bits::Int2),
            label_prop: true,
            ..Default::default()
        };
        let stats = train(3, tc, 400);
        assert!(stats.last().unwrap().test_acc > 0.5);
        // Quant bytes ≈ fp32/16.
        let s = &stats[5];
        assert!(s.comm_data_bytes > 0.0);
        assert!(s.comm_param_bytes > 0.0);
    }

    #[test]
    fn delayed_comm_runs_and_skips_exchanges() {
        let tc = TrainConfig {
            epochs: 10,
            delay_comm: 5,
            strategy: RemoteStrategy::PreOnly,
            ..Default::default()
        };
        let stats = train(3, tc, 300);
        // Comm happens only on epochs 0 and 5.
        let active: Vec<usize> = stats
            .iter()
            .filter(|s| s.comm_data_bytes > 0.0)
            .map(|s| s.epoch)
            .collect();
        assert_eq!(active, vec![0, 5]);
    }

    #[test]
    fn quant_reduces_forward_wire_bytes_16x() {
        // Forward halos are quantized (γ=16); the reverse cotangent
        // exchange stays FP32 (the paper quantizes the forward feature
        // communication). With equal fwd/bwd volumes the total ratio is
        // 2 / (1 + 1/16) ≈ 1.88.
        let tc_fp = TrainConfig {
            epochs: 2,
            ..Default::default()
        };
        let tc_q = TrainConfig {
            epochs: 2,
            quant: Some(Bits::Int2),
            ..Default::default()
        };
        let fp = train(3, tc_fp, 400);
        let q = train(3, tc_q, 400);
        let r = fp[1].comm_data_bytes / q[1].comm_data_bytes;
        assert!(r > 1.7 && r < 2.0, "total ratio {r}");
        // Isolating the forward half: fwd_q = total_q − bwd (= fwd_fp/2).
        let bwd = fp[1].comm_data_bytes / 2.0;
        let fwd_ratio = bwd / (q[1].comm_data_bytes - bwd);
        assert!(fwd_ratio > 15.0 && fwd_ratio < 17.0, "forward ratio {fwd_ratio}");
    }
}
