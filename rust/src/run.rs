//! Unified run configuration (DESIGN.md §15).
//!
//! One `RunConfig` describes a whole training run — regime selection
//! (`sampler`), numerics (lr/quant/strategy/...), executor shape
//! (transport/overlap/group-size), and the fault-tolerance policy
//! (checkpointing, resume, chaos injection). It is the **single
//! construction path** for both trainers: the CLI, benches, and examples
//! build a `RunConfig` and call [`RunConfig::full_batch_trainer`] /
//! [`RunConfig::minibatch_trainer`] instead of assembling
//! `TrainConfig`/`MiniBatchConfig`/`SamplerConfig` literals by hand, so
//! validation and the checkpoint fingerprint live in exactly one place.
//!
//! The [`RunConfig::fingerprint`] hash covers every field that affects
//! the training numerics (seed, lr, quant, sampler shape, ...) and
//! deliberately excludes the fields that are bit-exactness-preserving by
//! construction (transport, overlap, group-size, agg kernel —
//! `tests/spmd_parity.rs`) or pure accounting (machine profile, epoch
//! count, checkpoint knobs). A checkpoint therefore resumes under any
//! executor shape, but never under numerics that would silently diverge.

use crate::comm::transport::{FaultPlan, FaultSpec, Topology, TransportKind};
use crate::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use crate::coordinator::planner::{self, WorkerCtx};
use crate::coordinator::shard;
use crate::coordinator::trainer::{CheckpointPolicy, ElasticCtx, TrainConfig, Trainer};
use crate::exec::AggDispatch;
use crate::graph::generate::LabelledGraph;
use crate::graph::store::GraphStore;
use crate::hier::volume::RemoteStrategy;
use crate::model::optimizer::OptKind;
use crate::perfmodel::MachineProfile;
use crate::quant::Bits;
use crate::runtime::ShapeConfig;
use crate::sample::{SamplerConfig, SamplerKind};
use crate::util::rng::SplitMix64;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Everything a training run needs, in one struct (DESIGN.md §15).
/// Construct with struct-update syntax over [`RunConfig::default`].
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Training regime: `Full` runs the full-batch [`Trainer`], anything
    /// else the mini-batch loop over that sampler.
    pub sampler: SamplerKind,
    pub epochs: usize,
    pub lr: f32,
    pub opt: OptKind,
    /// Halo / fetched-row quantization (None = FP32).
    pub quant: Option<Bits>,
    pub hidden: usize,
    /// Masked label propagation (§6.1(1); full-batch only).
    pub label_prop: bool,
    pub lp_frac: f64,
    /// Remote-graph strategy (full-batch only; mini-batch fetches rows).
    pub strategy: RemoteStrategy,
    /// Halo exchange every N epochs (full-batch only; 1 = synchronous).
    pub delay_comm: usize,
    /// Mini-batch engine LayerNorm toggle (see `MiniBatchConfig`).
    pub layernorm: bool,
    pub machine: MachineProfile,
    pub agg: AggDispatch,
    pub transport: TransportKind,
    pub rank_threads: usize,
    pub overlap: bool,
    pub group_size: usize,
    pub seed: u64,
    /// Sampler hyperparameters (mini-batch regimes; see `SamplerConfig`).
    pub batch_size: usize,
    pub fanouts: Vec<usize>,
    pub walk_length: usize,
    pub num_clusters: usize,
    pub clusters_per_batch: usize,
    pub norm_batches: usize,
    /// Save a v2 checkpoint every N completed epochs (0 = off;
    /// `--checkpoint-every`).
    pub checkpoint_every: usize,
    pub checkpoint_path: PathBuf,
    /// Restore this checkpoint before training (`--resume`).
    pub resume: Option<PathBuf>,
    /// Chaos injection: kill one rank mid-epoch (`--chaos rank=R,epoch=E`;
    /// threaded transport only — test/bench hook, DESIGN.md §15).
    pub chaos: Option<FaultSpec>,
    /// Remote-feature cache capacity in rows per rank
    /// (`--feature-cache-rows`; mini-batch only, DESIGN.md §16).
    pub feature_cache_rows: usize,
    /// Remote-feature cache TTL in fetch rounds (`--feature-cache-ttl`;
    /// 0 = cache off, byte-for-byte the uncached path — DESIGN.md §16).
    /// When > 0, stale rows change the training numerics, so TTL and
    /// capacity join the checkpoint fingerprint.
    pub feature_cache_ttl: usize,
    /// Out-of-core mode (`--graph-dir`; DESIGN.md §17): train from an
    /// on-disk graph directory (`graph.sgcn` + `supergcn prepare` shard
    /// files) through the mmap [`GraphStore`] backend instead of an
    /// in-process graph. Storage only — per-epoch losses are bit-exact
    /// against the in-memory path, so it stays out of the fingerprint
    /// and checkpoints resume across backends.
    pub graph_dir: Option<PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            sampler: SamplerKind::Full,
            epochs: 100,
            lr: 0.01,
            opt: OptKind::Adam,
            quant: None,
            hidden: 64,
            label_prop: false,
            lp_frac: 0.5,
            strategy: RemoteStrategy::Hybrid,
            delay_comm: 1,
            layernorm: false,
            machine: MachineProfile::abci(),
            agg: AggDispatch::default(),
            transport: TransportKind::Sequential,
            rank_threads: 0,
            overlap: false,
            group_size: 1,
            seed: 42,
            batch_size: 512,
            fanouts: vec![15, 10, 5],
            walk_length: 3,
            num_clusters: 0,
            clusters_per_batch: 1,
            norm_batches: 20,
            checkpoint_every: 0,
            checkpoint_path: PathBuf::from("supergcn.ckpt"),
            resume: None,
            chaos: None,
            feature_cache_rows: 0,
            feature_cache_ttl: 0,
            graph_dir: None,
        }
    }
}

/// Fold one 64-bit word into the running fingerprint. SplitMix64 over
/// the xor keeps single-bit input changes avalanching across the hash.
fn mix(h: &mut u64, v: u64) {
    *h = SplitMix64::new(*h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64();
}

fn mix_str(h: &mut u64, s: &str) {
    mix(h, s.len() as u64);
    for b in s.as_bytes() {
        mix(h, *b as u64);
    }
}

impl RunConfig {
    /// The derived full-batch config (numerics + executor shape; the
    /// epoch budget rides along for `Trainer::run`'s loop bound).
    pub fn train_config(&self) -> TrainConfig {
        TrainConfig {
            epochs: self.epochs,
            lr: self.lr,
            opt: self.opt,
            quant: self.quant,
            label_prop: self.label_prop,
            lp_frac: self.lp_frac,
            strategy: self.strategy,
            delay_comm: self.delay_comm,
            machine: self.machine.clone(),
            agg: self.agg.clone(),
            transport: self.transport,
            rank_threads: self.rank_threads,
            overlap: self.overlap,
            group_size: self.group_size,
            seed: self.seed,
        }
    }

    /// The derived mini-batch config.
    pub fn minibatch_config(&self) -> MiniBatchConfig {
        MiniBatchConfig {
            epochs: self.epochs,
            lr: self.lr,
            opt: self.opt,
            quant: self.quant,
            hidden: self.hidden,
            layernorm: self.layernorm,
            agg: self.agg.clone(),
            transport: self.transport,
            rank_threads: self.rank_threads,
            overlap: self.overlap,
            group_size: self.group_size,
            machine: self.machine.clone(),
            seed: self.seed,
            feature_cache_rows: self.feature_cache_rows,
            feature_cache_ttl: self.feature_cache_ttl,
        }
    }

    /// The derived sampler hyperparameters.
    pub fn sampler_config(&self) -> SamplerConfig {
        SamplerConfig {
            batch_size: self.batch_size,
            fanouts: self.fanouts.clone(),
            walk_length: self.walk_length,
            num_clusters: self.num_clusters,
            clusters_per_batch: self.clusters_per_batch,
            norm_batches: self.norm_batches,
            seed: self.seed,
        }
    }

    /// Validate the whole configuration against a worker count — the one
    /// checking path the CLI, benches, and examples all share.
    pub fn validate(&self, workers: usize) -> Result<()> {
        TransportKind::validate_rank_threads(self.rank_threads, workers)?;
        Topology::validate_group_size(self.group_size, workers)?;
        if self.sampler != SamplerKind::Full {
            anyhow::ensure!(self.batch_size >= 1, "--batch-size must be >= 1");
            anyhow::ensure!(
                !self.fanouts.is_empty() && self.fanouts.iter().all(|&f| f >= 1),
                "--fanouts must be a non-empty comma-separated list of integers >= 1"
            );
        }
        if self.feature_cache_ttl > 0 {
            anyhow::ensure!(
                self.sampler != SamplerKind::Full,
                "--feature-cache-ttl applies to the mini-batch fetch path only \
                 (the full-batch regime exchanges halos, not feature rows)"
            );
        }
        if let Some(c) = self.chaos {
            anyhow::ensure!(
                self.transport == TransportKind::Threaded,
                "--chaos requires --transport threaded (a rank failure is a rank-thread panic)"
            );
            anyhow::ensure!(
                c.rank < workers,
                "--chaos rank {} out of range for {workers} workers",
                c.rank
            );
        }
        if self.graph_dir.is_some() {
            anyhow::ensure!(
                self.chaos.is_none(),
                "--chaos cannot combine with --graph-dir: elastic re-planning after a rank \
                 loss needs the in-memory graph backend"
            );
            anyhow::ensure!(
                self.sampler != SamplerKind::Cluster,
                "sampler 'cluster' needs the in-memory graph backend; with --graph-dir use a \
                 streaming sampler (neighbor|saint-rw|saint-node|saint-edge) or the full-batch \
                 regime"
            );
        }
        Ok(())
    }

    /// Hash of every numerics-affecting field — written into checkpoints
    /// and required to match on `--resume` (see the module docs for what
    /// is deliberately excluded and why).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0x5347_434e_0000_0002; // "SGCN" + fingerprint rev
        mix(&mut h, self.lr.to_bits() as u64);
        mix(&mut h, match self.opt {
            OptKind::Sgd => 1,
            OptKind::Adam => 2,
        });
        mix_str(&mut h, self.quant.map(|b| b.name()).unwrap_or("fp32"));
        mix(&mut h, self.label_prop as u64);
        mix(&mut h, self.lp_frac.to_bits());
        mix_str(&mut h, self.strategy.name());
        mix(&mut h, self.delay_comm as u64);
        mix(&mut h, self.hidden as u64);
        mix(&mut h, self.layernorm as u64);
        mix_str(&mut h, self.sampler.name());
        mix(&mut h, self.batch_size as u64);
        mix(&mut h, self.fanouts.len() as u64);
        for &f in &self.fanouts {
            mix(&mut h, f as u64);
        }
        mix(&mut h, self.walk_length as u64);
        mix(&mut h, self.num_clusters as u64);
        mix(&mut h, self.clusters_per_batch as u64);
        mix(&mut h, self.norm_batches as u64);
        mix(&mut h, self.seed);
        // The cache changes numerics only when TTL > 0 (stale rows feed
        // the engine); TTL=0 is the bit-exact identity, so a cache-off
        // checkpoint stays resumable regardless of the capacity knob.
        if self.feature_cache_ttl > 0 {
            mix(&mut h, self.feature_cache_ttl as u64);
            mix(&mut h, self.feature_cache_rows as u64);
        }
        h
    }

    /// The checkpoint policy this config asks for (None when
    /// `checkpoint_every` is 0).
    pub fn checkpoint_policy(&self) -> Option<CheckpointPolicy> {
        (self.checkpoint_every > 0).then(|| CheckpointPolicy {
            every: self.checkpoint_every,
            path: self.checkpoint_path.clone(),
            fingerprint: self.fingerprint(),
        })
    }

    /// Build the full-batch trainer over prepared worker contexts (the
    /// xla-backend path, where shapes come from an artifact manifest; no
    /// elastic recovery — re-planning needs the graph, see
    /// [`RunConfig::full_batch_trainer_elastic`]).
    pub fn full_batch_trainer(&self, ctxs: Vec<WorkerCtx>, shapes: ShapeConfig) -> Trainer {
        let mut tr = Trainer::new(ctxs, shapes, self.train_config());
        tr.ckpt = self.checkpoint_policy();
        tr.chaos = self.chaos.map(FaultPlan::new);
        tr
    }

    /// Partition `lg` across `k` workers, prepare contexts, and build the
    /// full-batch trainer with elastic rank-failure recovery armed
    /// (DESIGN.md §15). Equivalent numerics to `planner::prepare` +
    /// [`RunConfig::full_batch_trainer`].
    pub fn full_batch_trainer_elastic(&self, lg: Arc<LabelledGraph>, k: usize) -> Result<Trainer> {
        let part = planner::partition_for(&lg, k, self.seed);
        let (ctxs, cfg, _) = planner::prepare_parts(&lg, &part, self.strategy, None, self.hidden)?;
        let mut tr = self.full_batch_trainer(ctxs, cfg);
        tr.elastic = Some(ElasticCtx {
            lg,
            part,
            max_failures: k.saturating_sub(1),
        });
        Ok(tr)
    }

    /// Build the mini-batch trainer. Elastic recovery arms itself only on
    /// the in-memory backend (re-planning a lost rank walks the full
    /// graph, which an mmap `--graph-dir` store deliberately never
    /// materializes).
    pub fn minibatch_trainer(
        &self,
        graph: impl Into<GraphStore>,
        k: usize,
    ) -> Result<MiniBatchTrainer> {
        let mut tr = MiniBatchTrainer::new(
            graph,
            k,
            self.sampler,
            &self.sampler_config(),
            self.minibatch_config(),
        )?;
        tr.ckpt = self.checkpoint_policy();
        tr.chaos = self.chaos.map(FaultPlan::new);
        tr.elastic = tr.store.labelled().is_some();
        Ok(tr)
    }

    /// Build the mini-batch trainer for a `--graph-dir` run: the
    /// partition always comes from the streaming block partitioner —
    /// also when the store was materialized in memory for a reference
    /// run — so per-epoch losses are bit-identical across backends
    /// (DESIGN.md §17).
    pub fn minibatch_trainer_oocore(
        &self,
        store: GraphStore,
        k: usize,
    ) -> Result<MiniBatchTrainer> {
        anyhow::ensure!(k >= 1, "need at least one worker");
        let part = planner::block_partition(&store, k);
        let mut tr = MiniBatchTrainer::with_partition(
            store,
            part,
            self.sampler,
            &self.sampler_config(),
            self.minibatch_config(),
        )?;
        tr.ckpt = self.checkpoint_policy();
        tr.chaos = self.chaos.map(FaultPlan::new);
        tr.elastic = tr.store.labelled().is_some();
        Ok(tr)
    }

    /// Build the full-batch trainer from `supergcn prepare` shard files
    /// (DESIGN.md §17): each rank's context comes straight out of its
    /// self-contained shard, so the global graph is never loaded. The
    /// shards must have been prepared under the same remote-strategy the
    /// run asks for (plans are baked in at prepare time).
    pub fn full_batch_trainer_from_shards(&self, dir: &Path) -> Result<Trainer> {
        let shards = shard::load_shards(dir)?;
        anyhow::ensure!(
            shards[0].strategy == self.strategy,
            "shard files in {} were prepared with --strategy {}, but this run asks for {} — \
             re-run `supergcn prepare` with the matching strategy",
            dir.display(),
            shards[0].strategy.name(),
            self.strategy.name()
        );
        let bytes = shard::total_bytes(&shards);
        let (ctxs, shapes) = shard::build_ctxs_from_shards(&shards, self.hidden)?;
        let mut tr = self.full_batch_trainer(ctxs, shapes);
        tr.store_shard_bytes = bytes;
        Ok(tr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_ignores_executor_shape_and_budget() {
        let base = RunConfig::default();
        let fp = base.fingerprint();
        let variants = [
            RunConfig {
                epochs: 7,
                ..base.clone()
            },
            RunConfig {
                transport: TransportKind::Threaded,
                overlap: true,
                group_size: 2,
                ..base.clone()
            },
            RunConfig {
                machine: MachineProfile::fugaku(),
                ..base.clone()
            },
            RunConfig {
                checkpoint_every: 3,
                checkpoint_path: PathBuf::from("elsewhere.ckpt"),
                ..base.clone()
            },
            // TTL=0 is the identity, so capacity alone must not shift
            // the fingerprint (DESIGN.md §16).
            RunConfig {
                feature_cache_rows: 512,
                ..base.clone()
            },
            // Storage backend is loss-bit-neutral (DESIGN.md §17), so a
            // checkpoint written in memory resumes under --graph-dir.
            RunConfig {
                graph_dir: Some(PathBuf::from("/tmp/g")),
                ..base.clone()
            },
        ];
        for v in &variants {
            assert_eq!(v.fingerprint(), fp, "executor/budget field leaked into fingerprint");
        }
    }

    #[test]
    fn fingerprint_tracks_numerics() {
        let base = RunConfig::default();
        let fp = base.fingerprint();
        let variants = [
            RunConfig {
                lr: 0.02,
                ..base.clone()
            },
            RunConfig {
                seed: 43,
                ..base.clone()
            },
            RunConfig {
                quant: Some(Bits::Int2),
                ..base.clone()
            },
            RunConfig {
                sampler: SamplerKind::Neighbor,
                ..base.clone()
            },
            RunConfig {
                fanouts: vec![15, 10],
                ..base.clone()
            },
            RunConfig {
                hidden: 32,
                ..base.clone()
            },
            RunConfig {
                feature_cache_ttl: 2,
                ..base.clone()
            },
        ];
        for v in &variants {
            assert_ne!(v.fingerprint(), fp, "numerics field missing from fingerprint");
        }
        // With the cache live (TTL>0), capacity is numerics-affecting.
        let on = RunConfig {
            feature_cache_ttl: 2,
            feature_cache_rows: 64,
            ..base.clone()
        };
        let on2 = RunConfig {
            feature_cache_rows: 128,
            ..on.clone()
        };
        assert_ne!(on.fingerprint(), on2.fingerprint());
    }

    #[test]
    fn converters_copy_every_shared_field() {
        let rc = RunConfig {
            epochs: 9,
            lr: 0.05,
            quant: Some(Bits::Int4),
            hidden: 48,
            transport: TransportKind::Threaded,
            overlap: true,
            group_size: 2,
            seed: 7,
            batch_size: 33,
            fanouts: vec![4, 2],
            feature_cache_rows: 96,
            feature_cache_ttl: 3,
            ..RunConfig::default()
        };
        let tc = rc.train_config();
        assert_eq!(tc.epochs, 9);
        assert_eq!(tc.lr, 0.05);
        assert_eq!(tc.quant, Some(Bits::Int4));
        assert_eq!(tc.transport, TransportKind::Threaded);
        assert!(tc.overlap);
        assert_eq!(tc.group_size, 2);
        assert_eq!(tc.seed, 7);
        let mc = rc.minibatch_config();
        assert_eq!(mc.hidden, 48);
        assert_eq!(mc.seed, 7);
        assert_eq!(mc.quant, Some(Bits::Int4));
        assert_eq!(mc.feature_cache_rows, 96);
        assert_eq!(mc.feature_cache_ttl, 3);
        let sc = rc.sampler_config();
        assert_eq!(sc.batch_size, 33);
        assert_eq!(sc.fanouts, vec![4, 2]);
        assert_eq!(sc.seed, 7);
    }

    #[test]
    fn validate_checks_sampler_and_chaos() {
        let mut rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            fanouts: vec![],
            ..RunConfig::default()
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("--fanouts must be a non-empty"), "{e}");
        rc.fanouts = vec![5, 3];
        rc.batch_size = 0;
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("--batch-size must be >= 1"), "{e}");

        let rc = RunConfig {
            chaos: Some(FaultSpec { rank: 1, epoch: 2 }),
            ..RunConfig::default()
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("--chaos requires --transport threaded"), "{e}");
        let rc = RunConfig {
            chaos: Some(FaultSpec { rank: 9, epoch: 2 }),
            transport: TransportKind::Threaded,
            ..rc
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("out of range for 4 workers"), "{e}");
        let rc = RunConfig {
            chaos: Some(FaultSpec { rank: 1, epoch: 2 }),
            ..rc
        };
        rc.validate(4).unwrap();

        // Feature cache is a mini-batch knob: TTL>0 under the full-batch
        // regime is a config error; under a sampler it validates.
        let rc = RunConfig {
            feature_cache_ttl: 1,
            ..RunConfig::default()
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("--feature-cache-ttl applies to the mini-batch"), "{e}");
        let rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            ..rc
        };
        rc.validate(4).unwrap();

        // Out-of-core conflicts (DESIGN.md §17): no chaos/elastic re-plan
        // and no in-memory-only sampler on the mmap backend.
        let rc = RunConfig {
            graph_dir: Some(PathBuf::from("/tmp/g")),
            chaos: Some(FaultSpec { rank: 1, epoch: 2 }),
            transport: TransportKind::Threaded,
            ..RunConfig::default()
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("--chaos cannot combine with --graph-dir"), "{e}");
        let rc = RunConfig {
            graph_dir: Some(PathBuf::from("/tmp/g")),
            sampler: SamplerKind::Cluster,
            ..RunConfig::default()
        };
        let e = rc.validate(4).unwrap_err().to_string();
        assert!(e.contains("needs the in-memory graph backend"), "{e}");
        let rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            ..rc
        };
        rc.validate(4).unwrap();
    }

    #[test]
    fn checkpoint_policy_off_by_default() {
        assert!(RunConfig::default().checkpoint_policy().is_none());
        let rc = RunConfig {
            checkpoint_every: 5,
            ..RunConfig::default()
        };
        let p = rc.checkpoint_policy().unwrap();
        assert_eq!(p.every, 5);
        assert_eq!(p.fingerprint, rc.fingerprint());
    }
}
