//! [`GraphContext`] for the mini-batch regime: each SPMD lane processes
//! one sampled [`MiniBatch`] per round; neighbor features arrive by
//! fetching remote feature rows from their owning partitions (`u32` ids
//! on the wire, rows returned through `comm::alltoallv`, optionally
//! `quant::fused`-quantized), and aggregation runs the batch's induced
//! weighted CSR through the dispatcher's SpMM path.
//!
//! Like the full-batch module, two context flavors share the per-pair
//! request/serve/assemble building blocks: [`MiniBatchCtx`] (sequential
//! transport, all lanes in one driver thread) and [`MiniBatchRankCtx`]
//! (threaded transport, one lane per rank thread over the mailbox
//! [`Fabric`](crate::comm::transport::Fabric)) — bit-exactness across
//! transports is pinned by `tests/spmd_parity.rs`.

use super::dispatch::AggDispatch;
use super::GraphContext;
use crate::agg::spmm::CsrMatrix;
use crate::comm::transport::Fabric;
use crate::comm::{alltoallv, CommStats, Payload};
use crate::graph::generate::LabelledGraph;
use crate::perfmodel::MachineProfile;
use crate::quant::{fused, Bits};
use crate::sample::{mix2, MiniBatch};
use anyhow::Result;
use std::time::Instant;

/// One round's view: worker lane `w` processes `batches[per_lane[w]]`
/// (idle lanes — `None` — run zero-row no-ops through the engine).
pub struct MiniBatchCtx<'a> {
    lg: &'a LabelledGraph,
    /// Partition ownership of global feature rows.
    assign: &'a [u32],
    batches: &'a [MiniBatch],
    per_lane: &'a [Option<usize>],
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    comm: &'a mut CommStats,
    /// The induced weighted adjacency per lane, in the form `agg::spmm`
    /// wants (built once per round, shared by all three layers).
    mats: Vec<Option<CsrMatrix>>,
}

impl<'a> MiniBatchCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        lg: &'a LabelledGraph,
        assign: &'a [u32],
        batches: &'a [MiniBatch],
        per_lane: &'a [Option<usize>],
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        round: usize,
        comm: &'a mut CommStats,
    ) -> Self {
        let mats = per_lane
            .iter()
            .map(|slot| slot.map(|bi| induced_csr(&batches[bi])))
            .collect();
        Self {
            lg,
            assign,
            batches,
            per_lane,
            machine,
            quant,
            seed,
            epoch,
            round,
            comm,
            mats,
        }
    }
}

impl GraphContext for MiniBatchCtx<'_> {
    fn lanes(&self) -> usize {
        self.per_lane.len()
    }

    /// The fetch: id requests to owners, then (quantized) feature-row
    /// replies, then per-lane assembly of the batch input matrix.
    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.per_lane.len();
        let f = self.lg.feat_dim;
        // ---- id requests --------------------------------------------
        let req_sends: Vec<Vec<Payload>> = (0..k)
            .map(|w| match self.per_lane[w] {
                Some(bi) => request_ids(&self.batches[bi], self.assign, w, k)
                    .iter()
                    .map(|ids| ids_payload(ids))
                    .collect(),
                None => (0..k).map(|_| Payload::Empty).collect(),
            })
            .collect();
        let req_recvs = alltoallv(req_sends, self.machine, &mut *self.comm);

        // ---- replies (owner side) -----------------------------------
        let mut reply_sends: Vec<Vec<Payload>> = (0..k)
            .map(|_| (0..k).map(|_| Payload::Empty).collect())
            .collect();
        for (o, row) in req_recvs.iter().enumerate() {
            for (w, payload) in row.iter().enumerate() {
                let ids = match payload {
                    Payload::F32(v) if !v.is_empty() => v,
                    _ => continue,
                };
                reply_sends[o][w] = reply_payload(
                    self.lg,
                    ids,
                    self.quant,
                    self.seed,
                    self.epoch,
                    self.round,
                    o,
                    w,
                    &mut quant_secs[o],
                );
            }
        }
        let mut replies = alltoallv(reply_sends, self.machine, &mut *self.comm);

        // ---- assemble X per lane ------------------------------------
        for w in 0..k {
            let bi = match self.per_lane[w] {
                Some(bi) => bi,
                None => continue,
            };
            let mb = &self.batches[bi];
            let decoded = decode_replies(&mut replies[w], &mut quant_secs[w]);
            let t = Instant::now();
            assemble_x(self.lg, self.assign, mb, w, &decoded, f, &mut x[w])?;
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        _layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        for (w, mat) in self.mats.iter().enumerate() {
            if let Some(a) = mat {
                let t = Instant::now();
                let zv = &mut z[w][..a.n_rows * fin];
                zv.iter_mut().for_each(|x| *x = 0.0);
                disp.spmm(a, &h[w][..a.n_cols * fin], fin, zv);
                secs[w] += t.elapsed().as_secs_f64();
            }
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        for (w, mat) in self.mats.iter().enumerate() {
            if let Some(a) = mat {
                let t = Instant::now();
                disp.spmm_t(a, &dz[w][..a.n_rows * fin], fin, &mut d_h[w][..a.n_cols * fin]);
                secs[w] += t.elapsed().as_secs_f64();
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Per-pair building blocks, shared by the sequential multi-lane context
// and the threaded per-rank context (one implementation ⇒ transport
// parity is bit-exact by construction).
// ---------------------------------------------------------------------

fn induced_csr(mb: &MiniBatch) -> CsrMatrix {
    CsrMatrix {
        n_rows: mb.adj.n,
        n_cols: mb.adj.n,
        row_ptr: mb.adj.row_ptr.clone(),
        col_idx: mb.adj.col_idx.clone(),
        weights: mb.edge_weight.clone(),
    }
}

/// The remote feature-row ids lane `w` must fetch, grouped by owner.
fn request_ids(mb: &MiniBatch, assign: &[u32], w: usize, k: usize) -> Vec<Vec<u32>> {
    let mut req: Vec<Vec<u32>> = vec![Vec::new(); k];
    for &v in &mb.n_id {
        let o = assign[v as usize] as usize;
        if o != w {
            req[o].push(v);
        }
    }
    req
}

/// Ids travel as an F32 payload (`n < 2^24` keeps them exact — enforced
/// at trainer construction).
fn ids_payload(ids: &[u32]) -> Payload {
    if ids.is_empty() {
        Payload::Empty
    } else {
        Payload::F32(ids.iter().map(|&v| v as f32).collect())
    }
}

/// Owner `o` serves requester `w`: gather the requested feature rows,
/// optionally quantizing them (quantize time charged to the owner).
#[allow(clippy::too_many_arguments)]
fn reply_payload(
    lg: &LabelledGraph,
    ids: &[f32],
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    o: usize,
    w: usize,
    quant_secs: &mut f64,
) -> Payload {
    let f = lg.feat_dim;
    let rows = ids.len();
    let mut buf = Vec::with_capacity(rows * f);
    for &idf in ids {
        buf.extend_from_slice(lg.feature_row(idf as usize));
    }
    match quant {
        Some(bits) => {
            let t = Instant::now();
            let qseed = mix2(
                mix2(seed, ((epoch as u64) << 20) ^ round as u64),
                ((o as u64) << 8) ^ w as u64,
            );
            let q = fused::quantize(&buf, rows, f, bits, qseed);
            *quant_secs += t.elapsed().as_secs_f64();
            Payload::Quant(q)
        }
        None => Payload::F32(buf),
    }
}

/// Move each reply out of its slot and dequantize (dequantize time
/// charged to the requester). `decoded[o]` = rows from owner `o`.
fn decode_replies(replies: &mut [Payload], quant_secs: &mut f64) -> Vec<Option<Vec<f32>>> {
    let mut decoded: Vec<Option<Vec<f32>>> = vec![None; replies.len()];
    for (o, slot) in replies.iter_mut().enumerate() {
        match std::mem::replace(slot, Payload::Empty) {
            Payload::F32(v) if !v.is_empty() => decoded[o] = Some(v),
            Payload::Quant(q) => {
                let t = Instant::now();
                decoded[o] = Some(fused::dequantize(&q));
                *quant_secs += t.elapsed().as_secs_f64();
            }
            _ => {}
        }
    }
    decoded
}

/// Interleave local rows and decoded remote rows into the lane's batch
/// input matrix (each reply consumed front to back, exactly once).
fn assemble_x(
    lg: &LabelledGraph,
    assign: &[u32],
    mb: &MiniBatch,
    w: usize,
    decoded: &[Option<Vec<f32>>],
    f: usize,
    x: &mut [f32],
) -> Result<()> {
    let mut cursors = vec![0usize; decoded.len()];
    for (i, &v) in mb.n_id.iter().enumerate() {
        let o = assign[v as usize] as usize;
        if o == w {
            x[i * f..(i + 1) * f].copy_from_slice(lg.feature_row(v as usize));
        } else {
            let rows = decoded[o]
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("missing reply from {o} to {w}"))?;
            let c = cursors[o];
            anyhow::ensure!((c + 1) * f <= rows.len(), "reply row underflow");
            x[i * f..(i + 1) * f].copy_from_slice(&rows[c * f..(c + 1) * f]);
            cursors[o] += 1;
        }
    }
    Ok(())
}

/// Single-rank mini-batch context for the threaded transport: lane
/// `rank`'s batch only (or `None` for an idle lane — it still serves
/// feature rows it owns and participates in every collective). All
/// mutable state is the rank's own; shared inputs (`LabelledGraph`,
/// ownership assignment) are `&` — the Send/Sync contract of
/// DESIGN.md §10.
pub struct MiniBatchRankCtx<'a> {
    rank: usize,
    lg: &'a LabelledGraph,
    assign: &'a [u32],
    batch: Option<&'a MiniBatch>,
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    fabric: &'a Fabric,
    comm: &'a mut CommStats,
    mat: Option<CsrMatrix>,
}

impl<'a> MiniBatchRankCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        lg: &'a LabelledGraph,
        assign: &'a [u32],
        batch: Option<&'a MiniBatch>,
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        round: usize,
        fabric: &'a Fabric,
        comm: &'a mut CommStats,
    ) -> Self {
        let mat = batch.map(induced_csr);
        Self {
            rank,
            lg,
            assign,
            batch,
            machine,
            quant,
            seed,
            epoch,
            round,
            fabric,
            comm,
            mat,
        }
    }
}

impl GraphContext for MiniBatchRankCtx<'_> {
    fn lanes(&self) -> usize {
        1
    }

    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.fabric.k();
        let f = self.lg.feat_dim;
        // ---- id requests (own row) ----------------------------------
        let req_sends: Vec<Payload> = match self.batch {
            Some(mb) => request_ids(mb, self.assign, self.rank, k)
                .iter()
                .map(|ids| ids_payload(ids))
                .collect(),
            None => (0..k).map(|_| Payload::Empty).collect(),
        };
        let req_recvs = self.fabric.alltoallv(self.rank, req_sends, self.machine, self.comm);

        // ---- serve requests addressed to this owner -----------------
        let mut reply_sends: Vec<Payload> = (0..k).map(|_| Payload::Empty).collect();
        for (w, payload) in req_recvs.iter().enumerate() {
            let ids = match payload {
                Payload::F32(v) if !v.is_empty() => v,
                _ => continue,
            };
            reply_sends[w] = reply_payload(
                self.lg,
                ids,
                self.quant,
                self.seed,
                self.epoch,
                self.round,
                self.rank,
                w,
                &mut quant_secs[0],
            );
        }
        let mut replies = self.fabric.alltoallv(self.rank, reply_sends, self.machine, self.comm);

        // ---- assemble own X -----------------------------------------
        if let Some(mb) = self.batch {
            let decoded = decode_replies(&mut replies, &mut quant_secs[0]);
            let t = Instant::now();
            assemble_x(self.lg, self.assign, mb, self.rank, &decoded, f, &mut x[0])?;
            secs[0] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        _layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        if let Some(a) = &self.mat {
            let t = Instant::now();
            let zv = &mut z[0][..a.n_rows * fin];
            zv.iter_mut().for_each(|x| *x = 0.0);
            disp.spmm(a, &h[0][..a.n_cols * fin], fin, zv);
            secs[0] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        if let Some(a) = &self.mat {
            let t = Instant::now();
            disp.spmm_t(a, &dz[0][..a.n_rows * fin], fin, &mut d_h[0][..a.n_cols * fin]);
            secs[0] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Engine, LossSpec, StageClock};
    use crate::graph::generate::sbm;
    use crate::model::ModelParams;
    use crate::runtime::ShapeConfig;
    use crate::sample::{FullSampler, Sampler};
    use crate::util::propcheck::grad_check;
    use std::sync::Arc;

    fn fd_shapes() -> ShapeConfig {
        ShapeConfig {
            name: "fd".into(),
            n_pad: 0,
            f_in: 6,
            hidden: 5,
            classes: 3,
            e_local: 0,
            e_pre: 0,
            p_pre: 0,
            r_pre: 0,
            r_post: 0,
            e_post: 0,
        }
    }

    /// The shared finite-difference gradient check
    /// (`util::propcheck::grad_check`) run against the engine in the
    /// mini-batch regime; `tests/trainer_equivalence.rs` runs the same
    /// check in the full-batch regime.
    #[test]
    fn engine_backward_matches_finite_differences() {
        let lg = Arc::new(sbm(60, 3, 6.0, 0.9, 6, 0.3, 3));
        let mut sampler = FullSampler::new(lg.clone());
        let batches = vec![sampler.sample(0, 0)];
        let per_lane = vec![Some(0usize)];
        let shapes = fd_shapes();
        let engine = Engine::new(&shapes, false, AggDispatch::default());
        let params = ModelParams::init(&shapes, 7);
        let machine = MachineProfile::abci();
        let assign = vec![0u32; lg.n()];
        let rows = vec![batches[0].n()];
        let nt = batches[0].n_target;
        let labels: Vec<u32> = batches[0].n_id[..nt]
            .iter()
            .map(|&v| lg.labels[v as usize])
            .collect();
        let split: Vec<u8> = batches[0].n_id[..nt]
            .iter()
            .map(|&v| lg.split[v as usize])
            .collect();

        let run = |p: &ModelParams, want_grads: bool| -> (f64, Vec<f32>) {
            let mut comm = CommStats::new(1);
            let mut ctx = MiniBatchCtx::new(
                &lg, &assign, &batches, &per_lane, &machine, None, 5, 0, 0, &mut comm,
            );
            let mut tapes = engine.tapes(&rows, p);
            let mut clock = StageClock::new(1);
            engine
                .forward(p, &mut ctx, &mut tapes, None, &mut clock)
                .unwrap();
            let spec = LossSpec {
                score_rows: nt,
                labels: &labels,
                split: &split,
                loss_w: &batches[0].node_weight,
            };
            let tot = engine.loss_all(&mut tapes, &[spec], &mut clock)[0];
            let loss = tot.loss_sum / tot.wsum;
            if !want_grads {
                return (loss, Vec::new());
            }
            engine.scale_loss_grad(&mut tapes, &[(1.0 / tot.wsum) as f32]);
            engine
                .backward(p, &mut ctx, &mut tapes, None, false, &mut clock)
                .unwrap();
            (loss, tapes.grads[0].flatten())
        };

        let (_, analytic) = run(&params, true);
        let flat = params.flatten();
        // Probe w_self/w_neigh/b coordinates of each layer (layout: per
        // layer w_self, w_neigh, b).
        let l0 = 2 * 6 * 5 + 5;
        let l1 = 2 * 5 * 5 + 5;
        let probes = [
            0usize,              // layer0 w_self
            6 * 5 + 3,           // layer0 w_neigh
            2 * 6 * 5 + 2,       // layer0 b
            l0 + 1,              // layer1 w_self
            l0 + 5 * 5 + 2,      // layer1 w_neigh
            l0 + l1 + 4,         // layer2 w_self
            l0 + l1 + 5 * 3 + 1, // layer2 w_neigh
        ];
        grad_check(&flat, &analytic, &probes, 1e-2, |p| {
            let mut pp = ModelParams::init(&fd_shapes(), 7);
            pp.unflatten_into(p);
            run(&pp, false).0
        });
    }

    #[test]
    fn idle_lanes_are_noops() {
        let lg = Arc::new(sbm(80, 3, 5.0, 0.9, 6, 0.3, 9));
        let mut sampler = FullSampler::new(lg.clone());
        let batches = vec![sampler.sample(0, 0)];
        // Lane 1 idle.
        let per_lane = vec![Some(0usize), None];
        let shapes = fd_shapes();
        let engine = Engine::new(&shapes, false, AggDispatch::default());
        let params = ModelParams::init(&shapes, 3);
        let machine = MachineProfile::abci();
        let assign = vec![0u32; lg.n()];
        let rows = vec![batches[0].n(), 0];
        let mut comm = CommStats::new(2);
        let mut ctx = MiniBatchCtx::new(
            &lg, &assign, &batches, &per_lane, &machine, None, 1, 0, 0, &mut comm,
        );
        let mut tapes = engine.tapes(&rows, &params);
        let mut clock = StageClock::new(2);
        engine
            .forward(&params, &mut ctx, &mut tapes, None, &mut clock)
            .unwrap();
        assert!(tapes.h[3][0].iter().any(|&v| v != 0.0));
        assert!(tapes.h[3][1].is_empty());
        // Idle lane produced zero grads.
        engine
            .backward(&params, &mut ctx, &mut tapes, None, false, &mut clock)
            .unwrap();
        assert!(tapes.grads[1].flatten().iter().all(|&g| g == 0.0));
    }
}
