//! Communication-aware stochastic integer quantization (paper §6, §7.3).
//!
//! FP32 feature rows are quantized to intX (X ∈ {2,4,8}) in groups of
//! `GROUP_ROWS = 4` rows: each group stores a zero-point `Z = min` and a
//! scale `S = (max − min)/(2^b − 1)` as FP32 "params" that travel with the
//! payload (Eqn 5's `Params` term). Rounding is stochastic
//! (`⌊x + u⌋`, `u ∼ U[0,1)`), which keeps the dequantized message an
//! unbiased estimator — the property Lemma 1's convergence argument needs.
//!
//! Three implementations are provided:
//! * [`naive`]  — two-pass, division in the inner loop, generator state
//!   threaded through every element (the baseline the paper starts from),
//! * [`fused`]  — the paper's §7.3 optimized kernel: fused stats+quant
//!   over 4-row groups, reciprocal-multiply instead of division, counter-
//!   based noise with no sequential RNG dependency, chunked inner loops
//!   that auto-vectorize, and in-register int2 packing,
//! * [`simd`]   — explicit AVX2 intrinsics behind runtime ISA dispatch
//!   (scalar fallback = `fused`), wire-bit-identical to `fused`
//!   (DESIGN.md §14).

pub mod fused;
pub mod naive;
pub mod packing;
pub mod simd;

/// Bit width of the quantized payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bits {
    Int2,
    Int4,
    Int8,
}

impl Bits {
    pub fn bits(&self) -> usize {
        match self {
            Bits::Int2 => 2,
            Bits::Int4 => 4,
            Bits::Int8 => 8,
        }
    }
    /// Number of quantization levels − 1 (max code).
    pub fn max_code(&self) -> u32 {
        (1u32 << self.bits()) - 1
    }
    /// Values packed per byte.
    pub fn per_byte(&self) -> usize {
        8 / self.bits()
    }
    pub fn name(&self) -> &'static str {
        match self {
            Bits::Int2 => "int2",
            Bits::Int4 => "int4",
            Bits::Int8 => "int8",
        }
    }
}

/// Rows per parameter group (fixed to 4 per §7.3(2): four int2 values pack
/// into one byte, and stats are fused over the same 4 rows).
pub const GROUP_ROWS: usize = 4;

/// A quantized message: packed codes + per-group (zero, scale) params.
#[derive(Clone, Debug, PartialEq)]
pub struct Quantized {
    pub bits: Bits,
    pub rows: usize,
    pub cols: usize,
    /// ceil(rows/GROUP_ROWS) pairs of (zero_point, scale).
    pub params: Vec<(f32, f32)>,
    /// Packed codes, groups back to back; each group is
    /// `ceil(group_rows*cols*bits/8)` bytes with row-major code order.
    pub data: Vec<u8>,
}

impl Quantized {
    pub fn n_groups(&self) -> usize {
        self.rows.div_ceil(GROUP_ROWS)
    }

    /// Wire size in bytes: payload + params (Eqn 5's numerator).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
    pub fn param_bytes(&self) -> usize {
        self.params.len() * 8
    }
    pub fn wire_bytes(&self) -> usize {
        self.payload_bytes() + self.param_bytes()
    }
}

/// Compute (zero, scale) for a slice per §2.4.
#[inline]
pub fn group_params(vals: &[f32], bits: Bits) -> (f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &v in vals {
        mn = mn.min(v);
        mx = mx.max(v);
    }
    if !mn.is_finite() || !mx.is_finite() {
        return (0.0, 0.0);
    }
    let scale = (mx - mn) / bits.max_code() as f32;
    (mn, scale)
}

/// Quantization error bound: |dequant(x) − x| ≤ scale (stochastic rounding
/// can land on either neighbor). Used by tests.
pub fn error_bound(params: &[(f32, f32)]) -> f32 {
    params.iter().map(|&(_, s)| s).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_arithmetic() {
        assert_eq!(Bits::Int2.max_code(), 3);
        assert_eq!(Bits::Int4.max_code(), 15);
        assert_eq!(Bits::Int8.max_code(), 255);
        assert_eq!(Bits::Int2.per_byte(), 4);
        assert_eq!(Bits::Int8.per_byte(), 1);
    }

    #[test]
    fn group_params_range() {
        let (z, s) = group_params(&[1.0, 5.0, 3.0], Bits::Int2);
        assert_eq!(z, 1.0);
        assert!((s - 4.0 / 3.0).abs() < 1e-6);
        // Constant slice → scale 0.
        let (z2, s2) = group_params(&[2.5, 2.5], Bits::Int8);
        assert_eq!((z2, s2), (2.5, 0.0));
    }

    #[test]
    fn wire_bytes_accounting() {
        let q = Quantized {
            bits: Bits::Int2,
            rows: 8,
            cols: 16,
            params: vec![(0.0, 1.0); 2],
            data: vec![0; 2 * (4 * 16 * 2) / 8],
        };
        assert_eq!(q.n_groups(), 2);
        assert_eq!(q.payload_bytes(), 32);
        assert_eq!(q.param_bytes(), 16);
        assert_eq!(q.wire_bytes(), 48);
    }
}
