//! Offline vendored subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access (DESIGN.md §1
//! "Offline-dependency substitutions"), so this crate provides the slice
//! of `anyhow` the workspace actually uses: [`Error`] with a context
//! chain, [`Result`], the [`Context`] extension trait for `Result` and
//! `Option`, and the `anyhow!` / `ensure!` / `bail!` macros. Formatting
//! matches upstream closely enough for logs and tests: `{}` prints the
//! outermost message, `{:#}` prints the whole chain joined by `": "`,
//! and `{:?}` prints the message plus a `Caused by:` list.

use std::fmt;

/// An error with a stack of human-readable context messages.
/// `chain[0]` is the outermost (most recently attached) context; the
/// root cause is last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (the root cause).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// `?` on any std error inside a function returning `anyhow::Result`.
// (Error itself deliberately does NOT implement std::error::Error, which
// is what makes this blanket impl coherent — same trick as upstream.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path").context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out ({} given)", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out (5 given)");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let v: Option<u32> = Some(3);
        assert_eq!(v.with_context(|| "never").unwrap(), 3);
    }
}
