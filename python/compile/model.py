"""L2: per-layer GraphSAGE compute graphs for distributed full-batch
training (paper §2.1/§3.2), built on the L1 Pallas kernels.

The distributed trainer (Rust) orchestrates, per layer:

    pre_fwd   →  [halo exchange]  →  layer_fwd      (forward)
    layer_bwd →  [reverse exchange] → pre_bwd       (backward)

so each stage here is an independent jittable function with static padded
shapes, AOT-lowered by `aot.py` to one HLO artifact each. Backward
functions are produced with `jax.vjp` over the forward definitions, so
distributed gradients are exact by construction.

Shape/padding conventions (see DESIGN.md §4):
* every worker's tensors are padded to the artifact config's shapes;
* `h` has a reserved **zero row** (index n_pad−2) that padded gather
  indices point to, and a **trash row** (n_pad−1) that padded scatter
  destinations point to; `deg_inv`/`mask` are 0 on pads;
* edge arrays are padded to multiples of the kernel edge block (128).
"""

import jax
import jax.numpy as jnp

from .kernels.aggregate import segment_sum
from .kernels.layernorm import layernorm


# ---------------------------------------------------------------------------
# Forward stages
# ---------------------------------------------------------------------------

def pre_fwd(h, pre_gather, pre_segrel, pre_blockseg, *, n_pre_seg):
    """LayerNorm + pre-aggregation partial production (Fig 2 steps 3–4).

    h: [n_pad, f]. Returns (h_norm [n_pad, f], partials [n_pre_seg, f]).
    The last pre segment is the trash segment for padded entries.
    """
    h_norm = layernorm(h)
    partials = segment_sum(h_norm, pre_gather, pre_segrel, pre_blockseg, n_pre_seg)
    return h_norm, partials


def layer_fwd(
    h_norm,
    recv_pre,
    recv_post,
    w_self,
    w_neigh,
    b,
    local_gather,
    local_segrel,
    local_blockseg,
    rpre_dst,
    post_row,
    post_dst,
    deg_inv,
    *,
    relu,
):
    """Aggregate local + received halo contributions, then the SAGE update
    (Fig 2 steps 4, 6, 7).

    h_norm:    [n_pad, fin]   (from pre_fwd)
    recv_pre:  [r_pre, fin]   partials received (concatenated over peers)
    recv_post: [r_post, fin]  raw boundary rows received
    local_*:   planned segment-sum spec of the local edges (sorted by dst)
    rpre_dst:  [r_pre] local dst of each received partial (pads → trash row)
    post_row/post_dst: [e_post] post-aggregation edges (pads → zero recv
               row / trash dst)
    deg_inv:   [n_pad] 1/full-degree (0 on pads and isolated nodes)
    Returns h_out [n_pad, fout].
    """
    n_pad = h_norm.shape[0]
    z = segment_sum(h_norm, local_gather, local_segrel, local_blockseg, n_pad)
    z = z.at[rpre_dst].add(recv_pre)
    z = z.at[post_dst].add(recv_post[post_row])
    z = z * deg_inv[:, None]
    out = h_norm @ w_self + z @ w_neigh + b[None, :]
    if relu:
        out = jax.nn.relu(out)
    return out


def loss_head(logits, labels, mask):
    """Masked softmax cross-entropy **sum** + correct-prediction count.

    logits: [n_pad, c]; labels: [n_pad] int32; mask: [n_pad] f32 (0 on
    pads / non-split nodes). Returns (loss_sum, d_logits, correct, mask_sum).
    The caller (Rust) divides by the *global* masked count — workers can't
    know it locally — and rescales d_logits by the same factor before the
    backward sweep.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    n = logits.shape[0]
    picked = logp[jnp.arange(n), labels]
    loss_sum = -jnp.sum(picked * mask)
    # d(loss_sum)/d(logits) = (softmax - onehot) * mask
    sm = jnp.exp(logp)
    onehot = jax.nn.one_hot(labels, logits.shape[1], dtype=logits.dtype)
    d_logits = (sm - onehot) * mask[:, None]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels) * mask)
    return loss_sum, d_logits, correct, jnp.sum(mask)


# ---------------------------------------------------------------------------
# Backward stages (vjp-derived)
# ---------------------------------------------------------------------------

def layer_bwd(
    h_norm,
    recv_pre,
    recv_post,
    w_self,
    w_neigh,
    b,
    local_gather,
    local_segrel,
    local_blockseg,
    rpre_dst,
    post_row,
    post_dst,
    deg_inv,
    d_out,
    *,
    relu,
):
    """Cotangents of `layer_fwd` w.r.t. its differentiable inputs.

    Returns (d_h_norm, d_recv_pre, d_recv_post, d_w_self, d_w_neigh, d_b,
    out). The trailing primal output keeps every input live through XLA's
    dead-parameter elimination (without ReLU the bias value is unused by
    the cotangents, and PJRT would prune its buffer slot); d_recv_pre /
    d_recv_post are shipped back to their producers on the reverse halo
    exchange.
    """

    def f(h_norm_, recv_pre_, recv_post_, w_self_, w_neigh_, b_):
        return layer_fwd(
            h_norm_,
            recv_pre_,
            recv_post_,
            w_self_,
            w_neigh_,
            b_,
            local_gather,
            local_segrel,
            local_blockseg,
            rpre_dst,
            post_row,
            post_dst,
            deg_inv,
            relu=relu,
        )

    primal, vjp = jax.vjp(f, h_norm, recv_pre, recv_post, w_self, w_neigh, b)
    return vjp(d_out) + (primal,)


def pre_bwd(h, pre_gather, pre_segrel, pre_blockseg, d_h_norm, d_partials, *, n_pre_seg):
    """Cotangent of `pre_fwd` w.r.t. `h`.

    `d_h_norm` must already include the producer-side post-row cotangents
    (scatter-added by Rust); `d_partials` are the returned pre cotangents.
    Returns d_h [n_pad, f] — the gradient flowing into the layer below.
    """

    def f(h_):
        return pre_fwd(h_, pre_gather, pre_segrel, pre_blockseg, n_pre_seg=n_pre_seg)

    _, vjp = jax.vjp(f, h)
    (d_h,) = vjp((d_h_norm, d_partials))
    return d_h


# ---------------------------------------------------------------------------
# Single-machine reference (test oracle for the distributed decomposition)
# ---------------------------------------------------------------------------

def sage_forward_ref(x, edges_src, edges_dst, deg_inv, weights, *, n_layers=3):
    """Whole-graph 3-layer GraphSAGE forward on one machine, pure jnp.

    weights: list of (w_self, w_neigh, b). Used by pytest to check that the
    distributed pre/post decomposition reproduces the monolithic model.
    """
    h = x
    for l in range(n_layers):
        w_self, w_neigh, b = weights[l]
        h_norm = (h - h.mean(axis=1, keepdims=True)) / jnp.sqrt(
            h.var(axis=1, keepdims=True) + 1e-5
        )
        z = jnp.zeros_like(h_norm).at[edges_dst].add(h_norm[edges_src])
        z = z * deg_inv[:, None]
        h = h_norm @ w_self + z @ w_neigh + b[None, :]
        if l + 1 < n_layers:
            h = jax.nn.relu(h)
    return h
