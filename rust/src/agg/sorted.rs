//! Clustering & sorting for `index_add` (Fig. 3(b)) — the preprocessing
//! that turns an unordered index into sorted segment runs, plus the
//! reusable plan object (`SortedIndexAdd`) the trainer builds once per
//! graph and applies every epoch.

use super::blocked;

/// A sorted index_add plan: the permutation that clusters contributions by
/// destination, cached so the (expensive) sort happens once.
#[derive(Clone, Debug)]
pub struct SortedIndexAdd {
    pub n_dst: usize,
    /// Contribution order after clustering: position i takes source row
    /// `perm[i]`.
    pub perm: Vec<u32>,
    /// Non-decreasing destination per contribution.
    pub seg: Vec<u32>,
    /// CSR offsets per destination segment.
    pub offsets: Vec<usize>,
}

impl SortedIndexAdd {
    /// Build from an unordered index (`idx[i]` = destination of source row
    /// i, `n_dst` destinations).
    pub fn new(idx: &[u32], n_dst: usize) -> Self {
        let mut order: Vec<u32> = (0..idx.len() as u32).collect();
        // Stable sort keeps per-destination source order == input order,
        // so results match the vanilla accumulation bitwise.
        order.sort_by_key(|&i| idx[i as usize]);
        let seg: Vec<u32> = order.iter().map(|&i| idx[i as usize]).collect();
        let offsets = blocked::segment_offsets(&seg, n_dst);
        Self {
            n_dst,
            perm: order,
            seg,
            offsets,
        }
    }

    /// `dst += index_add(src)` using the cached clustering and the
    /// register-blocked kernel. `src` is m × f, `dst` n_dst × f.
    pub fn apply(&self, src: &[f32], f: usize, dst: &mut [f32]) {
        assert_eq!(src.len(), self.perm.len() * f);
        assert_eq!(dst.len(), self.n_dst * f);
        debug_assert!(crate::agg::is_sorted_segs(&self.seg));
        blocked::segment_sum(src, f, &self.perm, &self.seg, dst);
    }

    /// Number of contributions.
    pub fn m(&self) -> usize {
        self.perm.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::vanilla;
    use crate::util::propcheck::{prop_assert, prop_close, propcheck};
    use crate::util::rng::Rng;

    #[test]
    fn matches_vanilla_index_add() {
        let mut rng = Rng::new(21);
        let (m, n, f) = (300, 50, 20);
        let src: Vec<f32> = (0..m * f).map(|_| rng.f32() - 0.5).collect();
        let idx: Vec<u32> = (0..m).map(|_| rng.index(n) as u32).collect();
        let mut a = vec![0f32; n * f];
        vanilla::index_add(&mut a, f, &src, &idx);
        let plan = SortedIndexAdd::new(&idx, n);
        let mut b = vec![0f32; n * f];
        plan.apply(&src, f, &mut b);
        assert_eq!(a, b, "stable clustering must preserve accumulation order");
    }

    #[test]
    fn plan_is_reusable() {
        let idx = vec![2u32, 0, 2, 1];
        let plan = SortedIndexAdd::new(&idx, 3);
        let src = vec![1.0, 2.0, 3.0, 4.0];
        let mut d1 = vec![0f32; 3];
        plan.apply(&src, 1, &mut d1);
        assert_eq!(d1, vec![2.0, 4.0, 4.0]);
        // Second application accumulates again.
        plan.apply(&src, 1, &mut d1);
        assert_eq!(d1, vec![4.0, 8.0, 8.0]);
    }

    #[test]
    fn prop_sorted_plan_equals_vanilla() {
        propcheck(32, |gen| {
            let n = gen.usize(1, 50);
            let m = gen.usize(0, 200);
            let f = gen.usize(1, 40);
            let src = gen.vec_f32(m * f, -3.0, 3.0);
            let idx: Vec<u32> = (0..m).map(|_| gen.rng.index(n) as u32).collect();
            let mut a = vec![0f32; n * f];
            vanilla::index_add(&mut a, f, &src, &idx);
            let plan = SortedIndexAdd::new(&idx, n);
            prop_assert(plan.m() == m, "m mismatch")?;
            let mut b = vec![0f32; n * f];
            plan.apply(&src, f, &mut b);
            prop_close(&a, &b, 1e-6, 1e-6)
        });
    }
}
