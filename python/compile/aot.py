"""AOT pipeline: lower every L2 stage to HLO **text** + a JSON manifest.

Run once at build time (`make artifacts`); the Rust runtime
(`rust/src/runtime`) loads the text through
`HloModuleProto::from_text_file` and compiles it on the PJRT CPU client.
HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

EB = 128  # edge/row block of the Pallas kernels; all padded dims are multiples


class Config:
    """Static shape configuration for one artifact set.

    All tensor dims every worker pads to. `n_pad` includes the reserved
    zero row (n_pad−2) and trash row (n_pad−1).
    """

    def __init__(self, name, n_pad, f_in, hidden, classes,
                 e_local, e_pre, p_pre, r_pre, r_post, e_post):
        for dim, mult in [(n_pad, EB), (e_local, EB), (e_pre, EB)]:
            assert dim % mult == 0, f"{name}: {dim} not a multiple of {mult}"
        self.name = name
        self.n_pad = n_pad
        self.f_in = f_in
        self.hidden = hidden
        self.classes = classes
        self.e_local = e_local
        self.e_pre = e_pre
        self.p_pre = p_pre      # pre segments incl. 1 trash segment
        self.r_pre = r_pre      # received partial rows (pads zeroed)
        self.r_post = r_post    # received raw rows incl. 1 zero row (last)
        self.e_post = e_post    # post edges (pads → zero row / trash dst)

    def layer_dims(self):
        return [(self.f_in, self.hidden, True),
                (self.hidden, self.hidden, True),
                (self.hidden, self.classes, False)]

    def to_json(self):
        return {k: getattr(self, k) for k in
                ("name", "n_pad", "f_in", "hidden", "classes", "e_local",
                 "e_pre", "p_pre", "r_pre", "r_post", "e_post")}


CONFIGS = [
    # Fast CI/testing config.
    Config("tiny", n_pad=256, f_in=16, hidden=16, classes=4,
           e_local=1024, e_pre=256, p_pre=128, r_pre=128, r_post=128,
           e_post=256),
    # The quickstart / train_e2e config: arxiv-s (n=4000) on 4 workers.
    Config("quickstart", n_pad=1536, f_in=64, hidden=64, classes=16,
           e_local=12288, e_pre=4096, p_pre=2048, r_pre=2048, r_post=2048,
           e_post=8192),
]


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, example_args, arg_names):
    """jit-lower `fn` at `example_args`; returns (hlo_text, io_spec)."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    inputs = [
        {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
        for n, a in zip(arg_names, example_args)
    ]
    out = jax.eval_shape(fn, *example_args)
    leaves = jax.tree_util.tree_leaves(out)
    outputs = [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in leaves]
    return text, {"inputs": inputs, "outputs": outputs}


def build_config(cfg: Config, out_dir: str):
    arts = {}

    def emit(role, fn, args, names):
        text, io = lower_artifact(fn, args, names)
        fname = f"{cfg.name}_{role}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        arts[role] = {"file": fname, **io}
        print(f"  {cfg.name}/{role}: {len(text)} chars")

    n, ep, pp = cfg.n_pad, cfg.e_pre, cfg.p_pre

    # pre_fwd / pre_bwd per distinct input width.
    for f in sorted({cfg.f_in, cfg.hidden}):
        pre_args = (f32(n, f), i32(ep), i32(ep), i32(ep))
        emit(
            f"pre_fwd_f{f}",
            functools.partial(model.pre_fwd, n_pre_seg=pp),
            pre_args,
            ["h", "pre_gather", "pre_segrel", "pre_blockseg"],
        )
        emit(
            f"pre_bwd_f{f}",
            functools.partial(model.pre_bwd, n_pre_seg=pp),
            pre_args[:1] + pre_args[1:] + (f32(n, f), f32(pp, f)),
            ["h", "pre_gather", "pre_segrel", "pre_blockseg", "d_h_norm", "d_partials"],
        )

    # layer_fwd / layer_bwd per layer.
    el, rp, ro, epo = cfg.e_local, cfg.r_pre, cfg.r_post, cfg.e_post
    for l, (fin, fout, relu) in enumerate(cfg.layer_dims()):
        common = (
            f32(n, fin), f32(rp, fin), f32(ro, fin),
            f32(fin, fout), f32(fin, fout), f32(fout),
            i32(el), i32(el), i32(el),
            i32(rp), i32(epo), i32(epo), f32(n),
        )
        names = [
            "h_norm", "recv_pre", "recv_post", "w_self", "w_neigh", "b",
            "local_gather", "local_segrel", "local_blockseg",
            "rpre_dst", "post_row", "post_dst", "deg_inv",
        ]
        emit(
            f"layer_fwd_{l}",
            functools.partial(model.layer_fwd, relu=relu),
            common,
            names,
        )
        emit(
            f"layer_bwd_{l}",
            functools.partial(model.layer_bwd, relu=relu),
            common + (f32(n, fout),),
            names + ["d_out"],
        )

    emit(
        "loss_head",
        model.loss_head,
        (f32(n, cfg.classes), i32(n), f32(n)),
        ["logits", "labels", "mask"],
    )
    return {**cfg.to_json(), "artifacts": arts}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="",
                    help="comma-separated subset of config names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    want = set(filter(None, args.configs.split(",")))
    manifest = {"version": 1, "eb": EB, "configs": []}
    for cfg in CONFIGS:
        if want and cfg.name not in want:
            continue
        print(f"lowering config '{cfg.name}' ...")
        manifest["configs"].append(build_config(cfg, args.out_dir))
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
