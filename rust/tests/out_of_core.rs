//! Out-of-core storage acceptance tests (DESIGN.md §17): on-disk store
//! round-trip byte identity, streaming-synth determinism, shard-file
//! determinism, and the headline guarantee — training from an mmap-backed
//! `--graph-dir` store is loss-**bit**-identical to the in-memory path
//! across both regimes × both transports × overlap on/off × group-size
//! {1, 2}.

use std::path::PathBuf;
use std::sync::Arc;
use supergcn::comm::transport::TransportKind;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::{block_partition, prepare_store};
use supergcn::coordinator::shard;
use supergcn::coordinator::trainer::{EpochStats, TrainConfig, Trainer};
use supergcn::graph::generate::{sbm, LabelledGraph};
use supergcn::graph::store::GraphStore;
use supergcn::graph::synth::{generate_to_store, SynthConfig};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::sample::{SamplerConfig, SamplerKind};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("supergcn_oocore_test_{}_{name}", std::process::id()));
    p
}

fn small_lg() -> LabelledGraph {
    sbm(600, 4, 6.0, 0.8, 12, 0.5, 33)
}

fn scfg(seed: u64) -> SamplerConfig {
    SamplerConfig {
        batch_size: 120,
        fanouts: vec![4, 3],
        seed,
        ..Default::default()
    }
}

/// Every ctor parameter combination the issue pins: both transports,
/// overlap on/off, flat and two-level exchange.
const MATRIX: [(TransportKind, bool, usize); 8] = [
    (TransportKind::Sequential, false, 1),
    (TransportKind::Sequential, false, 2),
    (TransportKind::Sequential, true, 1),
    (TransportKind::Sequential, true, 2),
    (TransportKind::Threaded, false, 1),
    (TransportKind::Threaded, false, 2),
    (TransportKind::Threaded, true, 1),
    (TransportKind::Threaded, true, 2),
];

fn assert_bit_identical(tag: &str, a: &[EpochStats], b: &[EpochStats]) {
    assert_eq!(a.len(), b.len(), "{tag}: epoch count");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{tag}: epoch {} loss bits {} vs {}",
            x.epoch,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.train_acc.to_bits(), y.train_acc.to_bits(), "{tag}: train acc");
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "{tag}: val acc");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{tag}: test acc");
    }
}

#[test]
fn store_roundtrip_is_byte_identical_and_readback_matches() {
    let lg = Arc::new(small_lg());
    let mem = GraphStore::from(lg.clone());
    let dir = tmp("roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("a.sgcn");
    let p2 = dir.join("b.sgcn");
    mem.write(&p1).unwrap();
    mem.write(&p2).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b2, "two writes of the same graph must be byte-identical");

    // Every accessor of the mapped store agrees with the source graph.
    let mm = GraphStore::open(&p1).unwrap();
    assert_eq!(mm.backend_name(), "mmap");
    assert!(mm.mapped_bytes() > 0);
    assert_eq!(mm.n(), lg.n());
    assert_eq!(mm.m(), lg.graph.m());
    assert_eq!(mm.feat_dim(), lg.feat_dim);
    assert_eq!(mm.num_classes(), lg.num_classes);
    for v in 0..lg.n() {
        assert_eq!(mm.in_neighbors(v), lg.graph.in_neighbors(v), "row {v}");
        assert_eq!(mm.feature_row(v), lg.feature_row(v), "features {v}");
        assert_eq!(mm.label(v), lg.labels[v], "label {v}");
        assert_eq!(mm.split_of(v), lg.split[v], "split {v}");
    }

    // materialize() lifts the mapping back to an exact in-memory copy.
    let lifted = mm.materialize();
    assert_eq!(lifted.backend_name(), "mem");
    let llg = lifted.labelled().unwrap();
    assert_eq!(llg.graph, lg.graph);
    assert_eq!(llg.features, lg.features);
    assert_eq!(llg.labels, lg.labels);
    assert_eq!(llg.split, lg.split);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn synth_generator_is_seed_deterministic_on_disk() {
    let dir = tmp("synth");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = SynthConfig {
        n: 2_000,
        avg_deg: 6,
        window: 128,
        feat_dim: 8,
        num_classes: 4,
        seed: 9,
        ..Default::default()
    };
    let p1 = dir.join("s1.sgcn");
    let p2 = dir.join("s2.sgcn");
    let st1 = generate_to_store(&cfg, &p1).unwrap();
    let st2 = generate_to_store(&cfg, &p2).unwrap();
    assert_eq!(st1.n, 2_000);
    assert_eq!(st1.m, st2.m);
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "same seed must stream a byte-identical store file"
    );

    // A different seed changes the draw (and the store validates clean).
    let p3 = dir.join("s3.sgcn");
    generate_to_store(&SynthConfig { seed: 10, ..cfg }, &p3).unwrap();
    assert_ne!(std::fs::read(&p1).unwrap(), std::fs::read(&p3).unwrap());
    let st = GraphStore::open(&p3).unwrap();
    assert_eq!(st.n(), 2_000);
    assert!(st.m() > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shard_files_are_deterministic() {
    let lg = Arc::new(small_lg());
    let dir = tmp("sharddet");
    std::fs::create_dir_all(&dir).unwrap();
    let gp = dir.join("graph.sgcn");
    GraphStore::from(lg).write(&gp).unwrap();
    let store = GraphStore::open(&gp).unwrap();

    let d1 = dir.join("run1");
    let d2 = dir.join("run2");
    let i1 = shard::write_shards(&store, 3, RemoteStrategy::Hybrid, 42, &d1).unwrap();
    let i2 = shard::write_shards(&store, 3, RemoteStrategy::Hybrid, 42, &d2).unwrap();
    assert_eq!(i1.len(), 3);
    for (a, b) in i1.iter().zip(i2.iter()) {
        assert_eq!(a.rank, b.rank);
        assert_eq!(a.n_local, b.n_local);
        assert_eq!(
            std::fs::read(&a.path).unwrap(),
            std::fs::read(&b.path).unwrap(),
            "shard {} must be byte-identical across prepare runs",
            a.rank
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole guarantee, mini-batch regime: an mmap-backed store run is
/// loss-bit-identical to the in-memory path over the same (block)
/// partition, across transports × overlap × group-size.
#[test]
fn minibatch_mmap_loss_bits_match_in_memory() {
    let lg = Arc::new(small_lg());
    let dir = tmp("mb_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let gp = dir.join("graph.sgcn");
    let mem = GraphStore::from(lg);
    mem.write(&gp).unwrap();
    let mmap = GraphStore::open(&gp).unwrap();
    let k = 4;

    for (transport, overlap, group_size) in MATRIX {
        let tag = format!("mb {transport:?} overlap={overlap} gs={group_size}");
        let mc = MiniBatchConfig {
            epochs: 3,
            hidden: 16,
            transport,
            overlap,
            group_size,
            ..Default::default()
        };
        let run = |store: &GraphStore| {
            let part = block_partition(store, k);
            let mut tr = MiniBatchTrainer::with_partition(
                store.clone(),
                part,
                SamplerKind::Neighbor,
                &scfg(7),
                mc.clone(),
            )
            .unwrap();
            tr.run(false).unwrap()
        };
        assert_bit_identical(&tag, &run(&mem), &run(&mmap));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole guarantee, full-batch regime: contexts assembled from the
/// per-rank `prepare` shard files train loss-bit-identically to contexts
/// planned from the in-memory graph over the same partition.
#[test]
fn fullbatch_from_shards_loss_bits_match_in_memory() {
    let lg = Arc::new(small_lg());
    let dir = tmp("fb_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let gp = dir.join("graph.sgcn");
    let mem = GraphStore::from(lg);
    mem.write(&gp).unwrap();
    let mmap = GraphStore::open(&gp).unwrap();
    let k = 4;
    let hidden = 16;

    let shard_dir = dir.join("shards");
    shard::write_shards(&mmap, k, RemoteStrategy::Hybrid, 42, &shard_dir).unwrap();
    let shards = shard::load_shards(&shard_dir).unwrap();
    assert!(shard::total_bytes(&shards) > 0);

    for (transport, overlap, group_size) in MATRIX {
        let tag = format!("fb {transport:?} overlap={overlap} gs={group_size}");
        let tc = TrainConfig {
            epochs: 3,
            transport,
            overlap,
            group_size,
            ..Default::default()
        };

        // Reference: plan from the in-memory store over the same block
        // partition `prepare` used.
        let part = block_partition(&mem, k);
        let (ctxs, cfg, _) =
            prepare_store(&mem, &part, RemoteStrategy::Hybrid, None, hidden).unwrap();
        let mut reference = Trainer::new(ctxs, cfg, tc.clone());
        let ref_stats = reference.run(false).unwrap();

        // Candidate: contexts rebuilt purely from the shard files.
        let (ctxs, cfg) = shard::build_ctxs_from_shards(&shards, hidden).unwrap();
        let mut candidate = Trainer::new(ctxs, cfg, tc);
        let cand_stats = candidate.run(false).unwrap();

        assert_bit_identical(&tag, &ref_stats, &cand_stats);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Samplers that need random global CSR access refuse the mmap backend
/// with a descriptive error instead of panicking deep in the planner.
#[test]
fn mmap_backend_gates_in_memory_only_samplers() {
    let lg = Arc::new(small_lg());
    let dir = tmp("gate");
    std::fs::create_dir_all(&dir).unwrap();
    let gp = dir.join("graph.sgcn");
    GraphStore::from(lg).write(&gp).unwrap();
    let store = GraphStore::open(&gp).unwrap();

    for kind in [SamplerKind::Cluster, SamplerKind::Full] {
        let err = MiniBatchTrainer::new(
            store.clone(),
            2,
            kind,
            &scfg(7),
            MiniBatchConfig {
                epochs: 1,
                hidden: 16,
                ..Default::default()
            },
        )
        .err()
        .unwrap_or_else(|| panic!("{} must be rejected on the mmap backend", kind.name()));
        let msg = format!("{err:#}");
        assert!(
            msg.contains("in-memory graph backend"),
            "{}: unhelpful error: {msg}",
            kind.name()
        );
    }

    // A corrupt store file reports what field went wrong, not a panic.
    let bad = dir.join("bad.sgcn");
    std::fs::write(&bad, b"SGCNGRF1 but far too short").unwrap();
    let err = GraphStore::open(&bad).err().expect("truncated file must fail to open");
    assert!(!format!("{err:#}").is_empty());
    std::fs::remove_dir_all(&dir).ok();
}
