//! Per-rank shard files (`SGCNSHD1`) — the output of `supergcn prepare`
//! and the input of `supergcn train --graph-dir` (DESIGN.md §17).
//!
//! A shard is **self-contained**: it carries one worker's halo plan (the
//! full [`WorkerPlan`] — local node manifest, local edges, true global
//! degrees, per-peer send/recv halo specs) plus exactly the node data
//! that worker needs (feature / label / split rows in `local_nodes`
//! order). Training from shards therefore never touches the global graph
//! again: rank `r` opens `shard_00000r` and nothing else, which is what
//! bounds per-rank memory to its own slice of the dataset.
//!
//! Shards are produced deterministically from `(store, k, strategy,
//! seed)`: the streaming block partition and the generic plan builder are
//! pure functions of the graph, so the same inputs yield byte-identical
//! shard files — pinned in tests. The reader follows the
//! `model::checkpoint` v2 contract: every failed read names its field,
//! shape inconsistencies are descriptive `Err`s, and trailing bytes are
//! rejected.

use super::planner::{self, NodeSource, WorkerCtx};
use crate::graph::store::GraphStore;
use crate::hier::plan::{RecvPlan, SendPlan, WorkerPlan};
use crate::hier::volume::RemoteStrategy;
use crate::obs::trace::{span, TraceCategory};
use crate::partition::Partition;
use crate::runtime::ShapeConfig;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"SGCNSHD1";
const VERSION: u64 = 1;

/// Stable on-disk codes for [`RemoteStrategy`] (do not renumber).
fn strategy_code(s: RemoteStrategy) -> u64 {
    match s {
        RemoteStrategy::Raw => 0,
        RemoteStrategy::PreOnly => 1,
        RemoteStrategy::PostOnly => 2,
        RemoteStrategy::Hybrid => 3,
    }
}

fn strategy_from_code(c: u64) -> Result<RemoteStrategy> {
    Ok(match c {
        0 => RemoteStrategy::Raw,
        1 => RemoteStrategy::PreOnly,
        2 => RemoteStrategy::PostOnly,
        3 => RemoteStrategy::Hybrid,
        _ => anyhow::bail!("unknown remote strategy code {c} in shard header"),
    })
}

/// `dir/shard_00042.sgcnshard` — zero-padded so a directory listing
/// sorts in rank order.
pub fn shard_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("shard_{rank:05}.sgcnshard"))
}

/// One rank's loaded shard: the halo plan plus local node data. Implements
/// [`NodeSource`] (indexed by *local* position — the shard only holds its
/// own rows), so `planner::build_one` assembles the exact same padded
/// [`WorkerCtx`] it would have built from the global graph.
#[derive(Clone, Debug)]
pub struct Shard {
    pub k: usize,
    pub rank: usize,
    pub n_global: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub strategy: RemoteStrategy,
    pub seed: u64,
    pub plan: WorkerPlan,
    /// Local rows, `n_local × feat_dim`, in `plan.local_nodes` order.
    features: Vec<f32>,
    labels: Vec<u32>,
    split: Vec<u8>,
    /// On-disk size, for the `store.shard.bytes` gauge.
    pub file_bytes: u64,
}

impl NodeSource for Shard {
    fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn feature_row(&self, i: usize, _v: u32) -> &[f32] {
        &self.features[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    fn label(&self, i: usize, _v: u32) -> u32 {
        self.labels[i]
    }

    fn split(&self, i: usize, _v: u32) -> u8 {
        self.split[i]
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

struct ShardWriter<W: Write> {
    w: W,
}

impl<W: Write> ShardWriter<W> {
    fn u64(&mut self, x: u64) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }

    fn u32s(&mut self, xs: &[u32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    fn pairs(&mut self, xs: &[(u32, u32)]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &(a, b) in xs {
            self.w.write_all(&a.to_le_bytes())?;
            self.w.write_all(&b.to_le_bytes())?;
        }
        Ok(())
    }
}

/// Write one rank's shard file. Node data is pulled row by row through
/// the store, so the working set stays bounded regardless of graph size.
pub fn write_shard(
    store: &GraphStore,
    plan: &WorkerPlan,
    k: usize,
    strategy: RemoteStrategy,
    seed: u64,
    path: &Path,
) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating shard file {path:?}"))?;
    let mut w = ShardWriter { w: BufWriter::new(f) };

    // ---- header ---------------------------------------------------------
    w.w.write_all(MAGIC)?;
    w.u64(VERSION)?;
    w.u64(k as u64)?;
    w.u64(plan.worker as u64)?;
    w.u64(store.n() as u64)?;
    w.u64(store.feat_dim() as u64)?;
    w.u64(store.num_classes() as u64)?;
    w.u64(strategy_code(strategy))?;
    w.u64(seed)?;

    // ---- halo plan ------------------------------------------------------
    w.u32s(&plan.local_nodes)?;
    w.pairs(&plan.local_edges)?;
    w.u32s(&plan.degrees)?;
    anyhow::ensure!(plan.sends.len() == k, "send plan count {} != k {k}", plan.sends.len());
    anyhow::ensure!(plan.recvs.len() == k, "recv plan count {} != k {k}", plan.recvs.len());
    for sp in &plan.sends {
        w.u64(sp.peer as u64)?;
        w.u32s(&sp.pre_gather)?;
        w.u32s(&sp.pre_seg)?;
        w.u64(sp.n_pre_segments as u64)?;
        w.u32s(&sp.post_rows)?;
    }
    for rp in &plan.recvs {
        w.u64(rp.peer as u64)?;
        w.u32s(&rp.pre_dst)?;
        w.u64(rp.n_post_rows as u64)?;
        w.pairs(&rp.post_edges)?;
    }

    // ---- local node data, in local_nodes order --------------------------
    for &v in &plan.local_nodes {
        for &x in store.feature_row(v as usize) {
            w.w.write_all(&x.to_le_bytes())?;
        }
    }
    for &v in &plan.local_nodes {
        w.w.write_all(&store.label(v as usize).to_le_bytes())?;
    }
    for &v in &plan.local_nodes {
        w.w.write_all(&[store.split_of(v as usize)])?;
    }
    w.w.flush()
        .with_context(|| format!("flushing shard file {path:?}"))?;
    Ok(())
}

/// Per-rank summary returned by [`write_shards`], for the `prepare` CLI
/// report and the `store.shard.bytes` gauge.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub rank: usize,
    pub path: PathBuf,
    pub n_local: usize,
    pub bytes: u64,
}

/// The streaming `prepare` pipeline: block-partition the store, build +
/// validate halo plans (the exact generic code the in-memory path runs),
/// and write one self-contained shard per rank into `dir`. Deterministic:
/// same `(graph bytes, k, strategy, seed)` ⇒ byte-identical shard files.
pub fn write_shards(
    store: &GraphStore,
    k: usize,
    strategy: RemoteStrategy,
    seed: u64,
    dir: &Path,
) -> Result<Vec<ShardInfo>> {
    anyhow::ensure!(k >= 1, "prepare needs at least 1 worker");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard directory {dir:?}"))?;
    let part = planner::block_partition(store, k);
    let plans = crate::hier::plan::build_plans(store, &part, strategy);
    crate::hier::plan::validate_plans(store, &part, &plans).context("plan validation")?;
    let mut out = Vec::with_capacity(k);
    for plan in &plans {
        let path = shard_path(dir, plan.worker);
        write_shard(store, plan, k, strategy, seed, &path)
            .with_context(|| format!("writing shard for rank {}", plan.worker))?;
        let bytes = std::fs::metadata(&path)
            .with_context(|| format!("stat of shard file {path:?}"))?
            .len();
        out.push(ShardInfo {
            rank: plan.worker,
            path,
            n_local: plan.n_local(),
            bytes,
        });
    }
    Ok(out)
}

/// The partition the shards in `dir` were cut with — reconstructed from
/// the shard manifests (each shard lists its global node ids), so
/// trainers that need the global assignment don't re-partition.
pub fn partition_of(shards: &[Shard]) -> Result<Partition> {
    anyhow::ensure!(!shards.is_empty(), "no shards to reconstruct a partition from");
    let n = shards[0].n_global;
    let k = shards[0].k;
    let mut assign = vec![u32::MAX; n];
    for sh in shards {
        for &v in &sh.plan.local_nodes {
            anyhow::ensure!(
                (v as usize) < n,
                "shard {}: node id {v} out of range for n_global {n}",
                sh.rank
            );
            anyhow::ensure!(
                assign[v as usize] == u32::MAX,
                "node {v} claimed by two shards ({} and {})",
                assign[v as usize],
                sh.rank
            );
            assign[v as usize] = sh.rank as u32;
        }
    }
    if let Some(v) = assign.iter().position(|&a| a == u32::MAX) {
        anyhow::bail!("node {v} owned by no shard — incomplete shard set");
    }
    let part = Partition { k, assign };
    part.validate(n)?;
    Ok(part)
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

/// Checked little-endian reader: every failed read names what was being
/// read (the `model::checkpoint` v2 Reader contract).
struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn bytes8(&mut self, what: &str) -> Result<[u8; 8]> {
        let mut b = [0u8; 8];
        self.r
            .read_exact(&mut b)
            .with_context(|| format!("shard file truncated or unreadable while reading {what}"))?;
        Ok(b)
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes8(what)?))
    }

    fn len(&mut self, what: &str, cap: usize) -> Result<usize> {
        let l = self.u64(what)? as usize;
        anyhow::ensure!(l <= cap, "{what} length {l} exceeds plausible bound {cap}");
        Ok(l)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r
            .read_exact(&mut b)
            .with_context(|| format!("shard file truncated or unreadable while reading {what}"))?;
        Ok(u32::from_le_bytes(b))
    }

    fn u32s(&mut self, n: usize, what: &str) -> Result<Vec<u32>> {
        let mut v = Vec::with_capacity(n);
        let mut buf = [0u8; 4 * 1024];
        let mut left = n;
        while left > 0 {
            let take = left.min(buf.len() / 4);
            let b = &mut buf[..take * 4];
            self.r
                .read_exact(b)
                .with_context(|| format!("shard file truncated or unreadable while reading {what}"))?;
            for c in b.chunks_exact(4) {
                v.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            left -= take;
        }
        Ok(v)
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        Ok(self.u32s(n, what)?.into_iter().map(f32::from_bits).collect())
    }

    fn pairs(&mut self, n: usize, what: &str) -> Result<Vec<(u32, u32)>> {
        let flat = self.u32s(n * 2, what)?;
        Ok(flat.chunks_exact(2).map(|c| (c[0], c[1])).collect())
    }

    fn u8s(&mut self, n: usize, what: &str) -> Result<Vec<u8>> {
        let mut v = vec![0u8; n];
        self.r
            .read_exact(&mut v)
            .with_context(|| format!("shard file truncated or unreadable while reading {what}"))?;
        Ok(v)
    }

    fn expect_eof(&mut self) -> Result<()> {
        let mut b = [0u8; 1];
        match self.r.read(&mut b) {
            Ok(0) => Ok(()),
            Ok(_) => anyhow::bail!("shard file has trailing bytes past the declared payload"),
            Err(e) => Err(e).context("checking shard file end"),
        }
    }
}

/// Load + validate one shard file. Wrapped in a `fetch` span so shard
/// loading shows up in the trace next to the mini-batch fetch legs.
pub fn load_shard(path: &Path) -> Result<Shard> {
    let _sp = span(TraceCategory::Fetch, "shard load");
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening shard file {path:?}"))?;
    let file_bytes = file
        .metadata()
        .with_context(|| format!("stat of shard file {path:?}"))?
        .len();
    let mut r = Reader { r: BufReader::new(file) };

    // ---- header ---------------------------------------------------------
    let magic = r.bytes8("magic")?;
    anyhow::ensure!(&magic == MAGIC, "bad magic: not a supergcn shard file");
    let version = r.u64("version")?;
    anyhow::ensure!(
        version == VERSION,
        "unsupported shard format version {version} (this build reads v{VERSION})"
    );
    let k = r.u64("worker count")? as usize;
    let rank = r.u64("rank")? as usize;
    let n_global = r.u64("global node count")? as usize;
    let feat_dim = r.u64("feature dim")? as usize;
    let num_classes = r.u64("class count")? as usize;
    let strategy = strategy_from_code(r.u64("remote strategy")?)?;
    let seed = r.u64("partition seed")?;
    anyhow::ensure!(k >= 1, "shard header declares zero workers");
    anyhow::ensure!(rank < k, "shard rank {rank} out of range for k={k}");
    anyhow::ensure!(feat_dim >= 1, "shard header declares zero feature dim");

    // Length sanity bounds: nothing in a shard can exceed the whole file
    // in elements, so corrupt headers fail fast instead of allocating.
    let cap = (file_bytes as usize).max(1);

    // ---- halo plan ------------------------------------------------------
    let n_local = r.len("local node count", n_global.min(cap))?;
    let local_nodes = r.u32s(n_local, "local node ids")?;
    let n_edges = r.len("local edge count", cap)?;
    let local_edges = r.pairs(n_edges, "local edges")?;
    let n_deg = r.len("degree count", cap)?;
    anyhow::ensure!(
        n_deg == n_local,
        "degree count {n_deg} != local node count {n_local}"
    );
    let degrees = r.u32s(n_deg, "degrees")?;

    let mut sends = Vec::with_capacity(k);
    for i in 0..k {
        let peer = r.u64("send peer")? as usize;
        anyhow::ensure!(peer == i, "send plan {i} names peer {peer} (file out of order)");
        let ng = r.len("pre_gather length", cap)?;
        let pre_gather = r.u32s(ng, "pre_gather")?;
        let ns = r.len("pre_seg length", cap)?;
        anyhow::ensure!(ns == ng, "pre_seg length {ns} != pre_gather length {ng}");
        let pre_seg = r.u32s(ns, "pre_seg")?;
        let n_pre_segments = r.u64("pre segment count")? as usize;
        let np = r.len("post_rows length", cap)?;
        let post_rows = r.u32s(np, "post_rows")?;
        sends.push(SendPlan {
            peer,
            pre_gather,
            pre_seg,
            n_pre_segments,
            post_rows,
        });
    }
    let mut recvs = Vec::with_capacity(k);
    for i in 0..k {
        let peer = r.u64("recv peer")? as usize;
        anyhow::ensure!(peer == i, "recv plan {i} names peer {peer} (file out of order)");
        let nd = r.len("pre_dst length", cap)?;
        let pre_dst = r.u32s(nd, "pre_dst")?;
        let n_post_rows = r.u64("post row count")? as usize;
        let ne = r.len("post edge count", cap)?;
        let post_edges = r.pairs(ne, "post_edges")?;
        recvs.push(RecvPlan {
            peer,
            pre_dst,
            n_post_rows,
            post_edges,
        });
    }
    let plan = WorkerPlan {
        worker: rank,
        local_nodes,
        local_edges,
        degrees,
        sends,
        recvs,
    };
    plan.validate()
        .with_context(|| format!("shard file {path:?} carries an invalid halo plan"))?;
    for &v in &plan.local_nodes {
        anyhow::ensure!(
            (v as usize) < n_global,
            "local node id {v} out of range for global node count {n_global}"
        );
    }

    // ---- local node data ------------------------------------------------
    let features = r.f32s(n_local * feat_dim, "features")?;
    let labels = r.u32s(n_local, "labels")?;
    let split = r.u8s(n_local, "split")?;
    r.expect_eof()?;
    if let Some(&l) = labels.iter().find(|&&l| l as usize >= num_classes.max(1)) {
        anyhow::bail!("label {l} out of range for class count {num_classes}");
    }
    if let Some(&s) = split.iter().find(|&&s| s > 3) {
        anyhow::bail!("split tag {s} is not a known split (0..=3)");
    }

    Ok(Shard {
        k,
        rank,
        n_global,
        feat_dim,
        num_classes,
        strategy,
        seed,
        plan,
        features,
        labels,
        split,
        file_bytes,
    })
}

/// Load the full shard set of a prepared directory: `shard_00000` …
/// `shard_{k-1}`, cross-checked for a consistent header (same k /
/// n_global / dims / strategy / seed in every file).
pub fn load_shards(dir: &Path) -> Result<Vec<Shard>> {
    let first = load_shard(&shard_path(dir, 0))
        .with_context(|| format!("loading shard set from {dir:?}"))?;
    let k = first.k;
    let mut shards = Vec::with_capacity(k);
    shards.push(first);
    for rank in 1..k {
        let sh = load_shard(&shard_path(dir, rank))
            .with_context(|| format!("loading shard set from {dir:?}"))?;
        let a = &shards[0];
        anyhow::ensure!(sh.rank == rank, "shard file for rank {rank} declares rank {}", sh.rank);
        anyhow::ensure!(
            sh.k == a.k
                && sh.n_global == a.n_global
                && sh.feat_dim == a.feat_dim
                && sh.num_classes == a.num_classes
                && sh.strategy == a.strategy
                && sh.seed == a.seed,
            "shard {rank} header disagrees with shard 0 (mixed prepare outputs in {dir:?}?)"
        );
        shards.push(sh);
    }
    Ok(shards)
}

/// Total on-disk bytes of a shard set (the `store.shard.bytes` gauge).
pub fn total_bytes(shards: &[Shard]) -> u64 {
    shards.iter().map(|s| s.file_bytes).sum()
}

/// Assemble padded worker contexts from a loaded shard set. Bit-identical
/// to `prepare_store` on the same graph + partition: the plans are the
/// same (written at prepare time), and `build_one` fills node data
/// through the same [`NodeSource`] code path, just indexed locally.
pub fn build_ctxs_from_shards(
    shards: &[Shard],
    hidden: usize,
) -> Result<(Vec<WorkerCtx>, ShapeConfig)> {
    anyhow::ensure!(!shards.is_empty(), "no shards to build contexts from");
    let plans: Vec<WorkerPlan> = shards.iter().map(|s| s.plan.clone()).collect();
    let cfg = planner::fit_config(
        "fit",
        shards[0].feat_dim,
        hidden,
        shards[0].num_classes,
        &plans,
    );
    let ctxs = shards
        .iter()
        .map(|sh| planner::build_one(sh, &sh.plan, &cfg))
        .collect::<Result<Vec<_>>>()?;
    Ok((ctxs, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("supergcn_shard_test_{}_{name}", std::process::id()));
        p
    }

    fn small_store() -> GraphStore {
        GraphStore::from(sbm(400, 4, 7.0, 0.8, 12, 0.5, 21))
    }

    #[test]
    fn shard_roundtrip_preserves_plan_and_node_data() {
        let store = small_store();
        let dir = tmp("rt");
        let infos = write_shards(&store, 3, RemoteStrategy::Hybrid, 42, &dir).unwrap();
        assert_eq!(infos.len(), 3);
        let shards = load_shards(&dir).unwrap();
        let part = planner::block_partition(&store, 3);
        let plans = crate::hier::plan::build_plans(&store, &part, RemoteStrategy::Hybrid);
        for (sh, plan) in shards.iter().zip(plans.iter()) {
            assert_eq!(sh.plan.local_nodes, plan.local_nodes);
            assert_eq!(sh.plan.local_edges, plan.local_edges);
            assert_eq!(sh.plan.degrees, plan.degrees);
            for (a, b) in sh.plan.sends.iter().zip(plan.sends.iter()) {
                assert_eq!(a.pre_gather, b.pre_gather);
                assert_eq!(a.pre_seg, b.pre_seg);
                assert_eq!(a.n_pre_segments, b.n_pre_segments);
                assert_eq!(a.post_rows, b.post_rows);
            }
            for (a, b) in sh.plan.recvs.iter().zip(plan.recvs.iter()) {
                assert_eq!(a.pre_dst, b.pre_dst);
                assert_eq!(a.n_post_rows, b.n_post_rows);
                assert_eq!(a.post_edges, b.post_edges);
            }
            for (i, &v) in sh.plan.local_nodes.iter().enumerate() {
                assert_eq!(NodeSource::feature_row(sh, i, v), store.feature_row(v as usize));
                assert_eq!(NodeSource::label(sh, i, v), store.label(v as usize));
                assert_eq!(NodeSource::split(sh, i, v), store.split_of(v as usize));
            }
        }
        let rebuilt = partition_of(&shards).unwrap();
        assert_eq!(rebuilt.assign, part.assign);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_ctxs_match_prepare_store_bitwise() {
        let store = small_store();
        let dir = tmp("ctx");
        write_shards(&store, 3, RemoteStrategy::Hybrid, 42, &dir).unwrap();
        let shards = load_shards(&dir).unwrap();
        let (ctxs_s, cfg_s) = build_ctxs_from_shards(&shards, 64).unwrap();
        let part = planner::block_partition(&store, 3);
        let (ctxs_m, cfg_m, _) =
            planner::prepare_store(&store, &part, RemoteStrategy::Hybrid, None, 64).unwrap();
        assert_eq!(cfg_s.n_pad, cfg_m.n_pad);
        assert_eq!(cfg_s.e_local, cfg_m.e_local);
        for (a, b) in ctxs_s.iter().zip(ctxs_m.iter()) {
            assert_eq!(a.features, b.features);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.train_mask_f, b.train_mask_f);
            assert_eq!(a.val_mask, b.val_mask);
            assert_eq!(a.spec.local.gather, b.spec.local.gather);
            assert_eq!(a.spec.deg_inv, b.spec.deg_inv);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shards_are_deterministic_byte_identical() {
        let store = small_store();
        let (d1, d2) = (tmp("det1"), tmp("det2"));
        write_shards(&store, 4, RemoteStrategy::Hybrid, 7, &d1).unwrap();
        write_shards(&store, 4, RemoteStrategy::Hybrid, 7, &d2).unwrap();
        for rank in 0..4 {
            let a = std::fs::read(shard_path(&d1, rank)).unwrap();
            let b = std::fs::read(shard_path(&d2, rank)).unwrap();
            assert_eq!(a, b, "shard {rank} not byte-identical across runs");
            assert_eq!(&a[..8], MAGIC);
        }
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d2).ok();
    }

    #[test]
    fn truncated_shard_names_the_field() {
        let store = small_store();
        let dir = tmp("trunc");
        write_shards(&store, 2, RemoteStrategy::Hybrid, 1, &dir).unwrap();
        let p = shard_path(&dir, 0);
        let full = std::fs::read(&p).unwrap();
        for (cut, field) in [
            (4usize, "magic"),
            (12, "version"),
            (40, "feature dim"),
            (80, "local node ids"),
        ] {
            std::fs::write(&p, &full[..cut]).unwrap();
            let err = load_shard(&p).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") && msg.contains(field),
                "cut {cut}: expected field {field} in {msg}"
            );
        }
        // Trailing garbage rejected.
        let mut bytes = full.clone();
        bytes.push(0x5A);
        std::fs::write(&p, &bytes).unwrap();
        let err = load_shard(&p).unwrap_err();
        assert!(format!("{err:#}").contains("trailing bytes"), "{err:#}");
        // Bad magic rejected.
        let mut bad = full.clone();
        bad[..8].copy_from_slice(b"NOTSHARD");
        std::fs::write(&p, &bad).unwrap();
        let err = load_shard(&p).unwrap_err();
        assert!(format!("{err:#}").contains("not a supergcn shard"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_shard_sets_rejected() {
        let store = small_store();
        let dir = tmp("mixed");
        write_shards(&store, 2, RemoteStrategy::Hybrid, 1, &dir).unwrap();
        // Overwrite rank 1 with a different-seed prepare: header disagrees.
        let other = tmp("mixed_other");
        write_shards(&store, 2, RemoteStrategy::Hybrid, 99, &other).unwrap();
        std::fs::copy(shard_path(&other, 1), shard_path(&dir, 1)).unwrap();
        let err = load_shards(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("disagrees"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&other).ok();
    }
}
