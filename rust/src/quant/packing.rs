//! Bit-packing of quantization codes: 4×int2 / 2×int4 / 1×int8 per byte.

use super::Bits;

/// Pack a slice of codes (each ≤ max_code) into bytes.
pub fn pack(codes: &[u32], bits: Bits, out: &mut Vec<u8>) {
    match bits {
        Bits::Int8 => {
            out.extend(codes.iter().map(|&c| c as u8));
        }
        Bits::Int4 => {
            let mut it = codes.chunks_exact(2);
            for pair in &mut it {
                out.push((pair[0] as u8) | ((pair[1] as u8) << 4));
            }
            if let [last] = it.remainder() {
                out.push(*last as u8);
            }
        }
        Bits::Int2 => {
            let mut it = codes.chunks_exact(4);
            for quad in &mut it {
                out.push(
                    (quad[0] as u8)
                        | ((quad[1] as u8) << 2)
                        | ((quad[2] as u8) << 4)
                        | ((quad[3] as u8) << 6),
                );
            }
            let rem = it.remainder();
            if !rem.is_empty() {
                let mut b = 0u8;
                for (i, &c) in rem.iter().enumerate() {
                    b |= (c as u8) << (2 * i);
                }
                out.push(b);
            }
        }
    }
}

/// Unpack `n` codes from bytes.
pub fn unpack(bytes: &[u8], bits: Bits, n: usize, out: &mut Vec<u32>) {
    out.reserve(n);
    match bits {
        Bits::Int8 => {
            out.extend(bytes[..n].iter().map(|&b| b as u32));
        }
        Bits::Int4 => {
            for i in 0..n {
                let b = bytes[i / 2];
                out.push(((b >> (4 * (i % 2))) & 0xF) as u32);
            }
        }
        Bits::Int2 => {
            for i in 0..n {
                let b = bytes[i / 4];
                out.push(((b >> (2 * (i % 4))) & 0x3) as u32);
            }
        }
    }
}

/// Bytes needed for `n` codes.
pub fn packed_len(n: usize, bits: Bits) -> usize {
    n.div_ceil(bits.per_byte())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn known_int2_packing() {
        let mut out = Vec::new();
        pack(&[0, 1, 2, 3], Bits::Int2, &mut out);
        assert_eq!(out, vec![0b11_10_01_00]);
    }

    #[test]
    fn known_int4_packing() {
        let mut out = Vec::new();
        pack(&[0xA, 0x5, 0xF], Bits::Int4, &mut out);
        assert_eq!(out, vec![0x5A, 0x0F]);
    }

    #[test]
    fn prop_roundtrip_all_widths() {
        propcheck(48, |gen| {
            let n = gen.usize(0, 200);
            for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
                let codes: Vec<u32> =
                    (0..n).map(|_| gen.rng.below(bits.max_code() as u64 + 1) as u32).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                prop_assert(
                    packed.len() == packed_len(n, bits),
                    format!("packed_len mismatch for {}", bits.name()),
                )?;
                let mut un = Vec::new();
                unpack(&packed, bits, n, &mut un);
                prop_assert(un == codes, format!("roundtrip failed for {}", bits.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn int2_is_16x_smaller_than_f32() {
        let n = 1024;
        assert_eq!(packed_len(n, Bits::Int2) * 16, n * 4);
    }
}
