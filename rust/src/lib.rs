//! # SuperGCN
//!
//! A from-scratch reproduction of *"Scaling Large-scale GNN Training to
//! Thousands of Processors on CPU-based Supercomputers"* (SuperGCN,
//! ICS '25) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3** (this crate): the distributed full-batch GCN training
//!   coordinator — graph substrate, METIS-like partitioner, the paper's
//!   MVC-based hierarchical pre/post-aggregation planner, Int2 stochastic
//!   quantization, a simulated supercomputer interconnect, optimized CPU
//!   aggregation operators, and the epoch loop.
//! * **L2/L1** (`python/compile`): JAX per-layer compute graphs calling
//!   Pallas kernels, AOT-lowered to HLO-text artifacts executed from Rust
//!   through PJRT (`runtime`).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod agg;
pub mod backend;
pub mod benchcmp;
pub mod comm;
pub mod coordinator;
pub mod datasets;
pub mod exec;
pub mod exp;
pub mod graph;
pub mod hier;
pub mod model;
pub mod obs;
pub mod partition;
pub mod perfmodel;
pub mod quant;
pub mod run;
pub mod runtime;
pub mod sample;
pub mod util;
