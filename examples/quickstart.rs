//! Quickstart: partition a small citation-style graph across 4 simulated
//! workers and train a 3-layer GraphSAGE with the paper's full pipeline
//! (MVC hybrid pre/post-aggregation + Int2 quantized halos + masked label
//! propagation), printing the loss/accuracy curve.
//!
//!     cargo run --release --example quickstart

use supergcn::backend::native::NativeBackend;
use supergcn::coordinator::planner::prepare;
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::datasets;
use supergcn::graph::stats::stats;
use supergcn::hier::volume::RemoteStrategy;
use supergcn::quant::Bits;

fn main() -> anyhow::Result<()> {
    let spec = datasets::by_name("arxiv-s")?;
    let lg = spec.build();
    println!("dataset {} — {}", spec.name, stats(&lg.graph));

    let tc = TrainConfig {
        epochs: 60,
        lr: spec.lr,
        quant: Some(Bits::Int2),
        label_prop: true,
        strategy: RemoteStrategy::Hybrid,
        ..Default::default()
    };
    let (ctxs, cfg, plans) = prepare(&lg, 4, tc.strategy, None, tc.seed)?;
    println!(
        "partitioned into {} workers; halo rows/layer: {}",
        plans.len(),
        plans.iter().map(|p| p.send_rows()).sum::<usize>()
    );

    let backend = Box::new(NativeBackend::new(cfg));
    let mut tr = Trainer::new(ctxs, backend, tc);
    let stats = tr.run(true)?;
    let last = stats.last().unwrap();
    println!(
        "\nfinal: loss {:.4}, train acc {:.3}, test acc {:.3}",
        last.train_loss, last.train_acc, last.test_acc
    );
    println!("breakdown: {}", last.breakdown.report());
    Ok(())
}
