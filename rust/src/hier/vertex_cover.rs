//! Minimum vertex cover on bipartite graphs via König's theorem
//! (paper §5.3): |MVC| = |maximum matching|, and the cover is constructed
//! from the matching by alternating reachability.

use super::hopcroft_karp::{max_matching, Bipartite, Matching};

/// A vertex cover split by side.
#[derive(Clone, Debug, PartialEq)]
pub struct Cover {
    pub in_u: Vec<bool>,
    pub in_v: Vec<bool>,
}

impl Cover {
    pub fn size(&self) -> usize {
        self.in_u.iter().filter(|&&b| b).count() + self.in_v.iter().filter(|&&b| b).count()
    }

    /// Check every edge has an endpoint in the cover.
    pub fn is_cover(&self, g: &Bipartite) -> bool {
        for (u, vs) in g.adj.iter().enumerate() {
            for &v in vs {
                if !self.in_u[u] && !self.in_v[v as usize] {
                    return false;
                }
            }
        }
        true
    }
}

/// König construction: let Z = free U vertices ∪ vertices reachable from
/// them by alternating paths (unmatched U→V, matched V→U).
/// MVC = (U \ Z) ∪ (V ∩ Z).
pub fn minimum_vertex_cover(g: &Bipartite) -> (Cover, Matching) {
    let m = max_matching(g);
    let mut z_u = vec![false; g.nu];
    let mut z_v = vec![false; g.nv];
    let mut queue = std::collections::VecDeque::new();
    for u in 0..g.nu {
        if m.match_u[u].is_none() {
            z_u[u] = true;
            queue.push_back(u as u32);
        }
    }
    while let Some(u) = queue.pop_front() {
        for &v in &g.adj[u as usize] {
            // Traverse only NON-matching edges U→V.
            if m.match_u[u as usize] == Some(v) {
                continue;
            }
            if !z_v[v as usize] {
                z_v[v as usize] = true;
                // Traverse the matching edge V→U.
                if let Some(u2) = m.match_v[v as usize] {
                    if !z_u[u2 as usize] {
                        z_u[u2 as usize] = true;
                        queue.push_back(u2);
                    }
                }
            }
        }
    }
    let in_u: Vec<bool> = z_u.iter().map(|&z| !z).collect();
    let in_v = z_v;
    // Prune isolated U vertices (König picks U\Z ⊇ matched-but-isolated
    // never occurs; isolated U are free ⇒ in Z ⇒ excluded already).
    (Cover { in_u, in_v }, m)
}

/// Brute-force MVC size (test oracle, exponential — tiny graphs only).
#[cfg(test)]
pub fn brute_force_cover_size(g: &Bipartite) -> usize {
    let total = g.nu + g.nv;
    assert!(total <= 20, "too large for brute force");
    let edges: Vec<(usize, usize)> = g
        .adj
        .iter()
        .enumerate()
        .flat_map(|(u, vs)| vs.iter().map(move |&v| (u, v as usize)))
        .collect();
    let mut best = total;
    'outer: for mask in 0u32..(1 << total) {
        let cnt = mask.count_ones() as usize;
        if cnt >= best {
            continue;
        }
        for &(u, v) in &edges {
            let u_in = mask & (1 << u) != 0;
            let v_in = mask & (1 << (g.nu + v)) != 0;
            if !u_in && !v_in {
                continue 'outer;
            }
        }
        best = cnt;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn figure5_cover_is_nodes_2_and_4() {
        // Paper Fig 5: srcs U={4,5,6} (u-index 0,1,2), dsts V={1,2,3}
        // (v-index 0,1,2); edges 4-1,4-2,4-3,5-2,6-2.
        // MVC = {4, 2} → u-index 0 in U, v-index 1 in V. Size 2.
        let g = Bipartite::from_edges(3, 3, &[(0, 0), (0, 1), (0, 2), (1, 1), (2, 1)]);
        let (c, m) = minimum_vertex_cover(&g);
        assert!(c.is_cover(&g));
        assert_eq!(c.size(), 2);
        assert_eq!(c.size(), m.size(), "König: |MVC| = |matching|");
        assert!(c.in_u[0], "node 4 (src) must be in the cover");
        assert!(c.in_v[1], "node 2 (dst) must be in the cover");
        assert!(!c.in_u[1] && !c.in_u[2] && !c.in_v[0] && !c.in_v[2]);
    }

    #[test]
    fn empty_graph_empty_cover() {
        let g = Bipartite::from_edges(4, 3, &[]);
        let (c, _) = minimum_vertex_cover(&g);
        assert_eq!(c.size(), 0);
        assert!(c.is_cover(&g));
    }

    #[test]
    fn complete_bipartite_cover_is_smaller_side() {
        let mut edges = Vec::new();
        for u in 0..3u32 {
            for v in 0..5u32 {
                edges.push((u, v));
            }
        }
        let g = Bipartite::from_edges(3, 5, &edges);
        let (c, _) = minimum_vertex_cover(&g);
        assert!(c.is_cover(&g));
        assert_eq!(c.size(), 3);
    }

    #[test]
    fn prop_koenig_equals_brute_force() {
        propcheck(60, |gen| {
            let nu = gen.usize(1, 6);
            let nv = gen.usize(1, 6);
            let ne = gen.usize(0, 12);
            let edges: Vec<(u32, u32)> = (0..ne)
                .map(|_| (gen.rng.index(nu) as u32, gen.rng.index(nv) as u32))
                .collect();
            let g = Bipartite::from_edges(nu, nv, &edges);
            let (c, m) = minimum_vertex_cover(&g);
            prop_assert(c.is_cover(&g), format!("not a cover for {edges:?}"))?;
            prop_assert(
                c.size() == m.size(),
                format!("König violated: cover {} matching {}", c.size(), m.size()),
            )?;
            let bf = brute_force_cover_size(&g);
            prop_assert(
                c.size() == bf,
                format!("cover {} != brute force {} on {edges:?}", c.size(), bf),
            )
        });
    }

    #[test]
    fn prop_cover_valid_on_larger_graphs() {
        propcheck(24, |gen| {
            let nu = gen.usize(1, 60);
            let nv = gen.usize(1, 60);
            let ne = gen.usize(0, 300);
            let edges: Vec<(u32, u32)> = (0..ne)
                .map(|_| (gen.rng.index(nu) as u32, gen.rng.index(nv) as u32))
                .collect();
            let g = Bipartite::from_edges(nu, nv, &edges);
            let (c, m) = minimum_vertex_cover(&g);
            prop_assert(c.is_cover(&g), "not a cover")?;
            prop_assert(c.size() == m.size(), "size != matching")
        });
    }
}
