//! Communication performance model (paper §5.4 Eqn 2, §6.2 Eqn 3–8,
//! Fig. 7).
//!
//! The paper models distributed full-batch GCN communication as
//! `T = max_i Σ_j (V_ij / BW + L)` and derives the quantization speedup
//! `αβ(γ+δ) / ((1+δ)αβ + 2α(1+γ) + βγ) ≈ (γ+δ)/(1+δ)`. This module
//! implements those equations verbatim, parameterized by machine profiles
//! calibrated to ABCI (Xeon + InfiniBand EDR) and Fugaku (A64FX + Tofu-D)
//! from their public specs. The simulator charges these modeled times for
//! the wire, while computation is *measured* on the local CPU — see
//! DESIGN.md §1.

/// Hardware constants for one machine, in bits/second and seconds.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Per-rank injection bandwidth (bits/s) — `BW_comm`.
    pub bw_comm: f64,
    /// Per-message latency (s) — `L_comm`.
    pub latency: f64,
    /// Intra-node tier bandwidth (bits/s) — the shared-memory / on-package
    /// link ranks of one node exchange over (two-level transport,
    /// DESIGN.md §12). Orders of magnitude above `bw_comm` on both
    /// machines, which is what makes leader staging nearly free.
    pub bw_local: f64,
    /// Intra-node per-message latency (s) — a mailbox/shared-memory hop.
    pub latency_local: f64,
    /// Local compute throughput for streaming kernels (bits/s) — `TH_cal`.
    pub th_cal: f64,
    /// Ranks per physical node (Fugaku runs 4 ranks per A64FX).
    pub ranks_per_node: usize,
    /// Cores per rank: compute measured on this container's single core is
    /// divided by this when modeling a rank's epoch time (an ABCI rank is
    /// a 20-core socket, a Fugaku rank is a 12-core CMG). See DESIGN.md §1.
    pub cores_per_rank: f64,
}

impl MachineProfile {
    /// ABCI compute node: Intel Xeon Gold 6148 ×2, InfiniBand EDR.
    /// EDR ≈ 100 Gb/s per node shared by 2 ranks; MPI pt2pt latency ≈ 2 µs.
    /// Intra-node: the two socket-ranks exchange over UPI/shared memory
    /// (≈40 GB/s per direction, ≈0.3 µs shm hop).
    /// `TH_cal` models the quant/LN kernels' cache-resident streaming rate
    /// (≈0.9 TB/s aggregated over 20 cores), giving β = TH/BW ≈ 150 —
    /// the O(10²) regime §6.2.2 assumes.
    pub fn abci() -> Self {
        Self {
            name: "ABCI(Xeon+IB-EDR)",
            bw_comm: 100e9 / 2.0, // two ranks (sockets) share the HCA
            latency: 2e-6,
            bw_local: 40e9 * 8.0, // UPI / shared-memory between the sockets
            latency_local: 0.3e-6,
            th_cal: 7.5e12,
            ranks_per_node: 2,
            cores_per_rank: 20.0,
        }
    }

    /// Fugaku node: A64FX (4 CMGs = 4 ranks), Tofu-D.
    /// One Tofu-D link (6.8 GB/s) effectively serves the 4 ranks of a node
    /// for the unstructured alltoallv pattern; latency ≈ 1 µs; per-CMG
    /// HBM2 throughput ≈ 256 GB/s ⇒ β ≈ 150. Intra-node: the on-chip CMG
    /// ring network (>100 GB/s, ≈0.2 µs).
    pub fn fugaku() -> Self {
        Self {
            name: "Fugaku(A64FX+Tofu-D)",
            bw_comm: 6.8e9 * 8.0 / 4.0,
            latency: 1e-6,
            bw_local: 100e9 * 8.0, // on-chip CMG ring
            latency_local: 0.2e-6,
            th_cal: 256e9 * 8.0,
            ranks_per_node: 4,
            cores_per_rank: 12.0,
        }
    }

    /// β = TH_cal / BW_comm (Eqn 7).
    pub fn beta(&self) -> f64 {
        self.th_cal / self.bw_comm
    }
}

pub const BIT_FP32: f64 = 32.0;

/// Eqn 2 (upper): time to move `values` f32 values as one message.
pub fn t_comm_pair(values: f64, p: &MachineProfile) -> f64 {
    if values <= 0.0 {
        return 0.0;
    }
    values * BIT_FP32 / p.bw_comm + p.latency
}

/// Eqn 2 (lower): global comm time = slowest process's total send time.
/// `volume[i][j]` = f32 values sent i→j.
pub fn t_comm(volume: &[Vec<usize>], p: &MachineProfile) -> f64 {
    volume
        .iter()
        .map(|row| row.iter().map(|&v| t_comm_pair(v as f64, p)).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Ordered pair messages of the flat P×P `alltoallv`: `P(P−1)` — the
/// per-exchange message count the two-level transport is measured against.
pub fn flat_pair_messages(k: usize) -> usize {
    k * k.saturating_sub(1)
}

/// Ordered group-pair messages of the two-level exchange with `k` ranks in
/// groups of `g`: `(⌈k/g⌉)(⌈k/g⌉−1)` — the O((P/g)²) headline count
/// (DESIGN.md §12). Equals [`flat_pair_messages`] at `g = 1`.
pub fn inter_group_messages(k: usize, g: usize) -> usize {
    let ng = k.div_ceil(g.clamp(1, k.max(1)));
    ng * ng.saturating_sub(1)
}

/// Eqn-2-style bottleneck time of a volume matrix over the **two-level**
/// physical path (ranks grouped contiguously in groups of `g`, leader
/// staging — the same hop conventions `comm::TierStats` charges): per
/// sender, same-group values ride the intra tier, cross-group values pay
/// the inter bandwidth plus the staging/delivery intra hops, and each
/// leader pays `n_groups − 1` inter latencies for its group's dense
/// leader exchange. `volume[i][j]` = f32 values sent i→j. Reduces to
/// [`t_comm`]'s model at `g = 1` (identical on all-nonzero off-diagonal
/// matrices, where the flat per-pair latencies match the dense count).
pub fn t_comm_two_tier(volume: &[Vec<usize>], g: usize, p: &MachineProfile) -> f64 {
    let k = volume.len();
    let g = g.clamp(1, k.max(1));
    let ng = k.div_ceil(g);
    let mut worst = 0.0f64;
    for (i, row) in volume.iter().enumerate() {
        let mut t = 0.0f64;
        let mut out_bits = 0.0f64;
        for (j, &v) in row.iter().enumerate() {
            let bits = v as f64 * BIT_FP32;
            if bits <= 0.0 {
                continue;
            }
            if i / g == j / g {
                t += bits / p.bw_local + p.latency_local;
            } else {
                t += bits / p.bw_comm;
                out_bits += bits;
                if j % g != 0 {
                    // Delivery hop: destination-group leader → dst.
                    t += bits / p.bw_local + p.latency_local;
                }
            }
        }
        if out_bits > 0.0 && i % g != 0 {
            // Coalesced member→leader staging hop.
            t += out_bits / p.bw_local + p.latency_local;
        }
        if i % g == 0 {
            t += (ng - 1) as f64 * p.latency;
        }
        worst = worst.max(t);
    }
    worst
}

/// Eqn 3: masked label propagation + LayerNorm time over the local
/// subgraph (`subgraph_values` = values touched).
pub fn t_pre_quant(subgraph_values: f64, p: &MachineProfile) -> f64 {
    subgraph_values * BIT_FP32 / p.th_cal
}

/// Eqn 4: quantize (or dequantize) cost for one pair's payload.
pub fn t_quant_pair(values: f64, bits: f64, p: &MachineProfile) -> f64 {
    values * (BIT_FP32 + bits) / p.th_cal
}

/// Eqn 5: wire time for a quantized message (+FP32 params).
pub fn t_quant_comm_pair(values: f64, params: f64, bits: f64, p: &MachineProfile) -> f64 {
    if values <= 0.0 && params <= 0.0 {
        return 0.0;
    }
    (values * bits + params * BIT_FP32) / p.bw_comm + p.latency
}

/// Eqn 6: total quantized communication time.
/// `volume[i][j]` f32 values, `params[i][j]` f32 param values.
pub fn t_quant_comm_total(
    volume: &[Vec<usize>],
    params: &[Vec<usize>],
    subgraph_values: &[f64],
    bits: f64,
    p: &MachineProfile,
) -> f64 {
    let n = volume.len();
    (0..n)
        .map(|i| {
            let pre = t_pre_quant(subgraph_values[i], p);
            let row: f64 = (0..n)
                .map(|j| {
                    let v = volume[i][j] as f64;
                    let pm = params[i][j] as f64;
                    // quantize at i + wire + dequantize at j (charged to i
                    // per Eqn 6's sum).
                    2.0 * t_quant_pair(v, bits, p) + t_quant_comm_pair(v, pm, bits, p)
                })
                .sum();
            pre + row
        })
        .fold(0.0, f64::max)
}

/// Overlap-aware per-layer time (DESIGN.md §11): the halo alltoallv is
/// *posted* before interior aggregation starts, so wire time hides behind
/// the interior compute; only the boundary rows wait for receipt.
/// `max(interior, comm) + boundary`.
pub fn t_layer_overlap(interior: f64, comm: f64, boundary: f64) -> f64 {
    interior.max(comm) + boundary
}

/// The phase-serial model of the same layer (exchange at a barrier, then
/// all aggregation): `interior + comm + boundary`. By construction
/// `t_layer_overlap ≤ t_layer_serial` on identical inputs, with equality
/// only when the hidden term is zero.
pub fn t_layer_serial(interior: f64, comm: f64, boundary: f64) -> f64 {
    interior + comm + boundary
}

/// Both schedule models of one halo exchange, side by side — the shape
/// the telemetry report wants (DESIGN.md §13): each measured
/// `OverlapLedger` stage is published next to its modeled overlap/serial
/// times so modeled-vs-measured drift is visible per exchange.
#[derive(Clone, Copy, Debug)]
pub struct ExchangeEstimate {
    /// `t_layer_overlap(interior, comm, boundary)`.
    pub overlap_secs: f64,
    /// `t_layer_serial(interior, comm, boundary)`.
    pub serial_secs: f64,
}

/// Model one exchange under both schedules from its measured
/// interior/comm/boundary bottleneck times.
pub fn estimate_exchange(interior: f64, comm: f64, boundary: f64) -> ExchangeEstimate {
    ExchangeEstimate {
        overlap_secs: t_layer_overlap(interior, comm, boundary),
        serial_secs: t_layer_serial(interior, comm, boundary),
    }
}

/// The four ratios of Eqn 7.
#[derive(Clone, Copy, Debug)]
pub struct Ratios {
    /// data volume / params volume.
    pub alpha: f64,
    /// TH_cal / BW_comm.
    pub beta: f64,
    /// 32 / X.
    pub gamma: f64,
    /// latency / quantized transfer time.
    pub delta: f64,
}

impl Ratios {
    pub fn new(values_per_pair: f64, params_per_pair: f64, bits: f64, p: &MachineProfile) -> Self {
        let transfer = values_per_pair * bits / p.bw_comm;
        Self {
            alpha: values_per_pair / params_per_pair.max(1.0),
            beta: p.beta(),
            gamma: BIT_FP32 / bits,
            delta: if transfer > 0.0 { p.latency / transfer } else { f64::INFINITY },
        }
    }
}

/// Eqn 8: closed-form speedup of quantized over FP32 communication.
pub fn speedup_model(r: &Ratios) -> f64 {
    let Ratios { alpha, beta, gamma, delta } = *r;
    if delta.is_infinite() {
        return 1.0; // pure latency bound: no gain, no harm
    }
    alpha * beta * (gamma + delta)
        / ((1.0 + delta) * alpha * beta + 2.0 * alpha * (1.0 + gamma) + beta * gamma)
}

/// One point of the Fig. 7 sweep.
#[derive(Clone, Debug)]
pub struct Fig7Point {
    pub procs: usize,
    pub delta: f64,
    pub speedup: f64,
    pub regime: &'static str,
}

/// Fig. 7: sweep process count; per-pair volume shrinks ~1/P² under strong
/// scaling of an alltoall (total cut grows slowly, pairs grow P²), so δ
/// grows and the speedup decays from ≈γ to ≈1.
pub fn fig7_sweep(
    total_values_p1: f64,
    params_fraction: f64,
    bits: f64,
    procs: &[usize],
    p: &MachineProfile,
) -> Vec<Fig7Point> {
    procs
        .iter()
        .map(|&np| {
            let pairs = (np * np.saturating_sub(1)).max(1) as f64;
            // Cut volume grows ~√P with P parts (empirical for METIS on
            // bounded-degree graphs); per-pair volume then falls ~P^1.5.
            let total = total_values_p1 * (np as f64).sqrt();
            let per_pair = total / pairs;
            let r = Ratios::new(per_pair, per_pair * params_fraction, bits, p);
            let s = speedup_model(&r);
            Fig7Point {
                procs: np,
                delta: r.delta,
                speedup: s,
                regime: if r.delta < 1.0 { "throughput-bound" } else { "latency-bound" },
            }
        })
        .collect()
}

/// Latency-bound crossover: the process count P' where δ = 1 (transfer
/// time equals latency). The paper's Fig. 7 annotates the absolute-time
/// saving `(P − P')·L` of reaching the bound earlier.
pub fn crossover_procs(points: &[Fig7Point]) -> Option<usize> {
    points.iter().find(|pt| pt.delta >= 1.0).map(|pt| pt.procs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn near(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn profiles_have_beta_order_100() {
        // §6.2.2 assumes β ~ O(10²).
        for p in [MachineProfile::abci(), MachineProfile::fugaku()] {
            let b = p.beta();
            assert!((10.0..2000.0).contains(&b), "{}: β={b}", p.name);
        }
    }

    #[test]
    fn t_comm_is_bottleneck_max() {
        let p = MachineProfile::abci();
        let vol = vec![vec![0, 1000], vec![1_000_000, 0]];
        let t = t_comm(&vol, &p);
        assert!(near(t, t_comm_pair(1_000_000.0, &p), 1e-9));
    }

    #[test]
    fn two_tier_message_counts_scale_quadratically_in_groups() {
        assert_eq!(flat_pair_messages(4), 12);
        assert_eq!(inter_group_messages(4, 1), 12);
        assert_eq!(inter_group_messages(4, 2), 2);
        assert_eq!(inter_group_messages(4, 4), 0);
        assert_eq!(inter_group_messages(1024, 4), 256 * 255);
        // Ragged last group still counts as a group.
        assert_eq!(inter_group_messages(5, 2), 3 * 2);
        for k in [4usize, 8, 64] {
            for g in [2usize, 4] {
                assert!(
                    inter_group_messages(k, g) < flat_pair_messages(k),
                    "k={k} g={g}"
                );
            }
        }
    }

    #[test]
    fn two_tier_time_reduces_to_flat_at_g1_and_wins_when_latency_bound() {
        let p = MachineProfile::abci();
        // Dense off-diagonal volume: the g=1 two-tier model charges the
        // same k−1 latencies + bandwidth terms as Eqn 2's flat model.
        let k = 6;
        let vol: Vec<Vec<usize>> = (0..k)
            .map(|i| (0..k).map(|j| if i == j { 0 } else { 1000 }).collect())
            .collect();
        let flat = t_comm(&vol, &p);
        let g1 = t_comm_two_tier(&vol, 1, &p);
        assert!(near(flat, g1, 1e-12), "{flat} vs {g1}");
        // Tiny (latency-bound) payloads: staging through leaders trades
        // k−1 inter latencies for ⌈k/g⌉−1 plus cheap intra hops — a win
        // because latency_local ≪ latency.
        let tiny: Vec<Vec<usize>> = (0..k)
            .map(|i| (0..k).map(|j| usize::from(i != j)).collect())
            .collect();
        let two = t_comm_two_tier(&tiny, 3, &p);
        let one = t_comm_two_tier(&tiny, 1, &p);
        assert!(two < one, "two-level {two} should beat flat {one} when latency-bound");
    }

    #[test]
    fn profiles_have_fast_intra_tier() {
        for p in [MachineProfile::abci(), MachineProfile::fugaku()] {
            assert!(p.bw_local > p.bw_comm, "{}: intra tier must be faster", p.name);
            assert!(p.latency_local < p.latency, "{}: intra hop must be cheaper", p.name);
        }
    }

    #[test]
    fn throughput_bound_speedup_approaches_gamma() {
        // δ→0, α,β large: speedup → γ (paper: Int2 → ≈16×).
        let r = Ratios {
            alpha: 1e4,
            beta: 1e4,
            gamma: 16.0,
            delta: 1e-6,
        };
        let s = speedup_model(&r);
        assert!(s > 15.0 && s <= 16.01, "s={s}");
    }

    #[test]
    fn latency_bound_speedup_approaches_one() {
        let r = Ratios {
            alpha: 100.0,
            beta: 100.0,
            gamma: 16.0,
            delta: 1e6,
        };
        let s = speedup_model(&r);
        assert!(near(s, 1.0, 0.01), "s={s}");
    }

    #[test]
    fn speedup_never_below_one_sane_params() {
        // "It does not have any negative impact" (§6.2.2) for realistic
        // α ≳ 64 (4-row groups × ≥128 features / 2 params).
        for &delta in &[0.0, 0.1, 1.0, 10.0, 1e4] {
            for &alpha in &[64.0, 256.0, 1e4] {
                for &gamma in &[4.0, 8.0, 16.0] {
                    let r = Ratios { alpha, beta: 300.0, gamma, delta };
                    let s = speedup_model(&r);
                    assert!(s >= 0.95, "α={alpha} γ={gamma} δ={delta}: s={s}");
                }
            }
        }
    }

    #[test]
    fn approximation_matches_exact_for_large_alpha_beta() {
        // Eqn 8's ≈ (γ+δ)/(1+δ) limit.
        let r = Ratios { alpha: 1e6, beta: 1e6, gamma: 16.0, delta: 0.5 };
        let exact = speedup_model(&r);
        let approx = (r.gamma + r.delta) / (1.0 + r.delta);
        assert!(near(exact, approx, 0.01), "{exact} vs {approx}");
    }

    #[test]
    fn overlap_model_never_exceeds_serial() {
        for &(i, c, b) in &[
            (0.0, 0.0, 0.0),
            (1.0, 0.5, 0.2),
            (0.5, 1.0, 0.2),
            (2.0, 2.0, 0.0),
        ] {
            let ov = t_layer_overlap(i, c, b);
            let se = t_layer_serial(i, c, b);
            assert!(ov <= se, "overlap {ov} > serial {se}");
            // The hidden term is exactly min(interior, comm).
            assert!((se - ov - i.min(c)).abs() < 1e-12);
        }
    }

    #[test]
    fn exchange_estimate_matches_layer_models() {
        let e = estimate_exchange(1.0, 0.5, 0.2);
        assert!((e.overlap_secs - t_layer_overlap(1.0, 0.5, 0.2)).abs() < 1e-15);
        assert!((e.serial_secs - t_layer_serial(1.0, 0.5, 0.2)).abs() < 1e-15);
        assert!(e.overlap_secs <= e.serial_secs);
    }

    #[test]
    fn fig7_monotone_decay_and_crossover() {
        let p = MachineProfile::fugaku();
        let procs: Vec<usize> = (1..=13).map(|i| 1usize << i).collect();
        let pts = fig7_sweep(1e8, 1.0 / 256.0, 2.0, &procs, &p);
        // Speedup decays towards 1 as P grows.
        for w in pts.windows(2) {
            assert!(w[1].speedup <= w[0].speedup + 1e-9);
        }
        assert!(pts[0].speedup > 8.0, "medium scale should be ≈γ: {}", pts[0].speedup);
        assert!(pts.last().unwrap().speedup < 3.0);
        assert!(crossover_procs(&pts).is_some());
    }

    #[test]
    fn quant_total_beats_fp32_total_at_medium_scale() {
        let p = MachineProfile::abci();
        let n = 8;
        let vol = vec![vec![100_000usize; n]; n];
        let params = vec![vec![100_000usize / 256; n]; n];
        let sub = vec![1e6; n];
        let t_fp = t_comm(&vol, &p);
        let t_q = t_quant_comm_total(&vol, &params, &sub, 2.0, &p);
        assert!(t_q < t_fp, "quantized {t_q} should beat fp32 {t_fp}");
        assert!(t_fp / t_q > 4.0, "ratio {}", t_fp / t_q);
    }
}
