//! Offline substrate utilities: PRNG, JSON, CLI args, timing/breakdowns,
//! a scoped thread pool, and a mini property-testing harness.
//!
//! These exist because the build environment is fully offline (only the
//! `xla` and `anyhow` crates are vendored); see DESIGN.md §1
//! "Offline-dependency substitutions".

pub mod args;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod timer;

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert!(fmt_bytes(3.5 * 1024.0 * 1024.0 * 1024.0).contains("GiB"));
    }

    #[test]
    fn secs_formatting() {
        assert!(fmt_secs(2.5e-9).contains("ns"));
        assert!(fmt_secs(2.5e-6).contains("µs"));
        assert!(fmt_secs(2.5e-3).contains("ms"));
        assert!(fmt_secs(2.5).contains("s"));
    }
}
