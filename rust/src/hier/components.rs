//! Connected components of the bipartite remote graph — Algorithm 1
//! line 2 runs minimum vertex cover *per component*. Matchings and covers
//! decompose over components, so whole-graph Hopcroft–Karp (what
//! `prepost::split_pair` uses) computes the same optimum; this module
//! provides the explicit per-component path, used (a) to mirror the
//! paper's algorithm literally and (b) as a cross-check in tests.

use super::hopcroft_karp::Bipartite;
use super::vertex_cover::{minimum_vertex_cover, Cover};

/// Component id per left and right vertex (isolated vertices get their
/// own ids).
#[derive(Clone, Debug)]
pub struct Components {
    pub comp_u: Vec<u32>,
    pub comp_v: Vec<u32>,
    pub n_components: usize,
}

/// Union-find based bipartite connected components.
pub fn connected_components(g: &Bipartite) -> Components {
    let n = g.nu + g.nv;
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }
    for (u, vs) in g.adj.iter().enumerate() {
        for &v in vs {
            let a = find(&mut parent, u as u32);
            let b = find(&mut parent, (g.nu + v as usize) as u32);
            if a != b {
                parent[a as usize] = b;
            }
        }
    }
    // Compact roots to dense component ids.
    let mut id_of_root = std::collections::HashMap::new();
    let mut comp = vec![0u32; n];
    let mut next = 0u32;
    for x in 0..n as u32 {
        let r = find(&mut parent, x);
        let id = *id_of_root.entry(r).or_insert_with(|| {
            let i = next;
            next += 1;
            i
        });
        comp[x as usize] = id;
    }
    Components {
        comp_u: comp[..g.nu].to_vec(),
        comp_v: comp[g.nu..].to_vec(),
        n_components: next as usize,
    }
}

/// Per-component minimum vertex cover, merged back into a whole-graph
/// cover (the literal Algorithm-1 lines 1–3).
pub fn per_component_cover(g: &Bipartite) -> Cover {
    let comps = connected_components(g);
    let mut in_u = vec![false; g.nu];
    let mut in_v = vec![false; g.nv];
    for c in 0..comps.n_components {
        // Extract the component's subgraph with compacted indices.
        let us: Vec<usize> = (0..g.nu).filter(|&u| comps.comp_u[u] == c as u32).collect();
        let vs: Vec<usize> = (0..g.nv).filter(|&v| comps.comp_v[v] == c as u32).collect();
        if us.is_empty() || vs.is_empty() {
            continue;
        }
        let vmap: std::collections::HashMap<usize, u32> =
            vs.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        let edges: Vec<(u32, u32)> = us
            .iter()
            .enumerate()
            .flat_map(|(iu, &u)| {
                g.adj[u]
                    .iter()
                    .map(move |&v| (iu as u32, v))
                    .collect::<Vec<_>>()
            })
            .filter_map(|(iu, v)| vmap.get(&(v as usize)).map(|&iv| (iu, iv)))
            .collect();
        let sub = Bipartite::from_edges(us.len(), vs.len(), &edges);
        let (cover, _) = minimum_vertex_cover(&sub);
        for (iu, &u) in us.iter().enumerate() {
            if cover.in_u[iu] {
                in_u[u] = true;
            }
        }
        for (iv, &v) in vs.iter().enumerate() {
            if cover.in_v[iv] {
                in_v[v] = true;
            }
        }
    }
    Cover { in_u, in_v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn components_of_disjoint_stars() {
        // Star A: u0-{v0,v1}; star B: u1-{v2}; isolated u2, v3.
        let g = Bipartite::from_edges(3, 4, &[(0, 0), (0, 1), (1, 2)]);
        let c = connected_components(&g);
        assert_eq!(c.comp_u[0], c.comp_v[0]);
        assert_eq!(c.comp_u[0], c.comp_v[1]);
        assert_eq!(c.comp_u[1], c.comp_v[2]);
        assert_ne!(c.comp_u[0], c.comp_u[1]);
        // isolated vertices get their own components
        assert_eq!(c.n_components, 4);
    }

    #[test]
    fn per_component_cover_is_valid_and_minimal() {
        let g = Bipartite::from_edges(
            5,
            5,
            &[(0, 0), (0, 1), (1, 1), (2, 2), (3, 3), (4, 3), (4, 4)],
        );
        let c = per_component_cover(&g);
        assert!(c.is_cover(&g));
        let (whole, m) = minimum_vertex_cover(&g);
        assert_eq!(c.size(), whole.size());
        assert_eq!(c.size(), m.size());
    }

    #[test]
    fn prop_per_component_equals_whole_graph_optimum() {
        // Matchings/covers decompose over components: both paths must
        // yield the same size (the optimum), and both must be covers.
        propcheck(40, |gen| {
            let nu = gen.usize(1, 25);
            let nv = gen.usize(1, 25);
            let ne = gen.usize(0, 60);
            let edges: Vec<(u32, u32)> = (0..ne)
                .map(|_| (gen.rng.index(nu) as u32, gen.rng.index(nv) as u32))
                .collect();
            let g = Bipartite::from_edges(nu, nv, &edges);
            let per_comp = per_component_cover(&g);
            let (whole, _) = minimum_vertex_cover(&g);
            prop_assert(per_comp.is_cover(&g), "per-component result not a cover")?;
            prop_assert(
                per_comp.size() == whole.size(),
                format!("sizes differ: per-comp {} vs whole {}", per_comp.size(), whole.size()),
            )
        });
    }

    #[test]
    fn empty_graph() {
        let g = Bipartite::from_edges(3, 2, &[]);
        let c = connected_components(&g);
        assert_eq!(c.n_components, 5);
        let cover = per_component_cover(&g);
        assert_eq!(cover.size(), 0);
    }
}
