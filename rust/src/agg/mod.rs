//! General and efficient aggregation operators (paper §4).
//!
//! Full-batch GCN aggregation is `index_add` / SpMM: rows of a source
//! feature matrix are summed into destination rows selected by an index.
//! The paper's single-CPU contribution is a chain of four optimizations
//! over the vanilla scatter loop:
//!
//! 1. **Clustering & sorting** (`sorted::SortedIndexAdd`) — sort the index
//!    and cluster source rows aggregating to the same destination, so each
//!    destination row is touched once.
//! 2. **Loop reordering** — iterate destination-major so the destination
//!    row stays in registers across its whole source run.
//! 3. **Register-blocked inner kernel** (`blocked::segment_sum`) — a
//!    shape-adaptive inner kernel over fixed-width feature chunks
//!    (cache-line-aligned) with unrolled accumulators; safe Rust that
//!    auto-vectorizes to AVX-512/SVE on the paper's hardware.
//! 4. **2D dynamic parallelism + FLOPS-based load balancing**
//!    (`parallel::segment_sum`) — (destination-block × feature-block)
//!    tiles sized by *edge count* (FLOPS), pulled dynamically by threads.
//!
//! The common primitive is **segment sum**: given `gather[i]` (source row
//! of contribution `i`) and non-decreasing `seg[i]` (destination segment),
//! `out[seg[i]] += h[gather[i]]`. Local-edge aggregation, pre-aggregation
//! partials, and index_add all reduce to it.
//!
//! On top of the ladder, [`simd`] is the explicitly vectorized rung:
//! runtime-dispatched AVX2 intrinsics (scalar fallback elsewhere) that are
//! **bitwise identical** to the scalar kernels — DESIGN.md §14.

pub mod blocked;
pub mod parallel;
pub mod simd;
pub mod spmm;
pub mod sorted;
pub mod vanilla;

/// Uniform signature implemented by all segment-sum variants; `seg` must be
/// non-decreasing for the optimized kernels (vanilla accepts any order).
/// `out` has `n_seg * f` elements and is **accumulated into** (callers zero
/// it when they need `=` semantics).
pub type SegmentSumFn = fn(h: &[f32], f: usize, gather: &[u32], seg: &[u32], out: &mut [f32]);

/// Check `seg` is non-decreasing (debug aid; optimized kernels assume it).
pub fn is_sorted_segs(seg: &[u32]) -> bool {
    seg.windows(2).all(|w| w[0] <= w[1])
}

/// Divide each row of `x` (n × f) by `deg[i]` where deg > 0 (mean
/// aggregation). Rows with deg == 0 are left untouched.
pub fn scale_rows_by_inv_degree(x: &mut [f32], f: usize, deg: &[u32]) {
    for (i, &d) in deg.iter().enumerate() {
        if d > 0 {
            let inv = 1.0 / d as f32;
            for v in &mut x[i * f..(i + 1) * f] {
                *v *= inv;
            }
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::util::rng::Rng;

    /// Random (h, gather, sorted seg) problem.
    pub fn random_problem(
        rng: &mut Rng,
        n_src: usize,
        n_seg: usize,
        m: usize,
        f: usize,
    ) -> (Vec<f32>, Vec<u32>, Vec<u32>) {
        let h: Vec<f32> = (0..n_src * f).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let gather: Vec<u32> = (0..m).map(|_| rng.index(n_src) as u32).collect();
        let mut seg: Vec<u32> = (0..m).map(|_| rng.index(n_seg) as u32).collect();
        seg.sort_unstable();
        (h, gather, seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_check() {
        assert!(is_sorted_segs(&[0, 0, 1, 3, 3]));
        assert!(!is_sorted_segs(&[0, 2, 1]));
        assert!(is_sorted_segs(&[]));
    }

    #[test]
    fn mean_scaling() {
        let mut x = vec![2.0, 4.0, 6.0, 8.0];
        scale_rows_by_inv_degree(&mut x, 2, &[2, 0]);
        assert_eq!(x, vec![1.0, 2.0, 6.0, 8.0]);
    }
}
