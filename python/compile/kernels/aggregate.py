"""L1 Pallas kernel: blocked segment-sum aggregation.

TPU re-think of the paper's §4 CPU operator (DESIGN.md §2
Hardware-Adaptation): edges arrive **sorted by destination** (the paper's
clustering/sorting step, done once on the host by the Rust planner); the
kernel processes fixed-size edge blocks, and within a block the
per-destination accumulation is expressed as

    partial = one_hot(seg_rel)ᵀ @ gathered_rows        # [SEG, EB] @ [EB, FB]

i.e. an MXU matmul — the systolic-array analogue of the paper's
vector-register-blocked scatter. Feature columns are tiled by BlockSpec so
a (rows, one-hot, accumulator) triple fits VMEM (see DESIGN.md §8 for the
footprint estimate). Block partials are combined by a cheap scatter-add in
plain XLA (the 2D-parallel reduction of Fig 3(d)).

Both the forward (reduce) and backward (broadcast, `onehot @ d_partial`)
are Pallas kernels wrapped in one `jax.custom_vjp`.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Edge-block size and per-block segment capacity. EB == SEGB guarantees any
# block's distinct destinations fit (≤ EB of them).
EB = 128


def plan_segments(seg, eb=EB):
    """Host-side planning (numpy): given sorted segment ids `seg[e]`,
    produce (seg_rel[e], block_seg[nb*eb]) where within each eb-block
    seg_rel is the dense rank of the segment and block_seg maps
    (block, rank) → global segment (or `trash` = max+1 for unused slots).

    The Rust planner reimplements this; this copy serves the Python tests
    and the AOT examples.
    """
    seg = np.asarray(seg, dtype=np.int32)
    e = len(seg)
    assert e % eb == 0, "edge count must be padded to a block multiple"
    nb = e // eb
    seg_rel = np.zeros(e, dtype=np.int32)
    block_seg = np.full(nb * eb, -1, dtype=np.int32)
    for b in range(nb):
        blk = seg[b * eb : (b + 1) * eb]
        uniq, inv = np.unique(blk, return_inverse=True)
        seg_rel[b * eb : (b + 1) * eb] = inv.astype(np.int32)
        block_seg[b * eb : b * eb + len(uniq)] = uniq
    return seg_rel, block_seg


def _fwd_kernel(rows_ref, segrel_ref, out_ref):
    """One (edge-block, feature-block) tile: out = onehotᵀ @ rows."""
    rel = segrel_ref[...]  # [EB]
    onehot = (rel[:, None] == jax.lax.broadcasted_iota(jnp.int32, (EB, EB), 1)).astype(
        rows_ref.dtype
    )  # [EB, SEGB]
    out_ref[...] = jnp.dot(
        onehot.T, rows_ref[...], preferred_element_type=rows_ref.dtype
    )


def _bwd_kernel(dpart_ref, segrel_ref, drows_ref):
    """Backward tile: d_rows = onehot @ d_partials."""
    rel = segrel_ref[...]
    onehot = (rel[:, None] == jax.lax.broadcasted_iota(jnp.int32, (EB, EB), 1)).astype(
        dpart_ref.dtype
    )
    drows_ref[...] = jnp.dot(
        onehot, dpart_ref[...], preferred_element_type=dpart_ref.dtype
    )


def _block_reduce(rows, seg_rel):
    """partials[nb*EB, f] from rows[e, f] and seg_rel[e] (Pallas)."""
    e, f = rows.shape
    assert e % EB == 0
    nb = e // EB
    fb = min(f, 128)
    assert f % fb == 0, "feature dim must divide the 128 block (pad on host)"
    return pl.pallas_call(
        _fwd_kernel,
        grid=(nb, f // fb),
        in_specs=[
            pl.BlockSpec((EB, fb), lambda i, j: (i, j)),
            pl.BlockSpec((EB,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((EB, fb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb * EB, f), rows.dtype),
        interpret=True,
    )(rows, seg_rel)


def _block_broadcast(d_partials, seg_rel):
    """d_rows[e, f] from d_partials[nb*EB, f] (Pallas backward)."""
    e = seg_rel.shape[0]
    f = d_partials.shape[1]
    nb = e // EB
    fb = min(f, 128)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(nb, f // fb),
        in_specs=[
            pl.BlockSpec((EB, fb), lambda i, j: (i, j)),
            pl.BlockSpec((EB,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((EB, fb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, f), d_partials.dtype),
        interpret=True,
    )(d_partials, seg_rel)


@jax.custom_vjp
def _segment_reduce(rows, seg_rel):
    return _block_reduce(rows, seg_rel)


def _segment_reduce_fwd(rows, seg_rel):
    # seg_rel (int32) rides along as the residual; its own cotangent is None.
    return _block_reduce(rows, seg_rel), seg_rel


def _segment_reduce_bwd(seg_rel, d_partials):
    return (_block_broadcast(d_partials, seg_rel), None)


_segment_reduce.defvjp(_segment_reduce_fwd, _segment_reduce_bwd)


def segment_sum(h, gather, seg_rel, block_seg, n_seg):
    """Full segment sum `out[s] = Σ_{i: seg(i)=s} h[gather[i]]`.

    h:         [n, f] feature rows (differentiable)
    gather:    [e] int32 source-row index per contribution (padded entries
               must point at a zero row of `h`)
    seg_rel:   [e] int32 within-block segment rank (host-planned)
    block_seg: [e] int32 (= nb*EB) rank → global segment map; unused slots
               must be ≥ n_seg (they fall into the trash row and are
               sliced off)
    n_seg:     static segment count
    Returns [n_seg, f].
    """
    rows = h[gather]  # XLA gather (DMA on real hardware)
    partials = _segment_reduce(rows, seg_rel)  # Pallas hot loop
    safe = jnp.minimum(block_seg, n_seg)  # clamp trash slots to row n_seg
    out = jnp.zeros((n_seg + 1, h.shape[1]), dtype=h.dtype)
    out = out.at[safe].add(partials)
    return out[:n_seg]
