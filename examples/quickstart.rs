//! Quickstart: partition a small citation-style graph across 4 simulated
//! workers and train a 3-layer GraphSAGE two ways with the same comm
//! accounting:
//!
//! 1. the paper's **full-batch** pipeline (MVC hybrid pre/post-
//!    aggregation + Int2 quantized halos + masked label propagation),
//! 2. the **mini-batch** regime (`sample::`): neighbor fan-out batches
//!    over the same SPMD partitions, remote feature rows fetched through
//!    `comm::alltoallv` with Int2 quantization.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;
use supergcn::coordinator::planner::prepare;
use supergcn::datasets;
use supergcn::graph::stats::stats;
use supergcn::hier::volume::RemoteStrategy;
use supergcn::quant::Bits;
use supergcn::run::RunConfig;
use supergcn::sample::SamplerKind;
use supergcn::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let spec = datasets::by_name("arxiv-s")?;
    let lg = spec.build();
    println!("dataset {} — {}", spec.name, stats(&lg.graph));

    // ---- regime 1: full-batch (the paper's loop) -----------------------
    // One RunConfig per run (DESIGN.md §15): trainers for both regimes
    // are constructed through it instead of per-regime config literals.
    let rc = RunConfig {
        epochs: 60,
        lr: spec.lr,
        quant: Some(Bits::Int2),
        label_prop: true,
        strategy: RemoteStrategy::Hybrid,
        ..Default::default()
    };
    let (ctxs, cfg, plans) = prepare(&lg, 4, rc.strategy, None, rc.seed)?;
    println!(
        "partitioned into {} workers; halo rows/layer: {}",
        plans.len(),
        plans.iter().map(|p| p.send_rows()).sum::<usize>()
    );

    let mut tr = rc.full_batch_trainer(ctxs, cfg);
    let full_stats = tr.run(true)?;
    let last = full_stats.last().unwrap();
    println!(
        "\nfull-batch: loss {:.4}, train acc {:.3}, test acc {:.3}",
        last.train_loss, last.train_acc, last.test_acc
    );
    println!("breakdown: {}", last.breakdown.report());
    let full_epoch_bytes = full_stats[1].comm_data_bytes;

    // ---- regime 2: mini-batch neighbor sampling on the same substrate --
    let rc_mb = RunConfig {
        sampler: SamplerKind::Neighbor,
        epochs: 60,
        lr: spec.lr,
        quant: Some(Bits::Int2),
        hidden: spec.hidden,
        batch_size: 512,
        fanouts: vec![15, 10, 5],
        ..Default::default()
    };
    let mut mb = rc_mb.minibatch_trainer(Arc::new(lg), 4)?;
    println!(
        "\nmini-batch: sampler={}, {} batches/epoch over the same 4-way partition",
        mb.sampler_name(),
        mb.batches_per_epoch()
    );
    let mb_stats = mb.run(true)?;
    let last = mb_stats.last().unwrap();
    println!(
        "\nmini-batch: loss {:.4}, train acc {:.3}, test acc {:.3}",
        last.train_loss, last.train_acc, last.test_acc
    );
    println!(
        "per-epoch comm: full-batch {} vs mini-batch {}",
        fmt_bytes(full_epoch_bytes),
        fmt_bytes(mb_stats[1].comm_data_bytes),
    );
    Ok(())
}
