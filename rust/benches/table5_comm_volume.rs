//! Table 5: communication volume and modeled time for one GCN layer under
//! pre / post / hybrid / hybrid+Int2 (data and params rows), mag240M-like
//! workload on the Fugaku profile.
//!
//! Expected shape (paper): hybrid ≈ 1.5× less volume/time than pre or
//! post alone; +Int2 ≈ 15× further on the data row with a small params
//! row (α ≫ 1).

use supergcn::datasets;
use supergcn::exp::Table;
use supergcn::hier::remote_pairs;
use supergcn::hier::volume::{volume, RemoteStrategy};
use supergcn::partition::{multilevel, vertex_weights};
use supergcn::perfmodel::{t_comm, t_quant_comm_total, MachineProfile};
use supergcn::util::fmt_bytes;

fn main() {
    let machine = MachineProfile::fugaku();
    for (name, k) in [("mag240m-s", 16usize), ("uk2007-s", 16)] {
        let spec = datasets::by_name(name).unwrap();
        let lg = spec.build();
        let f = spec.feat_dim;
        let w = vertex_weights(&lg.graph, None, 4);
        let part = multilevel::multilevel(&lg.graph, k, &w, &multilevel::MultilevelOpts::default());
        let pairs = remote_pairs(&lg.graph, &part);

        let mut t = Table::new(
            &format!("Table 5: {} on {k} procs, feat {f}, 1 GCN layer", name),
            &["method", "comm volume", "modeled comm time (ms)"],
        );
        let mut vols = Vec::new();
        for s in [RemoteStrategy::PreOnly, RemoteStrategy::PostOnly, RemoteStrategy::Hybrid] {
            let v = volume(k, &pairs, s);
            let values: Vec<Vec<usize>> =
                v.rows.iter().map(|r| r.iter().map(|&x| x * f).collect()).collect();
            let secs = t_comm(&values, &machine);
            vols.push((s, v.payload_bytes(f, 32), secs));
            t.row(vec![
                format!("SuperGCN ({})", s.name()),
                fmt_bytes(v.payload_bytes(f, 32)),
                format!("{:.3}", secs * 1e3),
            ]);
        }
        let v = volume(k, &pairs, RemoteStrategy::Hybrid);
        let values: Vec<Vec<usize>> =
            v.rows.iter().map(|r| r.iter().map(|&x| x * f).collect()).collect();
        let params: Vec<Vec<usize>> = v
            .rows
            .iter()
            .map(|r| r.iter().map(|&x| x.div_ceil(4) * 2).collect())
            .collect();
        let sub = vec![(lg.n() / k * f) as f64; k];
        let tq = t_quant_comm_total(&values, &params, &sub, 2.0, &machine);
        t.row(vec![
            "SuperGCN (pre_post+Int2)  data".into(),
            fmt_bytes(v.payload_bytes(f, 2)),
            format!("{:.3} (incl quant/dequant)", tq * 1e3),
        ]);
        t.row(vec![
            "SuperGCN (pre_post+Int2) params".into(),
            fmt_bytes(v.param_bytes(4)),
            "-".into(),
        ]);
        t.print();

        // Shape assertions (paper's claims).
        let hybrid = vols[2];
        let best_single = vols[0].1.min(vols[1].1);
        println!(
            "hybrid saves {:.2}x volume vs best(pre, post); Int2 shrinks the data row {:.1}x",
            best_single / hybrid.1,
            hybrid.1 / v.payload_bytes(f, 2),
        );
        assert!(hybrid.1 <= best_single);
    }
}
