//! Remote-feature cache acceptance tests (DESIGN.md §16).
//!
//! 1. **TTL=0 identity** — `--feature-cache-ttl 0` (any capacity) is
//!    bit-exact with the seed's uncached fetch: per-epoch loss bits and
//!    `CommStats` wire bits, both transports × overlap on/off ×
//!    group-size ∈ {1, 2}. The CI spmd-parity leg runs the identity
//!    filter of this file.
//! 2. **fp32 hits are pure comm wins** — fp32 feature rows are immutable,
//!    so a cache hit reproduces the fetched bits exactly: TTL>0 keeps the
//!    loss curve bit-identical while the wire bits shrink by exactly the
//!    analytic saved-bits the cache charges.
//! 3. **Determinism** — runs with the cache live are bit-reproducible and
//!    transport-parity (the per-rank caches evolve in the identical
//!    probe/admit order on both executors), and eviction pressure does
//!    not break either property.
//! 4. **Capacity monotonicity** — more capacity never lowers the hit
//!    rate on the same workload.
//! 5. **Elastic invalidation** — after a chaos rank loss the cache is
//!    rebuilt cold at the survivor count: the recovered run's tail is
//!    bit-identical to a fresh survivor-plan run started from the
//!    pre-failure snapshot (which also starts cold).
//! 6. **Quantized window equality** — rows are cached post-dequant, so a
//!    hit within the TTL window returns the fused-decode bits of the
//!    fetch round exactly.

use std::sync::Arc;
use supergcn::comm::transport::{FaultSpec, TransportKind};
use supergcn::comm::CommStats;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::{partition_for, survivor_partition};
use supergcn::datasets;
use supergcn::exec::{FeatCache, FeatCacheConfig};
use supergcn::quant::{fused, Bits};
use supergcn::run::RunConfig;
use supergcn::sample::{SamplerConfig, SamplerKind};

fn assert_loss_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: epoch counts diverged");
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: epoch {e} loss diverged: {x} vs {y}");
    }
}

fn assert_comm_equal(a: &CommStats, b: &CommStats, what: &str) {
    assert_eq!(a.data_bits, b.data_bits, "{what}: data bits diverged");
    assert_eq!(a.param_bits, b.param_bits, "{what}: param bits diverged");
    assert_eq!(a.messages, b.messages, "{what}: message counts diverged");
    assert_eq!(
        a.modeled_send_secs, b.modeled_send_secs,
        "{what}: modeled wire seconds diverged"
    );
    assert!(a.total_data_bytes() > 0.0, "{what}: no traffic — vacuous test");
}

/// The parity-suite mini-batch workload (arxiv-xs, k=3, neighbor) plus
/// the two cache knobs.
fn cache_run(
    transport: TransportKind,
    quant: Option<Bits>,
    overlap: bool,
    group_size: usize,
    cache_rows: usize,
    cache_ttl: usize,
) -> (Vec<f32>, CommStats) {
    let spec = datasets::by_name("arxiv-xs").unwrap();
    let lg = Arc::new(spec.build());
    let mc = MiniBatchConfig {
        epochs: 3,
        lr: spec.lr,
        hidden: spec.hidden,
        quant,
        transport,
        overlap,
        group_size,
        seed: 42,
        feature_cache_rows: cache_rows,
        feature_cache_ttl: cache_ttl,
        ..Default::default()
    };
    let scfg = SamplerConfig {
        batch_size: 128,
        fanouts: vec![10, 5, 5],
        seed: 42,
        ..Default::default()
    };
    let mut tr = MiniBatchTrainer::new(lg, 3, SamplerKind::Neighbor, &scfg, mc).unwrap();
    let losses = tr.run(false).unwrap().iter().map(|s| s.train_loss).collect();
    (losses, tr.comm_stats.clone())
}

#[test]
fn ttl0_is_bit_exact_with_the_uncached_seed_path() {
    // The identity gate: TTL=0 must be byte-for-byte today's fetch — the
    // capacity knob alone may not change a single loss or wire bit, and
    // no cache counter may record anything. Full executor matrix.
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        for overlap in [false, true] {
            for group_size in [1usize, 2] {
                let (base_loss, base_comm) =
                    cache_run(transport, None, overlap, group_size, 0, 0);
                let (off_loss, off_comm) =
                    cache_run(transport, None, overlap, group_size, 256, 0);
                let what = format!(
                    "ttl0 identity {} overlap={overlap} g={group_size}",
                    transport.name()
                );
                assert_loss_bits(&base_loss, &off_loss, &what);
                assert_comm_equal(&base_comm, &off_comm, &what);
                assert!(!base_comm.cache.is_active(), "{what}: seed run counted cache");
                assert!(!off_comm.cache.is_active(), "{what}: disabled cache counted");
            }
        }
    }
}

#[test]
fn fp32_cache_saves_wire_bytes_without_changing_loss_bits() {
    // fp32 rows are immutable, so a hit returns the exact bits a fresh
    // fetch would: the loss curve is bit-identical to TTL=0 while the
    // data leg shrinks by exactly the analytic saved-bits (32-bit id on
    // the request leg + 32f row on the reply leg per hit) — integer bit
    // counts, so the f64 accounting is exact.
    let (base_loss, base_comm) =
        cache_run(TransportKind::Sequential, None, false, 1, 0, 0);
    let (hit_loss, hit_comm) =
        cache_run(TransportKind::Sequential, None, false, 1, 512, 2);
    assert_loss_bits(&base_loss, &hit_loss, "fp32 cache");
    let cache = &hit_comm.cache;
    assert!(cache.is_active(), "cache never probed");
    assert!(cache.total_hits() > 0, "no hits at 512 rows / TTL 2");
    assert!(cache.hit_rate() > 0.0);
    let base_bits: f64 = base_comm.data_bits.iter().flatten().sum();
    let hit_bits: f64 = hit_comm.data_bits.iter().flatten().sum();
    assert!(hit_bits < base_bits, "cache saved nothing: {hit_bits} vs {base_bits}");
    let saved = cache.total_saved_bytes() * 8.0;
    assert!(
        (base_bits - hit_bits - saved).abs() < 1e-6,
        "saved-bits accounting drifted: wire delta {} vs charged {saved}",
        base_bits - hit_bits
    );
}

#[test]
fn cache_on_runs_are_transport_and_overlap_parity() {
    // With the cache live the executor matrix must still agree to the
    // bit: the per-rank caches see the identical probe/admit sequence on
    // every transport/schedule/topology, so losses, wire bits, and the
    // cache counters themselves all match.
    let (base_loss, base_comm) =
        cache_run(TransportKind::Sequential, None, false, 1, 256, 2);
    assert!(base_comm.cache.total_hits() > 0, "vacuous: no hits in the base run");
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        for overlap in [false, true] {
            for group_size in [1usize, 2] {
                let (loss, comm) = cache_run(transport, None, overlap, group_size, 256, 2);
                let what = format!(
                    "cache-on parity {} overlap={overlap} g={group_size}",
                    transport.name()
                );
                assert_loss_bits(&base_loss, &loss, &what);
                assert_comm_equal(&base_comm, &comm, &what);
                assert_eq!(
                    base_comm.cache.hits, comm.cache.hits,
                    "{what}: per-rank hit counts diverged"
                );
                assert_eq!(
                    base_comm.cache.misses, comm.cache.misses,
                    "{what}: per-rank miss counts diverged"
                );
                assert_eq!(
                    base_comm.cache.evictions, comm.cache.evictions,
                    "{what}: per-rank eviction counts diverged"
                );
                assert_eq!(
                    base_comm.cache.saved_bits, comm.cache.saved_bits,
                    "{what}: per-rank saved bits diverged"
                );
            }
        }
    }
}

#[test]
fn eviction_pressure_keeps_runs_deterministic() {
    // A deliberately tight cache (heavy eviction churn) must stay
    // bit-reproducible: eviction picks the minimum (freq, round, id) key
    // — a total order — so HashMap iteration order never leaks into the
    // run. Two fresh runs agree bit-for-bit, counters included.
    let run = || cache_run(TransportKind::Sequential, None, false, 1, 24, 2);
    let (loss_a, comm_a) = run();
    let (loss_b, comm_b) = run();
    assert!(
        comm_a.cache.total_evictions() > 0,
        "capacity 24 must churn (got {} evictions)",
        comm_a.cache.total_evictions()
    );
    assert_loss_bits(&loss_a, &loss_b, "eviction determinism");
    assert_comm_equal(&comm_a, &comm_b, "eviction determinism");
    assert_eq!(comm_a.cache.hits, comm_b.cache.hits);
    assert_eq!(comm_a.cache.evictions, comm_b.cache.evictions);
    assert_eq!(comm_a.cache.saved_bits, comm_b.cache.saved_bits);
}

#[test]
fn hit_rate_is_monotone_in_capacity() {
    // Same workload, growing capacity: the hit rate never drops. The
    // zero-capacity point is the degenerate sweep anchor — it probes
    // (counts misses) but can never admit.
    let mut last = -1.0f64;
    for rows in [0usize, 16, 128, 1024] {
        let (_, comm) = cache_run(TransportKind::Sequential, None, false, 1, rows, 2);
        let hr = comm.cache.hit_rate();
        assert!(comm.cache.is_active(), "rows={rows}: TTL>0 must probe");
        if rows == 0 {
            assert_eq!(comm.cache.total_hits(), 0, "zero capacity cannot hit");
        }
        assert!(
            hr >= last,
            "hit rate fell from {last:.4} to {hr:.4} when capacity grew to {rows}"
        );
        last = hr;
    }
    assert!(last > 0.0, "largest capacity never hit — vacuous sweep");
}

#[test]
fn cache_is_rebuilt_cold_after_elastic_recovery() {
    // Chaos kills rank 1 entering epoch 2; recovery re-plans across the
    // 2 survivors and must invalidate the cache wholesale (ownership
    // changed). Reference: a fresh survivor-plan trainer — whose cache
    // also starts cold — restored from the pre-failure snapshot. Tails
    // bit-identical ⇔ the recovered cache carried nothing across.
    let spec = datasets::by_name("arxiv-xs").unwrap();
    let graph = Arc::new(spec.build());
    let total = 4usize;
    let fail_epoch = 2usize;
    let failed_rank = 1usize;
    let rc = RunConfig {
        sampler: SamplerKind::Neighbor,
        epochs: total,
        lr: spec.lr,
        hidden: spec.hidden,
        transport: TransportKind::Threaded,
        batch_size: 128,
        fanouts: vec![10, 5, 5],
        feature_cache_rows: 256,
        feature_cache_ttl: 2,
        chaos: Some(FaultSpec {
            rank: failed_rank,
            epoch: fail_epoch,
        }),
        ..Default::default()
    };
    rc.validate(3).unwrap();
    let mut a = rc.minibatch_trainer(graph.clone(), 3).unwrap();
    let sa = a.run(false).unwrap();
    assert_eq!(sa.len(), total);
    assert_eq!(a.k(), 2, "the failed rank must be gone from the plan");
    assert!(sa.iter().all(|s| s.train_loss.is_finite()));
    assert!(a.comm_stats.cache.is_active(), "survivor epochs must keep caching");

    // B: pre-failure reference (same config minus chaos) provides the
    // epoch-boundary snapshot the recovery rolled back to.
    let rc_b = RunConfig {
        epochs: fail_epoch,
        chaos: None,
        ..rc.clone()
    };
    let mut b = rc_b.minibatch_trainer(graph.clone(), 3).unwrap();
    let sb = b.run(false).unwrap();
    assert_loss_bits(
        &sa[..fail_epoch].iter().map(|s| s.train_loss).collect::<Vec<_>>(),
        &sb.iter().map(|s| s.train_loss).collect::<Vec<_>>(),
        "chaos prefix with cache",
    );

    // C: fresh trainer on the survivor plan (cold cache), restored from
    // B's snapshot, run to the full length.
    let part = partition_for(&graph, 3, rc.seed);
    let survivors = survivor_partition(&graph.graph, &part, failed_rank).unwrap();
    let rc_c = RunConfig {
        chaos: None,
        ..rc.clone()
    };
    let mut c = MiniBatchTrainer::with_partition(
        graph.clone(),
        survivors,
        SamplerKind::Neighbor,
        &rc_c.sampler_config(),
        rc_c.minibatch_config(),
    )
    .unwrap();
    c.restore(&b.snapshot());
    let sc = c.run(false).unwrap();
    assert_loss_bits(
        &sa[fail_epoch..].iter().map(|s| s.train_loss).collect::<Vec<_>>(),
        &sc.iter().map(|s| s.train_loss).collect::<Vec<_>>(),
        "chaos tail with cache (cold-rebuild invariant)",
    );
}

#[test]
fn int4_cached_rows_equal_the_fresh_decode_within_the_window() {
    // The post-dequant contract: what the cache returns inside the TTL
    // window is bit-identical to the fused int4 decode of the round that
    // fetched the row — the cache stores decoded values, never re-rounds.
    let f = 24usize;
    let rows = 6usize;
    let x: Vec<f32> = (0..rows * f).map(|i| ((i as f32) * 0.37).sin()).collect();
    let q = fused::quantize(&x, rows, f, Bits::Int4, 0xFEED_BEEF);
    let decoded = fused::dequantize(&q);

    let mut c = FeatCache::new(FeatCacheConfig { rows: 16, ttl: 2 });
    c.begin_round();
    for r in 0..rows {
        let id = r as u32;
        assert!(c.probe(id).is_none(), "cold cache must miss");
        c.admit(id, &decoded[r * f..(r + 1) * f]);
    }
    // Rounds +1 and +2 are inside the window: every row returns the
    // decode bits of the fetch round exactly.
    for _ in 0..2 {
        c.begin_round();
        for r in 0..rows {
            let hit = c.probe(r as u32).expect("within TTL window");
            let want = &decoded[r * f..(r + 1) * f];
            assert_eq!(hit.len(), f);
            for (a, b) in hit.iter().zip(want.iter()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "cached int4 row {r} diverged from its fresh decode"
                );
            }
        }
    }
    // One round past the window: stale, dropped, must re-fetch.
    c.begin_round();
    for r in 0..rows {
        assert!(c.probe(r as u32).is_none(), "row {r} must expire past TTL");
    }
}

#[test]
fn int4_cache_run_completes_and_saves_wire() {
    // Run-level quantized smoke: the TTL>0 int4 run (stale rows feed the
    // engine, qseed varies per round) must stay finite and still shrink
    // the wire by its charged saved-bits.
    let (base_loss, base_comm) =
        cache_run(TransportKind::Sequential, Some(Bits::Int4), false, 1, 0, 0);
    let (loss, comm) =
        cache_run(TransportKind::Sequential, Some(Bits::Int4), false, 1, 512, 1);
    assert!(loss.iter().all(|l| l.is_finite()));
    assert!(base_loss.iter().all(|l| l.is_finite()));
    assert!(comm.cache.total_hits() > 0);
    assert!(comm.cache.total_saved_bytes() > 0.0);
    let base_bits: f64 = base_comm.data_bits.iter().flatten().sum::<f64>()
        + base_comm.param_bits.iter().flatten().sum::<f64>();
    let bits: f64 = comm.data_bits.iter().flatten().sum::<f64>()
        + comm.param_bits.iter().flatten().sum::<f64>();
    assert!(bits < base_bits, "int4 cache saved nothing: {bits} vs {base_bits}");
}
