//! Collectives beyond alltoallv: ring allreduce (gradient averaging) and
//! barrier-style max-reduction used for epoch-time combination.
//!
//! Numerically the allreduce is an exact element-wise sum (computed once,
//! broadcast by clone — SPMD simulation), while the *charged* wire time
//! follows the standard ring-allreduce model:
//! `2·(P−1)/P · bytes / BW + 2·(P−1)·L`.

use crate::perfmodel::MachineProfile;

/// Sum-allreduce of per-worker gradient buffers; every worker receives the
/// sum. Returns the modeled collective seconds.
pub fn allreduce_sum(buffers: &mut [Vec<f32>], profile: &MachineProfile) -> f64 {
    let p = buffers.len();
    if p == 0 {
        return 0.0;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "gradient length mismatch");
    if p == 1 {
        return 0.0;
    }
    let mut sum = vec![0f32; n];
    for b in buffers.iter() {
        for (s, &x) in sum.iter_mut().zip(b.iter()) {
            *s += x;
        }
    }
    for b in buffers.iter_mut() {
        b.copy_from_slice(&sum);
    }
    ring_allreduce_secs(n * 4, p, profile)
}

/// Modeled ring allreduce time for `bytes` per rank.
pub fn ring_allreduce_secs(bytes: usize, ranks: usize, profile: &MachineProfile) -> f64 {
    if ranks <= 1 || bytes == 0 {
        return 0.0;
    }
    let steps = 2 * (ranks - 1);
    let chunk_bits = bytes as f64 * 8.0 / ranks as f64;
    steps as f64 * (chunk_bits / profile.bw_comm + profile.latency)
}

/// Max-allreduce of scalars (load-imbalance / sync accounting). An empty
/// participant set contributes no time: the reduction is 0.0, not -inf
/// (which would poison every downstream accumulation). Non-empty input
/// keeps the true max, including all-negative slices.
pub fn allreduce_max(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sums_and_broadcasts() {
        let p = MachineProfile::abci();
        let mut bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let t = allreduce_sum(&mut bufs, &p);
        for b in &bufs {
            assert_eq!(b, &vec![9.0, 12.0]);
        }
        assert!(t > 0.0);
    }

    #[test]
    fn single_rank_free() {
        let p = MachineProfile::fugaku();
        let mut bufs = vec![vec![1.0, 2.0]];
        assert_eq!(allreduce_sum(&mut bufs, &p), 0.0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn ring_model_scales_with_ranks_and_bytes() {
        let p = MachineProfile::abci();
        let t2 = ring_allreduce_secs(1 << 20, 2, &p);
        let t8 = ring_allreduce_secs(1 << 20, 8, &p);
        assert!(t8 > t2);
        let tbig = ring_allreduce_secs(1 << 24, 8, &p);
        assert!(tbig > t8);
        assert_eq!(ring_allreduce_secs(0, 8, &p), 0.0);
    }

    #[test]
    fn max_reduce() {
        assert_eq!(allreduce_max(&[1.0, 5.0, 3.0]), 5.0);
    }

    #[test]
    fn max_reduce_of_empty_is_zero() {
        // Regression: used to return -inf, which poisoned any sum it was
        // later folded into.
        let t = allreduce_max(&[]);
        assert_eq!(t, 0.0);
        assert!(t.is_finite());
        // Non-empty all-negative input still reduces to its true max.
        assert_eq!(allreduce_max(&[-0.5, -3.0]), -0.5);
    }

    #[test]
    #[should_panic(expected = "gradient length mismatch")]
    fn mismatched_lengths_panic() {
        let p = MachineProfile::abci();
        let mut bufs = vec![vec![1.0], vec![1.0, 2.0]];
        allreduce_sum(&mut bufs, &p);
    }
}
