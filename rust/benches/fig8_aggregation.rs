//! Fig. 8: single-CPU aggregation operator performance.
//!
//! The paper compares PyG's vanilla scatter against SuperGCN's optimized
//! operators on per-layer shapes of several datasets. Here: `vanilla`
//! (per-edge scatter, the PyG analogue) vs the §4 optimization ladder —
//! `+sort/cluster` (stable clustering, dst-major runs), `+blocked`
//! (register-blocked inner kernel), `+parallel` (2D dynamic tiles with
//! FLOPS balancing; degrades to blocked on 1 core).
//!
//! Expected shape (paper): optimized wins 1.8–8.4×, growing with graph
//! size and feature width.

use std::time::Instant;
use supergcn::agg::{blocked, sorted::SortedIndexAdd, vanilla};
use supergcn::agg::parallel::segment_sum_n;
use supergcn::datasets;
use supergcn::exp::Table;

fn bench_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    // Warmup + best-of-reps.
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best * 1e3
}

fn main() {
    let mut table = Table::new(
        "Fig 8: aggregation operator time (ms, lower is better; 1 CPU core)",
        &["dataset", "layer", "vanilla", "+sort", "+blocked", "+parallel", "speedup"],
    );
    for name in ["arxiv-s", "reddit-s", "products-s"] {
        let spec = datasets::by_name(name).unwrap();
        let lg = spec.build();
        let g = &lg.graph;
        let n = g.n;
        let edges = g.edges();
        let idx: Vec<u32> = edges.iter().map(|e| e.1).collect();
        let gat: Vec<u32> = edges.iter().map(|e| e.0).collect();

        for (layer, f) in [("L1(feat)", spec.feat_dim), ("L2(hidden)", spec.hidden.max(64))] {
            let h: Vec<f32> = (0..n * f).map(|i| (i % 97) as f32 * 0.01).collect();
            let mut out = vec![0f32; n * f];

            // vanilla: unordered per-edge scatter (PyG analogue).
            let t_van = bench_ms(3, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                vanilla::segment_sum(&h, f, &gat, &idx, &mut out);
            });

            // +sort/cluster: stable cluster once (plan), then runs (cost
            // includes apply only — the paper also amortizes the sort).
            let plan = SortedIndexAdd::new(&idx, n);
            let sorted_gat: Vec<u32> = plan.perm.iter().map(|&i| gat[i as usize]).collect();
            let t_sort = bench_ms(3, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                vanilla::segment_sum(&h, f, &sorted_gat, &plan.seg, &mut out);
            });

            // +blocked register kernel on the clustered runs.
            let t_blk = bench_ms(3, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                blocked::segment_sum(&h, f, &sorted_gat, &plan.seg, &mut out);
            });

            // +2D parallel with FLOPS balancing.
            let threads = supergcn::util::pool::default_threads();
            let t_par = bench_ms(3, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                segment_sum_n(threads, &h, f, &sorted_gat, &plan.seg, n, &mut out);
            });

            let best = t_blk.min(t_par);
            table.row(vec![
                name.into(),
                format!("{layer} f={f}"),
                format!("{t_van:.2}"),
                format!("{t_sort:.2}"),
                format!("{t_blk:.2}"),
                format!("{t_par:.2}"),
                format!("{:.2}x", t_van / best),
            ]);
        }
    }
    table.print();
    println!(
        "\n(1-core container: the +parallel column equals +blocked; on the paper's \
         20-core Xeon it adds the 2D dynamic tiling win.)"
    );
}
