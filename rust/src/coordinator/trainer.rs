//! The distributed full-batch training driver (paper Fig. 2) — a thin
//! loop over the unified layer-execution engine (`exec::Engine`,
//! DESIGN.md §9).
//!
//! All layer math (LayerNorm, aggregation, SAGE update, loss,
//! label-propagation embedding, the exact backward) lives in the engine;
//! this driver owns only *policy and state*: the per-epoch label-prop
//! selection, the `delay_comm` staleness decision, the gradient
//! allreduce + optimizer step, and the Eqn-2 / Fig-12 time accounting.
//!
//! The driver runs the ranks under either transport (DESIGN.md §10):
//!
//! * `--transport seq` — every lane steps inside this thread through the
//!   multi-lane [`exec::FullBatchCtx`] (the original simulation harness);
//! * `--transport threaded` — one OS thread per rank, each executing the
//!   identical engine control flow over its own
//!   [`exec::FullBatchRankCtx`] + [`exec::LaneHalo`], with halo payloads,
//!   the loss-total allgather, and the ring gradient-allreduce all
//!   rendezvousing through the mailbox [`Fabric`]. Per-epoch losses and
//!   `CommStats` wire bits are bit-identical across transports
//!   (`tests/spmd_parity.rs`).
//!
//! The backward pass is exact: cotangents of received halo tensors are
//! shipped back to their producers every exchange epoch (the reverse of
//! the forward halo pattern), so the distributed gradient equals the
//! single-machine gradient to f32 round-off — property-checked in
//! `rust/tests/trainer_equivalence.rs`.

use super::planner::{self, WorkerCtx};
use crate::comm::transport::{self, Fabric, FaultPlan, RankBody, RankLost, Topology, TransportKind};
use crate::comm::{collective, CommStats};
use crate::exec::{
    AggDispatch, Engine, FullBatchCtx, FullBatchRankCtx, FullBatchState, LaneHalo, LossSpec,
    LossTotals, LpInputs, OverlapLedger, StageClock, Tapes, SPLIT_NONE,
};
use crate::graph::generate::{LabelledGraph, SPLIT_TEST, SPLIT_TRAIN, SPLIT_VAL};
use crate::hier::volume::RemoteStrategy;
use crate::model::labelprop::{self, LpSelection};
use crate::model::optimizer::{OptKind, Optimizer};
use crate::model::{checkpoint, ModelParams};
use crate::partition::Partition;
use crate::obs::{self, ExchangeRow, Telemetry, TraceCategory};
use crate::perfmodel::{self, MachineProfile};
use crate::quant::Bits;
use crate::runtime::ShapeConfig;
use crate::util::rng::Rng;
use crate::util::timer::{Breakdown, Category, ALL_CATEGORIES};
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Training-run configuration (one Fig. 11 curve = one of these).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub opt: OptKind,
    /// Forward halo quantization (None = FP32; the paper fixes Int2).
    pub quant: Option<Bits>,
    /// Masked label propagation (§6.1(1)).
    pub label_prop: bool,
    pub lp_frac: f64,
    pub strategy: RemoteStrategy,
    /// Exchange halos every `delay_comm` epochs (1 = synchronous SuperGCN;
    /// 5 = the DistGNN cd-5 baseline's staleness).
    pub delay_comm: usize,
    pub machine: MachineProfile,
    /// §4 aggregation-kernel dispatch (CLI: `--agg-kernel`).
    pub agg: AggDispatch,
    /// SPMD executor (CLI: `--transport {seq,threaded}`; DESIGN.md §10).
    pub transport: TransportKind,
    /// Rank threads for the threaded transport: 0 = one per rank (the
    /// only supported concurrency — blocking mailbox collectives need
    /// every rank resident). Any other value must equal the worker
    /// count; the trainers enforce this (the CLI pre-validates too).
    pub rank_threads: usize,
    /// Communication–computation overlap (CLI: `--overlap {off,on}`;
    /// DESIGN.md §11): post each layer's halo alltoallv before interior
    /// aggregation so wire time hides behind compute. Bit-exact with the
    /// blocking schedule (`tests/spmd_parity.rs`).
    pub overlap: bool,
    /// Ranks per simulated node (CLI: `--group-size`; DESIGN.md §12):
    /// 1 = flat P×P alltoallv, ≥2 = two-level leader-staged exchange —
    /// identical numerics and logical wire accounting, with the physical
    /// path's intra/inter tiers charged to `CommStats::tiers`.
    pub group_size: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 100,
            lr: 0.01,
            opt: OptKind::Adam,
            quant: None,
            label_prop: false,
            lp_frac: 0.5,
            strategy: RemoteStrategy::Hybrid,
            delay_comm: 1,
            machine: MachineProfile::abci(),
            agg: AggDispatch::default(),
            transport: TransportKind::Sequential,
            rank_threads: 0,
            overlap: false,
            group_size: 1,
            seed: 42,
        }
    }
}

/// Epoch-boundary checkpointing policy (`--checkpoint-every` /
/// `--checkpoint-path`; DESIGN.md §15). The fingerprint is
/// `RunConfig::fingerprint()` of the run — written into every file and
/// verified on `--resume`.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Save every N completed epochs (and at the final epoch). 0 = never.
    pub every: usize,
    pub path: PathBuf,
    pub fingerprint: u64,
}

/// What elastic rank-failure recovery needs that the worker contexts
/// alone can't provide: the full graph and the live partition, so the
/// driver can re-plan onto the survivors (DESIGN.md §15).
pub struct ElasticCtx {
    pub lg: Arc<LabelledGraph>,
    /// The partition the current worker contexts were built from (updated
    /// on every recovery).
    pub part: Partition,
    /// Recovery budget: how many rank losses may be absorbed before the
    /// error propagates (typically `k - 1`).
    pub max_failures: usize,
}

/// Epoch-boundary snapshot of all driver-owned mutable training state —
/// everything a retried epoch reads. Taken before each epoch when elastic
/// recovery is armed; restoring it makes the retry bit-identical to a run
/// that started on the survivor plan with this state.
#[derive(Clone, Debug)]
pub struct DriverSnapshot {
    pub(crate) flat: Vec<f32>,
    pub(crate) opt_m: Vec<f32>,
    pub(crate) opt_v: Vec<f32>,
    pub(crate) opt_t: u64,
    /// Driver RNG (full-batch label-prop selection; the mini-batch driver
    /// owns no RNG and stores zeros).
    pub(crate) rng: [u64; 4],
    pub(crate) epoch: usize,
}

/// Per-epoch observables.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    /// Modeled epoch seconds: Σ_stage max_w compute + modeled comm.
    pub modeled_secs: f64,
    /// Measured wall seconds of the epoch (sequential transport: every
    /// rank steps on the driver thread; threaded: ranks run concurrently,
    /// so this is the real multi-core epoch time).
    pub measured_secs: f64,
    pub breakdown: Breakdown,
    pub comm_data_bytes: f64,
    pub comm_param_bytes: f64,
    /// Per-exchange interior/boundary/comm accounting (populated only
    /// under `--overlap on`; see [`OverlapLedger`], DESIGN.md §11).
    pub overlap: OverlapLedger,
}

pub struct Trainer {
    pub shapes: ShapeConfig,
    pub tc: TrainConfig,
    pub workers: Vec<WorkerCtx>,
    pub engine: Engine,
    pub params: ModelParams,
    opt: Optimizer,
    /// Multi-lane tape set (sequential transport; lazily allocated).
    tapes: Option<Tapes>,
    /// One single-lane tape set per rank (threaded transport; lazy).
    rank_tapes: Vec<Tapes>,
    fb: FullBatchState,
    lp_sels: Vec<LpSelection>,
    pub comm_stats: CommStats,
    /// Optional span tracer + metrics registry (`--trace` /
    /// `--metrics-json`, DESIGN.md §13). Default-off: disabled telemetry
    /// records nothing and changes no behavior.
    pub telemetry: Telemetry,
    /// Rank placement (`--group-size`, DESIGN.md §12), built once per run.
    topo: Topology,
    epoch: usize,
    rng: Rng,
    /// Epoch-boundary checkpointing (None = off). Set via
    /// `run::RunConfig::full_batch_trainer*`.
    pub ckpt: Option<CheckpointPolicy>,
    /// Chaos injection (`--chaos`; test/bench only): armed once per run,
    /// fires on the scheduled epoch's fabric.
    pub chaos: Option<FaultPlan>,
    /// Elastic rank-failure recovery (None = rank loss is fatal, the
    /// pre-§15 behavior). Requires the graph, so only the
    /// graph-owning construction path enables it.
    pub elastic: Option<ElasticCtx>,
    /// Rank losses absorbed so far this run.
    recovered: usize,
    /// Total bytes of the per-rank shard files these contexts came from
    /// (0 when built in memory; set by the `--graph-dir` path so the
    /// per-epoch metrics carry `store.shard.bytes` — DESIGN.md §17).
    pub store_shard_bytes: u64,
}

impl Trainer {
    pub fn new(workers: Vec<WorkerCtx>, shapes: ShapeConfig, tc: TrainConfig) -> Self {
        let params = ModelParams::init(&shapes, tc.seed);
        let opt = Optimizer::new(tc.opt, tc.lr, params.n_params());
        let k = workers.len();
        let topo = Topology::new(k, tc.group_size);
        let engine = Engine::new(&shapes, true, tc.agg.clone());
        let fb = FullBatchState::new(&shapes, k);
        let lp_sels = (0..k)
            .map(|_| LpSelection {
                embedded: vec![],
                loss_mask: vec![0.0; shapes.n_pad],
            })
            .collect();
        let rng = Rng::new(tc.seed ^ 0x7A13);
        Self {
            shapes,
            comm_stats: CommStats::new(k),
            tc,
            workers,
            engine,
            params,
            opt,
            tapes: None,
            rank_tapes: Vec::new(),
            fb,
            lp_sels,
            telemetry: Telemetry::default(),
            topo,
            epoch: 0,
            rng,
            ckpt: None,
            chaos: None,
            elastic: None,
            recovered: 0,
            store_shard_bytes: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.workers.len()
    }

    fn is_exchange_epoch(&self) -> bool {
        self.tc.delay_comm <= 1 || self.epoch % self.tc.delay_comm == 0
    }

    /// Per-epoch label-prop selection (driver policy — runs on the driver
    /// thread under both transports, consuming the same RNG stream).
    fn select_labelprop(&mut self) {
        let k = self.k();
        for w in 0..k {
            let frac = if self.tc.label_prop { self.tc.lp_frac } else { 0.0 };
            self.lp_sels[w] = labelprop::select(&self.workers[w].train_mask, frac, &mut self.rng);
        }
    }

    /// Run one epoch; returns the stats.
    pub fn epoch(&mut self) -> Result<EpochStats> {
        match self.tc.transport {
            TransportKind::Sequential => self.epoch_sequential(),
            TransportKind::Threaded => self.epoch_threaded(),
        }
    }

    fn epoch_sequential(&mut self) -> Result<EpochStats> {
        // All lanes step on this thread — the whole epoch records as
        // rank 0 / lane 0 (DESIGN.md §13 lane conventions).
        let _scope = self.telemetry.tracer.as_ref().map(|t| t.lane_scope(0, 0));
        let wall = Instant::now();
        let k = self.k();
        let n = self.shapes.n_pad;
        let mut breakdown = Breakdown::new();
        let mut epoch_comm = CommStats::new(k);
        let exchange = self.is_exchange_epoch();

        // ---- step 3: per-epoch label-prop selection (driver policy) ----
        self.select_labelprop();
        if self.tapes.is_none() {
            let rows = vec![n; k];
            self.tapes = Some(self.engine.tapes(&rows, &self.params));
        }
        self.tapes.as_mut().unwrap().clear_grads();

        // ---- engine: forward / loss / backward over the halo context ----
        let mut clock = StageClock::new(k);
        let tapes = self.tapes.as_mut().unwrap();
        let mut ctx = FullBatchCtx::new(
            &self.workers,
            &self.shapes,
            &mut self.fb,
            &self.tc.machine,
            self.tc.quant,
            self.tc.seed,
            self.epoch,
            exchange,
            self.tc.overlap,
            &mut epoch_comm,
        )
        .with_topology(self.topo);
        let lp = LpInputs {
            sel: &self.lp_sels,
            labels: self.workers.iter().map(|c| c.labels.as_slice()).collect(),
        };
        let lp_opt = if self.tc.label_prop { Some(&lp) } else { None };
        self.engine
            .forward(&self.params, &mut ctx, tapes, lp_opt, &mut clock)?;

        let tags: Vec<Vec<u8>> = (0..k)
            .map(|w| split_tags(&self.workers[w], &self.lp_sels[w], n))
            .collect();
        let specs: Vec<LossSpec> = (0..k)
            .map(|w| LossSpec {
                score_rows: n,
                labels: &self.workers[w].labels,
                split: &tags[w],
                loss_w: &self.lp_sels[w].loss_mask,
            })
            .collect();
        let lane_totals = self.engine.loss_all(tapes, &specs, &mut clock);
        let mut totals = LossTotals::default();
        for t in &lane_totals {
            totals.accumulate(t);
        }
        // Scale the loss gradient to the global mean.
        let scales = vec![loss_grad_scale(&totals); k];
        self.engine.scale_loss_grad(tapes, &scales);

        self.engine
            .backward(&self.params, &mut ctx, tapes, lp_opt, true, &mut clock)?;
        let ledger = ctx.take_ledger();
        drop(ctx);

        // ---- gradient allreduce + optimizer step -----------------------
        let t = Instant::now();
        let mut flats: Vec<Vec<f32>> = tapes.grads.iter().map(|g| g.flatten()).collect();
        let ar_secs = collective::allreduce_sum(&mut flats, &self.tc.machine);
        epoch_comm
            .modeled_send_secs
            .iter_mut()
            .for_each(|s| *s += ar_secs);
        let mut flat_params = self.params.flatten();
        {
            let _sp = obs::span(TraceCategory::OptStep, "optimizer step");
            self.opt.step(&mut flat_params, &flats[0]);
        }
        self.params.unflatten_into(&flat_params);
        breakdown.add(Category::Other, t.elapsed().as_secs_f64());

        Ok(self.finish_epoch(wall, breakdown, &clock, &epoch_comm, &totals, ledger))
    }

    /// One epoch under the threaded transport: every rank on its own OS
    /// thread, running the identical engine control flow over its own
    /// lane state; collectives rendezvous through the mailbox fabric.
    fn epoch_threaded(&mut self) -> Result<EpochStats> {
        let wall = Instant::now();
        let k = self.k();
        TransportKind::validate_rank_threads(self.tc.rank_threads, k)?;
        let exchange = self.is_exchange_epoch();
        self.select_labelprop();
        if self.rank_tapes.len() != k {
            self.rank_tapes = (0..k)
                .map(|_| self.engine.tapes(&[self.shapes.n_pad], &self.params))
                .collect();
        }
        for t in &mut self.rank_tapes {
            t.clear_grads();
        }

        let kill = self.chaos.as_ref().and_then(|c| c.arm(self.epoch));
        let fabric = Fabric::with_topology(self.topo).with_chaos(kill);
        let mut outs: Vec<RankOut> = (0..k).map(|_| RankOut::new(k)).collect();
        {
            // Shared inputs are `&` (Sync); each rank thread exclusively
            // owns its RankOut, LaneHalo, and Tapes — the Send/Sync
            // boundary of DESIGN.md §10.
            let workers: &[WorkerCtx] = &self.workers;
            let shapes = &self.shapes;
            let tc = &self.tc;
            let params = &self.params;
            let engine = &self.engine;
            let lp_sels: &[LpSelection] = &self.lp_sels;
            let epoch = self.epoch;
            let halos = self.fb.lanes_mut();
            let fabric = &fabric;
            let tracer = self.telemetry.tracer.clone();
            let bodies: Vec<RankBody<'_>> = outs
                .iter_mut()
                .zip(halos.iter_mut())
                .zip(self.rank_tapes.iter_mut())
                .enumerate()
                .map(|(w, ((out, halo), tp))| {
                    let tr = tracer.clone();
                    Box::new(move || {
                        // Rank thread = pid `w`, lane 0 (DESIGN.md §13);
                        // the scope flushes even on panic unwind.
                        let _scope = tr.as_ref().map(|t| t.lane_scope(w, 0));
                        run_rank_epoch(
                            w, out, halo, tp, fabric, workers, shapes, tc, params, engine,
                            lp_sels, epoch, exchange,
                        )
                    }) as RankBody<'_>
                })
                .collect();
            transport::run_ranks(fabric, bodies)?;
        }
        // Driver-side tail work records on pid 0's driver lane (tid 1).
        let _scope = self.telemetry.tracer.as_ref().map(|t| t.lane_scope(0, 1));

        // Merge per-rank shards: each shard populated only its own sender
        // row, so the merge reproduces the sequential accounting exactly.
        let mut epoch_comm = CommStats::new(k);
        for o in &outs {
            epoch_comm.merge(&o.comm);
        }
        // Optimizer step once, with the allreduced gradient (identical on
        // every rank — use rank 0's copy).
        let mut breakdown = Breakdown::new();
        let t = Instant::now();
        let mut flat_params = self.params.flatten();
        {
            let _sp = obs::span(TraceCategory::OptStep, "optimizer step");
            self.opt.step(&mut flat_params, &outs[0].summed);
        }
        self.params.unflatten_into(&flat_params);
        breakdown.add(Category::Other, t.elapsed().as_secs_f64());

        let clocks: Vec<StageClock> = outs.iter_mut().map(|o| std::mem::take(&mut o.clock)).collect();
        let clock = StageClock::merge_lanes(&clocks);
        let ledger = if self.tc.overlap {
            let ledgers: Vec<OverlapLedger> =
                outs.iter_mut().map(|o| std::mem::take(&mut o.ledger)).collect();
            OverlapLedger::merge_lanes(&ledgers)
        } else {
            OverlapLedger::default()
        };
        let totals = outs[0].totals;
        Ok(self.finish_epoch(wall, breakdown, &clock, &epoch_comm, &totals, ledger))
    }

    /// Transport-agnostic epoch accounting tail: Eqn-2 bottleneck math,
    /// Fig-12 breakdown, run-total accumulation.
    #[allow(clippy::too_many_arguments)]
    fn finish_epoch(
        &mut self,
        wall: Instant,
        mut breakdown: Breakdown,
        clock: &StageClock,
        epoch_comm: &CommStats,
        totals: &LossTotals,
        overlap: OverlapLedger,
    ) -> EpochStats {
        let k = self.k();
        // Compute was measured on this container's cores; a rank of the
        // modeled machine has `cores_per_rank` of them (DESIGN.md §1),
        // so the modeled epoch divides compute-side categories by that.
        let cscale = self.tc.machine.cores_per_rank.max(1.0);
        let (compute, sync) = clock.bottleneck();
        let modeled_compute = compute / cscale;
        for (cat, mx) in clock.category_maxes() {
            breakdown.add(cat, mx);
        }
        breakdown.add(Category::Quant, clock.quant_bottleneck());
        for c in [Category::Aggr, Category::Quant, Category::Other] {
            let v = breakdown.get(c);
            breakdown.add(c, v / cscale - v);
        }
        breakdown.add(Category::Sync, sync / k as f64 / cscale);
        let comm_secs = epoch_comm.modeled_comm_secs();
        breakdown.add(Category::Comm, comm_secs);
        // Accumulate into run totals.
        self.comm_stats.merge(epoch_comm);

        // Publish the epoch into the metrics registry (DESIGN.md §13) —
        // the same numbers EpochStats carries, named `subsystem.metric.unit`.
        if let Some(m) = &self.telemetry.metrics {
            m.begin_epoch(self.epoch);
            m.counter_add("comm.data.bytes", epoch_comm.total_data_bytes());
            m.counter_add("comm.param.bytes", epoch_comm.total_param_bytes());
            m.counter_add("comm.modeled.secs", comm_secs);
            m.counter_add("epoch.wall.secs", wall.elapsed().as_secs_f64());
            m.counter_add("epoch.modeled.secs", modeled_compute + comm_secs);
            m.gauge_set("train.loss.nats", totals.loss_sum / totals.wsum.max(1.0));
            for c in ALL_CATEGORIES {
                m.counter_add(&format!("breakdown.{}.secs", c.name()), breakdown.get(c));
            }
            if epoch_comm.tiers.is_active() {
                m.counter_add("comm.tier_intra.msgs", epoch_comm.tiers.total_intra_msgs() as f64);
                m.counter_add("comm.tier_inter.msgs", epoch_comm.tiers.total_inter_msgs() as f64);
                m.counter_add("comm.two_tier.secs", epoch_comm.tiers.modeled_two_tier_secs());
            }
            // Out-of-core storage telemetry (DESIGN.md §17): shard bytes
            // are nonzero only when the contexts came from `supergcn
            // prepare` files; peak RSS is process-wide (absent off-Linux).
            if self.store_shard_bytes > 0 {
                m.gauge_set("store.shard.bytes", self.store_shard_bytes as f64);
            }
            if let Some(rss) = crate::graph::store::peak_rss_bytes() {
                m.gauge_set("store.peak_rss.bytes", rss as f64);
            }
            // Measured interior/comm/boundary per exchange, next to the
            // §11 model of both schedules on the same inputs.
            for st in &overlap.stages {
                let (i, c, b) = st.maxes();
                let e = perfmodel::estimate_exchange(i, c, b);
                m.push_exchange(ExchangeRow {
                    label: st.label.to_string(),
                    interior_secs: i,
                    boundary_secs: b,
                    comm_secs: c,
                    modeled_overlap_secs: e.overlap_secs,
                    modeled_serial_secs: e.serial_secs,
                });
            }
            m.end_epoch();
        }

        let stats = EpochStats {
            epoch: self.epoch,
            train_loss: (totals.loss_sum / totals.wsum.max(1.0)) as f32,
            train_acc: (totals.train_correct / totals.train_cnt.max(1.0)) as f32,
            val_acc: (totals.val_correct / totals.val_cnt.max(1.0)) as f32,
            test_acc: (totals.test_correct / totals.test_cnt.max(1.0)) as f32,
            modeled_secs: modeled_compute + comm_secs,
            measured_secs: wall.elapsed().as_secs_f64(),
            breakdown,
            comm_data_bytes: epoch_comm.total_data_bytes(),
            comm_param_bytes: epoch_comm.total_param_bytes(),
            overlap,
        };
        self.epoch += 1;
        stats
    }

    /// Snapshot all driver-owned mutable training state at an epoch
    /// boundary (params, optimizer moments, RNG, epoch counter).
    pub fn snapshot(&self) -> DriverSnapshot {
        let (m, v, t) = self.opt.state();
        DriverSnapshot {
            flat: self.params.flatten(),
            opt_m: m.to_vec(),
            opt_v: v.to_vec(),
            opt_t: t,
            rng: self.rng.state(),
            epoch: self.epoch,
        }
    }

    /// Restore a [`Trainer::snapshot`] (inverse operation; same run, so
    /// the lengths always match).
    pub fn restore(&mut self, s: &DriverSnapshot) {
        self.params.unflatten_into(&s.flat);
        self.opt
            .restore(&s.opt_m, &s.opt_v, s.opt_t)
            .expect("snapshot taken from this run always fits");
        self.rng = Rng::from_state(s.rng);
        self.epoch = s.epoch;
    }

    /// Write a v2 checkpoint of the current state to `path`. The saved
    /// epoch counter is the *completed*-epoch count, and the RNG state is
    /// post-epoch — restoring continues the run bit-identically.
    pub fn save_checkpoint(&self, path: &Path, fingerprint: u64) -> Result<()> {
        checkpoint::save_state(&self.params, &self.opt, self.rng.state(), self.epoch, fingerprint, path)
    }

    fn maybe_checkpoint(&self) -> Result<()> {
        let Some(p) = &self.ckpt else { return Ok(()) };
        if p.every > 0 && (self.epoch % p.every == 0 || self.epoch == self.tc.epochs) {
            self.save_checkpoint(&p.path, p.fingerprint)?;
        }
        Ok(())
    }

    /// Restore a v2 checkpoint and continue from its epoch. When
    /// `fingerprint` is `Some`, the file's config fingerprint must match
    /// (resuming under numerics-changing config drift is refused).
    /// Returns the epoch training resumes from.
    pub fn resume_from(&mut self, path: &Path, fingerprint: Option<u64>) -> Result<usize> {
        let st = checkpoint::load_state(&mut self.params, &mut self.opt, path)?;
        if let Some(fp) = fingerprint {
            anyhow::ensure!(
                st.fingerprint == fp,
                "checkpoint config fingerprint mismatch: file {:#018x} vs run {:#018x} — \
                 resume needs the numerics-identical config that wrote the checkpoint",
                st.fingerprint,
                fp
            );
        }
        self.rng = Rng::from_state(st.rng_state);
        self.epoch = st.epoch;
        obs::instant(TraceCategory::Recovery, "resume");
        Ok(st.epoch)
    }

    /// Elastic recovery from a rank loss (DESIGN.md §15): drop the failed
    /// rank, re-plan its shard across the survivors, rebuild every
    /// plan-shaped buffer, and restore the epoch-boundary snapshot so the
    /// retried epoch is bit-identical to a fresh run on the survivor plan
    /// with the same driver state. Anything that is not a typed
    /// [`RankLost`] — or that exceeds the recovery budget — propagates.
    fn recover(&mut self, err: anyhow::Error, snap: &DriverSnapshot) -> Result<()> {
        let failed = match err.downcast_ref::<RankLost>() {
            Some(lost) if self.k() >= 2 => lost.rank,
            _ => return Err(err),
        };
        let (lg, part) = {
            let el = self.elastic.as_ref().expect("recover is only called with elastic armed");
            if self.recovered >= el.max_failures {
                return Err(err.context(format!(
                    "rank {failed} lost with no recovery budget left ({} already absorbed)",
                    self.recovered
                )));
            }
            (el.lg.clone(), el.part.clone())
        };
        let new_part = planner::survivor_partition(&lg.graph, &part, failed)?;
        let k2 = new_part.k;
        // Re-fit with the *same* model dims (f_in/hidden/classes), so the
        // restored parameters stay shape-compatible; only the plan-shaped
        // padding (n_pad, e_*, r_*) may change.
        let plans = crate::hier::plan::build_plans(&lg.graph, &new_part, self.tc.strategy);
        crate::hier::plan::validate_plans(&lg.graph, &new_part, &plans)?;
        let shapes = planner::fit_config(
            &self.shapes.name,
            self.shapes.f_in,
            self.shapes.hidden,
            self.shapes.classes,
            &plans,
        );
        let ctxs = planner::build_worker_ctxs(&lg, &plans, &shapes)?;

        let _scope = self.telemetry.tracer.as_ref().map(|t| t.lane_scope(0, 1));
        obs::instant(TraceCategory::Recovery, "elastic re-plan");
        if let Some(m) = &self.telemetry.metrics {
            m.counter_add("recovery.rank_lost.count", 1.0);
        }
        eprintln!(
            "rank {failed} lost in epoch {}: re-planned its shard across {k2} survivors, \
             retrying the epoch ({err:#})",
            snap.epoch
        );

        self.workers = ctxs;
        self.shapes = shapes;
        self.engine = Engine::new(&self.shapes, true, self.tc.agg.clone());
        self.fb = FullBatchState::new(&self.shapes, k2);
        self.tapes = None;
        self.rank_tapes = Vec::new();
        self.lp_sels = (0..k2)
            .map(|_| LpSelection {
                embedded: vec![],
                loss_mask: vec![0.0; self.shapes.n_pad],
            })
            .collect();
        // Run totals restart at the survivor count — `CommStats::merge`
        // requires matching k, so pre-failure totals cannot carry over
        // (documented in DESIGN.md §15).
        self.comm_stats = CommStats::new(k2);
        self.topo = Topology::new(k2, self.tc.group_size);
        self.elastic.as_mut().expect("checked above").part = new_part;
        self.recovered += 1;
        self.restore(snap);
        Ok(())
    }

    /// Train until the configured epoch count, returning per-epoch stats
    /// (for the epochs run here — a resumed run returns the tail). A rank
    /// loss with elastic recovery armed re-plans and retries the epoch;
    /// every other error propagates.
    pub fn run(&mut self, log: bool) -> Result<Vec<EpochStats>> {
        let total = self.tc.epochs;
        let mut out = Vec::with_capacity(total.saturating_sub(self.epoch));
        while self.epoch < total {
            let guard = self.elastic.is_some().then(|| self.snapshot());
            match self.epoch() {
                Ok(s) => {
                    if log && (s.epoch % 10 == 0 || s.epoch + 1 == total) {
                        eprintln!(
                            "epoch {:4}  loss {:.4}  train {:.4}  val {:.4}  test {:.4}  modeled {:.4}s",
                            s.epoch, s.train_loss, s.train_acc, s.val_acc, s.test_acc, s.modeled_secs
                        );
                    }
                    self.maybe_checkpoint()?;
                    out.push(s);
                }
                Err(e) => match guard {
                    Some(snap) => self.recover(e, &snap)?,
                    None => return Err(e),
                },
            }
        }
        Ok(out)
    }
}

/// Per-row split tags for the loss head (train rows follow the label-prop
/// loss mask so embedded nodes carry no loss).
fn split_tags(wc: &WorkerCtx, sel: &LpSelection, n: usize) -> Vec<u8> {
    let lm = &sel.loss_mask;
    (0..n)
        .map(|i| {
            if lm[i] > 0.0 {
                SPLIT_TRAIN
            } else if wc.val_mask[i] > 0.0 {
                SPLIT_VAL
            } else if wc.test_mask[i] > 0.0 {
                SPLIT_TEST
            } else {
                SPLIT_NONE
            }
        })
        .collect()
}

/// Global mean-loss gradient scale (`1 / Σ loss weights`).
fn loss_grad_scale(totals: &LossTotals) -> f32 {
    if totals.wsum > 0.0 {
        (1.0 / totals.wsum) as f32
    } else {
        0.0
    }
}

/// What one rank thread hands back to the driver after an epoch.
struct RankOut {
    /// Global (all-lane) loss totals — every rank folds the same
    /// allgathered records in rank order, so all copies agree bit-exactly.
    totals: LossTotals,
    clock: StageClock,
    /// This rank's CommStats shard (its own sender row only).
    comm: CommStats,
    /// This rank's single-lane overlap accounting (`--overlap on`).
    ledger: OverlapLedger,
    /// The allreduced (summed) flat gradient.
    summed: Vec<f32>,
}

impl RankOut {
    fn new(k: usize) -> Self {
        Self {
            totals: LossTotals::default(),
            clock: StageClock::new(1),
            comm: CommStats::new(k),
            ledger: OverlapLedger::new(1),
            summed: Vec::new(),
        }
    }
}

/// The SPMD body one rank thread executes for one full-batch epoch:
/// forward → loss (+ allgathered global totals) → backward → ring
/// gradient-allreduce. Mirrors `epoch_sequential` exactly, restricted to
/// lane `w`.
#[allow(clippy::too_many_arguments)]
fn run_rank_epoch(
    w: usize,
    out: &mut RankOut,
    halo: &mut LaneHalo,
    tapes: &mut Tapes,
    fabric: &Fabric,
    workers: &[WorkerCtx],
    shapes: &ShapeConfig,
    tc: &TrainConfig,
    params: &ModelParams,
    engine: &Engine,
    lp_sels: &[LpSelection],
    epoch: usize,
    exchange: bool,
) -> Result<()> {
    let n = shapes.n_pad;
    let mut clock = StageClock::new(1);
    {
        let mut ctx = FullBatchRankCtx::new(
            w,
            &workers[w],
            shapes,
            halo,
            &tc.machine,
            tc.quant,
            tc.seed,
            epoch,
            exchange,
            tc.overlap,
            fabric,
            &mut out.comm,
        );
        let lp = LpInputs {
            sel: &lp_sels[w..w + 1],
            labels: vec![workers[w].labels.as_slice()],
        };
        let lp_opt = if tc.label_prop { Some(&lp) } else { None };
        engine.forward(params, &mut ctx, tapes, lp_opt, &mut clock)?;

        let tags = split_tags(&workers[w], &lp_sels[w], n);
        let spec = LossSpec {
            score_rows: n,
            labels: &workers[w].labels,
            split: &tags,
            loss_w: &lp_sels[w].loss_mask,
        };
        let tot = engine.loss_all(tapes, &[spec], &mut clock)[0];
        // Combine lane totals in rank order — the identical f64 fold the
        // sequential driver performs.
        let gathered = fabric.allgather_f64(w, tot.to_vec());
        let mut totals = LossTotals::default();
        for g in &gathered {
            totals.accumulate(&LossTotals::from_slice(g));
        }
        engine.scale_loss_grad(tapes, &[loss_grad_scale(&totals)]);
        engine.backward(params, &mut ctx, tapes, lp_opt, true, &mut clock)?;
        out.ledger = ctx.take_ledger();
        out.totals = totals;
    }
    // Ring allreduce of the flat gradient (rank-order fold — bit-exact
    // with `collective::allreduce_sum`).
    let mut flat = tapes.grads[0].flatten();
    let ar_secs = fabric.allreduce_sum(w, &mut flat, &tc.machine);
    out.comm.modeled_send_secs[w] += ar_secs;
    out.summed = flat;
    out.clock = clock;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::prepare;
    use crate::exec::AggKernel;
    use crate::graph::generate::sbm;

    fn train(k: usize, tc: TrainConfig, n: usize) -> Vec<EpochStats> {
        let lg = sbm(n, 4, 8.0, 0.85, 16, 0.6, 11);
        let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, None, 5).unwrap();
        let mut tr = Trainer::new(ctxs, cfg, tc);
        tr.run(false).unwrap()
    }

    #[test]
    fn single_worker_learns_sbm() {
        let tc = TrainConfig {
            epochs: 30,
            lr: 0.01,
            ..Default::default()
        };
        let stats = train(1, tc, 400);
        let first = &stats[0];
        let last = stats.last().unwrap();
        assert!(last.train_loss < first.train_loss, "loss must decrease");
        assert!(last.test_acc > 0.5, "test acc {} too low", last.test_acc);
    }

    #[test]
    fn distributed_matches_single_worker_loss_curve() {
        // Full-batch + exact reverse halos ⇒ identical-to-roundoff training
        // trajectories regardless of partitioning.
        let tc = TrainConfig {
            epochs: 8,
            ..Default::default()
        };
        let s1 = train(1, tc.clone(), 300);
        let s3 = train(3, tc, 300);
        for (a, b) in s1.iter().zip(s3.iter()) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 2e-3,
                "epoch {}: {} vs {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
    }

    #[test]
    fn agg_kernel_override_preserves_numerics() {
        // The dispatcher's kernel choice is an algorithm-preserving
        // transformation: every §4 kernel trains the same trajectory.
        let base = train(2, TrainConfig { epochs: 4, ..Default::default() }, 300);
        for kernel in [AggKernel::Vanilla, AggKernel::Parallel, AggKernel::Spmm, AggKernel::Simd] {
            let tc = TrainConfig {
                epochs: 4,
                agg: AggDispatch::default().with_kernel(kernel).with_threads(2),
                ..Default::default()
            };
            let got = train(2, tc, 300);
            for (a, b) in base.iter().zip(got.iter()) {
                assert!(
                    (a.train_loss - b.train_loss).abs() < 2e-3,
                    "{}: epoch {}: {} vs {}",
                    kernel.name(),
                    a.epoch,
                    a.train_loss,
                    b.train_loss
                );
            }
        }
    }

    #[test]
    fn int2_with_lp_still_learns() {
        let tc = TrainConfig {
            epochs: 30,
            quant: Some(Bits::Int2),
            label_prop: true,
            ..Default::default()
        };
        let stats = train(3, tc, 400);
        assert!(stats.last().unwrap().test_acc > 0.5);
        // Quant bytes ≈ fp32/16.
        let s = &stats[5];
        assert!(s.comm_data_bytes > 0.0);
        assert!(s.comm_param_bytes > 0.0);
    }

    #[test]
    fn delayed_comm_runs_and_skips_exchanges() {
        let tc = TrainConfig {
            epochs: 10,
            delay_comm: 5,
            strategy: RemoteStrategy::PreOnly,
            ..Default::default()
        };
        let stats = train(3, tc, 300);
        // Comm happens only on epochs 0 and 5.
        let active: Vec<usize> = stats
            .iter()
            .filter(|s| s.comm_data_bytes > 0.0)
            .map(|s| s.epoch)
            .collect();
        assert_eq!(active, vec![0, 5]);
    }

    #[test]
    fn threaded_transport_trains_and_learns() {
        // The sequential↔threaded bit-parity suite lives in
        // tests/spmd_parity.rs; this is the in-crate smoke check that the
        // rank-thread epoch converges end to end (with staleness, so the
        // skip-exchange path also runs threaded).
        let tc = TrainConfig {
            epochs: 20,
            delay_comm: 2,
            transport: TransportKind::Threaded,
            ..Default::default()
        };
        let stats = train(3, tc, 400);
        let last = stats.last().unwrap();
        assert!(last.train_loss < stats[0].train_loss, "loss must decrease");
        assert!(last.comm_data_bytes >= 0.0);
    }

    #[test]
    fn hierarchical_transport_trains_and_charges_tiers() {
        // Bit-parity with the flat topology is pinned in
        // tests/spmd_parity.rs; this smoke-checks that grouped runs learn
        // end to end and record the two-level accounting on both
        // transports.
        let lg = sbm(400, 4, 8.0, 0.85, 16, 0.6, 11);
        for transport in [TransportKind::Sequential, TransportKind::Threaded] {
            let tc = TrainConfig {
                epochs: 4,
                group_size: 2,
                transport,
                ..Default::default()
            };
            let (ctxs, cfg, _) = prepare(&lg, 4, tc.strategy, None, 5).unwrap();
            let mut tr = Trainer::new(ctxs, cfg, tc);
            let stats = tr.run(false).unwrap();
            assert!(stats.last().unwrap().train_loss < stats[0].train_loss);
            let flat_msgs: usize = tr.comm_stats.messages.iter().flatten().sum();
            let t = &tr.comm_stats.tiers;
            assert!(t.is_active(), "grouped run must charge tier stats");
            assert!(t.total_intra_msgs() > 0 && t.total_inter_msgs() > 0);
            assert!(
                t.total_inter_msgs() < flat_msgs,
                "inter-group {} must undercut flat {flat_msgs}",
                t.total_inter_msgs()
            );
            assert!(t.modeled_two_tier_secs() > 0.0);
        }
    }

    #[test]
    fn overlap_schedule_learns_and_records_ledger() {
        // Bit-parity with the blocking schedule is pinned in
        // tests/spmd_parity.rs; this is the in-crate smoke check that the
        // interior/boundary split trains end to end under both transports
        // (with delay_comm so the stale-halo boundary path also runs).
        for transport in [TransportKind::Sequential, TransportKind::Threaded] {
            let tc = TrainConfig {
                epochs: 12,
                delay_comm: 2,
                overlap: true,
                transport,
                ..Default::default()
            };
            let stats = train(3, tc, 400);
            let last = stats.last().unwrap();
            assert!(last.train_loss < stats[0].train_loss, "loss must decrease");
            let ledger = &last.overlap;
            assert!(!ledger.is_empty(), "overlap epochs must record stages");
            assert!(ledger.modeled_overlap_secs() <= ledger.modeled_serial_secs());
        }
    }

    #[test]
    fn quant_reduces_forward_wire_bytes_16x() {
        // Forward halos are quantized (γ=16); the reverse cotangent
        // exchange stays FP32 (the paper quantizes the forward feature
        // communication). With equal fwd/bwd volumes the total ratio is
        // 2 / (1 + 1/16) ≈ 1.88.
        let tc_fp = TrainConfig {
            epochs: 2,
            ..Default::default()
        };
        let tc_q = TrainConfig {
            epochs: 2,
            quant: Some(Bits::Int2),
            ..Default::default()
        };
        let fp = train(3, tc_fp, 400);
        let q = train(3, tc_q, 400);
        let r = fp[1].comm_data_bytes / q[1].comm_data_bytes;
        assert!(r > 1.7 && r < 2.0, "total ratio {r}");
        // Isolating the forward half: fwd_q = total_q − bwd (= fwd_fp/2).
        let bwd = fp[1].comm_data_bytes / 2.0;
        let fwd_ratio = bwd / (q[1].comm_data_bytes - bwd);
        assert!(fwd_ratio > 15.0 && fwd_ratio < 17.0, "forward ratio {fwd_ratio}");
    }
}
