"""L1 Pallas kernels vs pure-jnp oracles (ref.py), swept with Hypothesis."""

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.aggregate import EB, plan_segments, segment_sum
from compile.kernels.layernorm import layernorm
from compile.kernels.quant import dequantize, quantize

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def run_segment_sum(h, gather, seg, n_seg):
    """Pad to the edge block and invoke the Pallas path."""
    e = len(gather)
    e_pad = ((e + EB - 1) // EB) * EB if e else EB
    n = h.shape[0]
    # zero row for padded gathers, trash segment for padded segs
    h_z = np.vstack([h, np.zeros((1, h.shape[1]), h.dtype)])
    g = np.concatenate([gather, np.full(e_pad - e, n, np.int32)]).astype(np.int32)
    s = np.concatenate([seg, np.full(e_pad - e, n_seg, np.int32)]).astype(np.int32)
    order = np.argsort(s, kind="stable")
    g, s = g[order], s[order]
    seg_rel, block_seg = plan_segments(s, EB)
    out = segment_sum(jnp.asarray(h_z), jnp.asarray(g), jnp.asarray(seg_rel),
                      jnp.asarray(block_seg), n_seg + 1)
    return np.asarray(out)[:n_seg]


@st.composite
def segsum_problem(draw):
    n = draw(st.integers(1, 60))
    f = draw(st.sampled_from([1, 3, 8, 16, 32]))
    n_seg = draw(st.integers(1, 40))
    e = draw(st.integers(0, 300))
    h = draw(
        hnp.arrays(np.float32, (n, f),
                   elements=st.floats(-8, 8, width=32)))
    gather = draw(hnp.arrays(np.int32, (e,), elements=st.integers(0, n - 1)))
    seg = draw(hnp.arrays(np.int32, (e,), elements=st.integers(0, n_seg - 1)))
    return h, gather, np.sort(seg), n_seg


@given(segsum_problem())
def test_segment_sum_matches_ref(problem):
    h, gather, seg, n_seg = problem
    got = run_segment_sum(h, gather, seg, n_seg)
    want = np.asarray(ref.segment_sum_ref(jnp.asarray(h), jnp.asarray(gather),
                                          jnp.asarray(seg), n_seg))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_segment_sum_known_values():
    h = np.array([[1.0, 10.0], [2.0, 20.0], [3.0, 30.0]], np.float32)
    gather = np.array([0, 2, 1], np.int32)
    seg = np.array([0, 0, 1], np.int32)
    out = run_segment_sum(h, gather, seg, 3)
    np.testing.assert_allclose(out, [[4, 40], [2, 20], [0, 0]])


def test_segment_sum_multi_block():
    # > EB edges so several blocks + segments spanning block boundaries.
    rng = np.random.default_rng(0)
    n, f, n_seg, e = 50, 16, 7, 5 * EB
    h = rng.normal(size=(n, f)).astype(np.float32)
    gather = rng.integers(0, n, e).astype(np.int32)
    seg = np.sort(rng.integers(0, n_seg, e).astype(np.int32))
    got = run_segment_sum(h, gather, seg, n_seg)
    want = np.asarray(ref.segment_sum_ref(jnp.asarray(h), jnp.asarray(gather),
                                          jnp.asarray(seg), n_seg))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_segment_sum_gradient():
    """custom_vjp (Pallas bwd kernel) vs autodiff of the reference."""
    rng = np.random.default_rng(1)
    n, f, n_seg, e = 20, 8, 6, EB
    h = rng.normal(size=(n + 1, f)).astype(np.float32)  # +zero row
    h[n] = 0
    gather = rng.integers(0, n, e).astype(np.int32)
    seg = np.sort(rng.integers(0, n_seg, e).astype(np.int32))
    seg_rel, block_seg = plan_segments(seg, EB)

    def f_pallas(hh):
        out = segment_sum(hh, jnp.asarray(gather), jnp.asarray(seg_rel),
                          jnp.asarray(block_seg), n_seg)
        return jnp.sum(out ** 2)

    def f_ref(hh):
        out = ref.segment_sum_ref(hh, jnp.asarray(gather), jnp.asarray(seg), n_seg)
        return jnp.sum(out ** 2)

    g1 = jax.grad(f_pallas)(jnp.asarray(h))
    g2 = jax.grad(f_ref)(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

@given(
    st.integers(1, 3),
    st.sampled_from([2, 5, 16, 64]),
    st.integers(0, 10_000),
)
def test_layernorm_matches_ref(blocks, f, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=4.0, size=(blocks * 128, f)).astype(np.float32)
    got = np.asarray(layernorm(jnp.asarray(x)))
    want = np.asarray(ref.layernorm_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_layernorm_gradient():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(128, 12)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(128, 12)).astype(np.float32))
    g1 = jax.grad(lambda v: jnp.sum(layernorm(v) * t))(x)
    g2 = jax.grad(lambda v: jnp.sum(ref.layernorm_ref(v) * t))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3, atol=1e-4)


def test_layernorm_removes_outliers():
    x = np.ones((128, 32), np.float32)
    x[0, 0] = 1e4  # huge outlier
    y = np.asarray(layernorm(jnp.asarray(x)))
    assert np.abs(y).max() < 10.0


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@given(
    st.integers(1, 6),
    st.sampled_from([4, 16, 33]),
    st.sampled_from([2, 4, 8]),
    st.integers(0, 10_000),
)
def test_quant_matches_ref(groups, f, bits, seed):
    rng = np.random.default_rng(seed)
    rows = groups * 4
    x = rng.normal(scale=3.0, size=(rows, f)).astype(np.float32)
    noise = rng.random(size=(rows, f)).astype(np.float32)
    c1, z1, s1 = quantize(jnp.asarray(x), jnp.asarray(noise), bits)
    c2, z2, s2 = ref.quantize_ref(jnp.asarray(x), jnp.asarray(noise), bits)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    # Round trip error ≤ scale.
    y = np.asarray(dequantize(c1, z1, s1))
    bound = np.repeat(np.asarray(s1), 4)[:, None] + 1e-6
    assert (np.abs(y - x) <= bound).all()


def test_dequantize_matches_ref():
    rng = np.random.default_rng(9)
    codes = jnp.asarray(rng.integers(0, 4, size=(8, 10)).astype(np.int32))
    zero = jnp.asarray(rng.normal(size=2).astype(np.float32))
    scale = jnp.asarray(rng.random(2).astype(np.float32))
    got = np.asarray(dequantize(codes, zero, scale))
    want = np.asarray(ref.dequantize_ref(codes, zero, scale))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_quant_constant_rows_zero_scale():
    x = jnp.full((4, 8), 2.5, jnp.float32)
    noise = jnp.zeros((4, 8), jnp.float32)
    codes, zero, scale = quantize(x, noise, 2)
    assert np.asarray(scale)[0] == 0.0
    y = np.asarray(dequantize(codes, zero, scale))
    np.testing.assert_allclose(y, 2.5)
