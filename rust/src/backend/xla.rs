//! XLA compute engine: executes the AOT'd JAX/Pallas artifacts through the
//! PJRT runtime. This is the three-layer architecture's L2/L1 path — every
//! numerical layer op runs inside a compiled HLO module whose hot loop is
//! the Pallas blocked segment-sum kernel.

use super::{Backend, LayerSpec, LossOut, SegSpec};
use crate::model::LayerParams;
use crate::runtime::{self, Runtime, ShapeConfig};
use anyhow::{Context, Result};

pub struct XlaBackend {
    rt: Runtime,
    cfg: ShapeConfig,
}

impl XlaBackend {
    pub fn new(rt: Runtime) -> Self {
        let cfg = rt.config.clone();
        Self { rt, cfg }
    }

    /// Load from the artifacts directory (convenience).
    pub fn load(artifacts_dir: &std::path::Path, config_name: &str) -> Result<Self> {
        Ok(Self::new(Runtime::load(artifacts_dir, config_name)?))
    }

    fn check_pre(&self, fdim: usize, pre: &SegSpec) -> Result<()> {
        anyhow::ensure!(
            pre.len() == self.cfg.e_pre,
            "pre spec has {} entries, config expects {}",
            pre.len(),
            self.cfg.e_pre
        );
        anyhow::ensure!(
            fdim == self.cfg.f_in || fdim == self.cfg.hidden,
            "no pre artifact for width {fdim}"
        );
        Ok(())
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn config(&self) -> &ShapeConfig {
        &self.cfg
    }

    fn pre_fwd(
        &mut self,
        fdim: usize,
        h: &[f32],
        pre: &SegSpec,
        h_norm: &mut [f32],
        partials: &mut [f32],
    ) -> Result<()> {
        self.check_pre(fdim, pre)?;
        let n = self.cfg.n_pad;
        let outs = self
            .rt
            .run(
                &format!("pre_fwd_f{fdim}"),
                &[
                    runtime::lit_f32(h, n, fdim)?,
                    runtime::lit_i32_vec(&pre.gather_i32),
                    runtime::lit_i32_vec(&pre.seg_rel),
                    runtime::lit_i32_vec(&pre.block_seg),
                ],
            )
            .context("pre_fwd artifact")?;
        anyhow::ensure!(outs.len() == 2, "pre_fwd returns 2 outputs");
        runtime::lit_to_f32(&outs[0], h_norm)?;
        runtime::lit_to_f32(&outs[1], partials)?;
        Ok(())
    }

    fn layer_fwd(
        &mut self,
        layer: usize,
        h_norm: &[f32],
        recv_pre: &[f32],
        recv_post: &[f32],
        params: &LayerParams,
        spec: &LayerSpec,
        out: &mut [f32],
    ) -> Result<()> {
        let (fin, fout, _) = self.cfg.layer_dims()[layer];
        let n = self.cfg.n_pad;
        let outs = self
            .rt
            .run(
                &format!("layer_fwd_{layer}"),
                &[
                    runtime::lit_f32(h_norm, n, fin)?,
                    runtime::lit_f32(recv_pre, self.cfg.r_pre, fin)?,
                    runtime::lit_f32(recv_post, self.cfg.r_post, fin)?,
                    runtime::lit_f32(&params.w_self, fin, fout)?,
                    runtime::lit_f32(&params.w_neigh, fin, fout)?,
                    runtime::lit_f32_vec(&params.b),
                    runtime::lit_i32_vec(&spec.local.gather_i32),
                    runtime::lit_i32_vec(&spec.local.seg_rel),
                    runtime::lit_i32_vec(&spec.local.block_seg),
                    runtime::lit_i32_vec(&spec.rpre_dst_i32),
                    runtime::lit_i32_vec(&spec.post_row_i32),
                    runtime::lit_i32_vec(&spec.post_dst_i32),
                    runtime::lit_f32_vec(&spec.deg_inv),
                ],
            )
            .context("layer_fwd artifact")?;
        anyhow::ensure!(outs.len() == 1, "layer_fwd returns 1 output");
        runtime::lit_to_f32(&outs[0], out)?;
        Ok(())
    }

    fn layer_bwd(
        &mut self,
        layer: usize,
        h_norm: &[f32],
        recv_pre: &[f32],
        recv_post: &[f32],
        params: &LayerParams,
        spec: &LayerSpec,
        _out: &[f32],
        d_out: &[f32],
        d_h_norm: &mut [f32],
        d_recv_pre: &mut [f32],
        d_recv_post: &mut [f32],
        grads: &mut LayerParams,
    ) -> Result<()> {
        let (fin, fout, _) = self.cfg.layer_dims()[layer];
        let n = self.cfg.n_pad;
        let outs = self
            .rt
            .run(
                &format!("layer_bwd_{layer}"),
                &[
                    runtime::lit_f32(h_norm, n, fin)?,
                    runtime::lit_f32(recv_pre, self.cfg.r_pre, fin)?,
                    runtime::lit_f32(recv_post, self.cfg.r_post, fin)?,
                    runtime::lit_f32(&params.w_self, fin, fout)?,
                    runtime::lit_f32(&params.w_neigh, fin, fout)?,
                    runtime::lit_f32_vec(&params.b),
                    runtime::lit_i32_vec(&spec.local.gather_i32),
                    runtime::lit_i32_vec(&spec.local.seg_rel),
                    runtime::lit_i32_vec(&spec.local.block_seg),
                    runtime::lit_i32_vec(&spec.rpre_dst_i32),
                    runtime::lit_i32_vec(&spec.post_row_i32),
                    runtime::lit_i32_vec(&spec.post_dst_i32),
                    runtime::lit_f32_vec(&spec.deg_inv),
                    runtime::lit_f32(d_out, n, fout)?,
                ],
            )
            .context("layer_bwd artifact")?;
        // 6 cotangents + the primal output (kept to defeat XLA's
        // dead-parameter pruning; ignored here).
        anyhow::ensure!(outs.len() == 7, "layer_bwd returns 6 cotangents + primal");
        runtime::lit_to_f32(&outs[0], d_h_norm)?;
        runtime::lit_to_f32(&outs[1], d_recv_pre)?;
        runtime::lit_to_f32(&outs[2], d_recv_post)?;
        // Parameter grads accumulate.
        let mut tmp = vec![0f32; fin * fout];
        runtime::lit_to_f32(&outs[3], &mut tmp)?;
        for (g, &t) in grads.w_self.iter_mut().zip(tmp.iter()) {
            *g += t;
        }
        runtime::lit_to_f32(&outs[4], &mut tmp)?;
        for (g, &t) in grads.w_neigh.iter_mut().zip(tmp.iter()) {
            *g += t;
        }
        let mut tb = vec![0f32; fout];
        runtime::lit_to_f32(&outs[5], &mut tb)?;
        for (g, &t) in grads.b.iter_mut().zip(tb.iter()) {
            *g += t;
        }
        Ok(())
    }

    fn pre_bwd(
        &mut self,
        fdim: usize,
        h: &[f32],
        pre: &SegSpec,
        d_h_norm: &[f32],
        d_partials: &[f32],
        d_h: &mut [f32],
    ) -> Result<()> {
        self.check_pre(fdim, pre)?;
        let n = self.cfg.n_pad;
        let outs = self
            .rt
            .run(
                &format!("pre_bwd_f{fdim}"),
                &[
                    runtime::lit_f32(h, n, fdim)?,
                    runtime::lit_i32_vec(&pre.gather_i32),
                    runtime::lit_i32_vec(&pre.seg_rel),
                    runtime::lit_i32_vec(&pre.block_seg),
                    runtime::lit_f32(d_h_norm, n, fdim)?,
                    runtime::lit_f32(d_partials, self.cfg.p_pre, fdim)?,
                ],
            )
            .context("pre_bwd artifact")?;
        anyhow::ensure!(outs.len() == 1, "pre_bwd returns 1 output");
        runtime::lit_to_f32(&outs[0], d_h)?;
        Ok(())
    }

    fn loss_head(&mut self, logits: &[f32], labels: &[i32], mask: &[f32]) -> Result<LossOut> {
        let n = self.cfg.n_pad;
        let c = self.cfg.classes;
        let outs = self
            .rt
            .run(
                "loss_head",
                &[
                    runtime::lit_f32(logits, n, c)?,
                    runtime::lit_i32_vec(labels),
                    runtime::lit_f32_vec(mask),
                ],
            )
            .context("loss_head artifact")?;
        anyhow::ensure!(outs.len() == 4, "loss_head returns 4 outputs");
        let mut d_logits = vec![0f32; n * c];
        runtime::lit_to_f32(&outs[1], &mut d_logits)?;
        Ok(LossOut {
            loss_sum: runtime::lit_scalar_f32(&outs[0])?,
            d_logits,
            correct: runtime::lit_scalar_f32(&outs[2])?,
            mask_sum: runtime::lit_scalar_f32(&outs[3])?,
        })
    }
}
