//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`. Used by the `supergcn` binary, the examples,
//! and the bench harnesses.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set: register options, then `parse`.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Register a `--key <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register a boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse from process args (skipping argv[0]). Exits on `--help`.
    pub fn parse(self) -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse from an explicit list (testable).
    pub fn parse_from(mut self, argv: &[String]) -> anyhow::Result<Self> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    self.values.insert(key, "true".to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("option --{key} needs a value"))?
                                .clone()
                        }
                    };
                    self.values.insert(key, val);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let default = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let kind = if spec.is_flag { "" } else { " <value>" };
            s.push_str(&format!("  --{}{}\n      {}{}\n", spec.name, kind, spec.help, default));
        }
        s.push_str("  --help\n      Show this help\n");
        s
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn get_str(&self, name: &str) -> String {
        self.raw(name)
            .unwrap_or_else(|| panic!("option --{name} was never registered"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        let v = self.get_str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        let v = self.get_str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        let v = self.get_str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name} expects a float, got '{v}'"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list of usize (e.g. `--procs 2,4,8`).
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        let v = self.get_str(name);
        v.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{name} expects comma-separated ints, got '{v}'"))
            })
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t", "")
            .opt("procs", "4", "")
            .parse_from(&sv(&[]))
            .unwrap();
        assert_eq!(a.get_usize("procs"), 4);
    }

    #[test]
    fn overrides_and_equals_syntax() {
        let a = Args::new("t", "")
            .opt("procs", "4", "")
            .opt("dataset", "sbm", "")
            .parse_from(&sv(&["--procs", "8", "--dataset=rmat"]))
            .unwrap();
        assert_eq!(a.get_usize("procs"), 8);
        assert_eq!(a.get_str("dataset"), "rmat");
    }

    #[test]
    fn flags_and_positional() {
        let a = Args::new("t", "")
            .flag("quant", "")
            .parse_from(&sv(&["file.txt", "--quant", "other"]))
            .unwrap();
        assert!(a.get_flag("quant"));
        assert_eq!(a.positional(), &["file.txt".to_string(), "other".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "").parse_from(&sv(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::new("t", "")
            .opt("procs", "1,2,4", "")
            .parse_from(&sv(&["--procs", "2,4,8,16"]))
            .unwrap();
        assert_eq!(a.get_usize_list("procs"), vec![2, 4, 8, 16]);
    }
}
