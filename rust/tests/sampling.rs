//! Integration tests for the mini-batch sampling subsystem:
//! seed-determinism of every sampler, end-to-end mini-batch training on a
//! catalog dataset, the cluster-vs-full-batch comm-volume acceptance
//! criterion (same partitioning, strictly less wire data per epoch), and
//! quantized-fetch round-trip unbiasedness on sampled halo rows.

use std::sync::Arc;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::{partition_for, prepare};
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::datasets;
use supergcn::graph::generate::LabelledGraph;
use supergcn::graph::store::GraphStore;
use supergcn::partition::multilevel::{multilevel, MultilevelOpts};
use supergcn::partition::vertex_weights;
use supergcn::quant::{fused, Bits};
use supergcn::sample::{build_sampler, Sampler, SamplerConfig, SamplerKind};

fn catalog_lg() -> Arc<LabelledGraph> {
    Arc::new(datasets::by_name("arxiv-xs").unwrap().build())
}

fn scfg(seed: u64) -> SamplerConfig {
    SamplerConfig {
        batch_size: 200,
        fanouts: vec![4, 3],
        num_clusters: 8,
        seed,
        ..Default::default()
    }
}

#[test]
fn samplers_are_seed_deterministic() {
    let lg = catalog_lg();
    let store = GraphStore::from(lg.clone());
    for kind in SamplerKind::ALL {
        let mut a = build_sampler(kind, &store, &scfg(17)).unwrap();
        let mut b = build_sampler(kind, &store, &scfg(17)).unwrap();
        assert_eq!(a.batches_per_epoch(), b.batches_per_epoch());
        for (epoch, batch) in [(0usize, 0usize), (3, 1), (7, 0)] {
            let batch = batch.min(a.batches_per_epoch() - 1);
            let x = a.sample(epoch, batch);
            let y = b.sample(epoch, batch);
            assert_eq!(x.n_id, y.n_id, "{} n_id diverged", kind.name());
            assert_eq!(x.adj, y.adj, "{} adjacency diverged", kind.name());
            assert_eq!(x.edge_weight, y.edge_weight, "{} weights diverged", kind.name());
            assert_eq!(x.node_weight, y.node_weight, "{} loss weights diverged", kind.name());
            x.validate(lg.n()).unwrap();
        }
        // A different seed must change the draw for the stochastic kinds.
        if kind != SamplerKind::Full && kind != SamplerKind::Cluster {
            let mut c = build_sampler(kind, &store, &scfg(18)).unwrap();
            assert_ne!(c.sample(0, 0).n_id, a.sample(0, 0).n_id, "{}", kind.name());
        }
    }
}

#[test]
fn neighbor_and_cluster_train_end_to_end_on_catalog_dataset() {
    let spec = datasets::by_name("arxiv-xs").unwrap();
    for kind in [SamplerKind::Neighbor, SamplerKind::Cluster] {
        let mc = MiniBatchConfig {
            epochs: 20,
            lr: spec.lr,
            hidden: spec.hidden,
            ..Default::default()
        };
        let mut tr =
            MiniBatchTrainer::new(Arc::new(spec.build()), 4, kind, &scfg(42), mc).unwrap();
        let stats = tr.run(false).unwrap();
        assert_eq!(stats.len(), 20);
        let first = &stats[0];
        let last = stats.last().unwrap();
        assert!(
            last.train_loss.is_finite() && last.train_loss < first.train_loss,
            "{}: loss {} -> {}",
            kind.name(),
            first.train_loss,
            last.train_loss
        );
        // arxiv-xs is the hard low-homophily/high-noise setting; a dozen
        // epochs must beat 8-class chance clearly, not converge.
        assert!(last.train_acc > 0.2, "{}: train acc {}", kind.name(), last.train_acc);
        assert!(stats[1].comm_data_bytes > 0.0, "{} moved no data", kind.name());
        assert!(stats[1].modeled_secs > 0.0);
    }
}

/// Acceptance criterion: per-epoch wire data for cluster-sampled training
/// is strictly below the full-batch epoch volume on the same partitioning.
#[test]
fn cluster_epoch_comm_below_full_batch_on_same_partition() {
    let lg = catalog_lg();
    let k = 4;
    let seed = 11;

    // One partition, shared by both regimes (the exact helper
    // `planner::prepare` calls internally).
    let part = partition_for(&lg, k, seed);

    // Full-batch epoch volume (FP32 halos, synchronous exchange).
    let tc = TrainConfig {
        epochs: 2,
        seed,
        ..Default::default()
    };
    let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, None, seed).unwrap();
    let mut full = Trainer::new(ctxs, cfg, tc);
    let full_stats = full.run(false).unwrap();
    let full_epoch_bytes = full_stats[1].comm_data_bytes;
    assert!(full_epoch_bytes > 0.0);

    // Cluster-sampled epoch volume over the *same* worker partition.
    let mc = MiniBatchConfig {
        epochs: 2,
        seed,
        hidden: 32,
        ..Default::default()
    };
    let mut mb =
        MiniBatchTrainer::with_partition(lg, part, SamplerKind::Cluster, &scfg(seed), mc).unwrap();
    let mb_stats = mb.run(false).unwrap();
    let mb_epoch_bytes = mb_stats[1].comm_data_bytes;
    assert!(mb_epoch_bytes > 0.0);
    assert!(
        mb_epoch_bytes < full_epoch_bytes,
        "cluster epoch moved {mb_epoch_bytes} B, full-batch {full_epoch_bytes} B"
    );
}

/// Quantized fetches of sampled halo rows must be unbiased: averaging the
/// dequantized rows over many stochastic-rounding seeds converges to the
/// original features far inside the single-shot quantization error.
#[test]
fn quantized_fetch_roundtrip_is_unbiased_on_sampled_halo_rows() {
    let lg = catalog_lg();
    let f = lg.feat_dim;
    let k = 4;
    let seed = 7;

    // Halo rows of one sampled batch w.r.t. the worker partition: the
    // rows a worker would fetch remotely.
    let weights = vertex_weights(&lg.graph, None, 0);
    let part = multilevel(
        &lg.graph,
        k,
        &weights,
        &MultilevelOpts {
            seed,
            ..Default::default()
        },
    );
    let store = GraphStore::from(lg.clone());
    let mut sampler = build_sampler(SamplerKind::Neighbor, &store, &scfg(seed)).unwrap();
    let mb = sampler.sample(0, 0);
    let w = 0usize; // perspective of worker 0
    let halo: Vec<u32> = mb
        .n_id
        .iter()
        .copied()
        .filter(|&v| part.assign[v as usize] as usize != w)
        .collect();
    assert!(halo.len() >= 8, "batch has too few halo rows to test");

    let mut orig = Vec::with_capacity(halo.len() * f);
    for &v in &halo {
        orig.extend_from_slice(lg.feature_row(v as usize));
    }

    let trials = 400;
    let mut acc = vec![0f64; orig.len()];
    let mut single_mae = 0f64;
    for t in 0..trials {
        let q = fused::quantize(&orig, halo.len(), f, Bits::Int2, 0xFE7C ^ t as u64);
        let y = fused::dequantize(&q);
        for (a, (&yy, &xx)) in acc.iter_mut().zip(y.iter().zip(orig.iter())) {
            *a += yy as f64;
            single_mae += (yy as f64 - xx as f64).abs();
        }
    }
    single_mae /= (trials * orig.len()) as f64;
    assert!(single_mae > 0.0, "quantization was lossless?");

    let mut bias_abs = 0f64;
    let mut bias_signed = 0f64;
    for (a, &x) in acc.iter().zip(orig.iter()) {
        let b = a / trials as f64 - x as f64;
        bias_abs += b.abs();
        bias_signed += b;
    }
    bias_abs /= orig.len() as f64;
    bias_signed /= orig.len() as f64;

    // Averaging kills the stochastic-rounding noise (unbiased), so the
    // residual bias sits far below the one-shot error.
    assert!(
        bias_abs < 0.5 * single_mae,
        "per-element bias {bias_abs} vs single-shot MAE {single_mae}"
    );
    assert!(
        bias_signed.abs() < 0.1 * single_mae,
        "systematic bias {bias_signed} vs single-shot MAE {single_mae}"
    );
}

#[test]
fn saint_regimes_run_and_report_comm() {
    let lg = catalog_lg();
    for kind in [SamplerKind::SaintRw, SamplerKind::SaintNode, SamplerKind::SaintEdge] {
        let mc = MiniBatchConfig {
            epochs: 3,
            hidden: 32,
            quant: Some(Bits::Int4),
            ..Default::default()
        };
        let mut tr = MiniBatchTrainer::new(lg.clone(), 3, kind, &scfg(5), mc).unwrap();
        let stats = tr.run(false).unwrap();
        assert!(stats.iter().all(|s| s.train_loss.is_finite()), "{}", kind.name());
        // Quantized fetches carry param bytes alongside packed data.
        assert!(stats[0].comm_data_bytes > 0.0, "{}", kind.name());
        assert!(stats[0].comm_param_bytes > 0.0, "{}", kind.name());
    }
}
