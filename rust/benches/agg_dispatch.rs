//! Aggregation-dispatch crossover: segment-sum vs SpMM operator form by
//! feature width and nnz, on the problems the `exec::AggDispatch` chooser
//! actually routes (sorted segment runs from R-MAT graphs).
//!
//! The §4 ladder gives two operator forms for the same aggregation —
//! edge-list segment sum (`agg::blocked`/`agg::parallel`) and CSR SpMM
//! (`agg::spmm`) — plus a serial/parallel split controlled by the
//! dispatcher's tunable work threshold (`--agg-threshold` on the CLI).
//! This harness sweeps (nnz, f) and reports where each form wins, the
//! data behind the `Auto` heuristic.

use std::time::Instant;
use supergcn::agg::spmm::CsrMatrix;
use supergcn::exec::{AggDispatch, AggKernel};
use supergcn::exp::Table;
use supergcn::graph::generate::rmat;
use supergcn::util::rng::Rng;

fn bench_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best * 1e3
}

fn main() {
    // Smoke mode (CI `bench-smoke` job): smaller problems, fewer reps —
    // exercises every kernel path without the full sweep's runtime.
    let smoke = std::env::var("SUPERGCN_BENCH_SMOKE").ok().as_deref() == Some("1")
        || std::env::args().any(|a| a == "--smoke");
    let scales: &[usize] = if smoke { &[8, 10] } else { &[8, 10, 12] };
    let feats: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 128] };
    let reps = if smoke { 2 } else { 3 };
    let mut table = Table::new(
        "agg dispatch crossover: segment-sum vs SpMM (ms, lower is better)",
        &["scale", "nnz", "f", "seg-blocked", "seg-parallel", "spmm", "auto", "winner"],
    );
    let mut rng = Rng::new(42);
    for &scale in scales {
        let g = rmat(scale, 8.0, 0.57, 0.19, 0.19, false, 7);
        let n = g.n;
        // Sorted segment form (CSR is sorted by destination already).
        let a = CsrMatrix::from_graph(&g);
        let mut gather = Vec::with_capacity(g.m());
        let mut seg = Vec::with_capacity(g.m());
        for v in 0..n {
            for &s in g.in_neighbors(v) {
                gather.push(s);
                seg.push(v as u32);
            }
        }
        for &f in feats {
            let h: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let mut out = vec![0f32; n * f];
            let blocked = AggDispatch::default().with_kernel(AggKernel::Blocked);
            let par = AggDispatch::default()
                .with_kernel(AggKernel::Parallel)
                .with_threads(4);
            let spmm = AggDispatch::default().with_kernel(AggKernel::Spmm);
            let auto = AggDispatch::default().with_threads(4);

            let t_blk = bench_ms(reps, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                blocked.segment_sum(&h, f, &gather, &seg, n, &mut out);
            });
            let t_par = bench_ms(reps, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                par.segment_sum(&h, f, &gather, &seg, n, &mut out);
            });
            let t_spmm = bench_ms(reps, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                spmm.spmm(&a, &h, f, &mut out);
            });
            let t_auto = bench_ms(reps, || {
                out.iter_mut().for_each(|x| *x = 0.0);
                auto.segment_sum(&h, f, &gather, &seg, n, &mut out);
            });
            let winner = [("seg-blocked", t_blk), ("seg-parallel", t_par), ("spmm", t_spmm)]
                .iter()
                .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
                .unwrap()
                .0;
            table.row(vec![
                scale.to_string(),
                g.m().to_string(),
                f.to_string(),
                format!("{t_blk:.3}"),
                format!("{t_par:.3}"),
                format!("{t_spmm:.3}"),
                format!("{t_auto:.3}"),
                winner.to_string(),
            ]);
        }
    }
    table.print();
    println!(
        "\nAuto routes serial below {} contributions, 2D-parallel above; override with \
         `supergcn train --agg-kernel` / tune with `--agg-threshold`.",
        supergcn::agg::spmm::SPMM_PARALLEL_MIN_NNZ
    );
}
