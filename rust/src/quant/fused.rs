//! Optimized quantization kernels (paper §7.3).
//!
//! Mirrors all four published optimizations:
//! 1. **Decentralized**: no synchronization — each (group, seed) quantizes
//!    independently; params travel with the payload.
//! 2. **Fusion**: stats and quantization are fused over one cache-resident
//!    4-row group (retrieve 4 rows once, compute min/max, quantize while
//!    hot).
//! 3. **Latency reduction**: the per-element division is replaced by a
//!    precomputed reciprocal multiply, and the sequential RNG in the
//!    rounding loop is replaced by *counter-based* noise (a stateless
//!    integer mix of the flat element index), which removes the loop-
//!    carried dependency chain entirely.
//! 4. **Vectorization**: inner loops run over fixed-width chunks with no
//!    branches so the compiler auto-vectorizes them; int2 packing happens
//!    in-register, 4 codes → 1 byte.
//!
//! Inputs are hardened against non-finite values: NaN/±inf (and
//! magnitudes beyond [`QUANT_CLAMP`]) are clamped by `sanitize` before the
//! group stats and the rounding kernel see them, so one poisoned feature
//! value can never turn its 4-row group's packed payload into NaN/±inf on
//! the wire (property-tested below). Sanitization runs **once** per
//! element, into a cache-resident group-sized scratch buffer; `minmax`
//! and `code_of` consume pre-sanitized values (`sanitize` is idempotent,
//! so this is bit-identical to sanitizing at each consumer — it used to
//! run twice per element on the hot path).
//!
//! The explicitly vectorized twin of this module is [`super::simd`]
//! (runtime AVX2 dispatch, bit-identical wire output — DESIGN.md §14); it
//! reuses the `pub(crate)` helpers below so params, noise, and packing
//! come from one definition.

use super::packing::packed_len;
use super::{Bits, Quantized, GROUP_ROWS};

/// Counter-based noise in [0,1): one round of splitmix-style mixing of the
/// element counter. Stateless ⇒ no dependency chain, vectorizable.
#[inline(always)]
fn counter_noise(seed: u64, idx: u64) -> f32 {
    let mut z = seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^= z >> 31;
    ((z >> 40) as u32) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Four noise lanes from ONE mix (§Perf: the per-element hash dominated
/// the kernel; one 64-bit mix yields 4×16-bit uniform lanes — 16 bits is
/// plenty for stochastic rounding between ≤256 levels).
#[inline(always)]
pub(crate) fn noise4(seed: u64, counter: u64) -> [f32; 4] {
    let mut z = seed ^ counter.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^= z >> 31;
    const S: f32 = 1.0 / 65536.0;
    [
        (z & 0xFFFF) as f32 * S,
        ((z >> 16) & 0xFFFF) as f32 * S,
        ((z >> 32) & 0xFFFF) as f32 * S,
        ((z >> 48) & 0xFFFF) as f32 * S,
    ]
}

/// Largest magnitude a value may carry into quantization (= `f32::MAX/4`).
/// Inputs are clamped here by the private `sanitize` helper so a group's
/// range (`mx − mn ≤ 2·QUANT_CLAMP`) and the dequant multiply-add
/// (`code·scale + zero`) stay strictly inside finite f32 — one poisoned
/// feature value must not turn its whole 4-row group into NaN/±inf on the
/// wire.
pub const QUANT_CLAMP: f32 = 8.507059e37;

/// Map non-finite and over-range inputs to a finite stand-in before the
/// group stats and the rounding kernel see them: NaN → 0, ±inf → ±clamp,
/// finite values clamp into `[-QUANT_CLAMP, QUANT_CLAMP]` (a no-op for
/// every sane feature scale). Branch shape keeps the loops vectorizable.
/// Idempotent: `sanitize(sanitize(v)) == sanitize(v)` bitwise, which is
/// what lets the hot path sanitize once up front.
#[inline(always)]
pub(crate) fn sanitize(v: f32) -> f32 {
    if v.is_finite() {
        v.clamp(-QUANT_CLAMP, QUANT_CLAMP)
    } else if v > 0.0 {
        QUANT_CLAMP
    } else if v < 0.0 {
        -QUANT_CLAMP
    } else {
        0.0 // NaN compares false both ways
    }
}

/// Quantize one **pre-sanitized** value: `t = (v-zero)·inv + u`; `t ≥ 0`
/// by construction so the f32→u32 cast truncates like `floor` and
/// saturates at 0 (§Perf: replaces floor + clamp). The cast saturates at
/// `max_code` for over-range results, so the code is always in range.
/// Callers own sanitization (done once per group buffer, see
/// [`quantize_into`]).
#[inline(always)]
pub(crate) fn code_of(v: f32, zero: f32, inv_scale: f32, noise: f32, max_code: u32) -> u8 {
    let t = (v - zero) * inv_scale + noise;
    (t as u32).min(max_code) as u8
}

/// Fused min/max over a **pre-sanitized** slice, chunked for
/// vectorization. Since every value already passed [`sanitize`], the
/// result is a finite pair with `mx − mn ≤ 2·QUANT_CLAMP` (non-empty
/// input).
#[inline]
pub(crate) fn minmax(xs: &[f32]) -> (f32, f32) {
    const W: usize = 8;
    let mut mns = [f32::INFINITY; W];
    let mut mxs = [f32::NEG_INFINITY; W];
    let chunks = xs.chunks_exact(W);
    let rem = chunks.remainder();
    for c in chunks {
        for i in 0..W {
            mns[i] = mns[i].min(c[i]);
            mxs[i] = mxs[i].max(c[i]);
        }
    }
    let mut mn = rem.iter().copied().fold(f32::INFINITY, f32::min);
    let mut mx = rem.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    for i in 0..W {
        mn = mn.min(mns[i]);
        mx = mx.max(mxs[i]);
    }
    (mn, mx)
}

/// Derive a group's `(zero, scale)` from its sanitized min/max. Shared by
/// the scalar and SIMD quantizers so the params are one definition (and
/// therefore trivially bit-identical between them).
#[inline]
pub(crate) fn group_zero_scale(mn: f32, mx: f32, max_code: f32) -> (f32, f32) {
    if mx > mn {
        // mx − mn ≤ 2·QUANT_CLAMP = f32::MAX/2, so the subtraction and
        // the scale stay finite in f32 — the clamp in `sanitize` is what
        // makes a full-range group safe here.
        (mn, (mx - mn) / max_code)
    } else {
        // Degenerate groups: constant input keeps its zero point; an
        // empty slice (cols == 0 ⇒ mn stays +inf) stores (0, 0).
        (if mn.is_finite() { mn } else { 0.0 }, 0.0)
    }
}

/// Pack one group's **pre-sanitized** values into `data`. `base` is the
/// flat element index of `slice[0]` in the full matrix and must be a
/// multiple of 4 (noise quads are addressed by flat index, one
/// [`noise4`] hash per quad — the wire format pins that alignment).
/// Shared by the scalar quantizer below and the SIMD quantizer's
/// remainder path ([`super::simd`]), so both pack through one definition.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn pack_group(
    slice: &[f32],
    bits: Bits,
    seed: u64,
    base: u64,
    zero: f32,
    inv_scale: f32,
    mc: u32,
    data: &mut Vec<u8>,
) {
    debug_assert_eq!(base % 4, 0, "noise quads are flat-index aligned");
    match bits {
        Bits::Int2 => {
            let mut it = slice.chunks_exact(4);
            let mut idx = 0u64;
            for quad in &mut it {
                // One hash serves the 4 codes of this byte.
                let nz = noise4(seed, base + idx);
                let mut byte = 0u8;
                // branch-free: scale==0 ⇒ inv_scale==0 ⇒ code 0
                for i in 0..4 {
                    byte |= code_of(quad[i], zero, inv_scale, nz[i], mc) << (2 * i);
                }
                data.push(byte);
                idx += 4;
            }
            let rem = it.remainder();
            if !rem.is_empty() {
                let nz = noise4(seed, base + idx);
                let mut byte = 0u8;
                for (i, &v) in rem.iter().enumerate() {
                    byte |= code_of(v, zero, inv_scale, nz[i], mc) << (2 * i);
                }
                data.push(byte);
            }
        }
        Bits::Int4 => {
            let mut it = slice.chunks_exact(4);
            let mut idx = 0u64;
            for quad in &mut it {
                let nz = noise4(seed, base + idx);
                let c0 = code_of(quad[0], zero, inv_scale, nz[0], mc);
                let c1 = code_of(quad[1], zero, inv_scale, nz[1], mc);
                let c2 = code_of(quad[2], zero, inv_scale, nz[2], mc);
                let c3 = code_of(quad[3], zero, inv_scale, nz[3], mc);
                data.push(c0 | (c1 << 4));
                data.push(c2 | (c3 << 4));
                idx += 4;
            }
            let rem = it.remainder();
            if !rem.is_empty() {
                let nz = noise4(seed, base + idx);
                let mut byte = 0u8;
                for (i, &v) in rem.iter().enumerate() {
                    let c = code_of(v, zero, inv_scale, nz[i], mc);
                    if i % 2 == 0 {
                        byte = c;
                        if i + 1 == rem.len() {
                            data.push(byte);
                        }
                    } else {
                        data.push(byte | (c << 4));
                    }
                }
            }
        }
        Bits::Int8 => {
            let mut it = slice.chunks_exact(4);
            let mut idx = 0u64;
            for quad in &mut it {
                let nz = noise4(seed, base + idx);
                for i in 0..4 {
                    data.push(code_of(quad[i], zero, inv_scale, nz[i], mc));
                }
                idx += 4;
            }
            let rem = it.remainder();
            if !rem.is_empty() {
                let nz = noise4(seed, base + idx);
                for (i, &v) in rem.iter().enumerate() {
                    data.push(code_of(v, zero, inv_scale, nz[i], mc));
                }
            }
        }
    }
}

/// Quantize into preallocated buffers (the comm hot path reuses `params`
/// and `data` across calls; the only allocation here is one group-sized
/// sanitize scratch buffer per call).
pub fn quantize_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    bits: Bits,
    seed: u64,
    params: &mut Vec<(f32, f32)>,
    data: &mut Vec<u8>,
) {
    assert_eq!(x.len(), rows * cols);
    params.clear();
    data.clear();
    params.reserve(rows.div_ceil(GROUP_ROWS));
    data.reserve(rows.div_ceil(GROUP_ROWS) * super::packing::packed_len(GROUP_ROWS * cols, bits));
    let max_code = bits.max_code() as f32;
    // Sanitize ONCE into a cache-resident group buffer; `minmax` and
    // `code_of` consume pre-sanitized values. Bit-identical to sanitizing
    // at each consumer because `sanitize` is idempotent.
    let mut sbuf = vec![0f32; GROUP_ROWS * cols];
    for g in (0..rows).step_by(GROUP_ROWS) {
        let g_rows = GROUP_ROWS.min(rows - g);
        let raw = &x[g * cols..(g + g_rows) * cols];
        let sane = &mut sbuf[..raw.len()];
        for (d, &v) in sane.iter_mut().zip(raw.iter()) {
            *d = sanitize(v);
        }
        // Sanitized stats: mn/mx are always finite (NaN ignored as 0,
        // ±inf clamped), so the params can never poison dequantization.
        let (mn, mx) = minmax(sane);
        let (zero, scale) = group_zero_scale(mn, mx, max_code);
        debug_assert!(zero.is_finite() && scale.is_finite());
        params.push((zero, scale));
        // Reciprocal-multiply instead of division (§7.3(3)).
        let inv_scale = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        pack_group(sane, bits, seed, (g * cols) as u64, zero, inv_scale, max_code as u32, data);
    }
}

/// Allocating wrapper around [`quantize_into`].
pub fn quantize(x: &[f32], rows: usize, cols: usize, bits: Bits, seed: u64) -> Quantized {
    let mut params = Vec::new();
    let mut data = Vec::new();
    quantize_into(x, rows, cols, bits, seed, &mut params, &mut data);
    Quantized {
        bits,
        rows,
        cols,
        params,
        data,
    }
}

/// Dequantize into a preallocated output (len = rows*cols).
pub fn dequantize_into(q: &Quantized, out: &mut [f32]) {
    assert_eq!(out.len(), q.rows * q.cols);
    let mut data_off = 0usize;
    for (gi, &(zero, scale)) in q.params.iter().enumerate() {
        let g = gi * GROUP_ROWS;
        let g_rows = GROUP_ROWS.min(q.rows - g);
        let n = g_rows * q.cols;
        let bytes = &q.data[data_off..data_off + packed_len(n, q.bits)];
        data_off += bytes.len();
        let dst = &mut out[g * q.cols..g * q.cols + n];
        match q.bits {
            Bits::Int2 => {
                // 4 codes per byte, unpacked with shifts; multiply-add.
                let full = n / 4;
                for bi in 0..full {
                    let b = bytes[bi];
                    let o = bi * 4;
                    dst[o] = (b & 0x3) as f32 * scale + zero;
                    dst[o + 1] = ((b >> 2) & 0x3) as f32 * scale + zero;
                    dst[o + 2] = ((b >> 4) & 0x3) as f32 * scale + zero;
                    dst[o + 3] = ((b >> 6) & 0x3) as f32 * scale + zero;
                }
                for i in full * 4..n {
                    let b = bytes[i / 4];
                    dst[i] = ((b >> (2 * (i % 4))) & 0x3) as f32 * scale + zero;
                }
            }
            Bits::Int4 => {
                for i in 0..n {
                    let b = bytes[i / 2];
                    dst[i] = ((b >> (4 * (i % 2))) & 0xF) as f32 * scale + zero;
                }
            }
            Bits::Int8 => {
                for i in 0..n {
                    dst[i] = bytes[i] as f32 * scale + zero;
                }
            }
        }
    }
}

/// Allocating wrapper around [`dequantize_into`].
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let mut out = vec![0f32; q.rows * q.cols];
    dequantize_into(q, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{error_bound, naive};
    use crate::util::propcheck::{prop_assert, propcheck};
    use crate::util::rng::Rng;

    #[test]
    fn matches_error_bound_like_naive() {
        let mut rng = Rng::new(8);
        let (rows, cols) = (17, 33);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32() * 8.0 - 4.0).collect();
        for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
            let q = quantize(&x, rows, cols, bits, 1);
            let y = dequantize(&q);
            let bound = error_bound(&q.params) + 1e-5;
            for (a, b) in x.iter().zip(y.iter()) {
                assert!((a - b).abs() <= bound, "{}: {a} vs {b}", bits.name());
            }
        }
    }

    #[test]
    fn params_match_naive_exactly() {
        // Optimized and naive must derive identical (zero, scale) params —
        // only the rounding noise differs.
        let mut rng = Rng::new(4);
        let (rows, cols) = (9, 16);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
        for bits in [Bits::Int2, Bits::Int8] {
            let a = quantize(&x, rows, cols, bits, 7);
            let b = naive::quantize(&x, rows, cols, bits, 7);
            for ((z1, s1), (z2, s2)) in a.params.iter().zip(b.params.iter()) {
                assert!((z1 - z2).abs() < 1e-6 && (s1 - s2).abs() < 1e-6);
            }
            assert_eq!(a.data.len(), b.data.len());
        }
    }

    #[test]
    fn naive_dequant_reads_fused_output() {
        // The two implementations share the wire format.
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..8 * 24).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let q = quantize(&x, 8, 24, Bits::Int2, 3);
        let y1 = dequantize(&q);
        let y2 = naive::dequantize(&q);
        assert_eq!(y1, y2);
    }

    #[test]
    fn deterministic_given_seed() {
        let x: Vec<f32> = (0..256).map(|i| (i as f32).sin()).collect();
        let a = quantize(&x, 8, 32, Bits::Int2, 9);
        let b = quantize(&x, 8, 32, Bits::Int2, 9);
        assert_eq!(a, b);
        let c = quantize(&x, 8, 32, Bits::Int2, 10);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn unbiased_rounding() {
        let cols = 2000;
        let mut x = vec![0.5f32; 4 * cols]; // exactly between codes with scale 1/3... set range
        x[0] = 0.0;
        x[1] = 3.0;
        let mut acc = 0.0f64;
        let trials = 300;
        for t in 0..trials {
            let q = quantize(&x, 4, cols, Bits::Int2, t as u64);
            let y = dequantize(&q);
            acc += y[100] as f64;
        }
        let mean = acc / trials as f64;
        assert!((mean - 0.5).abs() < 0.05, "biased: {mean}");
    }

    #[test]
    fn prop_fused_roundtrip() {
        propcheck(32, |gen| {
            let rows = gen.usize(1, 30);
            let cols = gen.usize(1, 50);
            let x = gen.vec_f32(rows * cols, -50.0, 50.0);
            for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
                let q = quantize(&x, rows, cols, bits, gen.rng.next_u64());
                let y = dequantize(&q);
                let bound = error_bound(&q.params) * 1.0001 + 1e-4;
                for (&a, &b) in x.iter().zip(y.iter()) {
                    prop_assert(
                        (a - b).abs() <= bound,
                        format!("{}: {a} vs {b} (bound {bound})", bits.name()),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_non_finite_rows_quantize_finite_and_in_range() {
        // A NaN/±inf feature value used to poison its whole 4-row group's
        // scale and ship NaN/inf to every consumer; sanitize() pins the
        // params finite and every dequantized value inside the group's
        // clamped range — for NaN, ±inf, and max-magnitude f32 inputs.
        propcheck(24, |gen| {
            let rows = gen.usize(1, 12);
            let cols = gen.usize(1, 40);
            let mut x = gen.vec_f32(rows * cols, -10.0, 10.0);
            // At least one NaN every run, plus the full poison set at
            // random positions when the matrix has room.
            x[0] = f32::NAN;
            for p in [f32::INFINITY, f32::NEG_INFINITY, f32::MAX, f32::MIN, f32::NAN] {
                let i = gen.usize(0, x.len() - 1);
                x[i] = p;
            }
            for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
                let q = quantize(&x, rows, cols, bits, gen.rng.next_u64());
                for &(zero, scale) in &q.params {
                    prop_assert(
                        zero.is_finite() && scale.is_finite(),
                        format!("{}: non-finite params ({zero}, {scale})", bits.name()),
                    )?;
                }
                let y = dequantize(&q);
                for (gi, chunk) in y.chunks(GROUP_ROWS * cols).enumerate() {
                    let (zero, scale) = q.params[gi];
                    let lo = zero as f64;
                    let hi = lo + scale as f64 * bits.max_code() as f64;
                    let tol = lo.abs().max(hi.abs()).max(1.0) * 1e-5;
                    for &v in chunk {
                        prop_assert(
                            v.is_finite(),
                            format!("{}: dequant produced {v}", bits.name()),
                        )?;
                        prop_assert(
                            v.abs() <= QUANT_CLAMP * 1.0001,
                            format!("{}: {v} escapes the clamp", bits.name()),
                        )?;
                        let vv = v as f64;
                        prop_assert(
                            vv >= lo - tol && vv <= hi + tol,
                            format!("{}: {v} outside group range [{lo}, {hi}]", bits.name()),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sanitize_pins_poison_values() {
        assert_eq!(sanitize(f32::NAN), 0.0);
        assert_eq!(sanitize(f32::INFINITY), QUANT_CLAMP);
        assert_eq!(sanitize(f32::NEG_INFINITY), -QUANT_CLAMP);
        assert_eq!(sanitize(f32::MAX), QUANT_CLAMP);
        assert_eq!(sanitize(f32::MIN), -QUANT_CLAMP);
        // Sane values pass through untouched.
        for v in [-3.25f32, 0.0, 1e-20, 7.5, -1e30] {
            assert_eq!(sanitize(v), v);
        }
    }

    #[test]
    fn counter_noise_is_uniform_ish() {
        let n = 100_000;
        let mean: f64 = (0..n).map(|i| counter_noise(42, i) as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        // No obvious correlation between consecutive counters.
        let corr: f64 = (0..n - 1)
            .map(|i| (counter_noise(42, i) as f64 - 0.5) * (counter_noise(42, i + 1) as f64 - 0.5))
            .sum::<f64>()
            / (n - 1) as f64;
        assert!(corr.abs() < 0.01, "corr {corr}");
    }
}
