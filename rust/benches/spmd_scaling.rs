//! SPMD transport scaling: wall-clock of the threaded rank-per-OS-thread
//! runtime vs the sequential harness, as a function of rank-thread count
//! (DESIGN.md §10), in both training regimes on `arxiv-xs`.
//!
//! The two transports are bit-exact (`tests/spmd_parity.rs`); this
//! harness measures the only thing that changes — real epoch wall-clock
//! — plus the (identical) communication volume.
//!
//! Modes:
//! * default — rank counts {1,2,4,8}, 12 epochs each;
//! * smoke (`SUPERGCN_BENCH_SMOKE=1` or `--smoke`) — {1,2,4}, 4 epochs:
//!   the CI `bench-smoke` job's configuration.
//!
//! A second section runs the full-batch regime with `--overlap on`
//! (DESIGN.md §11) and reports the per-layer interior/boundary/comm
//! breakdown from the run's [`OverlapLedger`], with the modeled overlap
//! time `max(interior, comm) + boundary` next to the phase-serial model
//! of the *same* run (overlap ≤ serial always; the gap is the hidden
//! wire time).
//!
//! A third section compares the flat transport against the two-level
//! topology (`--group-size`, DESIGN.md §12): bit-exact losses asserted,
//! and the grouped run's O((P/g)²) inter-node message count asserted
//! strictly below the flat O(P²) pair count (group size overridable via
//! `SUPERGCN_BENCH_GROUP_SIZE`; CI pins it to 2 and re-checks the
//! emitted JSON's `hier` block).
//!
//! Set `SUPERGCN_BENCH_JSON=path` to also write the rows as JSON (CI
//! uploads it as the `BENCH_ci.json` workflow artifact, and
//! `supergcn benchcmp` gates regressions against the committed
//! `BENCH_seed.json`).

use supergcn::comm::transport::{Topology, TransportKind};
use supergcn::comm::CommStats;
use supergcn::coordinator::planner::{group_send_rows, prepare};
use supergcn::coordinator::shard;
use supergcn::coordinator::trainer::EpochStats;
use supergcn::datasets;
use supergcn::exec::OverlapLedger;
use supergcn::exp::{train_minibatch, Table};
use supergcn::graph::store::GraphStore;
use supergcn::graph::synth::{generate_to_store, SynthConfig};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::obs::{Telemetry, Tracer};
use supergcn::run::RunConfig;
use supergcn::sample::SamplerKind;
use supergcn::util::json::{to_pretty, Json};

/// Epoch wall seconds, skipping epoch 0 (allocation/lazy-init warmup).
fn steady_wall_secs(stats: &[EpochStats]) -> f64 {
    let tail = &stats[1.min(stats.len().saturating_sub(1))..];
    tail.iter().map(|s| s.measured_secs).sum()
}

struct Row {
    regime: &'static str,
    k: usize,
    seq_secs: f64,
    thr_secs: f64,
    comm_data_bytes: f64,
    comm_param_bytes: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.seq_secs / self.thr_secs.max(1e-12)
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("SUPERGCN_BENCH_SMOKE").ok().as_deref() == Some("1")
        || std::env::args().any(|a| a == "--smoke");
    let spec = datasets::by_name("arxiv-xs")?;
    let epochs = if smoke { 4 } else { 12 };
    let ks: Vec<usize> = if smoke { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };
    println!(
        "spmd scaling on {} ({} epochs/run, {} mode)",
        spec.name,
        epochs,
        if smoke { "smoke" } else { "full" }
    );

    let mut rows: Vec<Row> = Vec::new();

    // ---- full-batch regime ------------------------------------------
    for &k in &ks {
        let run = |transport: TransportKind| -> anyhow::Result<(f64, f64, f64)> {
            let lg = spec.build();
            let rc = RunConfig {
                epochs,
                lr: spec.lr,
                transport,
                seed: 42,
                ..Default::default()
            };
            let (ctxs, mut cfg, _) = prepare(&lg, k, rc.strategy, None, rc.seed)?;
            cfg.hidden = spec.hidden;
            let mut tr = rc.full_batch_trainer(ctxs, cfg);
            let stats = tr.run(false)?;
            Ok((
                steady_wall_secs(&stats),
                tr.comm_stats.total_data_bytes(),
                tr.comm_stats.total_param_bytes(),
            ))
        };
        let (seq_secs, data, params) = run(TransportKind::Sequential)?;
        let (thr_secs, ..) = run(TransportKind::Threaded)?;
        rows.push(Row {
            regime: "full-batch",
            k,
            seq_secs,
            thr_secs,
            comm_data_bytes: data,
            comm_param_bytes: params,
        });
    }

    // ---- mini-batch regime (neighbor sampler) -----------------------
    for &k in &ks {
        let run = |transport: TransportKind| -> anyhow::Result<(f64, f64, f64)> {
            let rc = RunConfig {
                sampler: SamplerKind::Neighbor,
                epochs,
                transport,
                seed: 42,
                batch_size: 128,
                fanouts: vec![10, 5, 5],
                ..Default::default()
            };
            let (stats, tr) = train_minibatch(
                &spec, k, SamplerKind::Neighbor, &rc.sampler_config(), rc.minibatch_config(), None,
            )?;
            Ok((
                steady_wall_secs(&stats),
                tr.comm_stats.total_data_bytes(),
                tr.comm_stats.total_param_bytes(),
            ))
        };
        let (seq_secs, data, params) = run(TransportKind::Sequential)?;
        let (thr_secs, ..) = run(TransportKind::Threaded)?;
        rows.push(Row {
            regime: "mini-batch",
            k,
            seq_secs,
            thr_secs,
            comm_data_bytes: data,
            comm_param_bytes: params,
        });
    }

    // ---- overlap section (DESIGN.md §11) -----------------------------
    // Full-batch @ 4 ranks, threaded, overlap on vs off: wall clock plus
    // the per-exchange ledger of the overlap run.
    let overlap_k = 4usize;
    let run_fb = |overlap: bool, tracer: Option<Tracer>| -> anyhow::Result<(f64, OverlapLedger)> {
        let lg = spec.build();
        let rc = RunConfig {
            epochs,
            lr: spec.lr,
            transport: TransportKind::Threaded,
            overlap,
            seed: 42,
            ..Default::default()
        };
        let (ctxs, mut cfg, _) = prepare(&lg, overlap_k, rc.strategy, None, rc.seed)?;
        cfg.hidden = spec.hidden;
        let mut tr = rc.full_batch_trainer(ctxs, cfg);
        tr.telemetry = Telemetry { tracer, metrics: None };
        let stats = tr.run(false)?;
        let ledger = stats.last().unwrap().overlap.clone();
        Ok((steady_wall_secs(&stats), ledger))
    };
    let (blocking_secs, _) = run_fb(false, None)?;
    // Trace the overlap run (DESIGN.md §13) — span accounting lands in the
    // JSON artifact's `obs` block (which benchcmp must ignore).
    let overlap_tracer = Tracer::new();
    let (overlap_secs, ledger) = run_fb(true, Some(overlap_tracer.clone()))?;
    assert!(
        overlap_tracer.span_count() > 0,
        "traced overlap run must record spans"
    );
    println!(
        "overlap run traced {} spans across {overlap_k} rank threads \
         ({} dropped to ring capacity)",
        overlap_tracer.span_count(),
        overlap_tracer.dropped_count()
    );
    let mut ot = Table::new(
        &format!(
            "overlap ledger: full-batch @ {overlap_k} rank threads, last epoch \
             (interior runs while the posted exchange is in flight)"
        ),
        &["stage", "interior s", "comm s", "boundary s", "overlap model", "serial model"],
    );
    for st in &ledger.stages {
        let (i, c, b) = st.maxes();
        ot.row(vec![
            st.label.to_string(),
            format!("{i:.6}"),
            format!("{c:.6}"),
            format!("{b:.6}"),
            format!("{:.6}", supergcn::perfmodel::t_layer_overlap(i, c, b)),
            format!("{:.6}", supergcn::perfmodel::t_layer_serial(i, c, b)),
        ]);
    }
    ot.print();
    let model_overlap = ledger.modeled_overlap_secs();
    let model_serial = ledger.modeled_serial_secs();
    println!(
        "modeled epoch: overlap {model_overlap:.6}s vs phase-serial {model_serial:.6}s \
         (hidden wire time {:.6}s); measured threaded wall: overlap {overlap_secs:.4}s \
         vs blocking {blocking_secs:.4}s (bit-exact runs)",
        model_serial - model_overlap,
    );
    assert!(
        model_overlap <= model_serial,
        "overlap model must never exceed the serial model of the same run"
    );

    // ---- two-level topology section (DESIGN.md §12) -------------------
    // Flat vs `--group-size g` on the threaded transport: runs are
    // bit-exact (asserted), the *physical* accounting differs — the
    // grouped run's inter-node message count is O((P/g)²) vs the flat
    // exchange's O(P²). CI sets SUPERGCN_BENCH_GROUP_SIZE=2 explicitly.
    let hier_k = 4usize;
    let hier_g: usize = std::env::var("SUPERGCN_BENCH_GROUP_SIZE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let run_grouped = |group_size: usize| -> anyhow::Result<(Vec<f32>, CommStats)> {
        let lg = spec.build();
        let rc = RunConfig {
            epochs,
            lr: spec.lr,
            transport: TransportKind::Threaded,
            group_size,
            seed: 42,
            ..Default::default()
        };
        let (ctxs, mut cfg, _) = prepare(&lg, hier_k, rc.strategy, None, rc.seed)?;
        cfg.hidden = spec.hidden;
        let mut tr = rc.full_batch_trainer(ctxs, cfg);
        let losses = tr.run(false)?.iter().map(|s| s.train_loss).collect();
        Ok((losses, tr.comm_stats.clone()))
    };
    let (flat_loss, flat_comm) = run_grouped(1)?;
    let (hier_loss, hier_comm) = run_grouped(hier_g)?;
    for (e, (a, b)) in flat_loss.iter().zip(hier_loss.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: hierarchical transport must be bit-exact with flat"
        );
    }
    let flat_msgs: usize = flat_comm.messages.iter().flatten().sum();
    let tiers = &hier_comm.tiers;
    assert!(
        tiers.total_inter_msgs() < flat_msgs,
        "inter-group messages {} must undercut flat {flat_msgs}",
        tiers.total_inter_msgs()
    );
    // The planner's per-group coalescing map, restricted to *cross-group*
    // destinations: the rows each worker stages for its leader to ship
    // inter-node per layer (same-group rows ride the intra tier and are
    // excluded here so the number lines up with inter_bits above).
    let topo = Topology::new(hier_k, hier_g);
    let staged_rows: usize = {
        let lg = spec.build();
        let (ctxs, ..) = prepare(&lg, hier_k, supergcn::hier::volume::RemoteStrategy::Hybrid,
            None, 42)?;
        ctxs.iter()
            .map(|c| {
                group_send_rows(c, topo)
                    .iter()
                    .enumerate()
                    .filter(|&(g, _)| g != topo.group_of(c.worker))
                    .map(|(_, &rows)| rows)
                    .sum::<usize>()
            })
            .sum()
    };
    let mut ht = Table::new(
        &format!(
            "two-level transport: full-batch @ {hier_k} ranks, group-size {hier_g} \
             (bit-exact with flat; physical path accounting)"
        ),
        &["tier", "messages", "bytes", "modeled secs"],
    );
    ht.row(vec![
        "flat (g=1), all pairs".to_string(),
        flat_msgs.to_string(),
        supergcn::util::fmt_bytes(flat_comm.total_data_bytes() + flat_comm.total_param_bytes()),
        format!("{:.6}", flat_comm.modeled_comm_secs()),
    ]);
    ht.row(vec![
        format!("g={hier_g} inter-node (leader exchange)"),
        tiers.total_inter_msgs().to_string(),
        supergcn::util::fmt_bytes(tiers.total_inter_bits() / 8.0),
        format!("{:.6}", tiers.modeled_two_tier_secs()),
    ]);
    ht.row(vec![
        format!("g={hier_g} intra-node (staging + delivery)"),
        tiers.total_intra_msgs().to_string(),
        supergcn::util::fmt_bytes(tiers.total_intra_bits() / 8.0),
        "-".into(),
    ]);
    ht.print();
    println!(
        "per-exchange message model: flat {} vs inter-group {} \
         (perfmodel::inter_group_messages); cross-group rows staged for the \
         leaders per layer: {staged_rows}",
        supergcn::perfmodel::flat_pair_messages(hier_k),
        supergcn::perfmodel::inter_group_messages(hier_k, hier_g),
    );

    // ---- feature-cache section (DESIGN.md §16) ------------------------
    // Mini-batch neighbor fetch with the remote-feature cache on (TTL
    // from SUPERGCN_BENCH_CACHE_TTL; CI pins 1) vs the TTL=0 identity:
    // fp32 rows are immutable, so the runs differ only in wire volume —
    // the `cache` JSON block below is what the CI bench-smoke leg
    // validates (hit rate > 0, saved bytes > 0).
    let cache_k = 4usize;
    let cache_ttl: usize = std::env::var("SUPERGCN_BENCH_CACHE_TTL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let cache_rows = 512usize;
    let run_cached = |ttl: usize| -> anyhow::Result<(Vec<f32>, CommStats)> {
        let rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            epochs,
            transport: TransportKind::Threaded,
            seed: 42,
            batch_size: 128,
            fanouts: vec![10, 5, 5],
            feature_cache_rows: if ttl > 0 { cache_rows } else { 0 },
            feature_cache_ttl: ttl,
            ..Default::default()
        };
        let (stats, tr) = train_minibatch(
            &spec, cache_k, SamplerKind::Neighbor, &rc.sampler_config(), rc.minibatch_config(),
            None,
        )?;
        Ok((
            stats.iter().map(|s| s.train_loss).collect(),
            tr.comm_stats.clone(),
        ))
    };
    let (uncached_loss, uncached_comm) = run_cached(0)?;
    let (cached_loss, cached_comm) = run_cached(cache_ttl.max(1))?;
    // fp32 hits return the exact fetched bits, so the loss curve must
    // not move at any TTL.
    for (e, (a, b)) in uncached_loss.iter().zip(cached_loss.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: fp32 feature cache must be bit-exact with TTL=0"
        );
    }
    let cstats = &cached_comm.cache;
    assert!(cstats.total_hits() > 0, "cache section recorded no hits");
    let uncached_bytes = uncached_comm.total_data_bytes();
    let cached_bytes = cached_comm.total_data_bytes();
    let mut ct = Table::new(
        &format!(
            "feature cache: mini-batch @ {cache_k} ranks, ttl={} rows={cache_rows} \
             (fp32 — bit-exact with ttl=0, wire-only win)",
            cache_ttl.max(1)
        ),
        &["config", "fetch data", "hit rate", "hits", "evictions", "wire saved"],
    );
    ct.row(vec![
        "ttl=0 (uncached)".to_string(),
        supergcn::util::fmt_bytes(uncached_bytes),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    ct.row(vec![
        format!("ttl={}", cache_ttl.max(1)),
        supergcn::util::fmt_bytes(cached_bytes),
        format!("{:.1}%", cstats.hit_rate() * 100.0),
        cstats.total_hits().to_string(),
        cstats.total_evictions().to_string(),
        supergcn::util::fmt_bytes(cstats.total_saved_bytes()),
    ]);
    ct.print();
    println!(
        "fetch volume cut {:.1}% by caching remote rows for {} round(s)",
        (1.0 - cached_bytes / uncached_bytes.max(1e-12)) * 100.0,
        cache_ttl.max(1)
    );

    // ---- out-of-core section (DESIGN.md §17) --------------------------
    // Stream a synthetic graph to disk, `prepare` per-rank shards, and
    // train mini-batch from the mmap-backed store with the materialized
    // in-memory run over the *same* block partition as the bit-exactness
    // reference. The `oocore` JSON block below is what the CI bench-smoke
    // leg validates; `cargo bench --bench oocore` runs the 100M+-edge
    // full-scale version of the same pipeline.
    let oo_k = 4usize;
    let oo_dir = std::env::temp_dir().join(format!("supergcn_bench_oocore_{}", std::process::id()));
    std::fs::create_dir_all(&oo_dir)?;
    let oo_path = oo_dir.join("graph.sgcn");
    let oo_cfg = SynthConfig {
        n: if smoke { 4_000 } else { 20_000 },
        avg_deg: 8,
        window: 256,
        feat_dim: 16,
        num_classes: 8,
        seed: 42,
        ..Default::default()
    };
    let oo_synth = generate_to_store(&oo_cfg, &oo_path)?;
    let oo_store = GraphStore::open(&oo_path)?;
    let oo_shards = shard::write_shards(&oo_store, oo_k, RemoteStrategy::Hybrid, 42, &oo_dir)?;
    let oo_shard_bytes: u64 = oo_shards.iter().map(|s| s.bytes).sum();
    let oo_run = |store: GraphStore| -> anyhow::Result<(Vec<f32>, f64)> {
        let rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            epochs,
            transport: TransportKind::Threaded,
            seed: 42,
            batch_size: 128,
            fanouts: vec![6, 4],
            ..Default::default()
        };
        let mut tr = rc.minibatch_trainer_oocore(store, oo_k)?;
        let stats = tr.run(false)?;
        Ok((
            stats.iter().map(|s| s.train_loss).collect(),
            steady_wall_secs(&stats),
        ))
    };
    let (oo_mmap_loss, oo_mmap_secs) = oo_run(oo_store.clone())?;
    let (oo_mem_loss, oo_mem_secs) = oo_run(oo_store.materialize())?;
    for (e, (a, b)) in oo_mmap_loss.iter().zip(oo_mem_loss.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "epoch {e}: mmap-backed training must be bit-exact with in-memory"
        );
    }
    let oo_rss = supergcn::graph::store::peak_rss_bytes().unwrap_or(0);
    let mut oot = Table::new(
        &format!(
            "out-of-core: synth {} nodes / {} edges @ {oo_k} ranks, mmap vs \
             materialized (bit-exact losses asserted)",
            oo_synth.n, oo_synth.m
        ),
        &["metric", "value"],
    );
    oot.row(vec!["store file".into(), supergcn::util::fmt_bytes(oo_synth.file_bytes as f64)]);
    oot.row(vec!["shard files".into(), supergcn::util::fmt_bytes(oo_shard_bytes as f64)]);
    oot.row(vec!["mapped bytes".into(), supergcn::util::fmt_bytes(oo_store.mapped_bytes() as f64)]);
    oot.row(vec!["mmap wall s".into(), format!("{oo_mmap_secs:.4}")]);
    oot.row(vec!["mem wall s".into(), format!("{oo_mem_secs:.4}")]);
    oot.row(vec!["proc peak rss".into(), supergcn::util::fmt_bytes(oo_rss as f64)]);
    oot.print();
    std::fs::remove_dir_all(&oo_dir).ok();

    // ---- report ------------------------------------------------------
    let mut table = Table::new(
        "SPMD transport scaling: wall secs, seq vs threaded (bit-exact runs)",
        &["regime", "ranks", "seq s", "threaded s", "speedup", "comm data", "comm params"],
    );
    for r in &rows {
        table.row(vec![
            r.regime.to_string(),
            r.k.to_string(),
            format!("{:.4}", r.seq_secs),
            format!("{:.4}", r.thr_secs),
            format!("{:.2}x", r.speedup()),
            supergcn::util::fmt_bytes(r.comm_data_bytes),
            supergcn::util::fmt_bytes(r.comm_param_bytes),
        ]);
    }
    table.print();
    if let Some(r4) = rows.iter().find(|r| r.regime == "full-batch" && r.k == 4) {
        println!(
            "\nfull-batch @ 4 rank threads: {:.2}x (acceptance target > 1.5x on \
             multi-core hosts; 1-core containers cannot exceed ~1x)",
            r4.speedup()
        );
    }

    // ---- optional JSON artifact (CI: BENCH_ci.json) ------------------
    if let Ok(path) = std::env::var("SUPERGCN_BENCH_JSON") {
        let arr: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("regime", Json::Str(r.regime.to_string())),
                    ("ranks", Json::Num(r.k as f64)),
                    ("seq_wall_secs", Json::Num(r.seq_secs)),
                    ("threaded_wall_secs", Json::Num(r.thr_secs)),
                    ("speedup", Json::Num(r.speedup())),
                    ("comm_data_bytes", Json::Num(r.comm_data_bytes)),
                    ("comm_param_bytes", Json::Num(r.comm_param_bytes)),
                ])
            })
            .collect();
        let overlap_stages: Vec<Json> = ledger
            .stages
            .iter()
            .map(|st| {
                let (i, c, b) = st.maxes();
                Json::obj(vec![
                    ("stage", Json::Str(st.label.to_string())),
                    ("interior_secs", Json::Num(i)),
                    ("comm_secs", Json::Num(c)),
                    ("boundary_secs", Json::Num(b)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::Str("spmd_scaling".to_string())),
            ("dataset", Json::Str(spec.name.to_string())),
            ("epochs_per_run", Json::Num(epochs as f64)),
            ("smoke", Json::Bool(smoke)),
            (
                "overlap",
                Json::obj(vec![
                    ("ranks", Json::Num(overlap_k as f64)),
                    ("modeled_overlap_secs", Json::Num(model_overlap)),
                    ("modeled_serial_secs", Json::Num(model_serial)),
                    ("threaded_wall_secs_overlap", Json::Num(overlap_secs)),
                    ("threaded_wall_secs_blocking", Json::Num(blocking_secs)),
                    ("stages", Json::Arr(overlap_stages)),
                ]),
            ),
            (
                "hier",
                Json::obj(vec![
                    ("ranks", Json::Num(hier_k as f64)),
                    ("group_size", Json::Num(hier_g as f64)),
                    ("flat_msgs", Json::Num(flat_msgs as f64)),
                    (
                        "inter_group_msgs",
                        Json::Num(tiers.total_inter_msgs() as f64),
                    ),
                    ("intra_msgs", Json::Num(tiers.total_intra_msgs() as f64)),
                    ("inter_bytes", Json::Num(tiers.total_inter_bits() / 8.0)),
                    ("intra_bytes", Json::Num(tiers.total_intra_bits() / 8.0)),
                    (
                        "modeled_two_tier_secs",
                        Json::Num(tiers.modeled_two_tier_secs()),
                    ),
                    (
                        "modeled_flat_secs",
                        Json::Num(flat_comm.modeled_comm_secs()),
                    ),
                    ("losses_bit_exact", Json::Bool(true)),
                ]),
            ),
            (
                "cache",
                Json::obj(vec![
                    ("ranks", Json::Num(cache_k as f64)),
                    ("ttl", Json::Num(cache_ttl.max(1) as f64)),
                    ("rows", Json::Num(cache_rows as f64)),
                    ("hit_rate", Json::Num(cstats.hit_rate())),
                    ("hits", Json::Num(cstats.total_hits() as f64)),
                    ("misses", Json::Num(cstats.total_misses() as f64)),
                    ("evictions", Json::Num(cstats.total_evictions() as f64)),
                    ("saved_bytes", Json::Num(cstats.total_saved_bytes())),
                    ("uncached_data_bytes", Json::Num(uncached_bytes)),
                    ("cached_data_bytes", Json::Num(cached_bytes)),
                    ("losses_bit_exact", Json::Bool(true)),
                ]),
            ),
            (
                "oocore",
                Json::obj(vec![
                    ("ranks", Json::Num(oo_k as f64)),
                    ("nodes", Json::Num(oo_synth.n as f64)),
                    ("edges", Json::Num(oo_synth.m as f64)),
                    ("store_file_bytes", Json::Num(oo_synth.file_bytes as f64)),
                    ("shard_bytes", Json::Num(oo_shard_bytes as f64)),
                    ("mapped_bytes", Json::Num(oo_store.mapped_bytes() as f64)),
                    ("mmap_wall_secs", Json::Num(oo_mmap_secs)),
                    ("mem_wall_secs", Json::Num(oo_mem_secs)),
                    ("peak_rss_bytes", Json::Num(oo_rss as f64)),
                    ("losses_bit_exact", Json::Bool(true)),
                ]),
            ),
            (
                "obs",
                Json::obj(vec![
                    (
                        "overlap_span_count",
                        Json::Num(overlap_tracer.span_count() as f64),
                    ),
                    (
                        "overlap_spans_dropped",
                        Json::Num(overlap_tracer.dropped_count() as f64),
                    ),
                ]),
            ),
            (
                "host_parallelism",
                Json::Num(
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
                ),
            ),
            ("rows", Json::Arr(arr)),
        ]);
        std::fs::write(&path, to_pretty(&doc))?;
        println!("wrote {path}");
    }
    Ok(())
}
