//! Model checkpointing: binary save/load of the flattened parameters plus
//! shape metadata, so long training runs (and the examples) can resume.
//!
//! Two on-disk formats (DESIGN.md §15):
//!
//! * **v1** (`SGCNCKP1`) — weights + epoch counter only. Kept for old
//!   files; `save`/`load` below.
//! * **v2** (`SGCNCKP2`) — the fault-tolerance format: weights, optimizer
//!   moments + step count, driver RNG state, epoch counter, and the
//!   `RunConfig` fingerprint, so `--resume` can verify the run is
//!   numerics-identical to the one that wrote the file.
//!   `save_state`/`load_state` below.
//!
//! Both loaders are hardened: truncated, corrupt, or version-mismatched
//! files return a descriptive `Err` (never a panic) before any state is
//! mutated beyond the passed-in buffers.

use super::optimizer::Optimizer;
use super::ModelParams;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SGCNCKP1";
const MAGIC_V2: &[u8; 8] = b"SGCNCKP2";

/// Driver-side counters restored from a v2 checkpoint (weights and
/// optimizer moments land directly in the `ModelParams`/`Optimizer`
/// passed to [`load_state`]).
#[derive(Clone, Copy, Debug)]
pub struct RestoredState {
    /// Completed-epoch count at save time (training resumes here).
    pub epoch: usize,
    /// `RunConfig::fingerprint()` of the run that wrote the file.
    pub fingerprint: u64,
    /// Driver RNG state (xoshiro256**) captured after the saved epoch.
    pub rng_state: [u64; 4],
}

/// Checked little-endian reader: every failed read names what was being
/// read instead of surfacing a bare "failed to fill whole buffer".
struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn bytes8(&mut self, what: &str) -> Result<[u8; 8]> {
        let mut b = [0u8; 8];
        self.r
            .read_exact(&mut b)
            .with_context(|| format!("checkpoint truncated or unreadable while reading {what}"))?;
        Ok(b)
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes8(what)?))
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let mut out = vec![0f32; n];
        let mut b = [0u8; 4];
        for v in &mut out {
            self.r.read_exact(&mut b).with_context(|| {
                format!("checkpoint truncated or unreadable while reading {what}")
            })?;
            *v = f32::from_le_bytes(b);
        }
        Ok(out)
    }

    fn expect_eof(&mut self) -> Result<()> {
        let mut b = [0u8; 1];
        match self.r.read(&mut b) {
            Ok(0) => Ok(()),
            Ok(_) => anyhow::bail!("checkpoint has trailing bytes past the declared payload"),
            Err(e) => Err(e).context("checking checkpoint end"),
        }
    }
}

fn open(path: &Path) -> Result<Reader<BufReader<std::fs::File>>> {
    Ok(Reader {
        r: BufReader::new(std::fs::File::open(path).context("opening checkpoint")?),
    })
}

fn write_shapes(w: &mut impl Write, params: &ModelParams) -> std::io::Result<()> {
    w.write_all(&(params.num_classes as u64).to_le_bytes())?;
    w.write_all(&(params.f_in as u64).to_le_bytes())?;
    w.write_all(&(params.layers.len() as u64).to_le_bytes())?;
    for l in &params.layers {
        w.write_all(&(l.fin as u64).to_le_bytes())?;
        w.write_all(&(l.fout as u64).to_le_bytes())?;
    }
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    for v in xs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Check the shape header against `params`; shared by both loaders.
fn read_shapes(r: &mut Reader<impl Read>, params: &ModelParams) -> Result<()> {
    let classes = r.u64("class count")? as usize;
    let f_in = r.u64("input feature dim")? as usize;
    anyhow::ensure!(
        classes == params.num_classes && f_in == params.f_in,
        "checkpoint shape mismatch: classes {classes}/f_in {f_in}"
    );
    let n_layers = r.u64("layer count")? as usize;
    anyhow::ensure!(n_layers == params.layers.len(), "layer count mismatch");
    for l in &params.layers {
        let fin = r.u64("layer input dim")? as usize;
        let fout = r.u64("layer output dim")? as usize;
        anyhow::ensure!(fin == l.fin && fout == l.fout, "layer dim mismatch");
    }
    Ok(())
}

/// Save parameters (+ the epoch counter) to `path` (v1 format).
pub fn save(params: &ModelParams, epoch: usize, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(epoch as u64).to_le_bytes())?;
    write_shapes(&mut w, params)?;
    let flat = params.flatten();
    w.write_all(&(flat.len() as u64).to_le_bytes())?;
    write_f32s(&mut w, &flat)?;
    Ok(())
}

/// Load a v1 checkpoint into `params` (shapes must match); returns the
/// epoch.
pub fn load(params: &mut ModelParams, path: &Path) -> Result<usize> {
    let mut r = open(path)?;
    let m = r.bytes8("magic")?;
    if &m == MAGIC_V2 {
        anyhow::bail!(
            "checkpoint version mismatch: found v2 (SGCNCKP2, full training state) — \
             load it with checkpoint::load_state / --resume"
        );
    }
    anyhow::ensure!(&m == MAGIC, "not a supergcn checkpoint");
    let epoch = r.u64("epoch counter")? as usize;
    read_shapes(&mut r, params)?;
    let n = r.u64("parameter count")? as usize;
    anyhow::ensure!(n == params.n_params(), "parameter count mismatch");
    let flat = r.f32s(n, "parameter values")?;
    r.expect_eof()?;
    params.unflatten_into(&flat);
    Ok(epoch)
}

/// Save the full training state (v2): weights, optimizer moments + step
/// count, driver RNG state, epoch counter, and the config fingerprint.
pub fn save_state(
    params: &ModelParams,
    opt: &Optimizer,
    rng_state: [u64; 4],
    epoch: usize,
    fingerprint: u64,
    path: &Path,
) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path).context("creating checkpoint")?);
    w.write_all(MAGIC_V2)?;
    w.write_all(&fingerprint.to_le_bytes())?;
    w.write_all(&(epoch as u64).to_le_bytes())?;
    for s in rng_state {
        w.write_all(&s.to_le_bytes())?;
    }
    write_shapes(&mut w, params)?;
    let (m, v, t) = opt.state();
    w.write_all(&t.to_le_bytes())?;
    let flat = params.flatten();
    anyhow::ensure!(
        m.len() == flat.len() && v.len() == flat.len(),
        "optimizer moments ({}/{}) do not match the parameter count ({})",
        m.len(),
        v.len(),
        flat.len()
    );
    w.write_all(&(flat.len() as u64).to_le_bytes())?;
    write_f32s(&mut w, &flat)?;
    write_f32s(&mut w, m)?;
    write_f32s(&mut w, v)?;
    Ok(())
}

/// Load a v2 checkpoint: weights into `params`, moments + step count into
/// `opt`; returns the restored driver counters. Nothing is mutated until
/// the whole file has been read and validated.
pub fn load_state(params: &mut ModelParams, opt: &mut Optimizer, path: &Path) -> Result<RestoredState> {
    let mut r = open(path)?;
    let magic = r.bytes8("magic")?;
    if &magic == MAGIC {
        anyhow::bail!(
            "checkpoint version mismatch: found v1 (SGCNCKP1, weights only) — a resumable \
             checkpoint needs optimizer/RNG state; re-save with --checkpoint-every"
        );
    }
    anyhow::ensure!(&magic == MAGIC_V2, "not a supergcn checkpoint");
    let fingerprint = r.u64("config fingerprint")?;
    let epoch = r.u64("epoch counter")? as usize;
    let mut rng_state = [0u64; 4];
    for (i, s) in rng_state.iter_mut().enumerate() {
        *s = r.u64(&format!("RNG state word {i}"))?;
    }
    read_shapes(&mut r, params)?;
    let t = r.u64("optimizer step count")?;
    let n = r.u64("parameter count")? as usize;
    anyhow::ensure!(n == params.n_params(), "parameter count mismatch");
    let flat = r.f32s(n, "parameter values")?;
    let m = r.f32s(n, "optimizer first moments")?;
    let v = r.f32s(n, "optimizer second moments")?;
    r.expect_eof()?;
    params.unflatten_into(&flat);
    opt.restore(&m, &v, t)?;
    Ok(RestoredState { epoch, fingerprint, rng_state })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::optimizer::OptKind;
    use crate::model::test_config;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("supergcn_ckpt_{}_{name}", std::process::id()))
    }

    fn params_and_opt(seed: u64) -> (ModelParams, Optimizer) {
        let p = ModelParams::init(&test_config(), seed);
        let n = p.n_params();
        (p, Optimizer::new(OptKind::Adam, 0.01, n))
    }

    #[test]
    fn roundtrip() {
        let p = ModelParams::init(&test_config(), 7);
        let path = tmp("rt.bin");
        save(&p, 42, &path).unwrap();
        let mut q = ModelParams::init(&test_config(), 99);
        assert_ne!(q.flatten(), p.flatten());
        let epoch = load(&mut q, &path).unwrap();
        assert_eq!(epoch, 42);
        assert_eq!(q.flatten(), p.flatten());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let p = ModelParams::init(&test_config(), 1);
        let path = tmp("mm.bin");
        save(&p, 0, &path).unwrap();
        let mut cfg2 = test_config();
        cfg2.classes = 8;
        let mut q = ModelParams::init(&cfg2, 1);
        assert!(load(&mut q, &path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garb.bin");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        let mut p = ModelParams::init(&test_config(), 1);
        let err = load(&mut p, &path).unwrap_err();
        assert!(err.to_string().contains("not a supergcn checkpoint"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn state_roundtrip_bit_identical() {
        let (mut p, mut opt) = params_and_opt(3);
        // Take a few optimizer steps so the moments are non-trivial.
        let grads: Vec<f32> = (0..p.n_params()).map(|i| (i as f32).sin()).collect();
        let mut flat = p.flatten();
        for _ in 0..3 {
            opt.step(&mut flat, &grads);
        }
        p.unflatten_into(&flat);
        let rng = [1u64, 2, 3, 4];
        let path = tmp("v2rt.bin");
        save_state(&p, &opt, rng, 17, 0xDEAD_BEEF, &path).unwrap();

        let (mut q, mut opt2) = params_and_opt(99);
        let st = load_state(&mut q, &mut opt2, &path).unwrap();
        assert_eq!(st.epoch, 17);
        assert_eq!(st.fingerprint, 0xDEAD_BEEF);
        assert_eq!(st.rng_state, rng);
        assert_eq!(q.flatten(), p.flatten());
        assert_eq!(opt2.state().0, opt.state().0);
        assert_eq!(opt2.state().1, opt.state().1);
        assert_eq!(opt2.state().2, opt.state().2);

        // save → load → save is bit-identical on disk.
        let path2 = tmp("v2rt2.bin");
        save_state(&q, &opt2, st.rng_state, st.epoch, st.fingerprint, &path2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn truncated_v2_rejected_at_every_cut() {
        let (p, opt) = params_and_opt(5);
        let path = tmp("v2trunc.bin");
        save_state(&p, &opt, [9, 8, 7, 6], 2, 1, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Cut the file at several prefixes spanning header, shapes, and
        // payload; every one must fail with a descriptive error, and the
        // target buffers must be left loadable afterwards.
        for cut in [0, 4, 8, 15, 40, 80, full.len() / 2, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (mut q, mut o2) = params_and_opt(5);
            let err = load_state(&mut q, &mut o2, &path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("mismatch"),
                "cut {cut}: unexpected error {msg}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trailing_garbage_rejected() {
        let (p, opt) = params_and_opt(5);
        let path = tmp("v2trail.bin");
        save_state(&p, &opt, [0; 4], 0, 0, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0xAB);
        std::fs::write(&path, &bytes).unwrap();
        let (mut q, mut o2) = params_and_opt(5);
        let err = load_state(&mut q, &mut o2, &path).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_both_directions() {
        let (p, opt) = params_and_opt(5);
        let v1 = tmp("v1file.bin");
        let v2 = tmp("v2file.bin");
        save(&p, 3, &v1).unwrap();
        save_state(&p, &opt, [0; 4], 3, 0, &v2).unwrap();

        let (mut q, mut o2) = params_and_opt(5);
        let err = load_state(&mut q, &mut o2, &v1).unwrap_err();
        assert!(err.to_string().contains("found v1"), "{err:#}");
        let err = load(&mut q, &v2).unwrap_err();
        assert!(err.to_string().contains("found v2"), "{err:#}");
        std::fs::remove_file(&v1).ok();
        std::fs::remove_file(&v2).ok();
    }

    #[test]
    fn v2_shape_mismatch_rejected() {
        let (p, opt) = params_and_opt(5);
        let path = tmp("v2mm.bin");
        save_state(&p, &opt, [0; 4], 0, 0, &path).unwrap();
        let mut cfg2 = test_config();
        cfg2.classes = 8;
        let mut q = ModelParams::init(&cfg2, 1);
        let mut o2 = Optimizer::new(OptKind::Adam, 0.01, q.n_params());
        let err = load_state(&mut q, &mut o2, &path).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }
}
