//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO parsing);
//! that native library is not present in this build environment, so this
//! stub keeps the workspace compiling and the *host-side* pieces honest:
//!
//! * [`Literal`] is a real host tensor (f32/i32, shape-checked reshape,
//!   round-trips values) — the `runtime` literal helpers and their tests
//!   work against it unchanged.
//! * [`PjRtClient::cpu`] returns an error, so `Runtime::load` (and with
//!   it the `xla` backend) fails fast with a clear message instead of
//!   pretending to execute artifacts. The artifact-dependent tests and
//!   benches already skip when `artifacts/manifest.json` is absent.
//!
//! Swapping the vendored real bindings back in requires no source change
//! anywhere else — only this crate's directory is replaced.

use std::fmt;

/// Error type matching the real crate's `xla::Error` surface (Display).
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT is unavailable: this build uses the offline xla stub \
         (vendor/xla); use the native backend, or vendor the real \
         xla_extension bindings to run AOT artifacts"
            .to_string(),
    )
}

/// Element storage of a [`Literal`].
#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal does not hold f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>, Error> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal does not hold i32".to_string())),
        }
    }
}

/// A host tensor: element buffer + dims (row-major).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data)
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (stub: never constructible without PJRT).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle returned by `execute` (stub: unreachable).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub: unreachable).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(m.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn pjrt_is_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
    }
}
