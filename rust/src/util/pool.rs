//! A small scoped work-sharing thread pool (rayon is unavailable offline).
//!
//! The aggregation operators (§4) use 2D dynamic parallelism: work items
//! are (destination-block × feature-block) tiles pulled from a shared
//! atomic counter, which gives the dynamic load balancing the paper gets
//! from its FLOPS-based scheduler. On this single-core container the pool
//! degrades gracefully to sequential execution (`threads = 1`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: respects
/// `SUPERGCN_THREADS`, else available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SUPERGCN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index)` for every index in `0..n_chunks` on `threads`
/// scoped threads, pulling indices dynamically from a shared counter.
///
/// `f` must be `Sync` (called concurrently with distinct indices).
///
/// Scheduling audit (no hot busy-wait anywhere): when `n_chunks <=
/// threads` every worker owns exactly one statically assigned index, so
/// the shared work-stealing counter — and any contention on it — is
/// skipped entirely (the caller thread runs chunk 0 itself instead of
/// idling at the scope join). On the dynamic path, a worker whose
/// `fetch_add` overshoots `n_chunks` exits its loop immediately: the
/// counter is bounded by `n_chunks + threads` and is never spun on.
pub fn parallel_for(threads: usize, n_chunks: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    if n_chunks <= threads {
        // Static one-chunk-per-thread assignment: no shared counter.
        let f = &f;
        std::thread::scope(|scope| {
            for i in 1..n_chunks {
                scope.spawn(move || f(i));
            }
            f(0);
        });
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Split a mutable slice into `n` contiguous chunks and process each on the
/// pool: the safe way to parallelize disjoint row-block writes.
pub fn parallel_chunks_mut<T: Send>(
    threads: usize,
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let n = chunks.len();
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    parallel_for(threads, n, |i| {
        let (idx, chunk) = slots[i].lock().unwrap().take().expect("chunk taken twice");
        f(idx, chunk);
    });
}

/// Map `0..n` in parallel, collecting results in order.
pub fn parallel_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(threads, n, |i| {
            let v = f(i);
            **slots[i].lock().unwrap() = Some(v);
        });
    }
    out.into_iter().map(|x| x.expect("slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(4, n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_sequential_fallback() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 100, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn chunks_mut_writes_disjointly() {
        let mut v = vec![0u32; 103];
        parallel_chunks_mut(4, &mut v, 10, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u32 + 1;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, (i / 10) as u32 + 1);
        }
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(3, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn static_path_covers_all_indices_once() {
        // n_chunks <= threads takes the counter-free static assignment;
        // coverage must be identical to the dynamic path.
        for n in [2usize, 3, 7, 8] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_for(8, n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}: every index exactly once"
            );
        }
    }

    #[test]
    fn zero_chunks_ok() {
        parallel_for(4, 0, |_| panic!("should not be called"));
        let out: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(out.is_empty());
    }
}
