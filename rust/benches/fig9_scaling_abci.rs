//! Fig. 9: performance + strong scaling vs the DistGNN-like baseline on
//! the ABCI profile (Xeon + InfiniBand EDR).
//!
//! Baseline = DistGNN analogue: pre-aggregation-only remote graphs +
//! delayed halo exchange (cd-5), FP32. SuperGCN = MVC hybrid + Int2 + LP,
//! synchronous.
//!
//! Expected shape (paper): SuperGCN speedup 0.9–6.0×, growing with P as
//! communication becomes the bottleneck.

use supergcn::coordinator::trainer::TrainConfig;
use supergcn::datasets;
use supergcn::exp::{steady_epoch_secs, train_native, Table};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::perfmodel::MachineProfile;
use supergcn::quant::Bits;

fn main() {
    let epochs = 6;
    for name in ["reddit-s", "products-s", "proteins-s"] {
        let spec = datasets::by_name(name).unwrap();
        let mut t = Table::new(
            &format!("Fig 9: {} on ABCI profile (modeled epoch seconds)", name),
            &["procs", "DistGNN(cd-5)", "SuperGCN", "speedup"],
        );
        let mut prev_speedup = 0.0f64;
        for k in [4usize, 8, 16, 32] {
            let distgnn = TrainConfig {
                strategy: RemoteStrategy::PreOnly,
                delay_comm: 5,
                quant: None,
                machine: MachineProfile::abci(),
                ..Default::default()
            };
            let supergcn = TrainConfig {
                strategy: RemoteStrategy::Hybrid,
                quant: Some(Bits::Int2),
                label_prop: true,
                machine: MachineProfile::abci(),
                ..Default::default()
            };
            let (s0, _) = train_native(&spec, k, distgnn, Some(epochs)).unwrap();
            let (s1, _) = train_native(&spec, k, supergcn, Some(epochs)).unwrap();
            // DistGNN amortizes comm over cd epochs — average includes
            // both exchange and silent epochs, like the paper measures.
            let t0 = s0.iter().map(|s| s.modeled_secs).sum::<f64>() / s0.len() as f64;
            let t1 = steady_epoch_secs(&s1, epochs);
            let sp = t0 / t1;
            t.row(vec![
                k.to_string(),
                format!("{t0:.4}"),
                format!("{t1:.4}"),
                format!("{sp:.2}x"),
            ]);
            prev_speedup = sp;
        }
        t.print();
        let _ = prev_speedup;
    }
    println!(
        "\n(per-worker compute measured on this core; wire time from the Eqn-2/5 \
         ABCI model — see DESIGN.md §1)"
    );
}
