//! The degenerate sampler: one batch per epoch containing the whole
//! graph with exact mean-aggregation weights. It exists so the
//! mini-batch engine can run the full-batch regime through the *same*
//! fetch/compute/accounting path — the apples-to-apples baseline the
//! `sampling_regimes` bench and the comm-volume acceptance test compare
//! against.

use super::minibatch::{mean_edge_weights, MiniBatch};
use super::Sampler;
use crate::graph::generate::LabelledGraph;
use std::sync::Arc;

pub struct FullSampler {
    /// Built once — the batch never changes across epochs.
    batch: MiniBatch,
}

impl FullSampler {
    pub fn new(lg: Arc<LabelledGraph>) -> Self {
        let n = lg.n();
        let adj = lg.graph.clone();
        let edge_weight = mean_edge_weights(&adj);
        Self {
            batch: MiniBatch {
                sampler: "full",
                n_id: (0..n as u32).collect(),
                n_target: n,
                node_weight: vec![1.0; n],
                adj,
                edge_weight,
            },
        }
    }
}

impl Sampler for FullSampler {
    fn name(&self) -> &'static str {
        "full"
    }

    fn batches_per_epoch(&self) -> usize {
        1
    }

    fn sample(&mut self, _epoch: usize, _batch: usize) -> MiniBatch {
        self.batch.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;

    #[test]
    fn full_batch_is_the_whole_graph() {
        let lg = Arc::new(sbm(200, 3, 6.0, 0.8, 8, 0.5, 5));
        let mut s = FullSampler::new(lg.clone());
        assert_eq!(s.batches_per_epoch(), 1);
        let mb = s.sample(7, 0);
        mb.validate(200).unwrap();
        assert_eq!(mb.n(), 200);
        assert_eq!(mb.n_target, 200);
        assert_eq!(mb.adj, lg.graph);
        // Identical across epochs.
        assert_eq!(s.sample(8, 0).adj, mb.adj);
    }
}
