//! Preprocessing: build per-worker padded training contexts from the
//! dataset, the partition, and the hierarchical-aggregation plans.
//!
//! Everything runtime-shaped is decided here, once: padded index arrays
//! (with the zero-row / trash-row conventions of DESIGN.md §4), the
//! Pallas block planning, per-peer slice ranges into the flat send/recv
//! buffers, and the degree vector for mean aggregation.

use crate::backend::{LayerSpec, SegSpec};
use crate::comm::transport::Topology;
use crate::graph::generate::{LabelledGraph, SPLIT_TEST, SPLIT_TRAIN, SPLIT_VAL};
use crate::graph::store::GraphStore;
use crate::hier::plan::WorkerPlan;
use crate::runtime::ShapeConfig;
use anyhow::{Context, Result};

/// Node-data access for context building: `build_one` fills features,
/// labels, and masks through this, so the same padding/layout code runs
/// against the global in-memory graph, the mmap-backed store, and a
/// per-rank shard file (which only holds *local* rows). Each lookup gets
/// both coordinates of a node — its local index `i` in
/// `plan.local_nodes` and its global id `v` — and a backend uses
/// whichever one indexes its storage (DESIGN.md §17).
pub trait NodeSource {
    fn feat_dim(&self) -> usize;
    fn num_classes(&self) -> usize;
    fn feature_row(&self, i: usize, v: u32) -> &[f32];
    fn label(&self, i: usize, v: u32) -> u32;
    fn split(&self, i: usize, v: u32) -> u8;
}

impl NodeSource for LabelledGraph {
    fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn feature_row(&self, _i: usize, v: u32) -> &[f32] {
        LabelledGraph::feature_row(self, v as usize)
    }

    fn label(&self, _i: usize, v: u32) -> u32 {
        self.labels[v as usize]
    }

    fn split(&self, _i: usize, v: u32) -> u8 {
        self.split[v as usize]
    }
}

impl NodeSource for GraphStore {
    fn feat_dim(&self) -> usize {
        GraphStore::feat_dim(self)
    }

    fn num_classes(&self) -> usize {
        GraphStore::num_classes(self)
    }

    fn feature_row(&self, _i: usize, v: u32) -> &[f32] {
        GraphStore::feature_row(self, v as usize)
    }

    fn label(&self, _i: usize, v: u32) -> u32 {
        GraphStore::label(self, v as usize)
    }

    fn split(&self, _i: usize, v: u32) -> u8 {
        GraphStore::split_of(self, v as usize)
    }
}

/// The Pallas edge block; padded index arrays are multiples of this.
pub const EB: usize = 128;

fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m).max(1) * m
}

/// Everything one worker carries through training.
#[derive(Clone, Debug)]
pub struct WorkerCtx {
    pub worker: usize,
    pub n_real: usize,
    pub local_nodes: Vec<u32>,
    /// Send-side pre-aggregation (peers concatenated; n_seg = p_pre,
    /// trash segment last).
    pub pre: SegSpec,
    /// Per peer: segment range `[lo, hi)` of its partials inside the
    /// partials buffer.
    pub send_pre_range: Vec<(usize, usize)>,
    /// Per peer: local rows whose (normalized) features ship raw.
    pub send_post_rows: Vec<Vec<u32>>,
    /// Per peer: row range inside the recv_pre buffer.
    pub recv_pre_range: Vec<(usize, usize)>,
    /// Per peer: row range inside the recv_post buffer (last row of the
    /// buffer is the reserved zero row).
    pub recv_post_range: Vec<(usize, usize)>,
    /// Shared per-layer topology (identical for all three layers).
    pub spec: LayerSpec,
    /// Interior rows (no remote in-edge contributions): the subset of
    /// `0..n_pad` whose aggregation can run before the halo exchange
    /// completes, strictly increasing. Identical for all three layers
    /// (the remote topology is layer-invariant). DESIGN.md §11.
    pub interior_rows: Vec<u32>,
    /// Boundary rows (targets of `rpre_dst`/`post_dst` scatters, incl.
    /// the trash-row pads): complement of `interior_rows` in `0..n_pad`.
    pub boundary_rows: Vec<u32>,
    /// CSR-style run offsets of `spec.local.seg` (len `n_pad + 1`), for
    /// subset-restricted aggregation without materializing a sub-CSR.
    pub local_offsets: Vec<usize>,
    /// Padded features (n_pad × f_in), labels and masks.
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
    pub labels_i32: Vec<i32>,
    pub train_mask: Vec<bool>,
    pub train_mask_f: Vec<f32>,
    pub val_mask: Vec<f32>,
    pub test_mask: Vec<f32>,
}

impl WorkerCtx {
    /// Rows this worker sends per layer (pre partials + post rows).
    pub fn send_rows(&self, peer: usize) -> usize {
        (self.send_pre_range[peer].1 - self.send_pre_range[peer].0)
            + self.send_post_rows[peer].len()
    }
}

/// Per-destination-group coalescing map of the two-level transport
/// (DESIGN.md §12): `out[g]` = feature rows this worker ships into group
/// `g` per layer — the buffer its group leader stages into one inter-node
/// message. A *reporting/modeling* view derived once from the static halo
/// plans (like `interior_split` they are layer-invariant): the
/// per-exchange tier accounting itself (`CommStats::charge_row_tiers`)
/// is pure arithmetic over the payloads and never consults this map —
/// that is what keeps the hot path allocation-free. Used by
/// `benches/spmd_scaling.rs` to report the leader-staged row volume. The
/// worker's own traffic to same-group peers is included (it rides the
/// intra tier); self-rows are zero by construction.
pub fn group_send_rows(ctx: &WorkerCtx, topo: Topology) -> Vec<usize> {
    let mut out = vec![0usize; topo.n_groups()];
    for peer in 0..ctx.send_pre_range.len() {
        if peer == ctx.worker {
            continue;
        }
        out[topo.group_of(peer)] += ctx.send_rows(peer);
    }
    out
}

/// Compute the smallest [`ShapeConfig`] that fits `plans` (used by the
/// native engine, which has no static-shape constraint from artifacts).
pub fn fit_config(
    name: &str,
    f_in: usize,
    hidden: usize,
    classes: usize,
    plans: &[WorkerPlan],
) -> ShapeConfig {
    let mut n_local = 1;
    let mut e_local = 1;
    let mut e_pre = 1;
    let mut p_pre = 1;
    let mut r_pre = 1;
    let mut r_post = 1;
    let mut e_post = 1;
    for p in plans {
        n_local = n_local.max(p.n_local());
        e_local = e_local.max(p.local_edges.len());
        e_pre = e_pre.max(p.sends.iter().map(|s| s.pre_gather.len()).sum::<usize>());
        p_pre = p_pre.max(p.sends.iter().map(|s| s.n_pre_segments).sum::<usize>());
        r_pre = r_pre.max(p.recvs.iter().map(|r| r.pre_dst.len()).sum::<usize>());
        r_post = r_post.max(p.recvs.iter().map(|r| r.n_post_rows).sum::<usize>());
        e_post = e_post.max(p.recvs.iter().map(|r| r.post_edges.len()).sum::<usize>());
    }
    ShapeConfig {
        name: name.to_string(),
        n_pad: round_up(n_local + 2, EB),
        f_in,
        hidden,
        classes,
        e_local: round_up(e_local, EB),
        e_pre: round_up(e_pre, EB),
        p_pre: p_pre + 1,     // + trash segment
        r_pre: r_pre.max(1),
        r_post: r_post + 1,   // + reserved zero row
        e_post: e_post.max(1),
    }
}

/// Check a manifest config can host these plans.
pub fn check_fits(cfg: &ShapeConfig, plans: &[WorkerPlan]) -> Result<()> {
    let need = fit_config(&cfg.name, cfg.f_in, cfg.hidden, cfg.classes, plans);
    let checks = [
        ("n_pad", need.n_pad, cfg.n_pad),
        ("e_local", need.e_local, cfg.e_local),
        ("e_pre", need.e_pre, cfg.e_pre),
        ("p_pre", need.p_pre, cfg.p_pre),
        ("r_pre", need.r_pre, cfg.r_pre),
        ("r_post", need.r_post, cfg.r_post),
        ("e_post", need.e_post, cfg.e_post),
    ];
    for (what, needed, have) in checks {
        anyhow::ensure!(
            needed <= have,
            "config '{}' too small: {what} needs {needed}, artifact has {have} \
             (regenerate artifacts with a larger config or use a smaller dataset)",
            cfg.name
        );
    }
    Ok(())
}

/// Build all worker contexts from the in-memory graph.
pub fn build_worker_ctxs(
    lg: &LabelledGraph,
    plans: &[WorkerPlan],
    cfg: &ShapeConfig,
) -> Result<Vec<WorkerCtx>> {
    build_worker_ctxs_src(lg, plans, cfg)
}

/// Build all worker contexts from any [`NodeSource`] — the in-memory
/// graph, the mmap-backed store, and (via [`build_one`]) per-rank shard
/// files all produce bit-identical contexts for identical plans.
pub fn build_worker_ctxs_src<S: NodeSource + ?Sized>(
    src: &S,
    plans: &[WorkerPlan],
    cfg: &ShapeConfig,
) -> Result<Vec<WorkerCtx>> {
    check_fits(cfg, plans)?;
    anyhow::ensure!(src.feat_dim() == cfg.f_in, "feature dim mismatch");
    anyhow::ensure!(src.num_classes() <= cfg.classes, "class count exceeds config");
    plans
        .iter()
        .map(|p| build_one(src, p, cfg))
        .collect::<Result<Vec<_>>>()
}

/// Build one worker's padded context from its plan, filling node data
/// through the [`NodeSource`].
pub fn build_one<S: NodeSource + ?Sized>(
    src: &S,
    plan: &WorkerPlan,
    cfg: &ShapeConfig,
) -> Result<WorkerCtx> {
    let n_pad = cfg.n_pad;
    let zero = cfg.zero_row() as u32;
    let trash = cfg.trash_row() as u32;
    let n_real = plan.n_local();
    let k = plan.sends.len();

    // ---- local aggregation spec (edges already sorted by dst) ----------
    let mut lg_gather: Vec<u32> = plan.local_edges.iter().map(|e| e.0).collect();
    let mut lg_seg: Vec<u32> = plan.local_edges.iter().map(|e| e.1).collect();
    pad_to(&mut lg_gather, cfg.e_local, zero);
    pad_to(&mut lg_seg, cfg.e_local, trash);
    let local = SegSpec::new(lg_gather, lg_seg, n_pad, EB);

    // Transposed local edges (sorted by src) for the native backward.
    let mut t_edges: Vec<(u32, u32)> = plan.local_edges.iter().map(|&(s, d)| (d, s)).collect();
    t_edges.sort_unstable_by_key(|&(_, s)| s);
    let mut lt_gather: Vec<u32> = t_edges.iter().map(|e| e.0).collect();
    let mut lt_seg: Vec<u32> = t_edges.iter().map(|e| e.1).collect();
    pad_to(&mut lt_gather, cfg.e_local, zero);
    pad_to(&mut lt_seg, cfg.e_local, trash);
    let local_t = SegSpec::new(lt_gather, lt_seg, n_pad, EB);

    // ---- send-side pre aggregation --------------------------------------
    let mut pre_gather = Vec::new();
    let mut pre_seg = Vec::new();
    let mut send_pre_range = Vec::with_capacity(k);
    let mut seg_off = 0usize;
    for sp in &plan.sends {
        pre_gather.extend_from_slice(&sp.pre_gather);
        pre_seg.extend(sp.pre_seg.iter().map(|&s| s + seg_off as u32));
        send_pre_range.push((seg_off, seg_off + sp.n_pre_segments));
        seg_off += sp.n_pre_segments;
    }
    anyhow::ensure!(seg_off < cfg.p_pre, "pre segments overflow");
    pad_to(&mut pre_gather, cfg.e_pre, zero);
    pad_to(&mut pre_seg, cfg.e_pre, (cfg.p_pre - 1) as u32);
    let pre = SegSpec::new(pre_gather, pre_seg, cfg.p_pre, EB);

    let send_post_rows: Vec<Vec<u32>> = plan.sends.iter().map(|s| s.post_rows.clone()).collect();

    // ---- receive side ----------------------------------------------------
    let mut rpre_dst = Vec::new();
    let mut recv_pre_range = Vec::with_capacity(k);
    for rp in &plan.recvs {
        let lo = rpre_dst.len();
        rpre_dst.extend_from_slice(&rp.pre_dst);
        recv_pre_range.push((lo, rpre_dst.len()));
    }
    anyhow::ensure!(rpre_dst.len() <= cfg.r_pre, "recv_pre overflow");
    rpre_dst.resize(cfg.r_pre, trash);

    let zero_recv_row = (cfg.r_post - 1) as u32;
    let mut post_row = Vec::new();
    let mut post_dst = Vec::new();
    let mut recv_post_range = Vec::with_capacity(k);
    let mut row_off = 0usize;
    for rp in &plan.recvs {
        recv_post_range.push((row_off, row_off + rp.n_post_rows));
        for &(r, d) in &rp.post_edges {
            post_row.push(r + row_off as u32);
            post_dst.push(d);
        }
        row_off += rp.n_post_rows;
    }
    anyhow::ensure!(row_off < cfg.r_post, "recv_post overflow");
    anyhow::ensure!(post_row.len() <= cfg.e_post, "post edges overflow");
    pad_to(&mut post_row, cfg.e_post, zero_recv_row);
    pad_to(&mut post_dst, cfg.e_post, trash);

    // Transposed post edges (grouped by received row) for native backward:
    // d_recv_post[row] += dz[dst]. Pads scatter into the reserved zero row.
    let mut pt: Vec<(u32, u32)> = post_dst.iter().zip(post_row.iter()).map(|(&d, &r)| (d, r)).collect();
    pt.sort_unstable_by_key(|&(_, r)| r);
    let pt_gather: Vec<u32> = pt.iter().map(|e| e.0).collect();
    let pt_seg: Vec<u32> = pt.iter().map(|e| e.1).collect();
    // post arrays may not be EB multiples — pad both to EB for SegSpec.
    let e_post_pad = round_up(cfg.e_post, EB);
    let mut pt_gather = pt_gather;
    let mut pt_seg = pt_seg;
    pad_to(&mut pt_gather, e_post_pad, zero);
    pad_to(&mut pt_seg, e_post_pad, zero_recv_row);
    // Re-sort after padding (pads carry the max seg only if zero_recv_row
    // is the max — it is, by construction).
    let post_t = SegSpec::new(pt_gather, pt_seg, cfg.r_post, EB);

    // ---- degrees ----------------------------------------------------------
    let mut deg_inv = vec![0f32; n_pad];
    for (i, &d) in plan.degrees.iter().enumerate() {
        if d > 0 {
            deg_inv[i] = 1.0 / d as f32;
        }
    }

    // ---- features / labels / masks ---------------------------------------
    let f = src.feat_dim();
    let mut features = vec![0f32; n_pad * f];
    let mut labels = vec![0u32; n_pad];
    let mut train_mask = vec![false; n_pad];
    let mut train_mask_f = vec![0f32; n_pad];
    let mut val_mask = vec![0f32; n_pad];
    let mut test_mask = vec![0f32; n_pad];
    for (i, &v) in plan.local_nodes.iter().enumerate() {
        features[i * f..(i + 1) * f].copy_from_slice(src.feature_row(i, v));
        labels[i] = src.label(i, v);
        match src.split(i, v) {
            SPLIT_TRAIN => {
                train_mask[i] = true;
                train_mask_f[i] = 1.0;
            }
            SPLIT_VAL => val_mask[i] = 1.0,
            SPLIT_TEST => test_mask[i] = 1.0,
            _ => {}
        }
    }
    let labels_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();

    let spec = LayerSpec {
        local,
        local_t,
        rpre_dst_i32: rpre_dst.iter().map(|&x| x as i32).collect(),
        rpre_dst,
        post_row_i32: post_row.iter().map(|&x| x as i32).collect(),
        post_row,
        post_dst_i32: post_dst.iter().map(|&x| x as i32).collect(),
        post_dst,
        post_t,
        deg_inv,
    };

    // ---- interior/boundary split (overlap schedule, DESIGN.md §11) ------
    // Boundary = every destination the halo scatters touch, *including*
    // the trash-row pads of rpre_dst/post_dst: the boundary phase then
    // replays the full scatter loops verbatim, so blocking and overlap
    // accumulate identically per destination. Derived from the plans
    // (i.e. from `hier::remote_pairs`); identical across the 3 layers.
    let mut is_boundary = vec![false; n_pad];
    for &d in &spec.rpre_dst {
        is_boundary[d as usize] = true;
    }
    for &d in &spec.post_dst {
        is_boundary[d as usize] = true;
    }
    let (interior_rows, boundary_rows) = crate::partition::interior_split(&is_boundary);
    let local_offsets = crate::agg::blocked::segment_offsets(&spec.local.seg, n_pad);

    Ok(WorkerCtx {
        worker: plan.worker,
        n_real,
        local_nodes: plan.local_nodes.clone(),
        pre,
        send_pre_range,
        send_post_rows,
        recv_pre_range,
        recv_post_range,
        spec,
        interior_rows,
        boundary_rows,
        local_offsets,
        features,
        labels,
        labels_i32,
        train_mask,
        train_mask_f,
        val_mask,
        test_mask,
    })
}

fn pad_to(v: &mut Vec<u32>, len: usize, fill: u32) {
    assert!(v.len() <= len, "buffer {} exceeds padded length {}", v.len(), len);
    v.resize(len, fill);
}

/// The worker partition every trainer runs over: multilevel min-cut with
/// the §7.2 in-degree + train-mask vertex weights. Shared by [`prepare`]
/// (full-batch) and `MiniBatchTrainer::new` so both regimes — and the
/// tests comparing them — agree on the partitioning by construction.
pub fn partition_for(lg: &LabelledGraph, k: usize, seed: u64) -> crate::partition::Partition {
    use crate::partition::multilevel::{multilevel, MultilevelOpts};
    let mask: Vec<bool> = lg.split.iter().map(|&s| s == SPLIT_TRAIN).collect();
    let weights = crate::partition::vertex_weights(&lg.graph, Some(&mask), 4);
    let opts = MultilevelOpts {
        seed,
        ..Default::default()
    };
    multilevel(&lg.graph, k, &weights, &opts)
}

/// Full preprocessing pipeline: partition → plans → contexts, with the
/// in-degree + train-mask vertex weights of §7.2.
pub fn prepare(
    lg: &LabelledGraph,
    k: usize,
    strategy: crate::hier::volume::RemoteStrategy,
    cfg: Option<ShapeConfig>,
    seed: u64,
) -> Result<(Vec<WorkerCtx>, ShapeConfig, Vec<WorkerPlan>)> {
    let part = partition_for(lg, k, seed);
    prepare_parts(lg, &part, strategy, cfg, 64)
}

/// [`prepare`] from an existing partition: plans → contexts. This is the
/// entry the elastic recovery path reuses after [`survivor_partition`]
/// shrinks the worker set (DESIGN.md §15); `hidden` only matters when
/// `cfg` is `None` and a fit config is derived.
pub fn prepare_parts(
    lg: &LabelledGraph,
    part: &crate::partition::Partition,
    strategy: crate::hier::volume::RemoteStrategy,
    cfg: Option<ShapeConfig>,
    hidden: usize,
) -> Result<(Vec<WorkerCtx>, ShapeConfig, Vec<WorkerPlan>)> {
    let plans = crate::hier::plan::build_plans(&lg.graph, part, strategy);
    crate::hier::plan::validate_plans(&lg.graph, part, &plans).context("plan validation")?;
    let cfg = match cfg {
        Some(c) => c,
        None => fit_config("fit", lg.feat_dim, hidden, lg.num_classes, &plans),
    };
    let ctxs = build_worker_ctxs(lg, &plans, &cfg)?;
    Ok((ctxs, cfg, plans))
}

/// Streaming block partition over a store (DESIGN.md §17): contiguous id
/// ranges cut at weight quantiles, with the §7.2 vertex weights
/// (`1 + in_degree + 4·is_train`). This is exactly `partition::block`
/// over `partition::vertex_weights(g, Some(train_mask), 4)` — pinned
/// equal in tests — but computed in two bounded-memory scans instead of
/// materializing the weight vector. The multilevel partitioner needs the
/// whole CSR on the heap; this is the partition the out-of-core path
/// (`supergcn prepare` / `train --graph-dir`) plans with, on both
/// backends, so mmap and in-memory training see identical partitions.
pub fn block_partition(store: &GraphStore, k: usize) -> crate::partition::Partition {
    let n = store.n();
    let node_weight = |v: usize| -> u64 {
        let bonus = if store.split_of(v) == SPLIT_TRAIN { 4 } else { 0 };
        1 + store.in_degree(v) as u64 + bonus
    };
    let mut total = 0u64;
    for v in 0..n {
        total += node_weight(v);
    }
    let mut assign = vec![0u32; n];
    let mut acc = 0u64;
    let mut p = 0u32;
    for (v, slot) in assign.iter_mut().enumerate() {
        while (p as usize) + 1 < k && acc * k as u64 >= total * (p as u64 + 1) {
            p += 1;
        }
        *slot = p;
        acc += node_weight(v);
    }
    crate::partition::Partition { k, assign }
}

/// [`prepare_parts`] over a [`GraphStore`]: plans → contexts without
/// assuming a heap CSR. With a `Mem` backend this is bit-identical to
/// [`prepare_parts`] on the same partition (the generic planning code is
/// literally the same); with the mmap backend it is the out-of-core
/// planning path.
pub fn prepare_store(
    store: &GraphStore,
    part: &crate::partition::Partition,
    strategy: crate::hier::volume::RemoteStrategy,
    cfg: Option<ShapeConfig>,
    hidden: usize,
) -> Result<(Vec<WorkerCtx>, ShapeConfig, Vec<WorkerPlan>)> {
    let plans = crate::hier::plan::build_plans(store, part, strategy);
    crate::hier::plan::validate_plans(store, part, &plans).context("plan validation")?;
    let cfg = match cfg {
        Some(c) => c,
        None => fit_config("fit", store.feat_dim(), hidden, store.num_classes(), &plans),
    };
    let ctxs = build_worker_ctxs_src(store, &plans, &cfg)?;
    Ok((ctxs, cfg, plans))
}

/// Elastic re-plan after a rank failure (DESIGN.md §15): drop rank
/// `failed` from `part`, renumber the survivors densely (ranks above the
/// failed one shift down by one, so surviving shards keep their node
/// sets), and redistribute every node of the failed shard to the survivor
/// owning the most of its in-neighbors — the same locality objective the
/// multilevel partitioner optimizes. Fully deterministic: ties go to the
/// lowest survivor rank, and nodes with no surviving neighbor owner are
/// dealt round-robin across survivors in node order.
pub fn survivor_partition(
    g: &crate::graph::CsrGraph,
    part: &crate::partition::Partition,
    failed: usize,
) -> Result<crate::partition::Partition> {
    anyhow::ensure!(
        part.k >= 2,
        "cannot re-plan around rank {failed}: no survivors in a {}-way partition",
        part.k
    );
    anyhow::ensure!(failed < part.k, "failed rank {failed} out of range (k={})", part.k);
    let k2 = part.k - 1;
    let remap = |p: u32| if (p as usize) > failed { p - 1 } else { p };
    let mut assign = vec![0u32; part.assign.len()];
    let mut rr = 0usize;
    let mut votes = vec![0usize; part.k];
    for (v, a) in assign.iter_mut().enumerate() {
        let owner = part.assign[v] as usize;
        if owner != failed {
            *a = remap(part.assign[v]);
            continue;
        }
        votes.iter_mut().for_each(|c| *c = 0);
        for &u in g.in_neighbors(v) {
            votes[part.assign[u as usize] as usize] += 1;
        }
        let mut best = (usize::MAX, 0usize);
        for (q, &c) in votes.iter().enumerate() {
            if q != failed && c > best.1 {
                best = (q, c);
            }
        }
        *a = if best.1 > 0 {
            remap(best.0 as u32)
        } else {
            let q = (rr % k2) as u32;
            rr += 1;
            q
        };
    }
    let out = crate::partition::Partition { k: k2, assign };
    out.validate(g.n)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;
    use crate::hier::volume::RemoteStrategy;

    #[test]
    fn fit_and_build_small() {
        let lg = sbm(500, 4, 8.0, 0.85, 16, 0.5, 5);
        let (ctxs, cfg, plans) = prepare(&lg, 3, RemoteStrategy::Hybrid, None, 7).unwrap();
        assert_eq!(ctxs.len(), 3);
        assert_eq!(cfg.n_pad % EB, 0);
        for (ctx, plan) in ctxs.iter().zip(plans.iter()) {
            assert_eq!(ctx.n_real, plan.n_local());
            // Send ranges consistent with plan.
            for (peer, sp) in plan.sends.iter().enumerate() {
                let (lo, hi) = ctx.send_pre_range[peer];
                assert_eq!(hi - lo, sp.n_pre_segments);
                assert_eq!(ctx.send_post_rows[peer].len(), sp.post_rows.len());
            }
            // Spec arrays fully padded.
            assert_eq!(ctx.spec.local.len(), cfg.e_local);
            assert_eq!(ctx.pre.len(), cfg.e_pre);
            assert_eq!(ctx.spec.rpre_dst.len(), cfg.r_pre);
            assert_eq!(ctx.spec.post_row.len(), cfg.e_post);
            // Send/recv rows match pairwise.
            for peer in 0..ctxs.len() {
                let (plo, phi) = ctxs[peer].recv_pre_range[ctx.worker];
                assert_eq!(phi - plo, ctx.send_pre_range[peer].1 - ctx.send_pre_range[peer].0);
                let (qlo, qhi) = ctxs[peer].recv_post_range[ctx.worker];
                assert_eq!(qhi - qlo, ctx.send_post_rows[peer].len());
            }
        }
    }

    #[test]
    fn interior_boundary_split_covers_padded_rows_disjointly() {
        let lg = sbm(500, 4, 8.0, 0.85, 16, 0.5, 5);
        let (ctxs, cfg, _) = prepare(&lg, 3, RemoteStrategy::Hybrid, None, 7).unwrap();
        for ctx in &ctxs {
            // Disjoint, sorted, and jointly covering 0..n_pad.
            assert_eq!(
                ctx.interior_rows.len() + ctx.boundary_rows.len(),
                cfg.n_pad,
                "split must cover every padded row exactly once"
            );
            assert!(ctx.interior_rows.windows(2).all(|w| w[0] < w[1]));
            assert!(ctx.boundary_rows.windows(2).all(|w| w[0] < w[1]));
            let mut seen = vec![false; cfg.n_pad];
            for &r in ctx.interior_rows.iter().chain(ctx.boundary_rows.iter()) {
                assert!(!seen[r as usize], "row {r} in both subsets");
                seen[r as usize] = true;
            }
            // Boundary is exactly the halo-scatter target set.
            let mut want = vec![false; cfg.n_pad];
            for &d in ctx.spec.rpre_dst.iter().chain(ctx.spec.post_dst.iter()) {
                want[d as usize] = true;
            }
            for &r in &ctx.boundary_rows {
                assert!(want[r as usize], "row {r} marked boundary without a scatter");
            }
            for (r, &w) in want.iter().enumerate() {
                if w {
                    assert!(
                        ctx.boundary_rows.binary_search(&(r as u32)).is_ok(),
                        "scatter target {r} missing from boundary set"
                    );
                }
            }
            // With >1 workers and pads targeting trash, both sides exist.
            assert!(!ctx.boundary_rows.is_empty());
            assert!(!ctx.interior_rows.is_empty());
            // Offsets describe spec.local.seg runs.
            assert_eq!(ctx.local_offsets.len(), cfg.n_pad + 1);
            assert_eq!(*ctx.local_offsets.last().unwrap(), ctx.spec.local.seg.len());
        }
    }

    #[test]
    fn group_send_rows_coalesces_per_peer_rows() {
        let lg = sbm(500, 4, 8.0, 0.85, 16, 0.5, 5);
        let (ctxs, _, _) = prepare(&lg, 4, RemoteStrategy::Hybrid, None, 7).unwrap();
        for ctx in &ctxs {
            let total: usize = (0..ctxs.len())
                .filter(|&p| p != ctx.worker)
                .map(|p| ctx.send_rows(p))
                .sum();
            // Flat topology: one singleton group per peer.
            let flat = group_send_rows(ctx, Topology::flat(4));
            assert_eq!(flat.len(), 4);
            assert_eq!(flat[ctx.worker], 0, "no rows to self");
            for (peer, &rows) in flat.iter().enumerate() {
                if peer != ctx.worker {
                    assert_eq!(rows, ctx.send_rows(peer));
                }
            }
            // Two groups of two: per-group sums, conserving the total.
            let grouped = group_send_rows(ctx, Topology::new(4, 2));
            assert_eq!(grouped.len(), 2);
            assert_eq!(grouped.iter().sum::<usize>(), total);
            for (g, &rows) in grouped.iter().enumerate() {
                let want: usize = (g * 2..(g + 1) * 2)
                    .filter(|&p| p != ctx.worker)
                    .map(|p| ctx.send_rows(p))
                    .sum();
                assert_eq!(rows, want);
            }
        }
    }

    #[test]
    fn masks_partition_split() {
        let lg = sbm(400, 4, 6.0, 0.8, 8, 0.5, 9);
        let (ctxs, _, _) = prepare(&lg, 2, RemoteStrategy::Hybrid, None, 3).unwrap();
        let total_train: usize = ctxs
            .iter()
            .map(|c| c.train_mask.iter().filter(|&&t| t).count())
            .sum();
        assert_eq!(total_train, lg.count_split(SPLIT_TRAIN));
        let total_test: f32 = ctxs.iter().map(|c| c.test_mask.iter().sum::<f32>()).sum();
        assert_eq!(total_test as usize, lg.count_split(SPLIT_TEST));
    }

    #[test]
    fn too_small_config_rejected() {
        let lg = sbm(500, 4, 8.0, 0.85, 16, 0.5, 5);
        let (_, fitted, plans) = prepare(&lg, 3, RemoteStrategy::Hybrid, None, 7).unwrap();
        let mut small = fitted.clone();
        small.n_pad = 128;
        assert!(build_worker_ctxs(&lg, &plans, &small).is_err());
    }

    #[test]
    fn survivor_partition_covers_and_renumbers() {
        let lg = sbm(400, 4, 8.0, 0.85, 16, 0.5, 9);
        let part = partition_for(&lg, 4, 42);
        for failed in 0..4 {
            let sp = survivor_partition(&lg.graph, &part, failed).unwrap();
            assert_eq!(sp.k, 3);
            sp.validate(lg.n()).unwrap();
            // Surviving shards keep their nodes (renumbered densely).
            for v in 0..lg.n() {
                let owner = part.assign[v] as usize;
                if owner != failed {
                    let expect = if owner > failed { owner - 1 } else { owner };
                    assert_eq!(sp.assign[v] as usize, expect, "node {v} moved off survivor");
                }
            }
            // Deterministic: a second call is identical.
            let sp2 = survivor_partition(&lg.graph, &part, failed).unwrap();
            assert_eq!(sp.assign, sp2.assign);
            // The survivor plan must still validate end to end.
            let (ctxs, _, _) =
                prepare_parts(&lg, &sp, RemoteStrategy::Hybrid, None, 64).unwrap();
            assert_eq!(ctxs.len(), 3);
        }
        assert!(survivor_partition(&lg.graph, &part, 4).is_err());
        let one = crate::partition::Partition { k: 1, assign: vec![0; lg.n()] };
        assert!(survivor_partition(&lg.graph, &one, 0).is_err());
    }

    #[test]
    fn block_partition_matches_materialized_block() {
        let lg = sbm(500, 4, 8.0, 0.85, 16, 0.5, 5);
        let mask: Vec<bool> = lg.split.iter().map(|&s| s == SPLIT_TRAIN).collect();
        let weights = crate::partition::vertex_weights(&lg.graph, Some(&mask), 4);
        let want = crate::partition::block(lg.n(), 3, &weights);
        let store = GraphStore::from(lg);
        let got = block_partition(&store, 3);
        assert_eq!(got.assign, want.assign);
        got.validate(store.n()).unwrap();
    }

    #[test]
    fn prepare_store_matches_prepare_parts_bitwise() {
        let lg = sbm(400, 4, 7.0, 0.8, 12, 0.5, 21);
        let lg2 = lg.clone();
        let store = GraphStore::from(lg2);
        let part = block_partition(&store, 3);
        let (ctxs_a, cfg_a, plans_a) =
            prepare_parts(&lg, &part, RemoteStrategy::Hybrid, None, 64).unwrap();
        let (ctxs_b, cfg_b, plans_b) =
            prepare_store(&store, &part, RemoteStrategy::Hybrid, None, 64).unwrap();
        assert_eq!(cfg_a.n_pad, cfg_b.n_pad);
        assert_eq!(cfg_a.e_local, cfg_b.e_local);
        assert_eq!(plans_a.len(), plans_b.len());
        for (a, b) in plans_a.iter().zip(plans_b.iter()) {
            assert_eq!(a.local_nodes, b.local_nodes);
            assert_eq!(a.local_edges, b.local_edges);
            assert_eq!(a.degrees, b.degrees);
        }
        for (a, b) in ctxs_a.iter().zip(ctxs_b.iter()) {
            assert_eq!(a.features, b.features);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.train_mask_f, b.train_mask_f);
            assert_eq!(a.spec.local.gather, b.spec.local.gather);
        }
    }

    #[test]
    fn degrees_match_global_graph() {
        let lg = sbm(300, 3, 6.0, 0.8, 8, 0.5, 2);
        let (ctxs, _, _) = prepare(&lg, 2, RemoteStrategy::PostOnly, None, 1).unwrap();
        for ctx in &ctxs {
            for (i, &v) in ctx.local_nodes.iter().enumerate() {
                let d = lg.graph.in_degree(v as usize);
                if d > 0 {
                    assert!((ctx.spec.deg_inv[i] - 1.0 / d as f32).abs() < 1e-7);
                } else {
                    assert_eq!(ctx.spec.deg_inv[i], 0.0);
                }
            }
        }
    }
}
