//! Multilevel k-way min-cut partitioner (METIS-family; DESIGN.md §1).
//!
//! Pipeline: (1) **coarsen** by heavy-edge matching until the graph is
//! small, accumulating vertex and edge weights; (2) **initial partition**
//! of the coarsest graph by weighted greedy graph growing (BFS frontier,
//! best-gain expansion); (3) **uncoarsen** and refine at every level with
//! a bounded Fiduccia–Mattheyses pass over boundary vertices.
//!
//! Objective: minimize total cut edge weight subject to
//! `max part weight ≤ (1+ε)·avg`.

use super::Partition;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// Internal weighted undirected adjacency used across levels.
struct WGraph {
    n: usize,
    /// CSR over undirected weighted edges.
    xadj: Vec<usize>,
    adj: Vec<u32>,
    ewt: Vec<u64>,
    vwt: Vec<u64>,
}

impl WGraph {
    fn neighbors(&self, v: usize) -> impl Iterator<Item = (u32, u64)> + '_ {
        (self.xadj[v]..self.xadj[v + 1]).map(move |i| (self.adj[i], self.ewt[i]))
    }

    fn total_vwt(&self) -> u64 {
        self.vwt.iter().sum()
    }

    /// Build from a directed CsrGraph: symmetrize, merge parallel edges
    /// into weights.
    fn from_csr(g: &CsrGraph, vwt: Vec<u64>) -> WGraph {
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(g.m() * 2);
        for (s, d) in g.edges() {
            if s != d {
                pairs.push((s.min(d), s.max(d)));
            }
        }
        pairs.sort_unstable();
        pairs.dedup(); // treat multi-arcs as weight-1 undirected edges
        build_wgraph(g.n, &pairs, &[], vwt)
    }
}

/// Build an undirected weighted CSR from unique (u<v) pairs; `wts` parallel
/// to pairs or empty (=1).
fn build_wgraph(n: usize, pairs: &[(u32, u32)], wts: &[u64], vwt: Vec<u64>) -> WGraph {
    let mut deg = vec![0usize; n];
    for &(u, v) in pairs {
        deg[u as usize] += 1;
        deg[v as usize] += 1;
    }
    let mut xadj = vec![0usize; n + 1];
    for v in 0..n {
        xadj[v + 1] = xadj[v] + deg[v];
    }
    let mut cursor = xadj.clone();
    let mut adj = vec![0u32; pairs.len() * 2];
    let mut ewt = vec![0u64; pairs.len() * 2];
    for (i, &(u, v)) in pairs.iter().enumerate() {
        let w = wts.get(i).copied().unwrap_or(1);
        let cu = &mut cursor[u as usize];
        adj[*cu] = v;
        ewt[*cu] = w;
        *cu += 1;
        let cv = &mut cursor[v as usize];
        adj[*cv] = u;
        ewt[*cv] = w;
        *cv += 1;
    }
    WGraph { n, xadj, adj, ewt, vwt }
}

/// One coarsening step: heavy-edge matching, preferring the heaviest
/// incident edge for each unmatched vertex (visited in random order).
/// Returns (coarse graph, map fine→coarse).
fn coarsen(g: &WGraph, rng: &mut Rng) -> (WGraph, Vec<u32>) {
    let n = g.n;
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; n];
    for &v in &order {
        if mate[v] != u32::MAX {
            continue;
        }
        let mut best = u32::MAX;
        let mut best_w = 0u64;
        for (u, w) in g.neighbors(v) {
            if mate[u as usize] == u32::MAX && u as usize != v && w > best_w {
                best = u;
                best_w = w;
            }
        }
        if best != u32::MAX {
            mate[v] = best;
            mate[best as usize] = v as u32;
        } else {
            mate[v] = v as u32; // self-matched
        }
    }
    // Assign coarse ids.
    let mut cmap = vec![u32::MAX; n];
    let mut nc = 0u32;
    for v in 0..n {
        if cmap[v] == u32::MAX {
            cmap[v] = nc;
            let m = mate[v] as usize;
            if m != v {
                cmap[m] = nc;
            }
            nc += 1;
        }
    }
    // Coarse vertex weights.
    let mut cvwt = vec![0u64; nc as usize];
    for v in 0..n {
        cvwt[cmap[v] as usize] += g.vwt[v];
    }
    // Coarse edges: merge by (min,max) pair.
    let mut emap: std::collections::HashMap<(u32, u32), u64> = std::collections::HashMap::new();
    for v in 0..n {
        let cv = cmap[v];
        for (u, w) in g.neighbors(v) {
            let cu = cmap[u as usize];
            if cu != cv && v < u as usize {
                let key = (cv.min(cu), cv.max(cu));
                *emap.entry(key).or_insert(0) += w;
            }
        }
    }
    let mut pairs: Vec<(u32, u32)> = emap.keys().copied().collect();
    pairs.sort_unstable();
    let wts: Vec<u64> = pairs.iter().map(|p| emap[p]).collect();
    (build_wgraph(nc as usize, &pairs, &wts, cvwt), cmap)
}

/// Greedy graph growing k-way initial partition on the coarsest graph.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.n;
    let total = g.total_vwt();
    let target = total / k as u64 + 1;
    let mut assign = vec![u32::MAX; n];
    let mut order: Vec<usize> = (0..n).collect();
    // Grow from high-weight seeds for stability.
    order.sort_unstable_by_key(|&v| std::cmp::Reverse(g.vwt[v]));
    let mut next_seed = 0usize;
    for p in 0..k as u32 {
        // pick an unassigned seed
        while next_seed < n && assign[order[next_seed]] != u32::MAX {
            next_seed += 1;
        }
        if next_seed >= n {
            break;
        }
        let seed = order[next_seed];
        let mut part_w = 0u64;
        let mut frontier = std::collections::BinaryHeap::new(); // (gain, v)
        frontier.push((0i64, seed as u32));
        while part_w < target {
            let Some((_, v)) = frontier.pop() else { break };
            let v = v as usize;
            if assign[v] != u32::MAX {
                continue;
            }
            assign[v] = p;
            part_w += g.vwt[v];
            for (u, w) in g.neighbors(v) {
                if assign[u as usize] == u32::MAX {
                    frontier.push((w as i64, u));
                }
            }
            // If frontier dried up but part underweight, jump to a random
            // unassigned vertex (disconnected graphs).
            if frontier.is_empty() && part_w < target {
                if let Some(u) = pick_unassigned(&assign, rng) {
                    frontier.push((0, u as u32));
                } else {
                    break;
                }
            }
        }
    }
    // Any stragglers go to the lightest part.
    let mut wsum = vec![0u64; k];
    for v in 0..n {
        if assign[v] != u32::MAX {
            wsum[assign[v] as usize] += g.vwt[v];
        }
    }
    for v in 0..n {
        if assign[v] == u32::MAX {
            let p = (0..k).min_by_key(|&p| wsum[p]).unwrap();
            assign[v] = p as u32;
            wsum[p] += g.vwt[v];
        }
    }
    assign
}

fn pick_unassigned(assign: &[u32], rng: &mut Rng) -> Option<usize> {
    let unassigned: Vec<usize> = assign
        .iter()
        .enumerate()
        .filter(|(_, &a)| a == u32::MAX)
        .map(|(i, _)| i)
        .collect();
    if unassigned.is_empty() {
        None
    } else {
        Some(unassigned[rng.index(unassigned.len())])
    }
}

/// Bounded FM refinement: sweep boundary vertices, move a vertex to the
/// neighbor part with the best cut gain if balance stays within `eps`.
/// A few passes; strictly gain-positive or balance-improving moves only.
fn refine(g: &WGraph, assign: &mut [u32], k: usize, eps: f64, passes: usize) {
    let total = g.total_vwt();
    let maxw = ((total as f64 / k as f64) * (1.0 + eps)) as u64 + 1;
    let mut wsum = vec![0u64; k];
    for v in 0..g.n {
        wsum[assign[v] as usize] += g.vwt[v];
    }
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..g.n {
            let pv = assign[v] as usize;
            // Tally connection weight to each neighboring part (BTreeMap
            // for deterministic tie-breaking).
            let mut conn: std::collections::BTreeMap<usize, u64> = std::collections::BTreeMap::new();
            for (u, w) in g.neighbors(v) {
                *conn.entry(assign[u as usize] as usize).or_insert(0) += w;
            }
            let own = conn.get(&pv).copied().unwrap_or(0);
            let mut best_part = pv;
            let mut best_gain = 0i64;
            for (&p, &w) in &conn {
                if p == pv {
                    continue;
                }
                let gain = w as i64 - own as i64;
                let fits = wsum[p] + g.vwt[v] <= maxw;
                let better_balance = wsum[p] + g.vwt[v] < wsum[pv];
                if fits && (gain > best_gain || (gain == best_gain && gain > 0 && better_balance)) {
                    best_gain = gain;
                    best_part = p;
                }
            }
            if best_part != pv && best_gain > 0 {
                wsum[pv] -= g.vwt[v];
                wsum[best_part] += g.vwt[v];
                assign[v] = best_part as u32;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Options for the multilevel partitioner.
#[derive(Clone, Debug)]
pub struct MultilevelOpts {
    /// Stop coarsening below this many vertices (×k).
    pub coarsen_until_per_part: usize,
    /// Balance tolerance ε.
    pub eps: f64,
    /// FM passes per level.
    pub refine_passes: usize,
    pub seed: u64,
}

impl Default for MultilevelOpts {
    fn default() -> Self {
        Self {
            coarsen_until_per_part: 30,
            eps: 0.05,
            refine_passes: 4,
            seed: 0x5EED,
        }
    }
}

/// Multilevel k-way partition with vertex weights (see
/// `partition::vertex_weights`).
pub fn multilevel(g: &CsrGraph, k: usize, vwt: &[u64], opts: &MultilevelOpts) -> Partition {
    assert!(k >= 1);
    assert_eq!(vwt.len(), g.n);
    if k == 1 {
        return Partition {
            k,
            assign: vec![0; g.n],
        };
    }
    let mut rng = Rng::new(opts.seed);
    let base = WGraph::from_csr(g, vwt.to_vec());

    // Coarsening chain.
    let mut levels: Vec<WGraph> = vec![base];
    let mut maps: Vec<Vec<u32>> = Vec::new();
    let stop_at = (opts.coarsen_until_per_part * k).max(2 * k);
    loop {
        let top = levels.last().unwrap();
        if top.n <= stop_at {
            break;
        }
        let (coarse, cmap) = coarsen(top, &mut rng);
        // Bail out if matching stalls (e.g. star graphs).
        if coarse.n as f64 > top.n as f64 * 0.95 {
            break;
        }
        maps.push(cmap);
        levels.push(coarse);
    }

    // Initial partition on coarsest.
    let coarsest = levels.last().unwrap();
    let mut assign = initial_partition(coarsest, k, &mut rng);
    refine(coarsest, &mut assign, k, opts.eps, opts.refine_passes);

    // Uncoarsen + refine.
    for lvl in (0..maps.len()).rev() {
        let fine = &levels[lvl];
        let cmap = &maps[lvl];
        let mut fine_assign = vec![0u32; fine.n];
        for v in 0..fine.n {
            fine_assign[v] = assign[cmap[v] as usize];
        }
        refine(fine, &mut fine_assign, k, opts.eps, opts.refine_passes);
        assign = fine_assign;
    }

    Partition { k, assign }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{erdos_renyi, rmat, sbm};
    use crate::partition::{quality, random, vertex_weights};
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn beats_random_on_community_graph() {
        let lg = sbm(2000, 8, 10.0, 0.9, 4, 0.5, 21);
        let g = &lg.graph;
        let w = vertex_weights(g, None, 0);
        let p = multilevel(g, 8, &w, &MultilevelOpts::default());
        p.validate(g.n).unwrap();
        let q = quality(g, &p, &w);
        let qr = quality(g, &random(g.n, 8, 1), &w);
        assert!(
            (q.edge_cut as f64) < 0.5 * qr.edge_cut as f64,
            "multilevel cut {} vs random cut {}",
            q.edge_cut,
            qr.edge_cut
        );
        assert!(q.weight_imbalance < 1.35, "imbalance {}", q.weight_imbalance);
    }

    #[test]
    fn handles_powerlaw() {
        let g = rmat(11, 8.0, 0.57, 0.19, 0.19, true, 2);
        let w = vertex_weights(&g, None, 0);
        let p = multilevel(&g, 4, &w, &MultilevelOpts::default());
        p.validate(g.n).unwrap();
        let q = quality(&g, &p, &w);
        let qr = quality(&g, &random(g.n, 4, 7), &w);
        assert!(q.edge_cut < qr.edge_cut);
    }

    #[test]
    fn k1_trivial() {
        let g = erdos_renyi(50, 200, 1);
        let w = vertex_weights(&g, None, 0);
        let p = multilevel(&g, 1, &w, &MultilevelOpts::default());
        assert!(p.assign.iter().all(|&x| x == 0));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = erdos_renyi(500, 3000, 4);
        let w = vertex_weights(&g, None, 0);
        let a = multilevel(&g, 4, &w, &MultilevelOpts::default());
        let b = multilevel(&g, 4, &w, &MultilevelOpts::default());
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn prop_valid_partition_any_graph() {
        propcheck(24, |gen| {
            let n = gen.usize(2, 300);
            let m = gen.usize(0, 900);
            let edges = gen.edges(n, m, false);
            let g = CsrGraph::from_edges(n, &edges);
            let k = gen.usize(2, 6).min(n);
            let w = vertex_weights(&g, None, 0);
            let p = multilevel(&g, k, &w, &MultilevelOpts::default());
            p.validate(n).map_err(|e| e.to_string())?;
            // Every part id used at most k; all nodes assigned.
            prop_assert(p.assign.len() == n, "assign length")?;
            // Balance within a generous bound even for adversarial graphs.
            let q = quality(&g, &p, &w);
            prop_assert(
                q.weight_imbalance <= k as f64,
                format!("wild imbalance {}", q.weight_imbalance),
            )
        });
    }

    #[test]
    fn disconnected_graph_ok() {
        // Two cliques with no inter-edges: 2-way partition should cut 0.
        let mut edges = Vec::new();
        for u in 0..10u32 {
            for v in 0..10u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        for u in 10..20u32 {
            for v in 10..20u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        let g = CsrGraph::from_edges(20, &edges);
        let w = vertex_weights(&g, None, 0);
        let p = multilevel(&g, 2, &w, &MultilevelOpts::default());
        let q = quality(&g, &p, &w);
        assert_eq!(q.edge_cut, 0, "should separate the cliques");
    }

    #[test]
    fn train_mask_balances_samples() {
        // All train nodes in the first half by id; weighted partitioning
        // should still spread them.
        let lg = sbm(1200, 4, 8.0, 0.85, 4, 0.5, 33);
        let g = &lg.graph;
        let mask: Vec<bool> = (0..g.n).map(|v| v < 300).collect();
        let w = vertex_weights(g, Some(&mask), 50);
        let p = multilevel(g, 4, &w, &MultilevelOpts::default());
        let mut train_per_part = vec![0usize; 4];
        for v in 0..g.n {
            if mask[v] {
                train_per_part[p.assign[v] as usize] += 1;
            }
        }
        let max = *train_per_part.iter().max().unwrap();
        assert!(max < 300, "train samples concentrated: {train_per_part:?}");
    }
}
