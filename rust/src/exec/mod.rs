//! The unified layer-execution engine (DESIGN.md §9).
//!
//! One tape-based forward/backward for the 3-layer GraphSAGE stack —
//! dense matmuls via `backend::linalg`, optional LayerNorm, ReLU,
//! softmax/NLL loss head, masked label-propagation embedding — shared by
//! the full-batch trainer (`coordinator::trainer`) and the mini-batch
//! trainer (`coordinator::minibatch`). The two regimes differ only in
//!
//! * **how neighbor features arrive** — the [`GraphContext`] trait:
//!   [`fullbatch::FullBatchCtx`] exchanges pre-aggregated partials and
//!   raw post rows between partitions (`RemoteStrategy` plans, optional
//!   `delay_comm` staleness), [`minibatch::MiniBatchCtx`] fetches remote
//!   feature rows for a sampled batch over its induced CSR — both on
//!   `comm::alltoallv` with optional `quant::fused` payloads and shared
//!   `CommStats` / Eqn-2/5 accounting; and
//! * **which §4 kernel executes each aggregate** — every aggregation
//!   call routes through one [`dispatch::AggDispatch`] chooser.
//!
//! Per-lane compute is clocked into [`StageClock`] stages so the drivers
//! can keep the paper's Eqn-2 bottleneck accounting
//! (`Σ_stage max_lane t(stage, lane)`).

pub mod dispatch;
pub mod featcache;
pub mod fullbatch;
pub mod minibatch;

pub use dispatch::{AggDispatch, AggKernel};
pub use featcache::{FeatCache, FeatCacheConfig, FetchScratch, PayloadPool};
pub use fullbatch::{FullBatchCtx, FullBatchRankCtx, FullBatchState, LaneHalo};
pub use minibatch::{MiniBatchCtx, MiniBatchRankCtx};

use crate::backend::linalg as la;
use crate::graph::generate::{SPLIT_TEST, SPLIT_TRAIN, SPLIT_VAL};
use crate::model::labelprop::{self, LpSelection};
use crate::model::{ModelGrads, ModelParams};
use crate::obs::{self, Mergeable, TraceCategory};
use crate::runtime::ShapeConfig;
use crate::util::timer::Category;
use anyhow::Result;
use std::time::Instant;

/// Split tag for rows that carry neither loss nor metrics (pads,
/// label-embedded train nodes).
pub const SPLIT_NONE: u8 = u8::MAX;

/// How neighbor features arrive: the one abstraction separating the
/// full-batch and mini-batch regimes. A context executes over `lanes()`
/// parallel SPMD lanes (one per worker); per-lane compute seconds are
/// accumulated into the `secs`/`quant_secs` slices so drivers can apply
/// the Eqn-2 bottleneck rule.
pub trait GraphContext {
    /// Parallel lanes this context executes (== worker count).
    fn lanes(&self) -> usize;

    /// Fill each lane's input feature matrix (`rows × f_in`), performing
    /// any remote feature-row fetch. `disp` selects the kernel family for
    /// any payload quantization the fetch performs
    /// ([`AggDispatch::quantize`]/[`AggDispatch::dequantize`]).
    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()>;

    /// `z[lane] = Agg(h[lane])`: the (mean/weighted) neighbor aggregation
    /// for `layer`, including any halo communication. `z` buffers are
    /// `rows × fin` and fully overwritten.
    #[allow(clippy::too_many_arguments)]
    fn aggregate_fwd(
        &mut self,
        layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()>;

    /// Backward of [`GraphContext::aggregate_fwd`]: accumulate
    /// `d_h[lane] += ∂Agg/∂h · dz[lane]`, shipping halo cotangents back to
    /// their producers where the forward shipped activations. `dz` may be
    /// scratched in place.
    #[allow(clippy::too_many_arguments)]
    fn aggregate_bwd(
        &mut self,
        layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()>;
}

/// Per-lane stage timings for one epoch/round: the raw material of the
/// paper's Eqn-2 accounting (`Σ_stage max_lane`) and the Fig-12 breakdown.
#[derive(Clone, Debug, Default)]
pub struct StageClock {
    pub lanes: usize,
    /// (category, per-lane seconds) per barrier stage, in execution order.
    pub stages: Vec<(Category, Vec<f64>)>,
    /// Per-stage, per-lane quantize/dequantize seconds (Fig-12 "Quant"),
    /// pushed in lockstep with `stages`.
    pub quant: Vec<Vec<f64>>,
}

impl StageClock {
    pub fn new(lanes: usize) -> Self {
        Self {
            lanes,
            stages: Vec::new(),
            quant: Vec::new(),
        }
    }

    /// Open a new stage; returns (stage seconds, quant seconds).
    pub fn push(&mut self, cat: Category) -> (&mut Vec<f64>, &mut Vec<f64>) {
        self.stages.push((cat, vec![0.0; self.lanes]));
        self.quant.push(vec![0.0; self.lanes]);
        let StageClock { stages, quant, .. } = self;
        (
            &mut stages.last_mut().unwrap().1,
            quant.last_mut().unwrap(),
        )
    }

    /// Eqn-2 view of the quant work: `Σ_stage max_lane` (Fig-12 "Quant").
    pub fn quant_bottleneck(&self) -> f64 {
        self.quant
            .iter()
            .map(|q| q.iter().fold(0.0f64, |a, &b| a.max(b)))
            .sum()
    }

    /// Per-lane quant total across all stages.
    pub fn quant_lane_totals(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.lanes];
        for q in &self.quant {
            for (o, &t) in out.iter_mut().zip(q.iter()) {
                *o += t;
            }
        }
        out
    }

    /// Eqn-2 bottleneck compute and the implied sync waste:
    /// `(Σ_stage max_lane, Σ_stage Σ_lane (max − t))`.
    pub fn bottleneck(&self) -> (f64, f64) {
        let mut compute = 0f64;
        let mut sync = 0f64;
        for (_, st) in &self.stages {
            let mx = st.iter().fold(0.0f64, |a, &b| a.max(b));
            compute += mx;
            for &t in st {
                sync += mx - t;
            }
        }
        (compute, sync)
    }

    /// Per-lane total across all stages (the mini-batch round view).
    pub fn lane_totals(&self) -> Vec<f64> {
        let mut out = vec![0f64; self.lanes];
        for (_, st) in &self.stages {
            for (o, &t) in out.iter_mut().zip(st.iter()) {
                *o += t;
            }
        }
        out
    }

    /// Per-stage maxima summed per category (Fig-12 attribution).
    pub fn category_maxes(&self) -> Vec<(Category, f64)> {
        self.stages
            .iter()
            .map(|(c, st)| (*c, st.iter().fold(0.0f64, |a, &b| a.max(b))))
            .collect()
    }

    /// Zip single-lane rank clocks (threaded transport) into one k-lane
    /// clock with the sequential layout, so the drivers' Eqn-2/Fig-12
    /// accounting is transport-agnostic. Every rank runs the identical
    /// engine control flow, so the stage sequences always line up — a
    /// divergence is a bug, hence the asserts. Thin wrapper over the
    /// shared [`obs::merge_lanes`] fold (DESIGN.md §13).
    pub fn merge_lanes(clocks: &[StageClock]) -> StageClock {
        assert!(!clocks.is_empty(), "no rank clocks to merge");
        for c in clocks {
            assert_eq!(c.lanes, 1, "merge_lanes takes single-lane rank clocks");
        }
        obs::merge_lanes(clocks)
    }
}

impl Mergeable for StageClock {
    /// Lane-append: concatenate `other`'s lane columns stage by stage —
    /// folding k single-lane rank clocks in rank order reproduces the
    /// sequential k-lane layout exactly.
    fn merge_from(&mut self, other: &Self) {
        assert_eq!(
            self.stages.len(),
            other.stages.len(),
            "rank stage sequences diverged"
        );
        for ((dst, src), (dq, sq)) in self
            .stages
            .iter_mut()
            .zip(&other.stages)
            .zip(self.quant.iter_mut().zip(&other.quant))
        {
            debug_assert!(dst.0 == src.0, "stage categories diverged");
            dst.1.extend_from_slice(&src.1);
            dq.extend_from_slice(sq);
        }
        self.lanes += other.lanes;
    }
}

/// One overlapped exchange's per-lane accounting (DESIGN.md §11): the
/// interior compute that ran while the wire was busy, the boundary
/// compute that waited for receipt, and the modeled wire seconds of the
/// exchange itself (per sending lane, from `CommStats`).
#[derive(Clone, Debug, Default)]
pub struct OverlapStage {
    /// e.g. "fwd L0", "bwd L2", "fetch".
    pub label: &'static str,
    pub interior: Vec<f64>,
    pub boundary: Vec<f64>,
    pub comm: Vec<f64>,
}

impl OverlapStage {
    fn max(v: &[f64]) -> f64 {
        v.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Eqn-2-style lane maxima: `(interior, comm, boundary)`.
    pub fn maxes(&self) -> (f64, f64, f64) {
        (Self::max(&self.interior), Self::max(&self.comm), Self::max(&self.boundary))
    }
}

/// Overlap-aware time accounting for one epoch (or one run): one
/// [`OverlapStage`] per overlapped exchange, recorded by the graph
/// contexts when `--overlap on`. Alongside the *measured* wall time the
/// drivers already report, this yields two *modeled* views of the same
/// run — `Σ max(interior, comm) + boundary` (overlapped) vs
/// `Σ interior + comm + boundary` (phase-serial) — surfaced by
/// `benches/spmd_scaling.rs` and `benches/fig12_breakdown.rs`.
#[derive(Clone, Debug, Default)]
pub struct OverlapLedger {
    pub lanes: usize,
    pub stages: Vec<OverlapStage>,
}

impl OverlapLedger {
    pub fn new(lanes: usize) -> Self {
        Self {
            lanes,
            stages: Vec::new(),
        }
    }

    /// Open a new stage with zeroed per-lane columns.
    pub fn push(&mut self, label: &'static str) -> &mut OverlapStage {
        self.stages.push(OverlapStage {
            label,
            interior: vec![0.0; self.lanes],
            boundary: vec![0.0; self.lanes],
            comm: vec![0.0; self.lanes],
        });
        self.stages.last_mut().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Modeled epoch seconds under the overlap schedule
    /// ([`crate::perfmodel::t_layer_overlap`] per stage).
    pub fn modeled_overlap_secs(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| {
                let (i, c, b) = s.maxes();
                crate::perfmodel::t_layer_overlap(i, c, b)
            })
            .sum()
    }

    /// Modeled epoch seconds of the same run under the phase-serial
    /// schedule ([`crate::perfmodel::t_layer_serial`] per stage) — the
    /// comparison baseline for the overlap win.
    pub fn modeled_serial_secs(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| {
                let (i, c, b) = s.maxes();
                crate::perfmodel::t_layer_serial(i, c, b)
            })
            .sum()
    }

    /// Append another ledger's stages (mini-batch rounds accumulate into
    /// one epoch ledger).
    pub fn absorb(&mut self, other: &OverlapLedger) {
        if self.lanes == 0 {
            self.lanes = other.lanes;
        }
        debug_assert!(other.is_empty() || other.lanes == self.lanes);
        self.stages.extend(other.stages.iter().cloned());
    }

    /// Zip single-lane rank ledgers (threaded transport) into one k-lane
    /// ledger with the sequential layout — the [`StageClock::merge_lanes`]
    /// counterpart. Every rank records the identical stage sequence.
    /// Thin wrapper over the shared [`obs::merge_lanes`] fold.
    pub fn merge_lanes(ledgers: &[OverlapLedger]) -> OverlapLedger {
        assert!(!ledgers.is_empty(), "no rank ledgers to merge");
        for l in ledgers {
            assert_eq!(l.lanes, 1, "merge_lanes takes single-lane rank ledgers");
        }
        obs::merge_lanes(ledgers)
    }
}

impl Mergeable for OverlapLedger {
    /// Lane-append per overlap stage — the [`StageClock`] counterpart.
    fn merge_from(&mut self, other: &Self) {
        assert_eq!(
            self.stages.len(),
            other.stages.len(),
            "rank overlap stages diverged"
        );
        for (dst, src) in self.stages.iter_mut().zip(&other.stages) {
            debug_assert!(dst.label == src.label, "overlap stage labels diverged");
            dst.interior.extend_from_slice(&src.interior);
            dst.boundary.extend_from_slice(&src.boundary);
            dst.comm.extend_from_slice(&src.comm);
        }
        self.lanes += other.lanes;
    }
}

/// The saved forward state ("tape") of one engine pass: activations,
/// normalized activations, aggregated neighbor tensors, and the running
/// cotangent — everything the exact backward replays.
pub struct Tapes {
    pub lanes: usize,
    /// Rows per lane (padded `n_pad` in full-batch, batch size — possibly
    /// 0 for an idle worker — in mini-batch rounds).
    pub rows: Vec<usize>,
    /// `h[l][lane]`: activations entering layer `l`; `h[3]` = logits.
    pub h: Vec<Vec<Vec<f32>>>,
    /// LayerNorm outputs per layer (empty when the engine runs without LN).
    pub h_tilde: Vec<Vec<Vec<f32>>>,
    /// Saved aggregation outputs per layer (backward reuses them for the
    /// `w_neigh` gradient instead of re-aggregating).
    pub z: Vec<Vec<Vec<f32>>>,
    /// Running cotangent buffers (`rows × maxf`).
    pub d_cur: Vec<Vec<f32>>,
    pub d_next: Vec<Vec<f32>>,
    pub dz: Vec<Vec<f32>>,
    /// Pre-activation cotangent scratch (shared across lanes).
    dpre: Vec<f32>,
    /// Per-lane parameter gradients.
    pub grads: Vec<ModelGrads>,
}

impl Tapes {
    pub fn new(
        dims: &[(usize, usize, bool); 3],
        rows: &[usize],
        layernorm: bool,
        params: &ModelParams,
    ) -> Self {
        let lanes = rows.len();
        let widths = [dims[0].0, dims[1].0, dims[2].0, dims[2].1];
        let maxf = widths.iter().copied().max().unwrap_or(1);
        let max_rows = rows.iter().copied().max().unwrap_or(0);
        let h = (0..4)
            .map(|l| rows.iter().map(|&m| vec![0f32; m * widths[l]]).collect())
            .collect();
        let h_tilde = (0..3)
            .map(|l| {
                rows.iter()
                    .map(|&m| {
                        if layernorm {
                            vec![0f32; m * widths[l]]
                        } else {
                            Vec::new()
                        }
                    })
                    .collect()
            })
            .collect();
        let z = (0..3)
            .map(|l| rows.iter().map(|&m| vec![0f32; m * widths[l]]).collect())
            .collect();
        let scratch = || rows.iter().map(|&m| vec![0f32; m * maxf]).collect::<Vec<_>>();
        Self {
            lanes,
            rows: rows.to_vec(),
            h,
            h_tilde,
            z,
            d_cur: scratch(),
            d_next: scratch(),
            dz: scratch(),
            dpre: vec![0f32; max_rows * maxf],
            grads: (0..lanes).map(|_| ModelGrads::zeros(params)).collect(),
        }
    }

    /// Zero the per-lane gradient accumulators (start of an epoch/round).
    pub fn clear_grads(&mut self) {
        for g in &mut self.grads {
            g.clear();
        }
    }
}

/// Label-propagation inputs: the per-lane embedding selection and label
/// arrays (the selection policy — which nodes, which fraction — stays
/// with the driver; the engine applies the embedding and its gradient).
pub struct LpInputs<'a> {
    pub sel: &'a [LpSelection],
    pub labels: Vec<&'a [u32]>,
}

/// Per-lane loss-head specification.
pub struct LossSpec<'a> {
    /// Leading rows of the lane that are scored (all padded rows in
    /// full-batch — pads carry `SPLIT_NONE` — `n_target` in mini-batch).
    pub score_rows: usize,
    pub labels: &'a [u32],
    /// `SPLIT_TRAIN`/`SPLIT_VAL`/`SPLIT_TEST` or [`SPLIT_NONE`] per row.
    pub split: &'a [u8],
    /// Train loss/gradient weight per row (loss mask, SAINT coverage
    /// weight, …). Only read where `split == SPLIT_TRAIN`.
    pub loss_w: &'a [f32],
}

/// Loss and metric sums for one lane (or accumulated across lanes).
#[derive(Clone, Copy, Debug, Default)]
pub struct LossTotals {
    pub loss_sum: f64,
    /// Total train loss weight (the mean-loss normalizer).
    pub wsum: f64,
    pub train_correct: f64,
    pub train_cnt: f64,
    pub val_correct: f64,
    pub val_cnt: f64,
    pub test_correct: f64,
    pub test_cnt: f64,
}

impl LossTotals {
    /// Flat f64 record for the fabric allgather (threaded transport);
    /// inverse of [`LossTotals::from_slice`].
    pub fn to_vec(&self) -> Vec<f64> {
        vec![
            self.loss_sum,
            self.wsum,
            self.train_correct,
            self.train_cnt,
            self.val_correct,
            self.val_cnt,
            self.test_correct,
            self.test_cnt,
        ]
    }

    pub fn from_slice(v: &[f64]) -> LossTotals {
        assert_eq!(v.len(), 8, "LossTotals record has 8 fields");
        LossTotals {
            loss_sum: v[0],
            wsum: v[1],
            train_correct: v[2],
            train_cnt: v[3],
            val_correct: v[4],
            val_cnt: v[5],
            test_correct: v[6],
            test_cnt: v[7],
        }
    }

    pub fn accumulate(&mut self, o: &LossTotals) {
        self.loss_sum += o.loss_sum;
        self.wsum += o.wsum;
        self.train_correct += o.train_correct;
        self.train_cnt += o.train_cnt;
        self.val_correct += o.val_correct;
        self.val_cnt += o.val_cnt;
        self.test_correct += o.test_correct;
        self.test_cnt += o.test_cnt;
    }
}

/// The tape-based 3-layer SAGE executor.
pub struct Engine {
    pub dims: [(usize, usize, bool); 3],
    /// Row-wise LayerNorm before every layer (the paper's full-batch
    /// architecture; the mini-batch regime historically omits it).
    pub layernorm: bool,
    pub dispatch: AggDispatch,
}

impl Engine {
    pub fn new(shapes: &ShapeConfig, layernorm: bool, dispatch: AggDispatch) -> Self {
        Self {
            dims: shapes.layer_dims(),
            layernorm,
            dispatch,
        }
    }

    /// Allocate tapes matching this engine's widths.
    pub fn tapes(&self, rows: &[usize], params: &ModelParams) -> Tapes {
        Tapes::new(&self.dims, rows, self.layernorm, params)
    }

    /// Forward pass: inputs → logits, recording the tape.
    pub fn forward(
        &self,
        params: &ModelParams,
        ctx: &mut dyn GraphContext,
        tapes: &mut Tapes,
        lp: Option<&LpInputs>,
        clock: &mut StageClock,
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Phase, "forward");
        let lanes = tapes.lanes;
        anyhow::ensure!(ctx.lanes() == lanes, "context/tape lane mismatch");
        {
            let (secs, quant) = clock.push(Category::Aggr);
            ctx.load_inputs(&mut tapes.h[0], &self.dispatch, secs, quant)?;
        }
        if let Some(lp) = lp {
            let f_in = self.dims[0].0;
            for w in 0..lanes {
                labelprop::embed_into(
                    &mut tapes.h[0][w],
                    f_in,
                    &lp.sel[w],
                    lp.labels[w],
                    &params.w_embed,
                );
            }
        }
        for l in 0..3 {
            let (fin, fout, relu) = self.dims[l];
            if self.layernorm {
                let (secs, _) = clock.push(Category::Aggr);
                for w in 0..lanes {
                    let t = Instant::now();
                    la::layernorm(&tapes.h[l][w], tapes.rows[w], fin, &mut tapes.h_tilde[l][w]);
                    secs[w] += t.elapsed().as_secs_f64();
                }
            }
            {
                let (secs, quant) = clock.push(Category::Aggr);
                let src = if self.layernorm {
                    &tapes.h_tilde[l]
                } else {
                    &tapes.h[l]
                };
                ctx.aggregate_fwd(l, fin, src, &mut tapes.z[l], &self.dispatch, secs, quant)?;
            }
            {
                let (secs, _) = clock.push(Category::Aggr);
                let (h_in, h_out) = tapes.h.split_at_mut(l + 1);
                let src = if self.layernorm {
                    &tapes.h_tilde[l]
                } else {
                    &h_in[l]
                };
                for w in 0..lanes {
                    let m = tapes.rows[w];
                    let t = Instant::now();
                    let out = &mut h_out[0][w];
                    la::matmul(&src[w], &params.layers[l].w_self, m, fin, fout, out);
                    la::matmul_acc(&tapes.z[l][w], &params.layers[l].w_neigh, m, fin, fout, out);
                    la::add_bias(out, m, &params.layers[l].b);
                    if relu {
                        la::relu(out);
                    }
                    secs[w] += t.elapsed().as_secs_f64();
                }
            }
        }
        Ok(())
    }

    /// Softmax/NLL loss head over every lane's logits. Writes the
    /// *unscaled* loss gradient into `tapes.d_cur` (gradient of the sum
    /// loss, each row weighted by its `loss_w`); drivers normalize with
    /// [`Engine::scale_loss_grad`] after combining lane totals.
    pub fn loss_all(
        &self,
        tapes: &mut Tapes,
        specs: &[LossSpec],
        clock: &mut StageClock,
    ) -> Vec<LossTotals> {
        let _sp = obs::span(TraceCategory::Phase, "loss");
        let c = self.dims[2].1;
        let lanes = tapes.lanes;
        assert_eq!(specs.len(), lanes);
        let mut out = Vec::with_capacity(lanes);
        let (secs, _) = clock.push(Category::Other);
        for w in 0..lanes {
            let t = Instant::now();
            let m = tapes.rows[w];
            let spec = &specs[w];
            debug_assert!(spec.score_rows <= m);
            let logits = &tapes.h[3][w];
            let d = &mut tapes.d_cur[w][..m * c];
            d.iter_mut().for_each(|x| *x = 0.0);
            let mut tot = LossTotals::default();
            for i in 0..spec.score_rows {
                let row = &logits[i * c..(i + 1) * c];
                let label = spec.labels[i] as usize;
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                let correct = if best == label { 1.0 } else { 0.0 };
                match spec.split[i] {
                    SPLIT_TRAIN => {
                        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        let sum_exp: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
                        let log_z = mx + sum_exp.ln();
                        let wt = spec.loss_w[i];
                        tot.loss_sum += wt as f64 * (log_z - row[label]) as f64;
                        tot.wsum += wt as f64;
                        tot.train_cnt += 1.0;
                        tot.train_correct += correct;
                        for j in 0..c {
                            let sm = (row[j] - log_z).exp();
                            let y = if j == label { 1.0 } else { 0.0 };
                            d[i * c + j] = wt * (sm - y);
                        }
                    }
                    SPLIT_VAL => {
                        tot.val_cnt += 1.0;
                        tot.val_correct += correct;
                    }
                    SPLIT_TEST => {
                        tot.test_cnt += 1.0;
                        tot.test_correct += correct;
                    }
                    _ => {}
                }
            }
            out.push(tot);
            secs[w] += t.elapsed().as_secs_f64();
        }
        out
    }

    /// Scale each lane's loss gradient (e.g. by `1 / global mask sum` in
    /// full-batch, `1 / lane wsum` in mini-batch).
    pub fn scale_loss_grad(&self, tapes: &mut Tapes, scales: &[f32]) {
        let c = self.dims[2].1;
        for w in 0..tapes.lanes {
            let s = scales[w];
            for v in &mut tapes.d_cur[w][..tapes.rows[w] * c] {
                *v *= s;
            }
        }
    }

    /// Exact backward pass: consumes `tapes.d_cur` (the loss gradient)
    /// and accumulates parameter gradients into `tapes.grads`.
    ///
    /// `input_grad` controls whether the cotangent is propagated all the
    /// way to the input features of layer 0 (left in `tapes.d_cur`). The
    /// full-batch driver always passes `true` — its layer-0 reverse halo
    /// exchange is part of the regime's communication contract — while
    /// the mini-batch driver passes `false` to skip the unused layer-0
    /// input cotangent (it has no backward communication). Label-prop
    /// forces propagation regardless (the embedding gradient reads it).
    #[allow(clippy::too_many_arguments)]
    pub fn backward(
        &self,
        params: &ModelParams,
        ctx: &mut dyn GraphContext,
        tapes: &mut Tapes,
        lp: Option<&LpInputs>,
        input_grad: bool,
        clock: &mut StageClock,
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Phase, "backward");
        let lanes = tapes.lanes;
        let need_input = input_grad || lp.is_some();
        for l in (0..3).rev() {
            let (fin, fout, relu) = self.dims[l];
            let propagate = l > 0 || need_input;
            {
                let (secs, _) = clock.push(Category::Aggr);
                for w in 0..lanes {
                    let m = tapes.rows[w];
                    let t = Instant::now();
                    {
                        let dpre = &mut tapes.dpre[..m * fout];
                        if relu {
                            la::relu_bwd(&tapes.d_cur[w][..m * fout], &tapes.h[l + 1][w], dpre);
                        } else {
                            dpre.copy_from_slice(&tapes.d_cur[w][..m * fout]);
                        }
                    }
                    let dpre = &tapes.dpre[..m * fout];
                    let src = if self.layernorm {
                        &tapes.h_tilde[l][w]
                    } else {
                        &tapes.h[l][w]
                    };
                    let g = &mut tapes.grads[w].layers[l];
                    la::matmul_tn_acc(src, dpre, m, fin, fout, &mut g.w_self);
                    la::matmul_tn_acc(&tapes.z[l][w], dpre, m, fin, fout, &mut g.w_neigh);
                    la::col_sum_acc(dpre, m, fout, &mut g.b);
                    if propagate {
                        let dt = &mut tapes.d_next[w][..m * fin];
                        dt.iter_mut().for_each(|x| *x = 0.0);
                        la::matmul_nt_acc(dpre, &params.layers[l].w_self, m, fout, fin, dt);
                        let dzv = &mut tapes.dz[w][..m * fin];
                        dzv.iter_mut().for_each(|x| *x = 0.0);
                        la::matmul_nt_acc(dpre, &params.layers[l].w_neigh, m, fout, fin, dzv);
                    }
                    secs[w] += t.elapsed().as_secs_f64();
                }
            }
            if !propagate {
                break;
            }
            {
                let (secs, _) = clock.push(Category::Aggr);
                ctx.aggregate_bwd(
                    l,
                    fin,
                    &mut tapes.dz,
                    &mut tapes.d_next,
                    &self.dispatch,
                    secs,
                )?;
            }
            {
                let (secs, _) = clock.push(Category::Aggr);
                for w in 0..lanes {
                    let m = tapes.rows[w];
                    let t = Instant::now();
                    if self.layernorm {
                        // d_cur ← LN'(h) · d_tilde
                        let h_in = &tapes.h[l][w];
                        let dn = &tapes.d_next[w][..m * fin];
                        la::layernorm_bwd(h_in, dn, m, fin, &mut tapes.d_cur[w][..m * fin]);
                    } else {
                        std::mem::swap(&mut tapes.d_cur[w], &mut tapes.d_next[w]);
                    }
                    secs[w] += t.elapsed().as_secs_f64();
                }
            }
        }
        if let Some(lp) = lp {
            let f_in = self.dims[0].0;
            for w in 0..lanes {
                let m = tapes.rows[w];
                labelprop::grad_embed(
                    &mut tapes.grads[w].w_embed,
                    f_in,
                    &lp.sel[w],
                    lp.labels[w],
                    &tapes.d_cur[w][..m * f_in],
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_config;

    #[test]
    fn loss_head_known_values() {
        let cfg = test_config();
        let engine = Engine::new(&cfg, true, AggDispatch::default());
        let params = ModelParams::init(&cfg, 1);
        let n = 16usize;
        let c = cfg.classes;
        let mut tapes = engine.tapes(&[n], &params);
        let mut labels = vec![0u32; n];
        let mut split = vec![SPLIT_NONE; n];
        let loss_w = vec![1.0f32; n];
        for v in 0..8 {
            labels[v] = (v % c) as u32;
            tapes.h[3][0][v * c + v % c] = 10.0;
            split[v] = SPLIT_TRAIN;
        }
        split[9] = SPLIT_VAL;
        split[10] = SPLIT_TEST;
        let mut clock = StageClock::new(1);
        let spec = LossSpec {
            score_rows: n,
            labels: &labels,
            split: &split,
            loss_w: &loss_w,
        };
        let tot = engine.loss_all(&mut tapes, &[spec], &mut clock)[0];
        assert_eq!(tot.train_cnt, 8.0);
        assert_eq!(tot.train_correct, 8.0);
        assert_eq!(tot.wsum, 8.0);
        assert!(tot.loss_sum < 0.01);
        // Uniform-zero logit rows: label 0 is the argmax by first-wins.
        assert_eq!(tot.val_cnt, 1.0);
        assert_eq!(tot.test_cnt, 1.0);
        // Non-train rows get no gradient.
        let d = &tapes.d_cur[0];
        assert!(d[9 * c..].iter().all(|&x| x == 0.0));
        assert!(d[..8 * c].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn stage_clock_bottleneck_math() {
        let mut clock = StageClock::new(2);
        {
            let (s, _) = clock.push(Category::Aggr);
            s[0] = 1.0;
            s[1] = 3.0;
        }
        {
            let (s, _) = clock.push(Category::Other);
            s[0] = 2.0;
            s[1] = 1.0;
        }
        let (compute, sync) = clock.bottleneck();
        assert!((compute - 5.0).abs() < 1e-12);
        assert!((sync - 3.0).abs() < 1e-12);
        assert_eq!(clock.lane_totals(), vec![3.0, 4.0]);
        let cats = clock.category_maxes();
        assert_eq!(cats.len(), 2);
        assert_eq!(cats[0].0, Category::Aggr);
        assert!((cats[0].1 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn merge_lanes_reproduces_sequential_layout() {
        // Two single-lane rank clocks zip into the 2-lane sequential shape.
        let mut a = StageClock::new(1);
        let mut b = StageClock::new(1);
        for (clock, v) in [(&mut a, 1.0), (&mut b, 3.0)] {
            let (s, q) = clock.push(Category::Aggr);
            s[0] = v;
            q[0] = v * 0.1;
            let (s, _) = clock.push(Category::Other);
            s[0] = v * 2.0;
        }
        let m = StageClock::merge_lanes(&[a, b]);
        assert_eq!(m.lanes, 2);
        let (compute, sync) = m.bottleneck();
        assert!((compute - (3.0 + 6.0)).abs() < 1e-12);
        assert!((sync - (2.0 + 4.0)).abs() < 1e-12);
        assert!((m.quant_bottleneck() - 0.3).abs() < 1e-12);
        assert_eq!(m.lane_totals(), vec![3.0, 9.0]);
    }

    #[test]
    fn overlap_ledger_models_and_merge() {
        let mut a = OverlapLedger::new(1);
        let mut b = OverlapLedger::new(1);
        for (ledger, scale) in [(&mut a, 1.0f64), (&mut b, 2.0)] {
            let s = ledger.push("fwd L0");
            s.interior[0] = 1.0 * scale;
            s.comm[0] = 0.5 * scale;
            s.boundary[0] = 0.25 * scale;
            let s = ledger.push("bwd L0");
            s.interior[0] = 0.1 * scale;
            s.comm[0] = 0.4 * scale;
            s.boundary[0] = 0.0;
        }
        let m = OverlapLedger::merge_lanes(&[a, b]);
        assert_eq!(m.lanes, 2);
        assert_eq!(m.stages.len(), 2);
        // Lane maxima come from lane 1 (scale 2): stage 0 → max(2.0, 1.0)
        // + 0.5 = 2.5; stage 1 → max(0.2, 0.8) + 0 = 0.8.
        assert!((m.modeled_overlap_secs() - (2.5 + 0.8)).abs() < 1e-12);
        // Serial: (2.0 + 1.0 + 0.5) + (0.2 + 0.8) = 4.5.
        assert!((m.modeled_serial_secs() - 4.5).abs() < 1e-12);
        assert!(m.modeled_overlap_secs() <= m.modeled_serial_secs());
        // absorb appends stages.
        let mut epoch = OverlapLedger::new(0);
        epoch.absorb(&m);
        epoch.absorb(&m);
        assert_eq!(epoch.lanes, 2);
        assert_eq!(epoch.stages.len(), 4);
        assert!((epoch.modeled_serial_secs() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn mergeable_fold_equals_legacy_lane_zip() {
        // The obs::Mergeable fold must reproduce the pinned lane-zip
        // semantics of the legacy merge entry points exactly.
        let mk_clock = |v: f64| {
            let mut c = StageClock::new(1);
            let (s, q) = c.push(Category::Aggr);
            s[0] = v;
            q[0] = v / 10.0;
            let (s, _) = c.push(Category::Other);
            s[0] = 2.0 * v;
            c
        };
        let clocks = vec![mk_clock(1.0), mk_clock(2.0), mk_clock(3.0)];
        let legacy = StageClock::merge_lanes(&clocks);
        let folded = crate::obs::merge_lanes(&clocks);
        assert_eq!(folded.lanes, legacy.lanes);
        assert_eq!(folded.stages, legacy.stages);
        assert_eq!(folded.quant, legacy.quant);

        let mk_ledger = |v: f64| {
            let mut l = OverlapLedger::new(1);
            let s = l.push("fwd L0");
            s.interior[0] = v;
            s.comm[0] = v / 2.0;
            s.boundary[0] = v / 4.0;
            l
        };
        let ledgers = vec![mk_ledger(1.0), mk_ledger(4.0)];
        let legacy = OverlapLedger::merge_lanes(&ledgers);
        let folded = crate::obs::merge_lanes(&ledgers);
        assert_eq!(folded.lanes, legacy.lanes);
        assert_eq!(folded.stages.len(), legacy.stages.len());
        for (a, b) in folded.stages.iter().zip(&legacy.stages) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.interior, b.interior);
            assert_eq!(a.boundary, b.boundary);
            assert_eq!(a.comm, b.comm);
        }
    }

    #[test]
    fn loss_totals_record_roundtrip() {
        let t = LossTotals {
            loss_sum: 1.5,
            wsum: 2.0,
            train_correct: 3.0,
            train_cnt: 4.0,
            val_correct: 5.0,
            val_cnt: 6.0,
            test_correct: 7.0,
            test_cnt: 8.0,
        };
        let rt = LossTotals::from_slice(&t.to_vec());
        assert_eq!(rt.loss_sum, t.loss_sum);
        assert_eq!(rt.wsum, t.wsum);
        assert_eq!(rt.test_cnt, t.test_cnt);
    }

    #[test]
    fn tapes_shapes() {
        let cfg = test_config();
        let params = ModelParams::init(&cfg, 2);
        let dims = cfg.layer_dims();
        let tapes = Tapes::new(&dims, &[10, 0, 7], false, &params);
        assert_eq!(tapes.lanes, 3);
        assert_eq!(tapes.h[0][0].len(), 10 * cfg.f_in);
        assert_eq!(tapes.h[3][2].len(), 7 * cfg.classes);
        assert!(tapes.h[1][1].is_empty());
        assert!(tapes.h_tilde[0][0].is_empty(), "no LN ⇒ no h_tilde storage");
        let t2 = Tapes::new(&dims, &[4], true, &params);
        assert_eq!(t2.h_tilde[2][0].len(), 4 * cfg.hidden);
    }
}
