//! Sampling-regime comparison harness: full-batch training vs the
//! mini-batch producers (neighbor fan-out, GraphSAINT rw/node/edge,
//! Cluster-GCN) on the same dataset, worker count, and machine model —
//! one row per regime with accuracy, per-epoch comm volume, and modeled
//! epoch time (Eqn 2/5), FP32 and Int2 fetch variants.
//!
//! Expected shape: cluster/neighbor epochs move an order of magnitude
//! fewer bytes than full-batch halos; SAINT trades coverage for the
//! cheapest epochs; Int2 shrinks the fetched-row volume ~16x on top.
//!
//!     cargo bench --bench sampling_regimes

use supergcn::datasets;
use supergcn::exp::{best_test_acc, steady_epoch_secs, train_minibatch, train_native, Table};
use supergcn::quant::Bits;
use supergcn::run::RunConfig;
use supergcn::sample::SamplerKind;
use supergcn::util::fmt_bytes;

fn main() {
    let spec = datasets::by_name("arxiv-s").unwrap();
    let k = 8;
    let epochs = 30;
    let mut t = Table::new(
        &format!(
            "sampling regimes: {} on {k} workers, {epochs} epochs",
            spec.name
        ),
        &[
            "regime",
            "quant",
            "best test acc",
            "epoch data",
            "epoch params",
            "modeled epoch (ms)",
        ],
    );

    for quant in [None, Some(Bits::Int2)] {
        let qname = quant.map(|b| b.name()).unwrap_or("fp32");

        // Full-batch baseline (the paper's loop).
        let tc = RunConfig {
            epochs,
            quant,
            ..Default::default()
        };
        let (stats, _tr) = train_native(&spec, k, tc.train_config(), Some(epochs)).unwrap();
        t.row(vec![
            "full-batch".into(),
            qname.into(),
            format!("{:.3}", best_test_acc(&stats)),
            fmt_bytes(stats[1].comm_data_bytes),
            fmt_bytes(stats[1].comm_param_bytes),
            format!("{:.3}", steady_epoch_secs(&stats, 10) * 1e3),
        ]);

        // Mini-batch regimes through the same comm accounting.
        for kind in [
            SamplerKind::Neighbor,
            SamplerKind::SaintRw,
            SamplerKind::SaintNode,
            SamplerKind::SaintEdge,
            SamplerKind::Cluster,
        ] {
            let rc = RunConfig {
                sampler: kind,
                epochs,
                quant,
                batch_size: 512,
                fanouts: vec![15, 10, 5],
                num_clusters: 4 * k,
                ..Default::default()
            };
            let (stats, _tr) = train_minibatch(
                &spec, k, kind, &rc.sampler_config(), rc.minibatch_config(), Some(epochs),
            )
            .unwrap();
            t.row(vec![
                kind.name().into(),
                qname.into(),
                format!("{:.3}", best_test_acc(&stats)),
                fmt_bytes(stats[1].comm_data_bytes),
                fmt_bytes(stats[1].comm_param_bytes),
                format!("{:.3}", steady_epoch_secs(&stats, 10) * 1e3),
            ]);
        }
    }
    t.print();
}
