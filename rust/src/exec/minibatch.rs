//! [`GraphContext`] for the mini-batch regime: each SPMD lane processes
//! one sampled [`MiniBatch`] per round; neighbor features arrive by
//! fetching remote feature rows from their owning partitions (`u32` ids
//! on the wire, rows returned through `comm::alltoallv`, optionally
//! `quant::fused`-quantized), and aggregation runs the batch's induced
//! weighted CSR through the dispatcher's SpMM path.
//!
//! Like the full-batch module, two context flavors share the per-pair
//! request/serve/assemble building blocks: [`MiniBatchCtx`] (sequential
//! transport, all lanes in one driver thread) and [`MiniBatchRankCtx`]
//! (threaded transport, one lane per rank thread over the mailbox
//! [`Fabric`](crate::comm::transport::Fabric)) — bit-exactness across
//! transports is pinned by `tests/spmd_parity.rs`.

use super::dispatch::AggDispatch;
use super::{GraphContext, OverlapLedger};
use crate::agg::spmm::CsrMatrix;
use crate::comm::transport::Fabric;
use crate::comm::{alltoallv_routed, CommStats, Payload, Topology};
use crate::graph::generate::LabelledGraph;
use crate::obs::{self, TraceCategory};
use crate::perfmodel::MachineProfile;
use crate::quant::Bits;
use crate::sample::{mix2, MiniBatch};
use anyhow::Result;
use std::time::Instant;

/// Overlap-ledger labels for the remote feature-row fetch (DESIGN.md
/// §11). The fetch is *two* exchanges with different overlap structure,
/// so it records two stages: the id-request leg overlaps the copy of
/// locally owned batch rows (interior), while the reply leg is serial —
/// its wire time plus the remote-row fill (boundary) cannot start before
/// the requests complete. Lumping both wires into one stage would let
/// `max(interior, comm)` hide reply wire behind interior compute the
/// implemented schedule cannot actually hide.
const FETCH_REQ_STAGE: &str = "fetch req";
const FETCH_REPLY_STAGE: &str = "fetch reply";

/// One round's view: worker lane `w` processes `batches[per_lane[w]]`
/// (idle lanes — `None` — run zero-row no-ops through the engine).
pub struct MiniBatchCtx<'a> {
    lg: &'a LabelledGraph,
    /// Partition ownership of global feature rows.
    assign: &'a [u32],
    batches: &'a [MiniBatch],
    per_lane: &'a [Option<usize>],
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    /// Overlapped fetch schedule (`--overlap on`, DESIGN.md §11).
    overlap: bool,
    /// Rank placement driving the two-level tier accounting of the fetch
    /// exchanges (`--group-size`, DESIGN.md §12); flat by default.
    topo: Topology,
    ledger: OverlapLedger,
    comm: &'a mut CommStats,
    /// The induced weighted adjacency per lane, in the form `agg::spmm`
    /// wants (built once per round, shared by all three layers).
    mats: Vec<Option<CsrMatrix>>,
}

impl<'a> MiniBatchCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        lg: &'a LabelledGraph,
        assign: &'a [u32],
        batches: &'a [MiniBatch],
        per_lane: &'a [Option<usize>],
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        round: usize,
        overlap: bool,
        comm: &'a mut CommStats,
    ) -> Self {
        let mats = per_lane
            .iter()
            .map(|slot| slot.map(|bi| induced_csr(&batches[bi])))
            .collect();
        let lanes = per_lane.len();
        Self {
            lg,
            assign,
            batches,
            per_lane,
            machine,
            quant,
            seed,
            epoch,
            round,
            overlap,
            topo: Topology::flat(lanes),
            ledger: OverlapLedger::new(lanes),
            comm,
            mats,
        }
    }

    /// Route this round's fetch exchanges over a two-level rank topology
    /// (DESIGN.md §12): identical payloads and logical accounting — the
    /// grouped path only adds `CommStats::tiers` charges.
    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topo = topo;
        self
    }

    /// Hand the round's overlap accounting back to the driver (empty when
    /// `--overlap off`).
    pub fn take_ledger(&mut self) -> OverlapLedger {
        std::mem::take(&mut self.ledger)
    }

    /// Owner side of the fetch: serve every id request addressed to `o`.
    fn serve_requests(
        &self,
        req_recvs: &[Vec<Payload>],
        disp: &AggDispatch,
        quant_secs: &mut [f64],
    ) -> Vec<Vec<Payload>> {
        let k = self.per_lane.len();
        let mut reply_sends: Vec<Vec<Payload>> = (0..k)
            .map(|_| (0..k).map(|_| Payload::Empty).collect())
            .collect();
        for (o, row) in req_recvs.iter().enumerate() {
            for (w, payload) in row.iter().enumerate() {
                let ids = match payload {
                    Payload::F32(v) if !v.is_empty() => v,
                    _ => continue,
                };
                reply_sends[o][w] = reply_payload(
                    self.lg,
                    ids,
                    self.quant,
                    self.seed,
                    self.epoch,
                    self.round,
                    o,
                    w,
                    disp,
                    &mut quant_secs[o],
                );
            }
        }
        reply_sends
    }
}

impl GraphContext for MiniBatchCtx<'_> {
    fn lanes(&self) -> usize {
        self.per_lane.len()
    }

    /// The fetch: id requests to owners, then (quantized) feature-row
    /// replies, then per-lane assembly of the batch input matrix. Under
    /// `--overlap on` the locally owned rows are copied while the id
    /// exchange is outstanding (bit-exact either way: every batch row is
    /// written exactly once, from the same source).
    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Fetch, "fetch batch rows");
        let k = self.per_lane.len();
        let f = self.lg.feat_dim;
        // ---- id requests --------------------------------------------
        let req_sends: Vec<Vec<Payload>> = (0..k)
            .map(|w| match self.per_lane[w] {
                Some(bi) => request_ids(&self.batches[bi], self.assign, w, k)
                    .iter()
                    .map(|ids| ids_payload(ids))
                    .collect(),
                None => (0..k).map(|_| Payload::Empty).collect(),
            })
            .collect();
        if !self.overlap {
            let req_recvs = alltoallv_routed(req_sends, self.topo, self.machine, &mut *self.comm);
            let reply_sends = self.serve_requests(&req_recvs, disp, quant_secs);
            let mut replies =
                alltoallv_routed(reply_sends, self.topo, self.machine, &mut *self.comm);
            for w in 0..k {
                let bi = match self.per_lane[w] {
                    Some(bi) => bi,
                    None => continue,
                };
                let mb = &self.batches[bi];
                let decoded = decode_replies(&mut replies[w], disp, &mut quant_secs[w]);
                let t = Instant::now();
                assemble_x(self.lg, self.assign, mb, w, &decoded, f, &mut x[w])?;
                secs[w] += t.elapsed().as_secs_f64();
            }
            return Ok(());
        }
        // Overlap schedule: the request exchange is posted, the locally
        // owned batch rows copy while it is in flight, and only the
        // remotely owned rows wait for the replies.
        let before_req = self.comm.modeled_send_secs.clone();
        let mut interior_secs = vec![0f64; k];
        for w in 0..k {
            if let Some(bi) = self.per_lane[w] {
                let t = Instant::now();
                assemble_local(self.lg, self.assign, &self.batches[bi], w, f, &mut x[w]);
                interior_secs[w] = t.elapsed().as_secs_f64();
                secs[w] += interior_secs[w];
            }
        }
        let req_recvs = alltoallv_routed(req_sends, self.topo, self.machine, &mut *self.comm);
        let mut req_comm_secs = vec![0f64; k];
        for w in 0..k {
            req_comm_secs[w] = self.comm.modeled_send_secs[w] - before_req[w];
        }
        let reply_sends = self.serve_requests(&req_recvs, disp, quant_secs);
        let before_reply = self.comm.modeled_send_secs.clone();
        let mut replies =
            alltoallv_routed(reply_sends, self.topo, self.machine, &mut *self.comm);
        let mut reply_comm_secs = vec![0f64; k];
        for w in 0..k {
            reply_comm_secs[w] = self.comm.modeled_send_secs[w] - before_reply[w];
        }
        let mut boundary_secs = vec![0f64; k];
        for w in 0..k {
            let bi = match self.per_lane[w] {
                Some(bi) => bi,
                None => continue,
            };
            let mb = &self.batches[bi];
            let decoded = decode_replies(&mut replies[w], disp, &mut quant_secs[w]);
            let t = Instant::now();
            assemble_remote(self.assign, mb, w, &decoded, f, &mut x[w])?;
            boundary_secs[w] = t.elapsed().as_secs_f64();
            secs[w] += boundary_secs[w];
        }
        // Only the request leg overlaps the local-row copy; the reply
        // wire is serial and goes in its own stage so the model never
        // claims to hide it behind interior compute.
        let st = self.ledger.push(FETCH_REQ_STAGE);
        st.interior = interior_secs;
        st.comm = req_comm_secs;
        let st = self.ledger.push(FETCH_REPLY_STAGE);
        st.comm = reply_comm_secs;
        st.boundary = boundary_secs;
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        _layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Agg, "batch spmm");
        for (w, mat) in self.mats.iter().enumerate() {
            if let Some(a) = mat {
                let t = Instant::now();
                let zv = &mut z[w][..a.n_rows * fin];
                zv.iter_mut().for_each(|x| *x = 0.0);
                disp.spmm(a, &h[w][..a.n_cols * fin], fin, zv);
                secs[w] += t.elapsed().as_secs_f64();
            }
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Agg, "batch spmm transpose");
        for (w, mat) in self.mats.iter().enumerate() {
            if let Some(a) = mat {
                let t = Instant::now();
                disp.spmm_t(a, &dz[w][..a.n_rows * fin], fin, &mut d_h[w][..a.n_cols * fin]);
                secs[w] += t.elapsed().as_secs_f64();
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Per-pair building blocks, shared by the sequential multi-lane context
// and the threaded per-rank context (one implementation ⇒ transport
// parity is bit-exact by construction).
// ---------------------------------------------------------------------

fn induced_csr(mb: &MiniBatch) -> CsrMatrix {
    CsrMatrix {
        n_rows: mb.adj.n,
        n_cols: mb.adj.n,
        row_ptr: mb.adj.row_ptr.clone(),
        col_idx: mb.adj.col_idx.clone(),
        weights: mb.edge_weight.clone(),
    }
}

/// The remote feature-row ids lane `w` must fetch, grouped by owner.
fn request_ids(mb: &MiniBatch, assign: &[u32], w: usize, k: usize) -> Vec<Vec<u32>> {
    let mut req: Vec<Vec<u32>> = vec![Vec::new(); k];
    for &v in &mb.n_id {
        let o = assign[v as usize] as usize;
        if o != w {
            req[o].push(v);
        }
    }
    req
}

/// Ids travel as an F32 payload (`n < 2^24` keeps them exact — enforced
/// at trainer construction).
fn ids_payload(ids: &[u32]) -> Payload {
    if ids.is_empty() {
        Payload::Empty
    } else {
        Payload::F32(ids.iter().map(|&v| v as f32).collect())
    }
}

/// Owner `o` serves requester `w`: gather the requested feature rows,
/// optionally quantizing them (quantize time charged to the owner).
#[allow(clippy::too_many_arguments)]
fn reply_payload(
    lg: &LabelledGraph,
    ids: &[f32],
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    o: usize,
    w: usize,
    disp: &AggDispatch,
    quant_secs: &mut f64,
) -> Payload {
    let f = lg.feat_dim;
    let rows = ids.len();
    let mut buf = Vec::with_capacity(rows * f);
    for &idf in ids {
        buf.extend_from_slice(lg.feature_row(idf as usize));
    }
    match quant {
        Some(bits) => {
            let _sp = obs::span(TraceCategory::QuantPack, "quantize reply rows");
            let t = Instant::now();
            let qseed = mix2(
                mix2(seed, ((epoch as u64) << 20) ^ round as u64),
                ((o as u64) << 8) ^ w as u64,
            );
            let q = disp.quantize(&buf, rows, f, bits, qseed);
            *quant_secs += t.elapsed().as_secs_f64();
            Payload::Quant(q)
        }
        None => Payload::F32(buf),
    }
}

/// Move each reply out of its slot and dequantize (dequantize time
/// charged to the requester). `decoded[o]` = rows from owner `o`.
fn decode_replies(
    replies: &mut [Payload],
    disp: &AggDispatch,
    quant_secs: &mut f64,
) -> Vec<Option<Vec<f32>>> {
    let mut decoded: Vec<Option<Vec<f32>>> = vec![None; replies.len()];
    for (o, slot) in replies.iter_mut().enumerate() {
        match std::mem::replace(slot, Payload::Empty) {
            Payload::F32(v) if !v.is_empty() => decoded[o] = Some(v),
            Payload::Quant(q) => {
                let _sp = obs::span(TraceCategory::QuantUnpack, "dequantize reply rows");
                let t = Instant::now();
                decoded[o] = Some(disp.dequantize(&q));
                *quant_secs += t.elapsed().as_secs_f64();
            }
            _ => {}
        }
    }
    decoded
}

/// Copy the locally owned batch rows into `x` (the fetch's *interior*
/// half — needs no remote data, so the overlap schedule runs it while the
/// id exchange is outstanding).
fn assemble_local(
    lg: &LabelledGraph,
    assign: &[u32],
    mb: &MiniBatch,
    w: usize,
    f: usize,
    x: &mut [f32],
) {
    for (i, &v) in mb.n_id.iter().enumerate() {
        if assign[v as usize] as usize == w {
            x[i * f..(i + 1) * f].copy_from_slice(lg.feature_row(v as usize));
        }
    }
}

/// Fill the remotely owned batch rows from the decoded replies (the
/// *boundary* half — each reply consumed front to back, exactly once, in
/// `n_id` order, matching the owner's packing order).
fn assemble_remote(
    assign: &[u32],
    mb: &MiniBatch,
    w: usize,
    decoded: &[Option<Vec<f32>>],
    f: usize,
    x: &mut [f32],
) -> Result<()> {
    let mut cursors = vec![0usize; decoded.len()];
    for (i, &v) in mb.n_id.iter().enumerate() {
        let o = assign[v as usize] as usize;
        if o == w {
            continue;
        }
        let rows = decoded[o]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("missing reply from {o} to {w}"))?;
        let c = cursors[o];
        anyhow::ensure!((c + 1) * f <= rows.len(), "reply row underflow");
        x[i * f..(i + 1) * f].copy_from_slice(&rows[c * f..(c + 1) * f]);
        cursors[o] += 1;
    }
    Ok(())
}

/// Interleave local rows and decoded remote rows into the lane's batch
/// input matrix — the blocking-schedule assembly; every row is written by
/// exactly one of the two halves, so local-then-remote produces the
/// identical matrix.
fn assemble_x(
    lg: &LabelledGraph,
    assign: &[u32],
    mb: &MiniBatch,
    w: usize,
    decoded: &[Option<Vec<f32>>],
    f: usize,
    x: &mut [f32],
) -> Result<()> {
    assemble_local(lg, assign, mb, w, f, x);
    assemble_remote(assign, mb, w, decoded, f, x)
}

/// Single-rank mini-batch context for the threaded transport: lane
/// `rank`'s batch only (or `None` for an idle lane — it still serves
/// feature rows it owns and participates in every collective). All
/// mutable state is the rank's own; shared inputs (`LabelledGraph`,
/// ownership assignment) are `&` — the Send/Sync contract of
/// DESIGN.md §10.
pub struct MiniBatchRankCtx<'a> {
    rank: usize,
    lg: &'a LabelledGraph,
    assign: &'a [u32],
    batch: Option<&'a MiniBatch>,
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    round: usize,
    /// Overlapped fetch schedule over the split-phase fabric exchange
    /// (`--overlap on`, DESIGN.md §11).
    overlap: bool,
    ledger: OverlapLedger,
    fabric: &'a Fabric,
    comm: &'a mut CommStats,
    mat: Option<CsrMatrix>,
}

impl<'a> MiniBatchRankCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        lg: &'a LabelledGraph,
        assign: &'a [u32],
        batch: Option<&'a MiniBatch>,
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        round: usize,
        overlap: bool,
        fabric: &'a Fabric,
        comm: &'a mut CommStats,
    ) -> Self {
        let mat = batch.map(induced_csr);
        Self {
            rank,
            lg,
            assign,
            batch,
            machine,
            quant,
            seed,
            epoch,
            round,
            overlap,
            ledger: OverlapLedger::new(1),
            fabric,
            comm,
            mat,
        }
    }

    /// Hand this rank's single-lane overlap accounting back to the driver
    /// (empty when `--overlap off`).
    pub fn take_ledger(&mut self) -> OverlapLedger {
        std::mem::take(&mut self.ledger)
    }

    /// This rank's id-request send row.
    fn request_row(&self) -> Vec<Payload> {
        let k = self.fabric.k();
        match self.batch {
            Some(mb) => request_ids(mb, self.assign, self.rank, k)
                .iter()
                .map(|ids| ids_payload(ids))
                .collect(),
            None => (0..k).map(|_| Payload::Empty).collect(),
        }
    }

    /// Serve the id requests addressed to this owner.
    fn serve_row(
        &self,
        req_recvs: &[Payload],
        disp: &AggDispatch,
        quant_secs: &mut f64,
    ) -> Vec<Payload> {
        let k = self.fabric.k();
        let mut reply_sends: Vec<Payload> = (0..k).map(|_| Payload::Empty).collect();
        for (w, payload) in req_recvs.iter().enumerate() {
            let ids = match payload {
                Payload::F32(v) if !v.is_empty() => v,
                _ => continue,
            };
            reply_sends[w] = reply_payload(
                self.lg,
                ids,
                self.quant,
                self.seed,
                self.epoch,
                self.round,
                self.rank,
                w,
                disp,
                quant_secs,
            );
        }
        reply_sends
    }
}

impl GraphContext for MiniBatchRankCtx<'_> {
    fn lanes(&self) -> usize {
        1
    }

    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Fetch, "fetch batch rows");
        let f = self.lg.feat_dim;
        if !self.overlap {
            // Blocking schedule: request → serve → reply → assemble.
            let req_sends = self.request_row();
            let req_recvs =
                self.fabric.alltoallv(self.rank, req_sends, self.machine, self.comm);
            let reply_sends = self.serve_row(&req_recvs, disp, &mut quant_secs[0]);
            let mut replies =
                self.fabric.alltoallv(self.rank, reply_sends, self.machine, self.comm);
            if let Some(mb) = self.batch {
                let decoded = decode_replies(&mut replies, disp, &mut quant_secs[0]);
                let t = Instant::now();
                assemble_x(self.lg, self.assign, mb, self.rank, &decoded, f, &mut x[0])?;
                secs[0] += t.elapsed().as_secs_f64();
            }
            return Ok(());
        }
        // Overlap schedule: post the id requests, copy the locally owned
        // batch rows while peers deposit, then complete, serve, and fill
        // the remotely owned rows from the replies.
        let before_req = self.comm.modeled_send_secs[self.rank];
        let req_sends = self.request_row();
        self.fabric
            .post_alltoallv(self.rank, req_sends, self.machine, self.comm);
        let mut interior = 0f64;
        if let Some(mb) = self.batch {
            let t = Instant::now();
            assemble_local(self.lg, self.assign, mb, self.rank, f, &mut x[0]);
            interior = t.elapsed().as_secs_f64();
            secs[0] += interior;
        }
        let req_recvs = self.fabric.complete_alltoallv(self.rank);
        let req_comm = self.comm.modeled_send_secs[self.rank] - before_req;
        let reply_sends = self.serve_row(&req_recvs, disp, &mut quant_secs[0]);
        let before_reply = self.comm.modeled_send_secs[self.rank];
        self.fabric
            .post_alltoallv(self.rank, reply_sends, self.machine, self.comm);
        let mut replies = self.fabric.complete_alltoallv(self.rank);
        let reply_comm = self.comm.modeled_send_secs[self.rank] - before_reply;
        let mut boundary = 0f64;
        if let Some(mb) = self.batch {
            let decoded = decode_replies(&mut replies, disp, &mut quant_secs[0]);
            let t = Instant::now();
            assemble_remote(self.assign, mb, self.rank, &decoded, f, &mut x[0])?;
            boundary = t.elapsed().as_secs_f64();
            secs[0] += boundary;
        }
        // Two stages — only the request leg overlaps the local-row copy
        // (see FETCH_REQ_STAGE docs).
        let st = self.ledger.push(FETCH_REQ_STAGE);
        st.interior[0] = interior;
        st.comm[0] = req_comm;
        let st = self.ledger.push(FETCH_REPLY_STAGE);
        st.comm[0] = reply_comm;
        st.boundary[0] = boundary;
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        _layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Agg, "batch spmm");
        if let Some(a) = &self.mat {
            let t = Instant::now();
            let zv = &mut z[0][..a.n_rows * fin];
            zv.iter_mut().for_each(|x| *x = 0.0);
            disp.spmm(a, &h[0][..a.n_cols * fin], fin, zv);
            secs[0] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        let _sp = obs::span(TraceCategory::Agg, "batch spmm transpose");
        if let Some(a) = &self.mat {
            let t = Instant::now();
            disp.spmm_t(a, &dz[0][..a.n_rows * fin], fin, &mut d_h[0][..a.n_cols * fin]);
            secs[0] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{Engine, LossSpec, StageClock};
    use crate::graph::generate::sbm;
    use crate::model::ModelParams;
    use crate::runtime::ShapeConfig;
    use crate::sample::{FullSampler, Sampler};
    use crate::util::propcheck::grad_check;
    use std::sync::Arc;

    fn fd_shapes() -> ShapeConfig {
        ShapeConfig {
            name: "fd".into(),
            n_pad: 0,
            f_in: 6,
            hidden: 5,
            classes: 3,
            e_local: 0,
            e_pre: 0,
            p_pre: 0,
            r_pre: 0,
            r_post: 0,
            e_post: 0,
        }
    }

    /// The shared finite-difference gradient check
    /// (`util::propcheck::grad_check`) run against the engine in the
    /// mini-batch regime; `tests/trainer_equivalence.rs` runs the same
    /// check in the full-batch regime.
    #[test]
    fn engine_backward_matches_finite_differences() {
        let lg = Arc::new(sbm(60, 3, 6.0, 0.9, 6, 0.3, 3));
        let mut sampler = FullSampler::new(lg.clone());
        let batches = vec![sampler.sample(0, 0)];
        let per_lane = vec![Some(0usize)];
        let shapes = fd_shapes();
        let engine = Engine::new(&shapes, false, AggDispatch::default());
        let params = ModelParams::init(&shapes, 7);
        let machine = MachineProfile::abci();
        let assign = vec![0u32; lg.n()];
        let rows = vec![batches[0].n()];
        let nt = batches[0].n_target;
        let labels: Vec<u32> = batches[0].n_id[..nt]
            .iter()
            .map(|&v| lg.labels[v as usize])
            .collect();
        let split: Vec<u8> = batches[0].n_id[..nt]
            .iter()
            .map(|&v| lg.split[v as usize])
            .collect();

        let run = |p: &ModelParams, want_grads: bool| -> (f64, Vec<f32>) {
            let mut comm = CommStats::new(1);
            let mut ctx = MiniBatchCtx::new(
                &lg, &assign, &batches, &per_lane, &machine, None, 5, 0, 0, false, &mut comm,
            );
            let mut tapes = engine.tapes(&rows, p);
            let mut clock = StageClock::new(1);
            engine
                .forward(p, &mut ctx, &mut tapes, None, &mut clock)
                .unwrap();
            let spec = LossSpec {
                score_rows: nt,
                labels: &labels,
                split: &split,
                loss_w: &batches[0].node_weight,
            };
            let tot = engine.loss_all(&mut tapes, &[spec], &mut clock)[0];
            let loss = tot.loss_sum / tot.wsum;
            if !want_grads {
                return (loss, Vec::new());
            }
            engine.scale_loss_grad(&mut tapes, &[(1.0 / tot.wsum) as f32]);
            engine
                .backward(p, &mut ctx, &mut tapes, None, false, &mut clock)
                .unwrap();
            (loss, tapes.grads[0].flatten())
        };

        let (_, analytic) = run(&params, true);
        let flat = params.flatten();
        // Probe w_self/w_neigh/b coordinates of each layer (layout: per
        // layer w_self, w_neigh, b).
        let l0 = 2 * 6 * 5 + 5;
        let l1 = 2 * 5 * 5 + 5;
        let probes = [
            0usize,              // layer0 w_self
            6 * 5 + 3,           // layer0 w_neigh
            2 * 6 * 5 + 2,       // layer0 b
            l0 + 1,              // layer1 w_self
            l0 + 5 * 5 + 2,      // layer1 w_neigh
            l0 + l1 + 4,         // layer2 w_self
            l0 + l1 + 5 * 3 + 1, // layer2 w_neigh
        ];
        grad_check(&flat, &analytic, &probes, 1e-2, |p| {
            let mut pp = ModelParams::init(&fd_shapes(), 7);
            pp.unflatten_into(p);
            run(&pp, false).0
        });
    }

    #[test]
    fn idle_lanes_are_noops() {
        let lg = Arc::new(sbm(80, 3, 5.0, 0.9, 6, 0.3, 9));
        let mut sampler = FullSampler::new(lg.clone());
        let batches = vec![sampler.sample(0, 0)];
        // Lane 1 idle.
        let per_lane = vec![Some(0usize), None];
        let shapes = fd_shapes();
        let engine = Engine::new(&shapes, false, AggDispatch::default());
        let params = ModelParams::init(&shapes, 3);
        let machine = MachineProfile::abci();
        let assign = vec![0u32; lg.n()];
        let rows = vec![batches[0].n(), 0];
        let mut comm = CommStats::new(2);
        let mut ctx = MiniBatchCtx::new(
            &lg, &assign, &batches, &per_lane, &machine, None, 1, 0, 0, false, &mut comm,
        );
        let mut tapes = engine.tapes(&rows, &params);
        let mut clock = StageClock::new(2);
        engine
            .forward(&params, &mut ctx, &mut tapes, None, &mut clock)
            .unwrap();
        assert!(tapes.h[3][0].iter().any(|&v| v != 0.0));
        assert!(tapes.h[3][1].is_empty());
        // Idle lane produced zero grads.
        engine
            .backward(&params, &mut ctx, &mut tapes, None, false, &mut clock)
            .unwrap();
        assert!(tapes.grads[1].flatten().iter().all(|&g| g == 0.0));
    }
}
