//! Fault-tolerance contract tests (DESIGN.md §15).
//!
//! Three guarantees, each pinned to the bit:
//!
//! 1. **Checkpoint round-trip** — a v2 checkpoint restores the exact
//!    driver state that wrote it (params, optimizer moments, RNG, epoch),
//!    so re-saving a freshly resumed trainer reproduces the file
//!    byte-for-byte.
//! 2. **Resume equivalence** — `--checkpoint-every` + `--resume` splits a
//!    run in two with per-epoch losses bit-identical to the uninterrupted
//!    run, in both training regimes and under both transports.
//! 3. **Elastic recovery** — a rank killed mid-epoch (the `--chaos`
//!    injection hook) is absorbed at the epoch boundary: the failed
//!    shard is re-planned across the survivors and the run continues
//!    with losses bit-identical to a fresh run on the survivor plan
//!    started from the pre-failure snapshot.
//!
//! The chaos legs write a recovery trace to `$SUPERGCN_CHAOS_TRACE` when
//! set (the CI `chaos-smoke` job uploads it as a workflow artifact).

use std::path::PathBuf;
use std::sync::Arc;
use supergcn::comm::transport::{FaultSpec, TransportKind};
use supergcn::coordinator::minibatch::MiniBatchTrainer;
use supergcn::coordinator::planner::{partition_for, prepare_parts, survivor_partition};
use supergcn::coordinator::trainer::EpochStats;
use supergcn::graph::generate::{sbm, LabelledGraph};
use supergcn::model::optimizer::OptKind;
use supergcn::obs::{Telemetry, Tracer};
use supergcn::run::RunConfig;
use supergcn::sample::SamplerKind;

/// Small SBM workload: big enough that every rank owns halo rows, small
/// enough that the threaded chaos legs stay fast.
fn lg() -> Arc<LabelledGraph> {
    Arc::new(sbm(360, 4, 8.0, 0.8, 12, 0.5, 7))
}

/// Unique scratch path per (process, test) — tests run in parallel.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("supergcn_ckpt_{}_{name}", std::process::id()))
}

fn loss_bits(stats: &[EpochStats]) -> Vec<u32> {
    stats.iter().map(|s| s.train_loss.to_bits()).collect()
}

fn assert_bits_eq(a: &[u32], b: &[u32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: epoch count diverged");
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x, y, "{what}: loss bits diverged at position {e}");
    }
}

// ---- 1. checkpoint round-trip ---------------------------------------

#[test]
fn checkpoint_roundtrip_is_byte_identical() {
    for opt in [OptKind::Adam, OptKind::Sgd] {
        let rc = RunConfig {
            epochs: 4,
            opt,
            ..Default::default()
        };
        let fp = rc.fingerprint();
        let p1 = tmp(&format!("rt1_{opt:?}"));
        let p2 = tmp(&format!("rt2_{opt:?}"));

        let mut tr = rc.full_batch_trainer_elastic(lg(), 3).unwrap();
        tr.run(false).unwrap();
        tr.save_checkpoint(&p1, fp).unwrap();

        // A fresh trainer resumed from the file holds the exact same
        // driver state — re-saving must reproduce the file bit-for-bit.
        let mut tr2 = rc.full_batch_trainer_elastic(lg(), 3).unwrap();
        let epoch = tr2.resume_from(&p1, Some(fp)).unwrap();
        assert_eq!(epoch, 4, "resume must land on the saved epoch counter");
        tr2.save_checkpoint(&p2, fp).unwrap();

        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        assert_eq!(b1, b2, "{opt:?}: resumed re-save must be byte-identical");
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }
}

#[test]
fn resume_refuses_fingerprint_mismatch() {
    let rc = RunConfig {
        epochs: 2,
        ..Default::default()
    };
    let p = tmp("mismatch");
    let mut tr = rc.full_batch_trainer_elastic(lg(), 3).unwrap();
    tr.run(false).unwrap();
    tr.save_checkpoint(&p, rc.fingerprint()).unwrap();

    // A numerics-changing drift (different lr) must be refused…
    let drifted = RunConfig {
        lr: 0.05,
        ..rc.clone()
    };
    assert_ne!(rc.fingerprint(), drifted.fingerprint());
    let mut tr2 = drifted.full_batch_trainer_elastic(lg(), 3).unwrap();
    let err = tr2
        .resume_from(&p, Some(drifted.fingerprint()))
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("fingerprint mismatch"),
        "unexpected error: {err:#}"
    );

    // …while an executor-shape drift (epochs / checkpoint knobs) resumes
    // fine: the fingerprint deliberately excludes it.
    let extended = RunConfig {
        epochs: 9,
        checkpoint_every: 3,
        ..rc.clone()
    };
    assert_eq!(rc.fingerprint(), extended.fingerprint());
    let mut tr3 = extended.full_batch_trainer_elastic(lg(), 3).unwrap();
    assert_eq!(tr3.resume_from(&p, Some(extended.fingerprint())).unwrap(), 2);
    let _ = std::fs::remove_file(&p);
}

// ---- 2. resume equivalence ------------------------------------------

#[test]
fn resume_matches_uninterrupted_full_batch() {
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        let total = 9usize;
        let cut = 6usize;
        let path = tmp(&format!("fb_{}", transport.name()));

        // A: the uninterrupted reference.
        let rc_a = RunConfig {
            epochs: total,
            transport,
            ..Default::default()
        };
        let mut a = rc_a.full_batch_trainer_elastic(lg(), 3).unwrap();
        let sa = a.run(false).unwrap();

        // B: same numerics, stopped at the cut with a checkpoint written
        // there (epochs and checkpoint knobs are fingerprint-neutral).
        let rc_b = RunConfig {
            epochs: cut,
            checkpoint_every: cut,
            checkpoint_path: path.clone(),
            ..rc_a.clone()
        };
        assert_eq!(rc_a.fingerprint(), rc_b.fingerprint());
        let mut b = rc_b.full_batch_trainer_elastic(lg(), 3).unwrap();
        let sb = b.run(false).unwrap();

        // C: a fresh process resuming the checkpoint to the full length.
        let mut c = rc_a.full_batch_trainer_elastic(lg(), 3).unwrap();
        assert_eq!(c.resume_from(&path, Some(rc_a.fingerprint())).unwrap(), cut);
        let sc = c.run(false).unwrap();

        let what = format!("full-batch resume ({})", transport.name());
        assert_bits_eq(&loss_bits(&sb), &loss_bits(&sa[..cut]), &what);
        assert_bits_eq(&loss_bits(&sc), &loss_bits(&sa[cut..]), &what);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn resume_matches_uninterrupted_minibatch() {
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        let total = 5usize;
        let cut = 3usize;
        let path = tmp(&format!("mb_{}", transport.name()));

        let rc_a = RunConfig {
            sampler: SamplerKind::Neighbor,
            epochs: total,
            transport,
            batch_size: 64,
            fanouts: vec![4, 3],
            ..Default::default()
        };
        let mut a = rc_a.minibatch_trainer(lg(), 3).unwrap();
        let sa = a.run(false).unwrap();

        let rc_b = RunConfig {
            epochs: cut,
            checkpoint_every: cut,
            checkpoint_path: path.clone(),
            ..rc_a.clone()
        };
        assert_eq!(rc_a.fingerprint(), rc_b.fingerprint());
        let mut b = rc_b.minibatch_trainer(lg(), 3).unwrap();
        let sb = b.run(false).unwrap();

        let mut c = rc_a.minibatch_trainer(lg(), 3).unwrap();
        assert_eq!(c.resume_from(&path, Some(rc_a.fingerprint())).unwrap(), cut);
        let sc = c.run(false).unwrap();

        let what = format!("mini-batch resume ({})", transport.name());
        assert_bits_eq(&loss_bits(&sb), &loss_bits(&sa[..cut]), &what);
        assert_bits_eq(&loss_bits(&sc), &loss_bits(&sa[cut..]), &what);
        let _ = std::fs::remove_file(&path);
    }
}

// ---- 3. elastic rank-failure recovery -------------------------------

#[test]
fn chaos_rank_loss_recovers_full_batch() {
    let graph = lg();
    let total = 7usize;
    let fail_epoch = 3usize;
    let failed_rank = 1usize;

    // A: the chaos run — rank 1's thread is killed entering epoch 3; the
    // driver re-plans its shard across the 3 survivors and retries. The
    // CI chaos-smoke leg runs exactly this shape (threaded, group-size 2,
    // overlap on) and uploads the recovery trace.
    let rc = RunConfig {
        epochs: total,
        transport: TransportKind::Threaded,
        overlap: true,
        group_size: 2,
        chaos: Some(FaultSpec {
            rank: failed_rank,
            epoch: fail_epoch,
        }),
        ..Default::default()
    };
    let tracer = Tracer::new();
    let mut a = rc.full_batch_trainer_elastic(graph.clone(), 4).unwrap();
    a.telemetry = Telemetry {
        tracer: Some(tracer.clone()),
        metrics: None,
    };
    let sa = a.run(false).unwrap();
    assert_eq!(sa.len(), total, "every epoch must complete despite the kill");
    assert_eq!(a.k(), 3, "the failed rank must be gone from the plan");
    assert!(tracer.span_count() > 0, "recovery must land in the trace");
    if let Ok(path) = std::env::var("SUPERGCN_CHAOS_TRACE") {
        tracer.write(&path).unwrap();
    }

    // B: pre-failure reference — same config minus chaos, run to the
    // boundary the kill interrupted. Bit-identical prefix.
    let rc_b = RunConfig {
        epochs: fail_epoch,
        chaos: None,
        ..rc.clone()
    };
    let mut b = rc_b.full_batch_trainer_elastic(graph.clone(), 4).unwrap();
    let sb = b.run(false).unwrap();
    assert_bits_eq(
        &loss_bits(&sa[..fail_epoch]),
        &loss_bits(&sb),
        "full-batch chaos prefix",
    );

    // C: post-failure reference — a fresh trainer on the survivor plan,
    // started from B's epoch-boundary state. The recovered run's tail
    // must match it bit-for-bit.
    let part = partition_for(&graph, 4, rc.seed);
    let survivors = survivor_partition(&graph.graph, &part, failed_rank).unwrap();
    let (ctxs, cfg, _) =
        prepare_parts(&graph, &survivors, rc.strategy, None, rc.hidden).unwrap();
    let rc_c = RunConfig {
        chaos: None,
        ..rc.clone()
    };
    let mut c = rc_c.full_batch_trainer(ctxs, cfg);
    c.restore(&b.snapshot());
    let sc = c.run(false).unwrap();
    assert_bits_eq(
        &loss_bits(&sa[fail_epoch..]),
        &loss_bits(&sc),
        "full-batch chaos tail",
    );
}

#[test]
fn chaos_rank_loss_recovers_minibatch() {
    let graph = lg();
    let total = 5usize;
    let fail_epoch = 2usize;
    let failed_rank = 1usize;

    let rc = RunConfig {
        sampler: SamplerKind::Neighbor,
        epochs: total,
        transport: TransportKind::Threaded,
        batch_size: 64,
        fanouts: vec![4, 3],
        chaos: Some(FaultSpec {
            rank: failed_rank,
            epoch: fail_epoch,
        }),
        ..Default::default()
    };
    let mut a = rc.minibatch_trainer(graph.clone(), 3).unwrap();
    let sa = a.run(false).unwrap();
    assert_eq!(sa.len(), total, "every epoch must complete despite the kill");
    assert_eq!(a.k(), 2, "the failed rank must be gone from the plan");

    let rc_b = RunConfig {
        epochs: fail_epoch,
        chaos: None,
        ..rc.clone()
    };
    let mut b = rc_b.minibatch_trainer(graph.clone(), 3).unwrap();
    let sb = b.run(false).unwrap();
    assert_bits_eq(
        &loss_bits(&sa[..fail_epoch]),
        &loss_bits(&sb),
        "mini-batch chaos prefix",
    );

    let part = partition_for(&graph, 3, rc.seed);
    let survivors = survivor_partition(&graph.graph, &part, failed_rank).unwrap();
    let rc_c = RunConfig {
        chaos: None,
        ..rc.clone()
    };
    let mut c = MiniBatchTrainer::with_partition(
        graph.clone(),
        survivors,
        rc_c.sampler,
        &rc_c.sampler_config(),
        rc_c.minibatch_config(),
    )
    .unwrap();
    c.restore(&b.snapshot());
    let sc = c.run(false).unwrap();
    assert_bits_eq(
        &loss_bits(&sa[fail_epoch..]),
        &loss_bits(&sc),
        "mini-batch chaos tail",
    );
}
