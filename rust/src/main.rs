//! `supergcn` — the leader binary: distributed full-batch *and*
//! mini-batch GCN training on a simulated CPU supercomputer (see
//! DESIGN.md §1 for the simulation contract, §8 for the sampling
//! subsystem).
//!
//! Subcommands:
//!   train       end-to-end training run (native or xla backend);
//!               --sampler full|neighbor|saint-rw|saint-node|saint-edge|cluster
//!   partition   partition a dataset, report quality vs baselines
//!   volume      Table-5-style comm-volume report across strategies
//!   perfmodel   Fig-7 analytic speedup sweep
//!   datasets    list the Table-2-style catalog

use anyhow::Result;
use supergcn::comm::transport::{Topology, TransportKind};
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::exec::{AggDispatch, AggKernel};
use supergcn::coordinator::planner::prepare;
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::graph::generate::LabelledGraph;
use supergcn::sample::{SamplerConfig, SamplerKind};
use std::sync::Arc;
use supergcn::datasets;
use supergcn::exp::Table;
use supergcn::graph::stats::stats;
use supergcn::hier::volume::{volume, RemoteStrategy, ALL_STRATEGIES};
use supergcn::hier::remote_pairs;
use supergcn::model::optimizer::OptKind;
use supergcn::obs::{MetricsRegistry, Telemetry, Tracer};
use supergcn::partition::{self, multilevel};
use supergcn::perfmodel::{crossover_procs, fig7_sweep, MachineProfile};
use supergcn::quant::Bits;
use supergcn::util::args::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let r = match cmd {
        "train" => cmd_train(&rest),
        "partition" => cmd_partition(&rest),
        "volume" => cmd_volume(&rest),
        "perfmodel" => cmd_perfmodel(&rest),
        "benchcmp" => cmd_benchcmp(&rest),
        "datasets" => cmd_datasets(),
        _ => {
            eprintln!(
                "usage: supergcn <train|partition|volume|perfmodel|benchcmp|datasets> [--help]\n\
                 SuperGCN: distributed full-batch and mini-batch GCN training for CPU\n\
                 supercomputers. `train --sampler full` is the paper's full-batch loop;\n\
                 `--sampler neighbor|saint-rw|saint-node|saint-edge|cluster` trains with\n\
                 the sampling regime (see `train --help` for fan-out/batch flags).\n\
                 `--transport threaded` runs one OS thread per SPMD rank (mailbox\n\
                 collectives, real multi-core wall clock — bit-exact with `seq`);\n\
                 `--rank-threads` asserts the thread count (0 = one per worker).\n\
                 `--overlap on` posts each halo exchange before interior aggregation\n\
                 so wire time hides behind compute — bit-exact with `--overlap off`\n\
                 (DESIGN.md §11). `--group-size g` groups ranks onto simulated nodes\n\
                 and stages cross-node payloads through per-node leaders, cutting\n\
                 inter-node messages from O(P²) to O((P/g)²) — bit-exact with the\n\
                 flat exchange (DESIGN.md §12). `--agg-kernel simd` selects the\n\
                 runtime-dispatched AVX2 aggregation + quantization rung (scalar\n\
                 fallback off x86_64) — bit-exact with every other rung, and the\n\
                 default `auto` prefers it when the ISA is detected (DESIGN.md\n\
                 §14). `--trace out.json` records per-rank\n\
                 spans to a Perfetto/chrome trace; `--metrics-json out.json` writes\n\
                 the epoch-structured metrics report (DESIGN.md §13). `benchcmp`\n\
                 gates CI on the committed BENCH_seed.json."
            );
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_strategy(s: &str) -> Result<RemoteStrategy> {
    Ok(match s {
        "raw" => RemoteStrategy::Raw,
        "pre" => RemoteStrategy::PreOnly,
        "post" => RemoteStrategy::PostOnly,
        "hybrid" => RemoteStrategy::Hybrid,
        _ => anyhow::bail!("strategy must be raw|pre|post|hybrid"),
    })
}

fn parse_machine(s: &str) -> Result<MachineProfile> {
    Ok(match s {
        "abci" => MachineProfile::abci(),
        "fugaku" => MachineProfile::fugaku(),
        _ => anyhow::bail!("machine must be abci|fugaku"),
    })
}

fn parse_overlap(s: &str) -> Result<bool> {
    Ok(match s {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        _ => anyhow::bail!("overlap must be off|on"),
    })
}

fn parse_quant(s: &str) -> Result<Option<Bits>> {
    Ok(match s {
        "fp32" | "none" => None,
        "int2" => Some(Bits::Int2),
        "int4" => Some(Bits::Int4),
        "int8" => Some(Bits::Int8),
        _ => anyhow::bail!("quant must be fp32|int2|int4|int8"),
    })
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = Args::new("supergcn train", "distributed full-batch GCN training")
        .opt("dataset", "arxiv-s", "catalog dataset name (see `datasets`)")
        .opt("procs", "4", "number of simulated workers")
        .opt("epochs", "0", "override epochs (0 = dataset default)")
        .opt("backend", "native", "native | xla")
        .opt("config", "quickstart", "artifact config (xla backend)")
        .opt("artifacts", "artifacts", "artifacts directory (xla backend)")
        .opt("quant", "fp32", "fp32 | int2 | int4 | int8")
        .opt("strategy", "hybrid", "raw | pre | post | hybrid")
        .opt("machine", "abci", "abci | fugaku network model")
        .opt("delay-comm", "1", "halo exchange every N epochs (DistGNN cd-N)")
        .opt(
            "agg-kernel",
            "auto",
            "auto | vanilla | sorted | blocked | parallel | spmm | simd (§4 dispatch)",
        )
        .opt(
            "agg-threshold",
            "4096",
            "contribution/nnz count below which parallel aggregation falls back to serial",
        )
        .opt("agg-threads", "1", "threads for the parallel aggregation kernels")
        .opt(
            "transport",
            "seq",
            "seq | threaded — step SPMD ranks sequentially (modeled parallel time \
             only) or run one OS thread per rank with mailbox collectives for real \
             multi-core wall-clock scaling; bit-exact either way (DESIGN.md §10)",
        )
        .opt(
            "rank-threads",
            "0",
            "OS threads for --transport threaded (0 = one per worker; any other \
             value must equal --procs — blocking mailbox collectives need every \
             rank resident)",
        )
        .opt(
            "overlap",
            "off",
            "off | on — post each layer's halo exchange before interior \
             aggregation so wire time overlaps compute (boundary rows finish \
             after receipt); bit-exact with 'off' (DESIGN.md §11)",
        )
        .opt(
            "group-size",
            "1",
            "ranks per simulated node: 1 = flat P×P alltoallv; ≥2 = two-level \
             exchange staging cross-node payloads through per-node leaders \
             (O((P/g)²) inter-node messages, intra-node tier accounted \
             separately); bit-exact with the flat exchange (DESIGN.md §12)",
        )
        .opt("seed", "42", "random seed")
        .opt(
            "trace",
            "",
            "write a Perfetto/chrome trace_event JSON of per-rank spans here \
             (pid = rank, tid = lane; empty = tracing off, zero overhead — \
             DESIGN.md §13)",
        )
        .opt(
            "metrics-json",
            "",
            "write the epoch-structured metrics report here (replaces the \
             console summary; empty = off — DESIGN.md §13)",
        )
        .opt(
            "sampler",
            "full",
            "full | neighbor | saint-rw | saint-node | saint-edge | cluster",
        )
        .opt("batch-size", "512", "mini-batch target nodes / SAINT node budget")
        .opt("fanouts", "15,10,5", "per-layer neighbor fan-outs (comma-separated)")
        .opt("walk-length", "3", "SAINT random-walk length")
        .opt("clusters", "0", "Cluster-GCN cluster count (0 = auto)")
        .opt("cluster-batch", "1", "clusters unioned per batch")
        .flag("label-prop", "enable masked label propagation")
        .parse_from(argv)?;

    let spec = datasets::by_name(&a.get_str("dataset"))?;
    let k = a.get_usize("procs");
    let epochs = a.get_usize("epochs");
    let lg = spec.build();
    println!("dataset {} ({}): {}", spec.name, spec.paper_analog, stats(&lg.graph));

    let agg = AggDispatch::default()
        .with_kernel(AggKernel::parse(&a.get_str("agg-kernel"))?)
        .with_threads(a.get_usize("agg-threads"))
        .with_parallel_min_work(a.get_usize("agg-threshold"));
    let transport = TransportKind::parse(&a.get_str("transport"))?;
    let rank_threads = a.get_usize("rank-threads");
    TransportKind::validate_rank_threads(rank_threads, k)?;
    let overlap = parse_overlap(&a.get_str("overlap"))?;
    let group_size = a.get_usize("group-size");
    Topology::validate_group_size(group_size, k)?;
    let trace_path = Some(a.get_str("trace")).filter(|s| !s.is_empty());
    let metrics_path = Some(a.get_str("metrics-json")).filter(|s| !s.is_empty());
    let tc = TrainConfig {
        epochs: if epochs == 0 { spec.epochs } else { epochs },
        lr: spec.lr,
        opt: OptKind::Adam,
        quant: parse_quant(&a.get_str("quant"))?,
        label_prop: a.get_flag("label-prop"),
        lp_frac: 0.5,
        strategy: parse_strategy(&a.get_str("strategy"))?,
        delay_comm: a.get_usize("delay-comm"),
        machine: parse_machine(&a.get_str("machine"))?,
        agg: agg.clone(),
        transport,
        rank_threads,
        overlap,
        group_size,
        seed: a.get_u64("seed"),
    };

    let backend_name = a.get_str("backend");
    let kind = SamplerKind::parse(&a.get_str("sampler"))?;
    if kind != SamplerKind::Full {
        anyhow::ensure!(
            backend_name == "native",
            "mini-batch samplers run on the native engine (got --backend {backend_name})"
        );
        // Full-batch-only options must not silently vanish.
        anyhow::ensure!(
            !tc.label_prop,
            "--label-prop only applies to --sampler full (the full-batch loop)"
        );
        anyhow::ensure!(
            tc.delay_comm <= 1,
            "--delay-comm only applies to --sampler full (mini-batch rounds are synchronous)"
        );
        anyhow::ensure!(
            tc.strategy == RemoteStrategy::Hybrid,
            "--strategy only applies to --sampler full (mini-batch fetches whole rows; \
             leave the default 'hybrid')"
        );
        let scfg = SamplerConfig {
            batch_size: a.get_usize("batch-size"),
            fanouts: a.get_usize_list("fanouts"),
            walk_length: a.get_usize("walk-length"),
            num_clusters: a.get_usize("clusters"),
            clusters_per_batch: a.get_usize("cluster-batch"),
            seed: tc.seed,
            ..Default::default()
        };
        // Reject bad values here with the CLI error path; the sampler
        // constructors enforce the same invariants with assert!.
        anyhow::ensure!(scfg.batch_size >= 1, "--batch-size must be >= 1");
        anyhow::ensure!(
            !scfg.fanouts.is_empty() && scfg.fanouts.iter().all(|&f| f >= 1),
            "--fanouts must be a non-empty comma-separated list of integers >= 1"
        );
        let mc = MiniBatchConfig {
            epochs: tc.epochs,
            lr: spec.lr,
            opt: OptKind::Adam,
            quant: tc.quant,
            hidden: spec.hidden,
            layernorm: false,
            agg,
            transport: tc.transport,
            rank_threads: tc.rank_threads,
            overlap: tc.overlap,
            group_size: tc.group_size,
            machine: tc.machine.clone(),
            seed: tc.seed,
        };
        return run_minibatch_training(Arc::new(lg), k, kind, scfg, mc, trace_path, metrics_path);
    }
    let (ctxs, cfg) = match backend_name.as_str() {
        "xla" => {
            // Load + warm the AOT artifact set so a broken artifact dir
            // fails fast; per-op artifact execution is cross-validated in
            // tests/backend_parity.rs, while the training hot loop always
            // runs on the unified exec::Engine (DESIGN.md §9).
            let mut rt = supergcn::runtime::Runtime::load(
                std::path::Path::new(&a.get_str("artifacts")),
                &a.get_str("config"),
            )?;
            let cfg = rt.config.clone();
            let warmed = rt.warmup()?;
            println!(
                "artifacts '{}' on {}: {} modules warmed (training runs on exec::Engine)",
                cfg.name,
                rt.platform(),
                warmed.len()
            );
            let (ctxs, cfg, _) = prepare(&lg, k, tc.strategy, Some(cfg), tc.seed)?;
            (ctxs, cfg)
        }
        "native" => {
            let (ctxs, mut cfg, _) = prepare(&lg, k, tc.strategy, None, tc.seed)?;
            cfg.hidden = spec.hidden;
            (ctxs, cfg)
        }
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    run_training(ctxs, cfg, tc, trace_path, metrics_path)
}

/// Construct the run's telemetry sinks from the CLI paths: a sink exists
/// iff its flag was given, so flag-off runs carry `Telemetry::default()`
/// (the §13 zero-cost disabled mode).
fn build_telemetry(trace_path: &Option<String>, metrics_path: &Option<String>) -> Telemetry {
    Telemetry {
        tracer: trace_path.as_ref().map(|_| Tracer::new()),
        metrics: metrics_path.as_ref().map(|_| MetricsRegistry::new()),
    }
}

/// Flush the trace to disk — called before propagating a run error, so a
/// failed (even poisoned) run still leaves a valid, truncated trace.
fn write_trace(tracer: &Option<Tracer>, path: &Option<String>) -> Result<()> {
    if let (Some(t), Some(p)) = (tracer, path) {
        t.write(p)?;
        println!("trace: {} spans -> {p}", t.span_count());
    }
    Ok(())
}

/// Write the metrics report, folding in run-level totals the per-epoch
/// publishes don't carry (tracer span accounting).
fn write_metrics(
    metrics: &Option<MetricsRegistry>,
    path: &Option<String>,
    tracer: &Option<Tracer>,
) -> Result<bool> {
    if let (Some(m), Some(p)) = (metrics, path) {
        if let Some(t) = tracer {
            m.counter_add("trace.spans.count", t.span_count() as f64);
            m.counter_add("trace.spans.dropped", t.dropped_count() as f64);
        }
        m.write(p)?;
        println!("metrics: {} epochs -> {p}", m.epoch_count());
        return Ok(true);
    }
    Ok(false)
}

fn run_training(
    ctxs: Vec<supergcn::coordinator::planner::WorkerCtx>,
    cfg: supergcn::runtime::ShapeConfig,
    tc: TrainConfig,
    trace_path: Option<String>,
    metrics_path: Option<String>,
) -> Result<()> {
    println!(
        "training: {} workers, config={}, transport={}, overlap={}, group-size={}, \
         agg-kernel={}, quant={:?}, lp={}, strategy={}, machine={}",
        ctxs.len(),
        cfg.name,
        tc.transport.name(),
        if tc.overlap { "on" } else { "off" },
        tc.group_size,
        tc.agg.kernel.name(),
        tc.quant.map(|b| b.name()).unwrap_or("fp32"),
        tc.label_prop,
        tc.strategy.name(),
        tc.machine.name,
    );
    let epochs = tc.epochs;
    let mut tr = Trainer::new(ctxs, cfg, tc);
    tr.telemetry = build_telemetry(&trace_path, &metrics_path);
    let run = tr.run(true);
    write_trace(&tr.telemetry.tracer, &trace_path)?;
    let stats = run?;
    if !write_metrics(&tr.telemetry.metrics, &metrics_path, &tr.telemetry.tracer)? {
        report_summary(epochs, &stats, &tr.comm_stats);
    }
    Ok(())
}

/// Final console summary shared by the full-batch and mini-batch runs.
fn report_summary(
    epochs: usize,
    stats: &[supergcn::coordinator::trainer::EpochStats],
    comm: &supergcn::comm::CommStats,
) {
    let last = stats.last().unwrap();
    let steady = supergcn::exp::steady_epoch_secs(stats, 10);
    println!(
        "\ndone: {} epochs  loss {:.4}  train {:.4}  val {:.4}  test {:.4}",
        epochs, last.train_loss, last.train_acc, last.val_acc, last.test_acc
    );
    println!(
        "modeled epoch time {:.4}s  breakdown: {}",
        steady,
        last.breakdown.report()
    );
    println!(
        "total comm: data {}  params {}",
        supergcn::util::fmt_bytes(comm.total_data_bytes()),
        supergcn::util::fmt_bytes(comm.total_param_bytes()),
    );
    if comm.tiers.is_active() {
        println!(
            "two-level transport: inter-node {} in {} msgs, intra-node {} in {} msgs \
             (modeled two-tier wire {:.4}s — DESIGN.md §12)",
            supergcn::util::fmt_bytes(comm.tiers.total_inter_bits() / 8.0),
            comm.tiers.total_inter_msgs(),
            supergcn::util::fmt_bytes(comm.tiers.total_intra_bits() / 8.0),
            comm.tiers.total_intra_msgs(),
            comm.tiers.modeled_two_tier_secs(),
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn run_minibatch_training(
    lg: Arc<LabelledGraph>,
    k: usize,
    kind: SamplerKind,
    scfg: SamplerConfig,
    mc: MiniBatchConfig,
    trace_path: Option<String>,
    metrics_path: Option<String>,
) -> Result<()> {
    println!(
        "mini-batch training: {} workers, sampler={}, transport={}, group-size={}, \
         quant={}, machine={}",
        k,
        kind.name(),
        mc.transport.name(),
        mc.group_size,
        mc.quant.map(|b| b.name()).unwrap_or("fp32"),
        mc.machine.name,
    );
    let epochs = mc.epochs;
    let mut tr = MiniBatchTrainer::new(lg, k, kind, &scfg, mc)?;
    tr.telemetry = build_telemetry(&trace_path, &metrics_path);
    println!(
        "  {} batches/epoch over the {}-way partition",
        tr.batches_per_epoch(),
        tr.k()
    );
    let run = tr.run(true);
    write_trace(&tr.telemetry.tracer, &trace_path)?;
    let stats = run?;
    if !write_metrics(&tr.telemetry.metrics, &metrics_path, &tr.telemetry.tracer)? {
        report_summary(epochs, &stats, &tr.comm_stats);
    }
    Ok(())
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let a = Args::new("supergcn partition", "partition quality report")
        .opt("dataset", "arxiv-s", "catalog dataset name")
        .opt("procs", "8", "parts")
        .opt("seed", "42", "seed")
        .parse_from(argv)?;
    let spec = datasets::by_name(&a.get_str("dataset"))?;
    let lg = spec.build();
    let k = a.get_usize("procs");
    let w = partition::vertex_weights(&lg.graph, None, 4);
    let mut t = Table::new(
        &format!("partition quality: {} k={k}", spec.name),
        &["method", "edge cut", "cut %", "weight imbalance"],
    );
    let ml = multilevel::multilevel(
        &lg.graph,
        k,
        &w,
        &multilevel::MultilevelOpts {
            seed: a.get_u64("seed"),
            ..Default::default()
        },
    );
    for (name, part) in [
        ("multilevel (METIS-like)", ml),
        ("random", partition::random(lg.n(), k, 1)),
        ("block", partition::block(lg.n(), k, &w)),
    ] {
        let q = partition::quality(&lg.graph, &part, &w);
        t.row(vec![
            name.into(),
            q.edge_cut.to_string(),
            format!("{:.1}%", q.cut_fraction * 100.0),
            format!("{:.3}", q.weight_imbalance),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_volume(argv: &[String]) -> Result<()> {
    let a = Args::new("supergcn volume", "comm volume across remote-graph strategies")
        .opt("dataset", "products-s", "catalog dataset name")
        .opt("procs", "8", "parts")
        .opt("seed", "42", "seed")
        .parse_from(argv)?;
    let spec = datasets::by_name(&a.get_str("dataset"))?;
    let lg = spec.build();
    let k = a.get_usize("procs");
    let w = partition::vertex_weights(&lg.graph, None, 4);
    let part = multilevel::multilevel(
        &lg.graph,
        k,
        &w,
        &multilevel::MultilevelOpts {
            seed: a.get_u64("seed"),
            ..Default::default()
        },
    );
    let pairs = remote_pairs(&lg.graph, &part);
    let mut t = Table::new(
        &format!("comm volume: {} k={k} feat={}", spec.name, spec.feat_dim),
        &["strategy", "rows", "fp32 bytes", "int2 bytes (+params)"],
    );
    for s in ALL_STRATEGIES {
        let v = volume(k, &pairs, s);
        t.row(vec![
            s.name().into(),
            v.total_rows().to_string(),
            supergcn::util::fmt_bytes(v.payload_bytes(spec.feat_dim, 32)),
            format!(
                "{} (+{})",
                supergcn::util::fmt_bytes(v.payload_bytes(spec.feat_dim, 2)),
                supergcn::util::fmt_bytes(v.param_bytes(4))
            ),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_perfmodel(argv: &[String]) -> Result<()> {
    let a = Args::new("supergcn perfmodel", "Fig-7 analytic quantization speedup sweep")
        .opt("machine", "fugaku", "abci | fugaku")
        .opt("bits", "2", "quantization bit width")
        .opt("volume", "1e8", "total cut volume at P=1 (f32 values)")
        .parse_from(argv)?;
    let machine = parse_machine(&a.get_str("machine"))?;
    let bits = a.get_f64("bits");
    let procs: Vec<usize> = (1..=13).map(|i| 1usize << i).collect();
    let pts = fig7_sweep(a.get_f64("volume"), 1.0 / 256.0, bits, &procs, &machine);
    let mut t = Table::new(
        &format!("Fig 7: quantized-comm speedup on {} (int{bits})", machine.name),
        &["procs", "delta", "speedup", "regime"],
    );
    for p in &pts {
        t.row(vec![
            p.procs.to_string(),
            format!("{:.3}", p.delta),
            format!("{:.2}x", p.speedup),
            p.regime.into(),
        ]);
    }
    t.print();
    if let Some(px) = crossover_procs(&pts) {
        println!("latency-bound crossover at P' = {px}");
    }
    Ok(())
}

/// CI perf gate: compare a fresh `benches/spmd_scaling.rs` JSON record
/// against the committed baseline and fail on threaded wall-clock
/// regressions beyond the threshold. Rows are keyed by (regime, ranks);
/// rows missing from either side are reported but never fail the gate
/// (the bench matrix may grow). Baselines are refreshed by copying a
/// healthy CI run's `BENCH_ci.json` artifact over `BENCH_seed.json`.
fn cmd_benchcmp(argv: &[String]) -> Result<()> {
    let a = Args::new("supergcn benchcmp", "bench-record regression gate")
        .opt("baseline", "BENCH_seed.json", "committed baseline record")
        .opt("current", "BENCH_ci.json", "freshly produced record")
        .opt(
            "threshold-pct",
            "25",
            "fail when current threaded wall secs exceed baseline by more than this",
        )
        .opt(
            "min-secs",
            "0.005",
            "ignore rows whose baseline threaded wall secs are below this (timer noise)",
        )
        .parse_from(argv)?;
    // Parse/compare logic lives in `supergcn::benchcmp` (unit-tested:
    // missing/corrupt records and empty run sets error out loudly).
    let baseline = supergcn::benchcmp::load_rows(&a.get_str("baseline"))?;
    let current = supergcn::benchcmp::load_rows(&a.get_str("current"))?;
    let report = supergcn::benchcmp::compare(
        &baseline,
        &current,
        a.get_f64("threshold-pct"),
        a.get_f64("min-secs"),
    );

    let mut t = Table::new(
        "bench gate: threaded wall secs, current vs committed baseline",
        &["row", "baseline s", "current s", "ratio", "verdict"],
    );
    let fmt_opt = |v: Option<f64>| v.map(|s| format!("{s:.4}")).unwrap_or_else(|| "-".into());
    for row in &report.rows {
        t.row(vec![
            row.key.clone(),
            fmt_opt(row.baseline_secs),
            fmt_opt(row.current_secs),
            row.ratio().map(|r| format!("{r:.2}x")).unwrap_or_else(|| "-".into()),
            row.verdict.label().into(),
        ]);
    }
    t.print();
    anyhow::ensure!(
        report.failures.is_empty(),
        "threaded wall-clock regressed >{:.0}% vs committed baseline:\n  {}",
        a.get_f64("threshold-pct"),
        report.failures.join("\n  ")
    );
    println!("bench gate passed ({} rows compared)", report.compared);
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(
        "dataset catalog (Table-2 analogues, scaled; DESIGN.md §1)",
        &["name", "paper analog", "n", "avg deg", "feat", "classes", "epochs"],
    );
    for d in datasets::catalog() {
        t.row(vec![
            d.name.into(),
            d.paper_analog.into(),
            d.n.to_string(),
            format!("{:.0}", d.avg_deg),
            d.feat_dim.to_string(),
            d.num_classes.to_string(),
            d.epochs.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
