//! Fig. 7: analytic speedup of quantized communication (Eqn 7/8) across
//! process counts and bit widths, on both machine profiles.
//!
//! Expected shape (paper): ≈γ speedup while throughput-bound (Int2 → 16×
//! asymptotically, reduced by the quant/dequant overhead term), decaying
//! to 1× as δ → ∞ (latency-bound), never below 1×.

use supergcn::exp::Table;
use supergcn::perfmodel::{crossover_procs, fig7_sweep, MachineProfile};

fn main() {
    for machine in [MachineProfile::abci(), MachineProfile::fugaku()] {
        let procs: Vec<usize> = (1..=13).map(|i| 1usize << i).collect();
        let mut t = Table::new(
            &format!("Fig 7 on {} (β = {:.0})", machine.name, machine.beta()),
            &["procs", "int2", "int4", "int8", "δ(int2)", "regime"],
        );
        let sweeps: Vec<_> = [2.0, 4.0, 8.0]
            .iter()
            .map(|&b| fig7_sweep(1e8, 1.0 / 256.0, b, &procs, &machine))
            .collect();
        for (i, &p) in procs.iter().enumerate() {
            t.row(vec![
                p.to_string(),
                format!("{:.2}x", sweeps[0][i].speedup),
                format!("{:.2}x", sweeps[1][i].speedup),
                format!("{:.2}x", sweeps[2][i].speedup),
                format!("{:.3}", sweeps[0][i].delta),
                sweeps[0][i].regime.into(),
            ]);
        }
        t.print();
        if let Some(px) = crossover_procs(&sweeps[0]) {
            println!("int2 latency-bound crossover: P' = {px}");
        }
        // Sanity assertions on the paper-claimed shape.
        assert!(sweeps[0][0].speedup > 8.0, "medium-scale int2 should approach γ");
        assert!(sweeps[0].last().unwrap().speedup < 2.0, "large scale decays to ~1");
        assert!(
            sweeps[0].iter().all(|p| p.speedup >= 1.0 - 1e-9),
            "quantization must never hurt"
        );
    }
}
