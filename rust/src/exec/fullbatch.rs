//! [`GraphContext`] for the full-batch regime (paper Fig. 2): neighbor
//! features arrive through the hierarchical pre/post halo exchange over
//! the partition plans (`hier::plan` via `coordinator::planner`), with
//! optional `quant::fused` payloads and `delay_comm` staleness. The
//! reverse pass ships halo cotangents back to their producers, so the
//! distributed gradient equals the single-machine gradient to f32
//! round-off (`tests/trainer_equivalence.rs`).
//!
//! Two context flavors share the per-lane state ([`LaneHalo`]) and the
//! exact same per-lane FP work (bit-exactness pinned by
//! `tests/spmd_parity.rs`):
//!
//! * [`FullBatchCtx`] — the sequential transport: one driver thread
//!   steps every lane stage-synchronously and exchanges the whole k×k
//!   payload matrix through `comm::alltoallv`;
//! * [`FullBatchRankCtx`] — the threaded transport: each rank thread
//!   owns one lane (`&mut LaneHalo`, no shared mutable graph state) and
//!   rendezvouses its send row through the mailbox
//!   [`Fabric`](crate::comm::transport::Fabric).

use super::dispatch::AggDispatch;
use super::GraphContext;
use crate::comm::transport::Fabric;
use crate::comm::{alltoallv, CommStats, Payload};
use crate::coordinator::planner::WorkerCtx;
use crate::perfmodel::MachineProfile;
use crate::quant::{fused, Bits};
use crate::runtime::ShapeConfig;
use anyhow::Result;
use std::time::Instant;

/// One lane's persistent halo state: received tensors survive across
/// epochs so `delay_comm > 1` (the DistGNN cd-N baseline) trains on stale
/// halos between exchange epochs, exactly like the paper's baseline.
/// Owned exclusively by its lane — the Send/Sync boundary that lets each
/// rank thread take `&mut` to its own halo with no cross-rank aliasing.
pub struct LaneHalo {
    /// `recv_pre[layer]`: received pre-aggregated partial rows.
    recv_pre: Vec<Vec<f32>>,
    /// `recv_post[layer]`: received raw post rows.
    recv_post: Vec<Vec<f32>>,
    /// Send-side pre-aggregation partials (`p_pre × maxf` scratch).
    partials: Vec<f32>,
    d_recv_pre: Vec<f32>,
    d_recv_post: Vec<f32>,
    d_partials: Vec<f32>,
}

impl LaneHalo {
    fn new(shapes: &ShapeConfig) -> Self {
        let dims = shapes.layer_dims();
        let maxf = shapes.f_in.max(shapes.hidden).max(shapes.classes);
        Self {
            recv_pre: (0..3).map(|l| vec![0f32; shapes.r_pre * dims[l].0]).collect(),
            recv_post: (0..3).map(|l| vec![0f32; shapes.r_post * dims[l].0]).collect(),
            partials: vec![0f32; shapes.p_pre * maxf],
            d_recv_pre: vec![0f32; shapes.r_pre * maxf],
            d_recv_post: vec![0f32; shapes.r_post * maxf],
            d_partials: vec![0f32; shapes.p_pre * maxf],
        }
    }
}

/// Persistent halo state for all lanes (one [`LaneHalo`] per worker).
pub struct FullBatchState {
    lanes: Vec<LaneHalo>,
}

impl FullBatchState {
    pub fn new(shapes: &ShapeConfig, lanes: usize) -> Self {
        Self {
            lanes: (0..lanes).map(|_| LaneHalo::new(shapes)).collect(),
        }
    }

    /// Split into per-lane halves for the threaded transport (each rank
    /// thread takes one `&mut LaneHalo`).
    pub fn lanes_mut(&mut self) -> &mut [LaneHalo] {
        &mut self.lanes
    }
}

/// One epoch's view over the workers: borrows the static contexts and the
/// persistent halo state, charges communication to the epoch's
/// [`CommStats`].
pub struct FullBatchCtx<'a> {
    workers: &'a [WorkerCtx],
    shapes: &'a ShapeConfig,
    st: &'a mut FullBatchState,
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    /// Exchange halos this epoch? (`delay_comm` staleness policy —
    /// decided by the driver.)
    exchange: bool,
    comm: &'a mut CommStats,
}

impl<'a> FullBatchCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        workers: &'a [WorkerCtx],
        shapes: &'a ShapeConfig,
        st: &'a mut FullBatchState,
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        exchange: bool,
        comm: &'a mut CommStats,
    ) -> Self {
        Self {
            workers,
            shapes,
            st,
            machine,
            quant,
            seed,
            epoch,
            exchange,
            comm,
        }
    }

    fn k(&self) -> usize {
        self.workers.len()
    }

    fn empty_matrix(k: usize) -> Vec<Vec<Payload>> {
        (0..k).map(|_| (0..k).map(|_| Payload::Empty).collect()).collect()
    }

    /// Forward halo exchange for layer `l`: quantize → wire → dequantize,
    /// scattering into the persistent recv buffers.
    fn exchange_fwd(
        &mut self,
        l: usize,
        fin: usize,
        h: &[Vec<f32>],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        let mut sends = Self::empty_matrix(k);
        for w in 0..k {
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                if let Some(p) = pack_fwd(
                    &self.workers[w],
                    &self.st.lanes[w],
                    w,
                    peer,
                    l,
                    fin,
                    &h[w],
                    self.quant,
                    self.seed,
                    self.epoch,
                    &mut quant_secs[w],
                ) {
                    sends[w][peer] = p;
                }
            }
        }
        let recvs = alltoallv(sends, self.machine, &mut *self.comm);
        for w in 0..k {
            scatter_fwd(
                &self.workers[w],
                &mut self.st.lanes[w],
                l,
                fin,
                &recvs[w],
                &mut quant_secs[w],
            )?;
        }
        Ok(())
    }

    /// Reverse exchange: consumers return halo cotangents (FP32 — the
    /// paper quantizes the forward feature communication only); producers
    /// fold them into `d_partials` / `d_h`.
    fn exchange_bwd(&mut self, fin: usize, d_h: &mut [Vec<f32>]) -> Result<()> {
        let k = self.k();
        let mut sends = Self::empty_matrix(k);
        for w in 0..k {
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                if let Some(p) = pack_bwd(&self.workers[w], &self.st.lanes[w], peer, fin) {
                    sends[w][peer] = p;
                }
            }
        }
        let recvs = alltoallv(sends, self.machine, &mut *self.comm);
        for w in 0..k {
            scatter_bwd(
                &self.workers[w],
                &mut self.st.lanes[w],
                fin,
                &recvs[w],
                &mut d_h[w],
            )?;
        }
        Ok(())
    }
}

impl GraphContext for FullBatchCtx<'_> {
    fn lanes(&self) -> usize {
        self.workers.len()
    }

    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        for (w, ctx) in self.workers.iter().enumerate() {
            let t = Instant::now();
            x[w].copy_from_slice(&ctx.features);
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        // Send-side pre-aggregation partials (§5: producer partially
        // aggregates covered destinations before shipping).
        for w in 0..k {
            let t = Instant::now();
            pre_partials(
                &self.workers[w],
                &mut self.st.lanes[w],
                self.shapes,
                fin,
                &h[w],
                disp,
            );
            secs[w] += t.elapsed().as_secs_f64();
        }
        if self.exchange {
            self.exchange_fwd(layer, fin, h, quant_secs)?;
        }
        // Local aggregation + received-halo scatter + mean scaling.
        for w in 0..k {
            let t = Instant::now();
            local_agg(
                &self.workers[w],
                &self.st.lanes[w],
                self.shapes,
                layer,
                fin,
                &h[w],
                &mut z[w],
                disp,
            );
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        for w in 0..k {
            let t = Instant::now();
            local_agg_bwd(
                &self.workers[w],
                &mut self.st.lanes[w],
                self.shapes,
                fin,
                &mut dz[w],
                &mut d_h[w],
                disp,
            );
            secs[w] += t.elapsed().as_secs_f64();
        }
        for w in 0..k {
            self.st.lanes[w].d_partials[..self.shapes.p_pre * fin]
                .iter_mut()
                .for_each(|x| *x = 0.0);
        }
        if self.exchange {
            self.exchange_bwd(fin, d_h)?;
        }
        // Scatter returned partial cotangents back through the pre gather:
        // d_h[gather[i]] += d_partials[seg[i]].
        for w in 0..k {
            let t = Instant::now();
            fold_returned_partials(&self.workers[w], &self.st.lanes[w], fin, &mut d_h[w]);
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Per-lane building blocks, shared verbatim by the sequential multi-lane
// context and the threaded per-rank context — one implementation is what
// makes transport parity bit-exact by construction.
// ---------------------------------------------------------------------

/// Zero and fill one lane's send-side pre-aggregation partials.
fn pre_partials(
    ctx: &WorkerCtx,
    lane: &mut LaneHalo,
    shapes: &ShapeConfig,
    fin: usize,
    h: &[f32],
    disp: &AggDispatch,
) {
    let p_pre = shapes.p_pre;
    let p = &mut lane.partials[..p_pre * fin];
    p.iter_mut().for_each(|x| *x = 0.0);
    disp.segment_sum(h, fin, &ctx.pre.gather, &ctx.pre.seg, p_pre, p);
}

/// Build the forward payload lane `w` sends to `peer` for layer `l`
/// (pre partials + raw post rows, optionally quantized). `None` when the
/// pair exchanges nothing.
#[allow(clippy::too_many_arguments)]
fn pack_fwd(
    ctx: &WorkerCtx,
    lane: &LaneHalo,
    w: usize,
    peer: usize,
    l: usize,
    fin: usize,
    h: &[f32],
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    quant_secs: &mut f64,
) -> Option<Payload> {
    let (plo, phi) = ctx.send_pre_range[peer];
    let post = &ctx.send_post_rows[peer];
    let rows = (phi - plo) + post.len();
    if rows == 0 {
        return None;
    }
    let mut buf = Vec::with_capacity(rows * fin);
    buf.extend_from_slice(&lane.partials[plo * fin..phi * fin]);
    for &r in post {
        buf.extend_from_slice(&h[r as usize * fin..(r as usize + 1) * fin]);
    }
    Some(match quant {
        Some(bits) => {
            let t = Instant::now();
            let qseed =
                (epoch as u64) << 32 | (w as u64) << 16 | (peer as u64) << 8 | l as u64;
            let q = fused::quantize(&buf, rows, fin, bits, qseed ^ seed);
            *quant_secs += t.elapsed().as_secs_f64();
            Payload::Quant(q)
        }
        None => Payload::F32(buf),
    })
}

/// Scatter one lane's received forward payloads (indexed by sender) into
/// its persistent recv buffers for layer `l`, resetting them first so
/// stale pads never leak.
fn scatter_fwd(
    ctx: &WorkerCtx,
    lane: &mut LaneHalo,
    l: usize,
    fin: usize,
    recvs: &[Payload],
    quant_secs: &mut f64,
) -> Result<()> {
    lane.recv_pre[l].iter_mut().for_each(|x| *x = 0.0);
    lane.recv_post[l].iter_mut().for_each(|x| *x = 0.0);
    for (peer, payload) in recvs.iter().enumerate() {
        if payload.is_empty() {
            continue;
        }
        let (plo, phi) = ctx.recv_pre_range[peer];
        let (qlo, qhi) = ctx.recv_post_range[peer];
        let rows = (phi - plo) + (qhi - qlo);
        let data: Vec<f32> = match payload {
            Payload::F32(v) => v.clone(),
            Payload::Quant(q) => {
                let t = Instant::now();
                let d = fused::dequantize(q);
                *quant_secs += t.elapsed().as_secs_f64();
                d
            }
            Payload::Empty => continue,
        };
        anyhow::ensure!(
            data.len() == rows * fin,
            "halo payload from {peer} to worker {}: {} values, expected {}",
            ctx.worker,
            data.len(),
            rows * fin
        );
        lane.recv_pre[l][plo * fin..phi * fin].copy_from_slice(&data[..(phi - plo) * fin]);
        lane.recv_post[l][qlo * fin..qhi * fin].copy_from_slice(&data[(phi - plo) * fin..]);
    }
    Ok(())
}

/// Local aggregation + received-halo scatter + mean scaling for one lane;
/// fully overwrites `z`.
#[allow(clippy::too_many_arguments)]
fn local_agg(
    ctx: &WorkerCtx,
    lane: &LaneHalo,
    shapes: &ShapeConfig,
    layer: usize,
    fin: usize,
    h: &[f32],
    z: &mut Vec<f32>,
    disp: &AggDispatch,
) {
    let n = shapes.n_pad;
    z.iter_mut().for_each(|x| *x = 0.0);
    disp.segment_sum(h, fin, &ctx.spec.local.gather, &ctx.spec.local.seg, n, z);
    let rp = &lane.recv_pre[layer];
    for (i, &d) in ctx.spec.rpre_dst.iter().enumerate() {
        let src = &rp[i * fin..(i + 1) * fin];
        let dst = &mut z[d as usize * fin..(d as usize + 1) * fin];
        for (a, &b) in dst.iter_mut().zip(src.iter()) {
            *a += b;
        }
    }
    let ro = &lane.recv_post[layer];
    for (&row, &d) in ctx.spec.post_row.iter().zip(ctx.spec.post_dst.iter()) {
        let src = &ro[row as usize * fin..(row as usize + 1) * fin];
        let dst = &mut z[d as usize * fin..(d as usize + 1) * fin];
        for (a, &b) in dst.iter_mut().zip(src.iter()) {
            *a += b;
        }
    }
    for (i, &dv) in ctx.spec.deg_inv.iter().enumerate() {
        for v in &mut z[i * fin..(i + 1) * fin] {
            *v *= dv;
        }
    }
}

/// Backward of [`local_agg`] for one lane: fold mean scaling into `dz`,
/// scatter through the transposed local/post specs, and capture the halo
/// cotangents (`d_recv_pre`/`d_recv_post`) for the reverse exchange.
fn local_agg_bwd(
    ctx: &WorkerCtx,
    lane: &mut LaneHalo,
    shapes: &ShapeConfig,
    fin: usize,
    dz: &mut [f32],
    d_h: &mut [f32],
    disp: &AggDispatch,
) {
    let n = shapes.n_pad;
    // Mean scaling folds into dZ.
    for (i, &dv) in ctx.spec.deg_inv.iter().enumerate() {
        for v in &mut dz[i * fin..(i + 1) * fin] {
            *v *= dv;
        }
    }
    let dzv = &dz[..n * fin];
    // (1) local edges, transposed: d_h[src] += dz[dst].
    disp.segment_sum(
        dzv,
        fin,
        &ctx.spec.local_t.gather,
        &ctx.spec.local_t.seg,
        n,
        &mut d_h[..n * fin],
    );
    // (2) received partials: d_recv_pre[i] = dz[rpre_dst[i]].
    for (i, &d) in ctx.spec.rpre_dst.iter().enumerate() {
        lane.d_recv_pre[i * fin..(i + 1) * fin]
            .copy_from_slice(&dzv[d as usize * fin..(d as usize + 1) * fin]);
    }
    // (3) post rows: d_recv_post[row] += dz[dst] (transposed spec).
    let drp = &mut lane.d_recv_post[..shapes.r_post * fin];
    drp.iter_mut().for_each(|x| *x = 0.0);
    disp.segment_sum(
        dzv,
        fin,
        &ctx.spec.post_t.gather,
        &ctx.spec.post_t.seg,
        shapes.r_post,
        drp,
    );
}

/// Build the reverse (cotangent) payload one lane returns to `peer`:
/// the pre/post halo cotangents it received from that producer.
fn pack_bwd(ctx: &WorkerCtx, lane: &LaneHalo, peer: usize, fin: usize) -> Option<Payload> {
    let (plo, phi) = ctx.recv_pre_range[peer];
    let (qlo, qhi) = ctx.recv_post_range[peer];
    let rows = (phi - plo) + (qhi - qlo);
    if rows == 0 {
        return None;
    }
    let mut buf = Vec::with_capacity(rows * fin);
    buf.extend_from_slice(&lane.d_recv_pre[plo * fin..phi * fin]);
    buf.extend_from_slice(&lane.d_recv_post[qlo * fin..qhi * fin]);
    Some(Payload::F32(buf))
}

/// Producer side of the reverse exchange: unpack returned cotangents into
/// `d_partials` (pre) and accumulate post-row cotangents into `d_h`.
fn scatter_bwd(
    ctx: &WorkerCtx,
    lane: &mut LaneHalo,
    fin: usize,
    recvs: &[Payload],
    d_h: &mut [f32],
) -> Result<()> {
    for (peer, payload) in recvs.iter().enumerate() {
        let payload = match payload {
            Payload::F32(v) if !v.is_empty() => v,
            _ => continue,
        };
        let (plo, phi) = ctx.send_pre_range[peer];
        let post = &ctx.send_post_rows[peer];
        let pre_vals = (phi - plo) * fin;
        anyhow::ensure!(
            payload.len() == pre_vals + post.len() * fin,
            "reverse payload size mismatch"
        );
        lane.d_partials[plo * fin..phi * fin].copy_from_slice(&payload[..pre_vals]);
        // d_h[post_row] += returned post cotangent.
        for (i, &r) in post.iter().enumerate() {
            let src = &payload[pre_vals + i * fin..pre_vals + (i + 1) * fin];
            let dst = &mut d_h[r as usize * fin..(r as usize + 1) * fin];
            for (a, &x) in dst.iter_mut().zip(src.iter()) {
                *a += x;
            }
        }
    }
    Ok(())
}

/// Final backward step for one lane: scatter returned partial cotangents
/// back through the pre gather (`d_h[gather[i]] += d_partials[seg[i]]`).
fn fold_returned_partials(ctx: &WorkerCtx, lane: &LaneHalo, fin: usize, d_h: &mut [f32]) {
    for (&g, &s) in ctx.pre.gather.iter().zip(ctx.pre.seg.iter()) {
        let src = &lane.d_partials[s as usize * fin..(s as usize + 1) * fin];
        let dst = &mut d_h[g as usize * fin..(g as usize + 1) * fin];
        for (a, &b) in dst.iter_mut().zip(src.iter()) {
            *a += b;
        }
    }
}

/// Single-rank full-batch context for the threaded transport: lane
/// `rank`'s view only. All mutable state is the rank's own
/// ([`LaneHalo`], its `CommStats` shard); everything shared is `&`
/// (worker plan, shapes, machine profile) — the Send/Sync contract of
/// DESIGN.md §10. Halo payloads rendezvous through the mailbox
/// [`Fabric`]; the engine drives it exactly like the sequential context
/// (it implements the same [`GraphContext`], with `lanes() == 1`).
pub struct FullBatchRankCtx<'a> {
    rank: usize,
    ctx: &'a WorkerCtx,
    shapes: &'a ShapeConfig,
    st: &'a mut LaneHalo,
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    exchange: bool,
    fabric: &'a Fabric,
    comm: &'a mut CommStats,
}

impl<'a> FullBatchRankCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        ctx: &'a WorkerCtx,
        shapes: &'a ShapeConfig,
        st: &'a mut LaneHalo,
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        exchange: bool,
        fabric: &'a Fabric,
        comm: &'a mut CommStats,
    ) -> Self {
        Self {
            rank,
            ctx,
            shapes,
            st,
            machine,
            quant,
            seed,
            epoch,
            exchange,
            fabric,
            comm,
        }
    }

    fn exchange_fwd(
        &mut self,
        l: usize,
        fin: usize,
        h: &[f32],
        quant_secs: &mut f64,
    ) -> Result<()> {
        let k = self.fabric.k();
        let mut sends: Vec<Payload> = (0..k).map(|_| Payload::Empty).collect();
        for (peer, slot) in sends.iter_mut().enumerate() {
            if peer == self.rank {
                continue;
            }
            if let Some(p) = pack_fwd(
                self.ctx, self.st, self.rank, peer, l, fin, h, self.quant, self.seed,
                self.epoch, quant_secs,
            ) {
                *slot = p;
            }
        }
        let recvs = self.fabric.alltoallv(self.rank, sends, self.machine, self.comm);
        scatter_fwd(self.ctx, self.st, l, fin, &recvs, quant_secs)
    }

    fn exchange_bwd(&mut self, fin: usize, d_h: &mut [f32]) -> Result<()> {
        let k = self.fabric.k();
        let mut sends: Vec<Payload> = (0..k).map(|_| Payload::Empty).collect();
        for (peer, slot) in sends.iter_mut().enumerate() {
            if peer == self.rank {
                continue;
            }
            if let Some(p) = pack_bwd(self.ctx, self.st, peer, fin) {
                *slot = p;
            }
        }
        let recvs = self.fabric.alltoallv(self.rank, sends, self.machine, self.comm);
        scatter_bwd(self.ctx, self.st, fin, &recvs, d_h)
    }
}

impl GraphContext for FullBatchRankCtx<'_> {
    fn lanes(&self) -> usize {
        1
    }

    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        let t = Instant::now();
        x[0].copy_from_slice(&self.ctx.features);
        secs[0] += t.elapsed().as_secs_f64();
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        {
            let t = Instant::now();
            pre_partials(self.ctx, self.st, self.shapes, fin, &h[0], disp);
            secs[0] += t.elapsed().as_secs_f64();
        }
        if self.exchange {
            self.exchange_fwd(layer, fin, &h[0], &mut quant_secs[0])?;
        }
        let t = Instant::now();
        local_agg(
            self.ctx,
            self.st,
            self.shapes,
            layer,
            fin,
            &h[0],
            &mut z[0],
            disp,
        );
        secs[0] += t.elapsed().as_secs_f64();
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        {
            let t = Instant::now();
            local_agg_bwd(
                self.ctx,
                self.st,
                self.shapes,
                fin,
                &mut dz[0],
                &mut d_h[0],
                disp,
            );
            secs[0] += t.elapsed().as_secs_f64();
        }
        self.st.d_partials[..self.shapes.p_pre * fin]
            .iter_mut()
            .for_each(|x| *x = 0.0);
        if self.exchange {
            self.exchange_bwd(fin, &mut d_h[0])?;
        }
        let t = Instant::now();
        fold_returned_partials(self.ctx, self.st, fin, &mut d_h[0]);
        secs[0] += t.elapsed().as_secs_f64();
        Ok(())
    }
}
