//! Native compute engine: the paper's CPU path, built on the §4
//! aggregation operators with explicit hand-derived backward passes.
//!
//! Numerics match the JAX definitions (same LN epsilon, same accumulation
//! structure); `rust/tests/backend_parity.rs` asserts agreement with the
//! artifact engine to f32 tolerance.

use super::linalg as la;
use super::{Backend, LayerSpec, LossOut, SegSpec};
use crate::agg::parallel::segment_sum_n;
use crate::model::LayerParams;
use crate::runtime::ShapeConfig;
use anyhow::Result;

/// Fine-grained timing sink so the trainer can split the Fig-12 breakdown
/// into aggregation vs NN time even inside one backend call.
#[derive(Clone, Debug, Default)]
pub struct NativeTimings {
    pub aggr_secs: f64,
    pub nn_secs: f64,
}

pub struct NativeBackend {
    cfg: ShapeConfig,
    threads: usize,
    /// Use the unoptimized scatter operator (the "Base"/PyG-like engine of
    /// Fig. 8 / Fig. 12) instead of the §4-optimized kernels.
    vanilla_agg: bool,
    pub timings: NativeTimings,
    // Scratch buffers reused across calls (no allocation on the hot path).
    z: Vec<f32>,
    dz: Vec<f32>,
    dpre: Vec<f32>,
    dhn_tmp: Vec<f32>,
}

impl NativeBackend {
    pub fn new(cfg: ShapeConfig) -> Self {
        let maxf = cfg.f_in.max(cfg.hidden).max(cfg.classes);
        let n = cfg.n_pad;
        Self {
            cfg,
            threads: 1,
            vanilla_agg: false,
            timings: NativeTimings::default(),
            z: vec![0.0; n * maxf],
            dz: vec![0.0; n * maxf],
            dpre: vec![0.0; n * maxf],
            dhn_tmp: vec![0.0; n * maxf],
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Switch to the vanilla scatter aggregation (baseline engine).
    pub fn with_vanilla_agg(mut self, vanilla: bool) -> Self {
        self.vanilla_agg = vanilla;
        self
    }

    #[inline]
    fn segsum(&self, h: &[f32], f: usize, spec_gather: &[u32], spec_seg: &[u32], n_seg: usize, out: &mut [f32]) {
        if self.vanilla_agg {
            crate::agg::vanilla::segment_sum(h, f, spec_gather, spec_seg, out);
        } else {
            segment_sum_n(self.threads, h, f, spec_gather, spec_seg, n_seg, out);
        }
    }

    fn aggr<T>(&mut self, f: impl FnOnce(&mut Self) -> T) -> T {
        let t = std::time::Instant::now();
        let r = f(self);
        self.timings.aggr_secs += t.elapsed().as_secs_f64();
        r
    }

    /// Recompute `z` (the mean-aggregated neighborhood) for a layer.
    fn compute_z(
        &mut self,
        h_norm: &[f32],
        recv_pre: &[f32],
        recv_post: &[f32],
        spec: &LayerSpec,
        fin: usize,
    ) {
        let n = self.cfg.n_pad;
        let z = &mut self.z[..n * fin];
        z.iter_mut().for_each(|x| *x = 0.0);
        if self.vanilla_agg {
            crate::agg::vanilla::segment_sum(h_norm, fin, &spec.local.gather, &spec.local.seg, z);
        } else {
            segment_sum_n(self.threads, h_norm, fin, &spec.local.gather, &spec.local.seg, n, z);
        }
        // Received partials scatter.
        for (i, &d) in spec.rpre_dst.iter().enumerate() {
            let src = &recv_pre[i * fin..(i + 1) * fin];
            let dst = &mut z[d as usize * fin..(d as usize + 1) * fin];
            for (a, &b) in dst.iter_mut().zip(src.iter()) {
                *a += b;
            }
        }
        // Post edges scatter.
        for (&row, &d) in spec.post_row.iter().zip(spec.post_dst.iter()) {
            let src = &recv_post[row as usize * fin..(row as usize + 1) * fin];
            let dst = &mut z[d as usize * fin..(d as usize + 1) * fin];
            for (a, &b) in dst.iter_mut().zip(src.iter()) {
                *a += b;
            }
        }
        // Mean: multiply by deg_inv.
        for (i, &dv) in spec.deg_inv.iter().enumerate() {
            let row = &mut z[i * fin..(i + 1) * fin];
            for v in row.iter_mut() {
                *v *= dv;
            }
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ShapeConfig {
        &self.cfg
    }

    fn pre_fwd(
        &mut self,
        fdim: usize,
        h: &[f32],
        pre: &SegSpec,
        h_norm: &mut [f32],
        partials: &mut [f32],
    ) -> Result<()> {
        let n = self.cfg.n_pad;
        la::layernorm(h, n, fdim, h_norm);
        partials.iter_mut().for_each(|x| *x = 0.0);
        let vanilla = self.vanilla_agg;
        let threads = self.threads;
        self.aggr(|_s| {
            if vanilla {
                crate::agg::vanilla::segment_sum(h_norm, fdim, &pre.gather, &pre.seg, partials);
            } else {
                segment_sum_n(threads, h_norm, fdim, &pre.gather, &pre.seg, pre.n_seg, partials);
            }
        });
        Ok(())
    }

    fn layer_fwd(
        &mut self,
        layer: usize,
        h_norm: &[f32],
        recv_pre: &[f32],
        recv_post: &[f32],
        params: &LayerParams,
        spec: &LayerSpec,
        out: &mut [f32],
    ) -> Result<()> {
        let (fin, fout, relu) = self.cfg.layer_dims()[layer];
        let n = self.cfg.n_pad;
        self.aggr(|s| s.compute_z(h_norm, recv_pre, recv_post, spec, fin));
        let t = std::time::Instant::now();
        la::matmul(h_norm, &params.w_self, n, fin, fout, out);
        la::matmul_acc(&self.z[..n * fin], &params.w_neigh, n, fin, fout, out);
        la::add_bias(out, n, &params.b);
        if relu {
            la::relu(out);
        }
        self.timings.nn_secs += t.elapsed().as_secs_f64();
        Ok(())
    }

    fn layer_bwd(
        &mut self,
        layer: usize,
        h_norm: &[f32],
        recv_pre: &[f32],
        recv_post: &[f32],
        params: &LayerParams,
        spec: &LayerSpec,
        out: &[f32],
        d_out: &[f32],
        d_h_norm: &mut [f32],
        d_recv_pre: &mut [f32],
        d_recv_post: &mut [f32],
        grads: &mut LayerParams,
    ) -> Result<()> {
        let (fin, fout, relu) = self.cfg.layer_dims()[layer];
        let n = self.cfg.n_pad;

        // dPre = d_out ⊙ relu'(preact) (relu mask from saved `out`).
        let t_nn = std::time::Instant::now();
        let dpre = &mut self.dpre[..n * fout];
        if relu {
            la::relu_bwd(d_out, out, dpre);
        } else {
            dpre.copy_from_slice(d_out);
        }
        self.timings.nn_secs += t_nn.elapsed().as_secs_f64();

        // z is needed for dW_neigh — recompute (aggregation path).
        self.aggr(|s| s.compute_z(h_norm, recv_pre, recv_post, spec, fin));

        let t_nn = std::time::Instant::now();
        let dpre = &self.dpre[..n * fout];
        // Parameter grads.
        la::matmul_tn_acc(h_norm, dpre, n, fin, fout, &mut grads.w_self);
        la::matmul_tn_acc(&self.z[..n * fin], dpre, n, fin, fout, &mut grads.w_neigh);
        la::col_sum_acc(dpre, n, fout, &mut grads.b);
        // d_h_norm (self path) and dZ.
        d_h_norm.iter_mut().for_each(|x| *x = 0.0);
        la::matmul_nt_acc(dpre, &params.w_self, n, fout, fin, d_h_norm);
        let dz = &mut self.dz[..n * fin];
        dz.iter_mut().for_each(|x| *x = 0.0);
        la::matmul_nt_acc(dpre, &params.w_neigh, n, fout, fin, dz);
        // Mean scaling folds into dZ.
        for (i, &dv) in spec.deg_inv.iter().enumerate() {
            let row = &mut dz[i * fin..(i + 1) * fin];
            for v in row.iter_mut() {
                *v *= dv;
            }
        }
        self.timings.nn_secs += t_nn.elapsed().as_secs_f64();

        // dZ flows back through the three aggregation paths.
        let threads = self.threads;
        let vanilla = self.vanilla_agg;
        let t_ag = std::time::Instant::now();
        {
            let dz = &self.dz[..n * fin];
            // (1) local edges, transposed: d_h_norm[src] += dz[dst].
            if vanilla {
                crate::agg::vanilla::segment_sum(dz, fin, &spec.local_t.gather, &spec.local_t.seg, d_h_norm);
            } else {
                segment_sum_n(
                    threads,
                    dz,
                    fin,
                    &spec.local_t.gather,
                    &spec.local_t.seg,
                    n,
                    d_h_norm,
                );
            }
            // (2) received partials: d_recv_pre[i] = dz[rpre_dst[i]].
            for (i, &d) in spec.rpre_dst.iter().enumerate() {
                d_recv_pre[i * fin..(i + 1) * fin]
                    .copy_from_slice(&dz[d as usize * fin..(d as usize + 1) * fin]);
            }
            // (3) post rows: d_recv_post[row] += dz[dst] (transposed spec).
            d_recv_post.iter_mut().for_each(|x| *x = 0.0);
            if vanilla {
                crate::agg::vanilla::segment_sum(dz, fin, &spec.post_t.gather, &spec.post_t.seg, d_recv_post);
            } else {
                segment_sum_n(
                    threads,
                    dz,
                    fin,
                    &spec.post_t.gather,
                    &spec.post_t.seg,
                    spec.post_t.n_seg,
                    d_recv_post,
                );
            }
        }
        self.timings.aggr_secs += t_ag.elapsed().as_secs_f64();
        Ok(())
    }

    fn pre_bwd(
        &mut self,
        fdim: usize,
        h: &[f32],
        pre: &SegSpec,
        d_h_norm: &[f32],
        d_partials: &[f32],
        d_h: &mut [f32],
    ) -> Result<()> {
        let n = self.cfg.n_pad;
        // Total h_norm cotangent = d_h_norm + scatter of d_partials back
        // through the pre gather: d_hn[gather[i]] += d_partials[seg[i]].
        let dhn = &mut self.dhn_tmp[..n * fdim];
        dhn.copy_from_slice(d_h_norm);
        let t = std::time::Instant::now();
        for (&g, &s) in pre.gather.iter().zip(pre.seg.iter()) {
            let src = &d_partials[s as usize * fdim..(s as usize + 1) * fdim];
            let dst = &mut dhn[g as usize * fdim..(g as usize + 1) * fdim];
            for (a, &b) in dst.iter_mut().zip(src.iter()) {
                *a += b;
            }
        }
        self.timings.aggr_secs += t.elapsed().as_secs_f64();
        la::layernorm_bwd(h, &self.dhn_tmp[..n * fdim], n, fdim, d_h);
        Ok(())
    }

    fn loss_head(&mut self, logits: &[f32], labels: &[i32], mask: &[f32]) -> Result<LossOut> {
        let n = self.cfg.n_pad;
        let c = self.cfg.classes;
        let mut d_logits = vec![0f32; n * c];
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut mask_sum = 0f64;
        for i in 0..n {
            let m = mask[i];
            let row = &logits[i * c..(i + 1) * c];
            // log-softmax (stable).
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let sum_exp: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let log_z = mx + sum_exp.ln();
            let label = labels[i] as usize;
            if m > 0.0 {
                loss_sum += (log_z - row[label]) as f64 * m as f64;
                mask_sum += m as f64;
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                if argmax == label {
                    correct += m as f64;
                }
            }
            if m > 0.0 {
                let d = &mut d_logits[i * c..(i + 1) * c];
                for (j, dj) in d.iter_mut().enumerate() {
                    let sm = (row[j] - log_z).exp();
                    *dj = (sm - if j == label { 1.0 } else { 0.0 }) * m;
                }
            }
        }
        Ok(LossOut {
            loss_sum: loss_sum as f32,
            correct: correct as f32,
            mask_sum: mask_sum as f32,
            d_logits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_config;
    use crate::util::rng::Rng;

    fn empty_layer_spec(cfg: &ShapeConfig) -> LayerSpec {
        // All pads: no local edges, no remote.
        let eb = 128;
        let zero = cfg.zero_row() as u32;
        let trash = cfg.trash_row() as u32;
        let local = SegSpec::new(
            vec![zero; eb],
            vec![trash; eb],
            cfg.n_pad,
            eb,
        );
        let local_t = local.clone();
        let post_t = SegSpec::new(vec![trash; eb], vec![(cfg.r_post - 1) as u32; eb], cfg.r_post, eb);
        LayerSpec {
            local,
            local_t,
            rpre_dst: vec![trash; cfg.r_pre],
            rpre_dst_i32: vec![trash as i32; cfg.r_pre],
            post_row: vec![(cfg.r_post - 1) as u32; cfg.e_post],
            post_row_i32: vec![(cfg.r_post - 1) as i32; cfg.e_post],
            post_dst: vec![trash; cfg.e_post],
            post_dst_i32: vec![trash as i32; cfg.e_post],
            post_t,
            deg_inv: vec![0.0; cfg.n_pad],
        }
    }

    #[test]
    fn loss_head_known_values() {
        let cfg = test_config();
        let mut be = NativeBackend::new(cfg.clone());
        let n = cfg.n_pad;
        let c = cfg.classes;
        let mut logits = vec![0f32; n * c];
        let mut labels = vec![0i32; n];
        let mut mask = vec![0f32; n];
        for v in 0..8 {
            labels[v] = (v % c) as i32;
            logits[v * c + v % c] = 10.0;
            mask[v] = 1.0;
        }
        let out = be.loss_head(&logits, &labels, &mask).unwrap();
        assert_eq!(out.mask_sum, 8.0);
        assert_eq!(out.correct, 8.0);
        assert!(out.loss_sum < 0.01);
        // Unmasked rows get zero gradient.
        assert!(out.d_logits[9 * c..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn loss_gradient_finite_difference() {
        let cfg = test_config();
        let mut be = NativeBackend::new(cfg.clone());
        let n = cfg.n_pad;
        let c = cfg.classes;
        let mut rng = Rng::new(3);
        let mut logits: Vec<f32> = (0..n * c).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let labels: Vec<i32> = (0..n).map(|_| rng.index(c) as i32).collect();
        let mut mask = vec![0f32; n];
        for m in mask.iter_mut().take(20) {
            *m = 1.0;
        }
        let out = be.loss_head(&logits, &labels, &mask).unwrap();
        let eps = 1e-2f32;
        for idx in [0usize, 5, 37] {
            let orig = logits[idx];
            logits[idx] = orig + eps;
            let lp = be.loss_head(&logits, &labels, &mask).unwrap().loss_sum;
            logits[idx] = orig - eps;
            let lm = be.loss_head(&logits, &labels, &mask).unwrap().loss_sum;
            logits[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - out.d_logits[idx]).abs() < 1e-2,
                "idx {idx}: fd {fd} vs {}",
                out.d_logits[idx]
            );
        }
    }

    #[test]
    fn vanilla_agg_is_algorithm_preserving() {
        // The Fig-8 "Base" scatter engine must produce the optimized
        // engine's numbers exactly (same accumulation order on sorted
        // specs) — the flag only changes speed, never results.
        let cfg = test_config();
        let mut rng = Rng::new(23);
        let f = cfg.f_in;
        let n = cfg.n_pad;
        let h: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let pre = SegSpec::new(
            vec![cfg.zero_row() as u32; 128],
            vec![(cfg.p_pre - 1) as u32; 128],
            cfg.p_pre,
            128,
        );
        let run = |vanilla: bool| {
            let mut be = NativeBackend::new(cfg.clone()).with_vanilla_agg(vanilla);
            let mut h_norm = vec![0f32; n * f];
            let mut partials = vec![0f32; cfg.p_pre * f];
            be.pre_fwd(f, &h, &pre, &mut h_norm, &mut partials).unwrap();
            (h_norm, partials)
        };
        let (hn_o, pa_o) = run(false);
        let (hn_v, pa_v) = run(true);
        assert_eq!(hn_o, hn_v);
        assert_eq!(pa_o, pa_v);
    }

    #[test]
    fn pre_fwd_layernorm_and_empty_partials() {
        let cfg = test_config();
        let mut be = NativeBackend::new(cfg.clone());
        let f = cfg.f_in;
        let n = cfg.n_pad;
        let mut rng = Rng::new(9);
        let h: Vec<f32> = (0..n * f).map(|_| rng.f32() * 4.0).collect();
        let pre = SegSpec::new(
            vec![cfg.zero_row() as u32; 128],
            vec![(cfg.p_pre - 1) as u32; 128],
            cfg.p_pre,
            128,
        );
        let mut h_norm = vec![0f32; n * f];
        let mut partials = vec![0f32; cfg.p_pre * f];
        be.pre_fwd(f, &h, &pre, &mut h_norm, &mut partials).unwrap();
        // Rows are normalized.
        let row = &h_norm[0..f];
        let mean = row.iter().sum::<f32>() / f as f32;
        assert!(mean.abs() < 1e-4);
        // Only the trash partial may be non-zero (zero row → zeros anyway).
        assert!(partials[..(cfg.p_pre - 1) * f].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn layer_fwd_bwd_gradcheck_no_remote() {
        // Finite-difference check of d_h_norm through the full layer on a
        // small local graph.
        let cfg = test_config();
        let mut be = NativeBackend::new(cfg.clone());
        let (fin, fout, _) = cfg.layer_dims()[0];
        let n = cfg.n_pad;
        let mut rng = Rng::new(17);
        let mut spec = empty_layer_spec(&cfg);
        // A few real local edges: 0→1, 2→1, 1→3 (sorted by dst).
        let eb = 128;
        let mut gather = vec![cfg.zero_row() as u32; eb];
        let mut seg = vec![cfg.trash_row() as u32; eb];
        gather[0] = 0;
        seg[0] = 1;
        gather[1] = 2;
        seg[1] = 1;
        gather[2] = 1;
        seg[2] = 3;
        // keep sorted: seg = [1,1,3,trash...]
        spec.local = SegSpec::new(gather, seg, n, eb);
        let mut tg = vec![cfg.zero_row() as u32; eb];
        let mut ts = vec![cfg.trash_row() as u32; eb];
        // transpose: src 0 gets dz[1]; src 1 gets dz[3]; src 2 gets dz[1]
        tg[0] = 1;
        ts[0] = 0;
        tg[1] = 3;
        ts[1] = 1;
        tg[2] = 1;
        ts[2] = 2;
        spec.local_t = SegSpec::new(tg, ts, n, eb);
        spec.deg_inv[1] = 0.5;
        spec.deg_inv[3] = 1.0;

        let h_norm: Vec<f32> = (0..n * fin).map(|_| rng.f32() - 0.5).collect();
        let recv_pre = vec![0f32; cfg.r_pre * fin];
        let recv_post = vec![0f32; cfg.r_post * fin];
        let params = LayerParams::glorot(fin, fout, &mut rng);
        let t: Vec<f32> = (0..n * fout).map(|_| rng.f32() - 0.5).collect();

        let mut out = vec![0f32; n * fout];
        be.layer_fwd(0, &h_norm, &recv_pre, &recv_post, &params, &spec, &mut out)
            .unwrap();
        let mut d_hn = vec![0f32; n * fin];
        let mut d_rp = vec![0f32; cfg.r_pre * fin];
        let mut d_ro = vec![0f32; cfg.r_post * fin];
        let mut grads = params.zeros_like();
        be.layer_bwd(
            0, &h_norm, &recv_pre, &recv_post, &params, &spec, &out, &t, &mut d_hn,
            &mut d_rp, &mut d_ro, &mut grads,
        )
        .unwrap();

        let scalar = |be: &mut NativeBackend, h: &[f32]| -> f32 {
            let mut o = vec![0f32; n * fout];
            be.layer_fwd(0, h, &recv_pre, &recv_post, &params, &spec, &mut o)
                .unwrap();
            o.iter().zip(t.iter()).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2f32;
        for idx in [0usize, fin + 2, 2 * fin + 5, 3 * fin + 1] {
            let mut hp = h_norm.clone();
            hp[idx] += eps;
            let mut hm = h_norm.clone();
            hm[idx] -= eps;
            let fd = (scalar(&mut be, &hp) - scalar(&mut be, &hm)) / (2.0 * eps);
            assert!(
                (fd - d_hn[idx]).abs() < 3e-2,
                "idx {idx}: fd {fd} vs analytic {}",
                d_hn[idx]
            );
        }
    }
}
