//! Miniature property-based testing harness (proptest is unavailable
//! offline). Seeded, reproducible, with failing-case reporting and a basic
//! numeric shrink.
//!
//! Usage:
//! ```ignore
//! propcheck(64, |g| {
//!     let n = g.usize(1, 100);
//!     let xs = g.vec_f32(n, -10.0, 10.0);
//!     prop_assert(invariant(&xs), format!("violated for {xs:?}"));
//! });
//! ```

use crate::util::rng::Rng;

/// A source of sized random values for one test case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// usize in [lo, hi] inclusive, biased towards edges on early cases.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        match self.case {
            0 => lo,
            1 => hi,
            _ => lo + self.rng.index(hi - lo + 1),
        }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        match self.case {
            0 => lo,
            1 => hi,
            _ => lo + self.rng.below(hi - lo + 1),
        }
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }

    /// Random edge list over `n` nodes (allows duplicates, no self-loop
    /// unless `self_loops`).
    pub fn edges(&mut self, n: usize, m: usize, self_loops: bool) -> Vec<(u32, u32)> {
        assert!(n >= 1);
        let mut out = Vec::with_capacity(m);
        for _ in 0..m {
            let u = self.rng.index(n) as u32;
            let mut v = self.rng.index(n) as u32;
            if !self_loops && n > 1 {
                while v == u {
                    v = self.rng.index(n) as u32;
                }
            }
            out.push((u, v));
        }
        out
    }
}

/// Result type used inside properties; `prop_assert` produces the Err.
pub type PropResult = Result<(), String>;

/// Assert helper for properties.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f32 slices are close.
pub fn prop_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Finite-difference gradient check (shared by the engine gradient tests
/// in both training regimes): for each probed coordinate, central
/// differences of `loss` around `params[idx]` must match
/// `analytic[idx]`. Panics with the offending coordinate on mismatch.
pub fn grad_check(
    params: &[f32],
    analytic: &[f32],
    probes: &[usize],
    eps: f32,
    mut loss: impl FnMut(&[f32]) -> f64,
) {
    assert_eq!(params.len(), analytic.len());
    for &idx in probes {
        let mut p = params.to_vec();
        p[idx] = params[idx] + eps;
        let hi = loss(&p);
        p[idx] = params[idx] - eps;
        let lo = loss(&p);
        let fd = (hi - lo) / (2.0 * eps as f64);
        let an = analytic[idx] as f64;
        assert!(
            (fd - an).abs() < 1e-2 + 0.1 * an.abs().max(fd.abs()),
            "param {idx}: finite-diff {fd} vs analytic {an}"
        );
    }
}

/// Run `cases` property evaluations with deterministic seeds. Panics with
/// the case index + seed on first failure so the case can be replayed.
pub fn propcheck(cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let base_seed = std::env::var("SUPERGCN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (replay: SUPERGCN_PROP_SEED={base_seed}):\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        propcheck(32, |g| {
            let n = g.usize(0, 100);
            prop_assert(n <= 100, "range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        propcheck(32, |g| {
            let n = g.usize(0, 100);
            prop_assert(n < 100, "n must be < 100 (false at the hi edge case)")
        });
    }

    #[test]
    fn edge_cases_cover_bounds() {
        // case 0 must produce lo, case 1 must produce hi
        let mut hit_lo = false;
        let mut hit_hi = false;
        propcheck(8, |g| {
            let v = g.usize(3, 9);
            if g.case == 0 {
                hit_lo = v == 3;
            }
            if g.case == 1 {
                hit_hi = v == 9;
            }
            Ok(())
        });
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn prop_close_detects_mismatch() {
        assert!(prop_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-5, 0.0).is_ok());
        assert!(prop_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(prop_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }

    #[test]
    fn grad_check_quadratic() {
        // loss(p) = Σ p², analytic gradient 2p.
        let params = vec![0.5f32, -1.0, 2.0];
        let analytic: Vec<f32> = params.iter().map(|&p| 2.0 * p).collect();
        grad_check(&params, &analytic, &[0, 1, 2], 1e-3, |p| {
            p.iter().map(|&x| (x as f64) * (x as f64)).sum()
        });
    }

    #[test]
    #[should_panic(expected = "finite-diff")]
    fn grad_check_catches_wrong_gradient() {
        let params = vec![1.0f32];
        grad_check(&params, &[5.0], &[0], 1e-3, |p| {
            p.iter().map(|&x| (x as f64) * (x as f64)).sum()
        });
    }

    #[test]
    fn gen_edges_valid() {
        let mut g = Gen { rng: Rng::new(2), case: 5 };
        let es = g.edges(10, 50, false);
        assert_eq!(es.len(), 50);
        for &(u, v) in &es {
            assert!(u < 10 && v < 10 && u != v);
        }
    }
}
