//! Synthetic graph generators — the data substitute for the paper's OGB /
//! Reddit / IGB260M datasets (see DESIGN.md §1).
//!
//! * `sbm` — stochastic block model with label-correlated Gaussian
//!   features: the training-accuracy experiments (Fig 11, Table 3) need
//!   homophilous graphs where a GCN genuinely learns.
//! * `rmat` — R-MAT power-law graphs: the communication experiments
//!   (Table 5, Fig 9/10) need the skewed degree distributions that make
//!   hybrid pre/post-aggregation pay off.
//! * `erdos_renyi` — uniform random baseline used in tests/ablations.

use super::CsrGraph;
use crate::util::rng::Rng;

/// A labelled attributed graph: what a GNN dataset is.
#[derive(Clone, Debug)]
pub struct LabelledGraph {
    pub graph: CsrGraph,
    /// Row-major `n × feat_dim`.
    pub features: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u32>,
    pub num_classes: usize,
    /// 0 = unused, 1 = train, 2 = val, 3 = test.
    pub split: Vec<u8>,
}

pub const SPLIT_TRAIN: u8 = 1;
pub const SPLIT_VAL: u8 = 2;
pub const SPLIT_TEST: u8 = 3;

impl LabelledGraph {
    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn feature_row(&self, v: usize) -> &[f32] {
        &self.features[v * self.feat_dim..(v + 1) * self.feat_dim]
    }

    pub fn count_split(&self, s: u8) -> usize {
        self.split.iter().filter(|&&x| x == s).count()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.graph.validate()?;
        anyhow::ensure!(self.features.len() == self.n() * self.feat_dim, "feature size");
        anyhow::ensure!(self.labels.len() == self.n(), "label size");
        anyhow::ensure!(self.split.len() == self.n(), "split size");
        anyhow::ensure!(
            self.labels.iter().all(|&l| (l as usize) < self.num_classes),
            "label out of range"
        );
        Ok(())
    }
}

/// Stochastic block model: `n` nodes in `k` equal blocks; arc probability
/// `p_in` within a block, `p_out` across. Features = one Gaussian cluster
/// center per class + noise; symmetric arcs. `avg_deg` parameterizes the
/// edge budget instead of raw probabilities so configs scale with n:
/// expected degree is split `homophily`-fraction intra-block.
pub fn sbm(
    n: usize,
    k: usize,
    avg_deg: f64,
    homophily: f64,
    feat_dim: usize,
    feat_noise: f32,
    seed: u64,
) -> LabelledGraph {
    assert!(k >= 1 && n >= k);
    let mut rng = Rng::new(seed);
    // Block assignment: contiguous-ish but shuffled so partitioning can't
    // trivially align blocks with workers.
    let mut labels: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    rng.shuffle(&mut labels);

    // Per-class membership lists for intra-block sampling.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &c) in labels.iter().enumerate() {
        members[c as usize].push(v as u32);
    }

    let m_target = ((n as f64) * avg_deg / 2.0) as usize; // undirected pairs
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(m_target * 2);
    for _ in 0..m_target {
        let u = rng.index(n) as u32;
        let v = if rng.chance(homophily) {
            // intra-block partner
            let blk = &members[labels[u as usize] as usize];
            blk[rng.index(blk.len())]
        } else {
            rng.index(n) as u32
        };
        if u != v {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    edges.sort_unstable();
    edges.dedup();
    let graph = CsrGraph::from_edges(n, &edges);

    // Class centers on the unit sphere-ish; features = center + noise.
    let mut centers = vec![0f32; k * feat_dim];
    for c in centers.iter_mut() {
        *c = rng.normal() as f32;
    }
    let inv_sqrt = 1.0 / (feat_dim as f32).sqrt();
    for c in 0..k {
        for j in 0..feat_dim {
            centers[c * feat_dim + j] *= inv_sqrt * 2.0;
        }
    }
    let mut features = vec![0f32; n * feat_dim];
    for v in 0..n {
        let c = labels[v] as usize;
        for j in 0..feat_dim {
            features[v * feat_dim + j] =
                centers[c * feat_dim + j] + feat_noise * rng.normal() as f32;
        }
    }

    let split = make_split(n, 0.5, 0.25, &mut rng);
    LabelledGraph {
        graph,
        features,
        feat_dim,
        labels,
        num_classes: k,
        split,
    }
}

/// Standard 60/20/20-style split (ratios configurable): train/val/test.
pub fn make_split(n: usize, train: f64, val: f64, rng: &mut Rng) -> Vec<u8> {
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let n_train = ((n as f64) * train) as usize;
    let n_val = ((n as f64) * val) as usize;
    let mut split = vec![SPLIT_TEST; n];
    for &v in &order[..n_train] {
        split[v] = SPLIT_TRAIN;
    }
    for &v in &order[n_train..(n_train + n_val).min(n)] {
        split[v] = SPLIT_VAL;
    }
    split
}

/// R-MAT (recursive matrix) generator with the classic (a,b,c,d)
/// quadrant probabilities; produces the heavy-tailed degree distributions
/// of web/social graphs (UK-2007-05-like). Returns a directed arc list
/// (deduped), optionally symmetrized.
pub fn rmat(
    scale: u32,
    avg_deg: f64,
    a: f64,
    b: f64,
    c: f64,
    undirected: bool,
    seed: u64,
) -> CsrGraph {
    let n = 1usize << scale;
    let m = (n as f64 * avg_deg) as usize;
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0, "quadrant probs must sum <= 1");
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m * if undirected { 2 } else { 1 });
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for level in (0..scale).rev() {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u |= du << level;
            v |= dv << level;
        }
        if u != v {
            edges.push((u as u32, v as u32));
            if undirected {
                edges.push((v as u32, u as u32));
            }
        }
    }
    edges.sort_unstable();
    edges.dedup();
    CsrGraph::from_edges(n, &edges)
}

/// Attach SBM-style labels/features to an arbitrary structural graph (used
/// to make R-MAT graphs trainable): labels from hashing + light smoothing,
/// features = class center + noise.
pub fn attach_labels(graph: CsrGraph, k: usize, feat_dim: usize, seed: u64) -> LabelledGraph {
    let n = graph.n;
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut labels: Vec<u32> = (0..n).map(|_| rng.index(k) as u32).collect();
    // One round of majority smoothing so labels correlate with structure.
    let mut counts = vec![0u32; k];
    for v in 0..n {
        for c in counts.iter_mut() {
            *c = 0;
        }
        for &s in graph.in_neighbors(v) {
            counts[labels[s as usize] as usize] += 1;
        }
        if let Some((best, &cnt)) = counts.iter().enumerate().max_by_key(|(_, &c)| c) {
            if cnt > 0 {
                labels[v] = best as u32;
            }
        }
    }
    let mut centers = vec![0f32; k * feat_dim];
    for c in centers.iter_mut() {
        *c = rng.normal() as f32 * 2.0 / (feat_dim as f32).sqrt();
    }
    let mut features = vec![0f32; n * feat_dim];
    for v in 0..n {
        let c = labels[v] as usize;
        for j in 0..feat_dim {
            features[v * feat_dim + j] = centers[c * feat_dim + j] + 0.5 * rng.normal() as f32;
        }
    }
    let split = make_split(n, 0.5, 0.25, &mut rng);
    LabelledGraph {
        graph,
        features,
        feat_dim,
        labels,
        num_classes: k,
        split,
    }
}

/// Erdős–Rényi G(n, m): m distinct directed arcs.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut set = std::collections::HashSet::with_capacity(m);
    let cap = n.saturating_mul(n.saturating_sub(1));
    let m = m.min(cap);
    while set.len() < m {
        let u = rng.index(n) as u32;
        let v = rng.index(n) as u32;
        if u != v {
            set.insert((u, v));
        }
    }
    let edges: Vec<(u32, u32)> = set.into_iter().collect();
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sbm_valid_and_homophilous() {
        let g = sbm(400, 4, 12.0, 0.85, 16, 0.5, 7);
        g.validate().unwrap();
        assert!(g.graph.m() > 400, "too few edges: {}", g.graph.m());
        // Count intra-class arcs: should be clear majority.
        let mut intra = 0usize;
        for (s, d) in g.graph.edges() {
            if g.labels[s as usize] == g.labels[d as usize] {
                intra += 1;
            }
        }
        let frac = intra as f64 / g.graph.m() as f64;
        assert!(frac > 0.6, "homophily too low: {frac}");
    }

    #[test]
    fn sbm_features_separate_classes() {
        let g = sbm(300, 3, 10.0, 0.9, 8, 0.3, 11);
        // Mean distance to own class center < to other centers (via class
        // means recomputed from features).
        let k = g.num_classes;
        let f = g.feat_dim;
        let mut means = vec![0f64; k * f];
        let mut cnt = vec![0usize; k];
        for v in 0..g.n() {
            let c = g.labels[v] as usize;
            cnt[c] += 1;
            for j in 0..f {
                means[c * f + j] += g.features[v * f + j] as f64;
            }
        }
        for c in 0..k {
            for j in 0..f {
                means[c * f + j] /= cnt[c].max(1) as f64;
            }
        }
        let mut own = 0f64;
        let mut other = 0f64;
        let mut n_other = 0usize;
        for v in 0..g.n() {
            let c = g.labels[v] as usize;
            for cc in 0..k {
                let d: f64 = (0..f)
                    .map(|j| (g.features[v * f + j] as f64 - means[cc * f + j]).powi(2))
                    .sum();
                if cc == c {
                    own += d;
                } else {
                    other += d;
                    n_other += 1;
                }
            }
        }
        assert!(own / (g.n() as f64) < other / (n_other as f64));
    }

    #[test]
    fn split_fractions() {
        let g = sbm(1000, 4, 6.0, 0.8, 4, 0.5, 3);
        let tr = g.count_split(SPLIT_TRAIN);
        let va = g.count_split(SPLIT_VAL);
        let te = g.count_split(SPLIT_TEST);
        assert_eq!(tr + va + te, 1000);
        assert!((tr as i64 - 500).abs() <= 1);
        assert!((va as i64 - 250).abs() <= 1);
    }

    #[test]
    fn rmat_skewed_degrees() {
        let g = rmat(10, 8.0, 0.57, 0.19, 0.19, true, 5);
        g.validate().unwrap();
        let max_deg = (0..g.n).map(|v| g.in_degree(v)).max().unwrap();
        let mean_deg = g.m() as f64 / g.n as f64;
        assert!(
            max_deg as f64 > 6.0 * mean_deg,
            "R-MAT not skewed: max {max_deg} mean {mean_deg}"
        );
        // Symmetry.
        for (s, d) in g.edges().iter().take(200) {
            assert!(g.in_neighbors(*s as usize).contains(d));
        }
    }

    #[test]
    fn erdos_renyi_exact_m() {
        let g = erdos_renyi(50, 300, 9);
        g.validate().unwrap();
        assert_eq!(g.m(), 300);
    }

    #[test]
    fn attach_labels_correlates() {
        let s = rmat(9, 6.0, 0.45, 0.22, 0.22, true, 13);
        let g = attach_labels(s, 5, 8, 13);
        g.validate().unwrap();
        let mut intra = 0usize;
        for (s, d) in g.graph.edges() {
            if g.labels[s as usize] == g.labels[d as usize] {
                intra += 1;
            }
        }
        // Better than the 1/k = 20% chance level.
        assert!(intra as f64 / g.graph.m() as f64 > 0.3);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = sbm(200, 3, 8.0, 0.8, 8, 0.4, 42);
        let b = sbm(200, 3, 8.0, 0.8, 8, 0.4, 42);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.features, b.features);
        let c = sbm(200, 3, 8.0, 0.8, 8, 0.4, 43);
        assert_ne!(a.graph.edges(), c.graph.edges());
    }
}
