//! Masked label propagation (paper §2.5, §6.1(1)).
//!
//! Each epoch, a random half of the *training* nodes have their label
//! embedded (`x_aug[v] = x[v] + w_embed[label[v]]`) so labels propagate
//! through aggregation; the **other** half carries the loss (no leakage).
//! Proposition 1: this tightens same-label clusters in latent space,
//! which is what restores Int2 accuracy on hard datasets.

use crate::util::rng::Rng;

/// The per-epoch selection: which local nodes got their label embedded,
/// and the complementary loss mask.
#[derive(Clone, Debug)]
pub struct LpSelection {
    /// Nodes whose labels were embedded this epoch (local indices).
    pub embedded: Vec<u32>,
    /// Loss mask over padded local rows: train ∧ ¬embedded.
    pub loss_mask: Vec<f32>,
}

/// Draw the per-epoch LP selection.
///
/// `train_mask`: padded local rows, true where the node is a train sample.
/// `frac`: fraction of train nodes to embed (paper: random selection; we
/// use 0.5 by default). When LP is disabled call with `frac = 0` — the
/// loss mask is then the full train mask.
pub fn select(train_mask: &[bool], frac: f64, rng: &mut Rng) -> LpSelection {
    let train: Vec<u32> = train_mask
        .iter()
        .enumerate()
        .filter(|(_, &t)| t)
        .map(|(i, _)| i as u32)
        .collect();
    let k = ((train.len() as f64) * frac).round() as usize;
    let chosen_idx = rng.sample_indices(train.len(), k.min(train.len()));
    let mut embedded: Vec<u32> = chosen_idx.iter().map(|&i| train[i]).collect();
    embedded.sort_unstable();
    let mut loss_mask = vec![0f32; train_mask.len()];
    for (i, &t) in train_mask.iter().enumerate() {
        if t {
            loss_mask[i] = 1.0;
        }
    }
    for &v in &embedded {
        loss_mask[v as usize] = 0.0;
    }
    LpSelection { embedded, loss_mask }
}

/// Apply the embedding: `x_aug = x; x_aug[v] += w_embed[label[v]]` for the
/// selected nodes. `x` is padded rows × f.
pub fn embed_into(
    x_aug: &mut [f32],
    f: usize,
    sel: &LpSelection,
    labels: &[u32],
    w_embed: &[f32],
) {
    for &v in &sel.embedded {
        let c = labels[v as usize] as usize;
        let row = &mut x_aug[v as usize * f..(v as usize + 1) * f];
        let emb = &w_embed[c * f..(c + 1) * f];
        for (r, &e) in row.iter_mut().zip(emb.iter()) {
            *r += e;
        }
    }
}

/// Accumulate the embedding-table gradient from the input-feature
/// cotangent: `d_w_embed[label[v]] += d_x[v]` over embedded nodes.
pub fn grad_embed(
    d_w_embed: &mut [f32],
    f: usize,
    sel: &LpSelection,
    labels: &[u32],
    d_x: &[f32],
) {
    for &v in &sel.embedded {
        let c = labels[v as usize] as usize;
        let dst = &mut d_w_embed[c * f..(c + 1) * f];
        let src = &d_x[v as usize * f..(v as usize + 1) * f];
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_splits_train_set() {
        let mut rng = Rng::new(1);
        let train: Vec<bool> = (0..100).map(|i| i < 60).collect();
        let sel = select(&train, 0.5, &mut rng);
        assert_eq!(sel.embedded.len(), 30);
        // Loss mask covers exactly the non-embedded train nodes.
        let loss_count = sel.loss_mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(loss_count, 30);
        for &v in &sel.embedded {
            assert!(train[v as usize]);
            assert_eq!(sel.loss_mask[v as usize], 0.0);
        }
    }

    #[test]
    fn zero_frac_disables_lp() {
        let mut rng = Rng::new(2);
        let train: Vec<bool> = (0..50).map(|i| i % 2 == 0).collect();
        let sel = select(&train, 0.0, &mut rng);
        assert!(sel.embedded.is_empty());
        assert_eq!(sel.loss_mask.iter().filter(|&&m| m > 0.0).count(), 25);
    }

    #[test]
    fn embed_and_grad_are_adjoint() {
        let f = 4;
        let n = 8;
        let labels = vec![0u32, 1, 0, 1, 0, 1, 0, 1];
        let sel = LpSelection {
            embedded: vec![1, 2],
            loss_mask: vec![0.0; n],
        };
        let w_embed = vec![1.0f32; 2 * f];
        let mut x = vec![0f32; n * f];
        embed_into(&mut x, f, &sel, &labels, &w_embed);
        assert_eq!(x[1 * f], 1.0); // node 1 embedded
        assert_eq!(x[2 * f], 1.0);
        assert_eq!(x[0], 0.0); // node 0 untouched
        // grad: d_x = x ⇒ d_w_embed[c] = Σ selected rows of class c.
        let mut dwe = vec![0f32; 2 * f];
        grad_embed(&mut dwe, f, &sel, &labels, &x);
        assert_eq!(dwe[0 * f], 1.0); // class 0 from node 2
        assert_eq!(dwe[1 * f], 1.0); // class 1 from node 1
    }

    #[test]
    fn no_label_leakage() {
        // Embedded nodes never appear in the loss mask.
        let mut rng = Rng::new(3);
        let train: Vec<bool> = vec![true; 40];
        for frac in [0.25, 0.5, 0.75] {
            let sel = select(&train, frac, &mut rng);
            for &v in &sel.embedded {
                assert_eq!(sel.loss_mask[v as usize], 0.0, "leak at {v}");
            }
        }
    }
}
