//! Fig-7 analytic sweep: speedup of quantized communication vs process
//! count for Int2/Int4/Int8, showing the throughput-bound → latency-bound
//! transition (Eqn 7/8).
//!
//!     cargo run --release --example perf_model -- --machine fugaku

use supergcn::exp::Table;
use supergcn::perfmodel::{crossover_procs, fig7_sweep, MachineProfile};
use supergcn::util::args::Args;

fn main() -> anyhow::Result<()> {
    let a = Args::new("perf_model", "Fig 7 analytic speedup curves")
        .opt("machine", "fugaku", "abci | fugaku")
        .opt("volume", "1e8", "total cut volume at P=1 (f32 values)")
        .parse();
    let machine = if a.get_str("machine") == "abci" {
        MachineProfile::abci()
    } else {
        MachineProfile::fugaku()
    };
    let vol = a.get_f64("volume");
    let procs: Vec<usize> = (1..=13).map(|i| 1usize << i).collect();

    let mut t = Table::new(
        &format!("Fig 7: quantization speedup on {} (β={:.0})", machine.name, machine.beta()),
        &["procs", "int2 speedup", "int4 speedup", "int8 speedup", "δ (int2)"],
    );
    let sweeps: Vec<_> = [2.0, 4.0, 8.0]
        .iter()
        .map(|&b| fig7_sweep(vol, 1.0 / 256.0, b, &procs, &machine))
        .collect();
    for (i, &p) in procs.iter().enumerate() {
        t.row(vec![
            p.to_string(),
            format!("{:.2}x", sweeps[0][i].speedup),
            format!("{:.2}x", sweeps[1][i].speedup),
            format!("{:.2}x", sweeps[2][i].speedup),
            format!("{:.3}", sweeps[0][i].delta),
        ]);
    }
    t.print();
    if let Some(px) = crossover_procs(&sweeps[0]) {
        println!(
            "int2 goes latency-bound at P' = {px}; beyond that the speedup decays \
             toward 1x but never below (paper §6.2.2)."
        );
    }
    Ok(())
}
