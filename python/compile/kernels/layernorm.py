"""L1 Pallas kernel: row-wise LayerNorm (no affine params).

Paper §6.1(2): LayerNorm is applied to the embedding table before each GCN
layer to remove large-magnitude outliers so aggressive (Int2) quantization
keeps small error. Rows are independent, so the kernel tiles over row
blocks; mean/variance stay in VMEM registers per row.

Forward and backward (the standard non-affine LN gradient
`dx = inv_std/f · (f·dy − Σdy − x̂·Σ(dy·x̂))`) are both Pallas kernels
under one `jax.custom_vjp`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RB = 128  # rows per block
EPS = 1e-5


def _ln_fwd_kernel(x_ref, y_ref):
    x = x_ref[...]
    mean = jnp.mean(x, axis=1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=1, keepdims=True)
    y_ref[...] = (x - mean) * jax.lax.rsqrt(var + EPS)


def _ln_bwd_kernel(x_ref, dy_ref, dx_ref):
    x = x_ref[...]
    dy = dy_ref[...]
    f = x.shape[1]
    mean = jnp.mean(x, axis=1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * inv
    sum_dy = jnp.sum(dy, axis=1, keepdims=True)
    sum_dyx = jnp.sum(dy * xhat, axis=1, keepdims=True)
    dx_ref[...] = (inv / f) * (f * dy - sum_dy - xhat * sum_dyx)


def _run(kernel, out_shape, *args):
    n, f = args[0].shape
    assert n % RB == 0, "row count must be padded to the 128 block"
    return pl.pallas_call(
        kernel,
        grid=(n // RB,),
        in_specs=[pl.BlockSpec((RB, f), lambda i: (i, 0)) for _ in args],
        out_specs=pl.BlockSpec((RB, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), args[0].dtype),
        interpret=True,
    )(*args)


@jax.custom_vjp
def layernorm(x):
    """Row-wise non-affine LayerNorm; x: [n, f], n % 128 == 0."""
    return _run(_ln_fwd_kernel, x.shape, x)


def _fwd(x):
    return layernorm(x), x


def _bwd(x, dy):
    return (_run(_ln_bwd_kernel, x.shape, x, dy),)


layernorm.defvjp(_fwd, _bwd)
