//! PJRT runtime: load AOT'd HLO-text artifacts and execute them from the
//! training hot path (Python is never involved at runtime).
//!
//! Flow per artifact: `HloModuleProto::from_text_file` → `XlaComputation`
//! → `PjRtClient::compile` (once, cached) → `execute` per call.
//! Interchange is HLO *text*: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects in proto form; the text parser reassigns
//! ids (see /opt/xla-example/README.md).

pub mod manifest;

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use manifest::{Manifest, ShapeConfig};

/// A PJRT client + the executable cache for one artifact config.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// role → compiled executable (lazy).
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// role → artifact file (from the manifest).
    files: HashMap<String, String>,
    pub config: ShapeConfig,
}

impl Runtime {
    /// Load `artifacts/manifest.json` and prepare the named config.
    pub fn load(artifacts_dir: &Path, config_name: &str) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let cfg = manifest
            .config(config_name)
            .with_context(|| format!("config '{config_name}' not in manifest"))?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self {
            client,
            dir: artifacts_dir.to_path_buf(),
            exes: HashMap::new(),
            files: cfg.artifacts.clone(),
            config: cfg.shapes.clone(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for `role`.
    fn exe(&mut self, role: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(role) {
            let file = self
                .files
                .get(role)
                .with_context(|| format!("artifact role '{role}' not in manifest"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_anyhow)?;
            self.exes.insert(role.to_string(), exe);
        }
        Ok(&self.exes[role])
    }

    /// Execute `role` with the given literals, returning the flattened
    /// output tuple.
    pub fn run(&mut self, role: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(role)?;
        let result = exe.execute::<xla::Literal>(inputs).map_err(to_anyhow)?;
        let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        lit.to_tuple().map_err(to_anyhow)
    }

    /// Pre-compile every artifact of the config (front-load compile cost).
    pub fn warmup(&mut self) -> Result<Vec<String>> {
        let roles: Vec<String> = self.files.keys().cloned().collect();
        for r in &roles {
            self.exe(r)?;
        }
        Ok(roles)
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// f32 matrix literal, row-major.
pub fn lit_f32(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(to_anyhow)
}

/// f32 vector literal.
pub fn lit_f32_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// i32 vector literal.
pub fn lit_i32_vec(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Copy a literal back into an f32 buffer.
pub fn lit_to_f32(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    let v = lit.to_vec::<f32>().map_err(to_anyhow)?;
    anyhow::ensure!(v.len() == out.len(), "literal size {} != buffer {}", v.len(), out.len());
    out.copy_from_slice(&v);
    Ok(())
}

/// Scalar f32 from a literal.
pub fn lit_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(to_anyhow)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, 2, 3).unwrap();
        let mut out = vec![0f32; 6];
        lit_to_f32(&lit, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn loads_tiny_config_and_runs_loss_head() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::load(&artifacts_dir(), "tiny").unwrap();
        let n = rt.config.n_pad;
        let c = rt.config.classes;
        // logits favoring class = label for first 10 nodes; mask those.
        let mut logits = vec![0f32; n * c];
        let mut labels = vec![0i32; n];
        let mut mask = vec![0f32; n];
        for v in 0..10 {
            let l = v % c;
            labels[v] = l as i32;
            logits[v * c + l] = 5.0;
            mask[v] = 1.0;
        }
        let outs = rt
            .run(
                "loss_head",
                &[
                    lit_f32(&logits, n, c).unwrap(),
                    lit_i32_vec(&labels),
                    lit_f32_vec(&mask),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 4);
        let loss = lit_scalar_f32(&outs[0]).unwrap();
        let correct = lit_scalar_f32(&outs[2]).unwrap();
        let msum = lit_scalar_f32(&outs[3]).unwrap();
        assert_eq!(msum, 10.0);
        assert_eq!(correct, 10.0);
        assert!(loss > 0.0 && loss < 10.0, "loss {loss}");
    }

    #[test]
    fn unknown_config_errors() {
        if !have_artifacts() {
            return;
        }
        assert!(Runtime::load(&artifacts_dir(), "nonexistent").is_err());
    }
}
