//! §7.3 quantization kernel ablation: the naive two-pass / divide /
//! sequential-RNG kernel vs the fused / reciprocal / counter-noise kernel,
//! plus dequantization throughput, across message sizes.
//!
//! Expected shape (paper): fusion + reciprocal + RNG elimination give a
//! solid single-core speedup that grows with message size (cache reuse).

use std::time::Instant;
use supergcn::exp::Table;
use supergcn::quant::{fused, naive, Bits};
use supergcn::util::rng::Rng;

fn bench_gbs(bytes: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    bytes as f64 / best / 1e9
}

fn main() {
    let mut t = Table::new(
        "§7.3 ablation: quantization kernel throughput (GB/s of fp32 input, int2)",
        &["rows×cols", "naive quant", "fused quant", "speedup", "dequant"],
    );
    let mut rng = Rng::new(1);
    for (rows, cols) in [(64usize, 128usize), (1024, 128), (8192, 128), (8192, 512)] {
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let bytes = x.len() * 4;
        let g_naive = bench_gbs(5, 5, || {
            std::hint::black_box(naive::quantize(&x, rows, cols, Bits::Int2, 7));
        });
        let mut params = Vec::new();
        let mut data = Vec::new();
        let g_fused = bench_gbs(bytes, 5, || {
            fused::quantize_into(&x, rows, cols, Bits::Int2, 7, &mut params, &mut data);
            std::hint::black_box(&data);
        });
        // naive throughput recomputed over bytes (bench_gbs misuse guard)
        let g_naive = {
            let t0 = Instant::now();
            for _ in 0..5 {
                std::hint::black_box(naive::quantize(&x, rows, cols, Bits::Int2, 7));
            }
            let _ = g_naive;
            bytes as f64 * 5.0 / t0.elapsed().as_secs_f64() / 1e9
        };
        let q = fused::quantize(&x, rows, cols, Bits::Int2, 7);
        let mut out = vec![0f32; rows * cols];
        let g_dq = bench_gbs(bytes, 5, || {
            fused::dequantize_into(&q, &mut out);
            std::hint::black_box(&out);
        });
        t.row(vec![
            format!("{rows}x{cols}"),
            format!("{g_naive:.2}"),
            format!("{g_fused:.2}"),
            format!("{:.2}x", g_fused / g_naive),
            format!("{g_dq:.2}"),
        ]);
    }
    t.print();

    // Bit-width sweep at a fixed size (γ trade-off table).
    let (rows, cols) = (4096usize, 128usize);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
    let mut t2 = Table::new(
        "quantize throughput by bit width (fused kernel)",
        &["bits", "GB/s", "wire reduction"],
    );
    for bits in [Bits::Int2, Bits::Int4, Bits::Int8] {
        let mut params = Vec::new();
        let mut data = Vec::new();
        let g = bench_gbs(rows * cols * 4, 5, || {
            fused::quantize_into(&x, rows, cols, bits, 3, &mut params, &mut data);
            std::hint::black_box(&data);
        });
        t2.row(vec![
            bits.name().into(),
            format!("{g:.2}"),
            format!("{}x", 32 / bits.bits()),
        ]);
    }
    t2.print();
}
