//! Layer-wise neighbor fan-out sampling (GraphSAGE / NeighborLoader
//! style).
//!
//! Each epoch deterministically permutes all nodes into target batches.
//! A batch grows the union computation graph outwards: for every node
//! first reached at hop `ℓ`, its in-neighborhood is sampled once with
//! fan-out `fanouts[ℓ]` — all neighbors (weight `1/deg`) when the degree
//! fits the budget, otherwise `fanout` distinct neighbors (weight
//! `1/fanout`), so the sampled weighted sum is an unbiased estimator of
//! the full mean aggregation. Nodes at the sampling horizon keep no
//! in-arcs (their aggregation term is zero; the self path still
//! contributes through `w_self`).
//!
//! Targets cycle over *all* nodes so every epoch also produces val/test
//! predictions (loss is only charged on train-masked targets).

use super::minibatch::{csr_with_weights, MiniBatch};
use super::{batch_rng, epoch_rng, Sampler};
use crate::graph::store::GraphStore;
use std::collections::HashMap;

pub struct NeighborSampler {
    store: GraphStore,
    fanouts: Vec<usize>,
    batch_size: usize,
    seed: u64,
    /// Cached `(epoch, permutation)` — the permutation depends only on
    /// `(seed, epoch)`, so caching keeps sampling call-order-free while
    /// avoiding a full O(n) shuffle per *batch*.
    epoch_order: Option<(usize, Vec<u32>)>,
}

impl NeighborSampler {
    pub fn new(store: GraphStore, fanouts: Vec<usize>, batch_size: usize, seed: u64) -> Self {
        assert!(!fanouts.is_empty(), "need at least one fan-out");
        assert!(fanouts.iter().all(|&f| f >= 1), "fan-outs must be >= 1");
        assert!(batch_size >= 1, "batch_size must be >= 1");
        Self {
            store,
            fanouts,
            batch_size,
            seed,
            epoch_order: None,
        }
    }

    /// Targets of `(epoch, batch)`: a slice of the epoch's permutation.
    fn targets_of(&mut self, epoch: usize, batch: usize) -> Vec<u32> {
        let n = self.store.n();
        if self.epoch_order.as_ref().map(|(e, _)| *e) != Some(epoch) {
            let mut order: Vec<u32> = (0..n as u32).collect();
            epoch_rng(self.seed, epoch).shuffle(&mut order);
            self.epoch_order = Some((epoch, order));
        }
        let order = &self.epoch_order.as_ref().unwrap().1;
        let lo = (batch * self.batch_size).min(n);
        let hi = ((batch + 1) * self.batch_size).min(n);
        order[lo..hi].to_vec()
    }
}

impl Sampler for NeighborSampler {
    fn name(&self) -> &'static str {
        "neighbor"
    }

    fn batches_per_epoch(&self) -> usize {
        self.store.n().div_ceil(self.batch_size)
    }

    fn sample(&mut self, epoch: usize, batch: usize) -> MiniBatch {
        let targets = self.targets_of(epoch, batch);
        let g = &self.store;
        let mut rng = batch_rng(self.seed, epoch, batch);

        let mut n_id = targets.clone();
        let mut loc: HashMap<u32, u32> = HashMap::with_capacity(targets.len() * 4);
        for (i, &v) in targets.iter().enumerate() {
            loc.insert(v, i as u32);
        }
        let mut arcs: Vec<(u32, u32, f32)> = Vec::new();
        let mut frontier = targets.clone();
        for &fanout in &self.fanouts {
            let mut next = Vec::new();
            for &v in &frontier {
                let nbrs = g.in_neighbors(v as usize);
                if nbrs.is_empty() {
                    continue;
                }
                let dst = loc[&v];
                let (picked, w) = if nbrs.len() <= fanout {
                    (nbrs.to_vec(), 1.0 / nbrs.len() as f32)
                } else {
                    let idx = rng.sample_indices(nbrs.len(), fanout);
                    (
                        idx.iter().map(|&i| nbrs[i]).collect::<Vec<u32>>(),
                        1.0 / fanout as f32,
                    )
                };
                for u in picked {
                    let cached = loc.get(&u).copied();
                    let lu = match cached {
                        Some(l) => l,
                        None => {
                            let l = n_id.len() as u32;
                            loc.insert(u, l);
                            n_id.push(u);
                            next.push(u);
                            l
                        }
                    };
                    arcs.push((lu, dst, w));
                }
            }
            frontier = next;
        }
        let (adj, edge_weight) = csr_with_weights(n_id.len(), &arcs);
        MiniBatch {
            sampler: "neighbor",
            n_target: targets.len(),
            node_weight: vec![1.0; targets.len()],
            n_id,
            adj,
            edge_weight,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;

    fn lg() -> GraphStore {
        GraphStore::from(sbm(400, 4, 10.0, 0.8, 8, 0.5, 11))
    }

    #[test]
    fn epoch_targets_partition_all_nodes() {
        let mut s = NeighborSampler::new(lg(), vec![5, 3], 64, 1);
        let nb = s.batches_per_epoch();
        assert_eq!(nb, 400usize.div_ceil(64));
        let mut seen: Vec<u32> = Vec::new();
        for b in 0..nb {
            let mb = s.sample(3, b);
            seen.extend_from_slice(&mb.n_id[..mb.n_target]);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..400u32).collect::<Vec<_>>());
    }

    #[test]
    fn fanout_bounds_degrees_and_weights() {
        let fan = [4usize, 2];
        let mut s = NeighborSampler::new(lg(), fan.to_vec(), 32, 5);
        let mb = s.sample(0, 0);
        mb.validate(400).unwrap();
        let max_fan = *fan.iter().max().unwrap();
        for v in 0..mb.adj.n {
            assert!(
                mb.adj.in_degree(v) <= max_fan,
                "node {v} has sampled degree {}",
                mb.adj.in_degree(v)
            );
            // Weighted in-degree is 1 for sampled rows (mean estimator).
            let s: f32 = mb.edge_weight[mb.adj.row_ptr[v]..mb.adj.row_ptr[v + 1]]
                .iter()
                .sum();
            if mb.adj.in_degree(v) > 0 {
                assert!((s - 1.0).abs() < 1e-5, "row {v} weight sum {s}");
            }
        }
    }

    #[test]
    fn deterministic_per_seed_and_call_order_free() {
        let mut a = NeighborSampler::new(lg(), vec![5, 3], 50, 9);
        let mut b = NeighborSampler::new(lg(), vec![5, 3], 50, 9);
        // Different call orders must not change results.
        let a2 = a.sample(1, 2);
        let a0 = a.sample(1, 0);
        let b0 = b.sample(1, 0);
        let b2 = b.sample(1, 2);
        assert_eq!(a0.n_id, b0.n_id);
        assert_eq!(a0.adj, b0.adj);
        assert_eq!(a0.edge_weight, b0.edge_weight);
        assert_eq!(a2.n_id, b2.n_id);
        assert_eq!(a2.adj, b2.adj);
        // Different seeds diverge.
        let mut c = NeighborSampler::new(lg(), vec![5, 3], 50, 10);
        assert_ne!(c.sample(1, 0).n_id, a0.n_id);
    }

    #[test]
    fn small_degree_rows_keep_all_neighbors() {
        // Fan-out larger than any degree => induced exact neighborhoods.
        let mut s = NeighborSampler::new(lg(), vec![1_000], 400, 3);
        let mb = s.sample(0, 0);
        assert_eq!(mb.n_target, 400);
        let g = lg();
        for (i, &v) in mb.n_id.iter().enumerate() {
            assert_eq!(mb.adj.in_degree(i), g.in_degree(v as usize));
        }
    }
}
