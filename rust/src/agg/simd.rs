//! Explicitly vectorized aggregation kernels with runtime ISA dispatch —
//! the hand-tuned rung of the §4 ladder (DESIGN.md §14).
//!
//! The scalar ladder (`blocked`/`parallel`/`spmm`) *hopes* for
//! auto-vectorization; this module writes the vectors out by hand. On
//! `x86_64` hosts where `is_x86_feature_detected!("avx2")` reports AVX2,
//! every entry point routes to `core::arch` intrinsics behind
//! `#[target_feature(enable = "avx2")]`; everywhere else it falls back to
//! the portable scalar kernels (`blocked::segment_sum`,
//! `spmm::spmm_blocked`, …) — no new dependencies, offline build
//! preserved.
//!
//! **Bit-exactness contract.** Every kernel here is bitwise identical
//! (`to_bits()`) to its scalar twin, because vectorization happens only
//! across *feature lanes*: each output element is still the same chain of
//! IEEE-754 single adds, in the same order, as the scalar kernel
//! produces. Three rules keep that true (DESIGN.md §14):
//!
//! 1. the per-destination accumulation mirrors
//!    [`blocked::accumulate_run`]'s three zones exactly — the
//!    single-source fast path (direct `dst += src`), zero-initialized
//!    accumulators over columns `0..f/LANE*LANE`, and the
//!    direct-accumulation scalar tail;
//! 2. **no FMA**: the weighted kernels round the product before the add
//!    (`_mm256_mul_ps` + `_mm256_add_ps`), exactly like the scalar
//!    `acc[j] += w * src[j]`;
//! 3. accumulator *width* is free (a wider chunk only regroups which
//!    column lives in which register, never the per-element add order) —
//!    which is what lets the AVX2 path run cache-blocked 64-column macro
//!    tiles (8 `ymm` accumulators, each gathered source row traversed
//!    `f/64` times instead of `f/16`).
//!
//! The irregular gathers get software prefetch: while streaming the first
//! column chunk of a run, the row [`PREFETCH_DIST`] gathers ahead is
//! prefetched (`_mm_prefetch`, T0), hiding the DRAM latency of the next
//! random source row behind the current row's arithmetic.

use super::blocked;
use super::spmm::{self, CsrMatrix};

/// Which instruction set the runtime dispatcher selected for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdIsa {
    /// 256-bit AVX2 intrinsics path.
    Avx2,
    /// Portable scalar fallback (delegates to the `blocked`/`spmm`
    /// kernels).
    Scalar,
}

impl SimdIsa {
    pub fn name(self) -> &'static str {
        match self {
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Scalar => "scalar",
        }
    }
}

fn detect() -> SimdIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdIsa::Avx2;
        }
    }
    SimdIsa::Scalar
}

/// The ISA the dispatcher uses, detected once per process (the CPUID
/// probe is not free; the result cannot change while we run).
pub fn isa() -> SimdIsa {
    static ISA: std::sync::OnceLock<SimdIsa> = std::sync::OnceLock::new();
    *ISA.get_or_init(detect)
}

/// True when an explicit vector path (not the scalar fallback) is active.
pub fn simd_active() -> bool {
    isa() != SimdIsa::Scalar
}

/// `out[seg[i]] += h[gather[i]]`, `seg` non-decreasing — bitwise
/// identical to [`blocked::segment_sum`].
pub fn segment_sum(h: &[f32], f: usize, gather: &[u32], seg: &[u32], out: &mut [f32]) {
    assert_eq!(gather.len(), seg.len());
    debug_assert!(super::is_sorted_segs(seg));
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::segment_sum(h, f, gather, seg, out) };
            return;
        }
    }
    blocked::segment_sum(h, f, gather, seg, out)
}

/// Subset-restricted segment sum over the destination rows in `rows`
/// (strictly increasing; CSR-style `seg_offsets` from
/// [`blocked::segment_offsets`]) — bitwise identical to
/// [`blocked::segment_sum_rows`].
pub fn segment_sum_rows(
    h: &[f32],
    f: usize,
    gather: &[u32],
    seg_offsets: &[usize],
    rows: &[u32],
    out: &mut [f32],
) {
    debug_assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must be strictly increasing");
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::segment_sum_rows(h, f, gather, seg_offsets, rows, out) };
            return;
        }
    }
    blocked::segment_sum_rows(h, f, gather, seg_offsets, rows, out)
}

/// Weighted SpMM `out += A · h` — bitwise identical to
/// [`spmm::spmm_blocked`].
pub fn spmm(a: &CsrMatrix, h: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(h.len(), a.n_cols * f);
    assert_eq!(out.len(), a.n_rows * f);
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::spmm(a, h, f, out) };
            return;
        }
    }
    spmm::spmm_blocked(a, h, f, out)
}

/// Transpose scatter `out[col] += w · d[row]` — bitwise identical to
/// [`spmm::spmm_transpose`].
pub fn spmm_t(a: &CsrMatrix, d: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(d.len(), a.n_rows * f);
    assert_eq!(out.len(), a.n_cols * f);
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::spmm_t(a, d, f, out) };
            return;
        }
    }
    spmm::spmm_transpose(a, d, f, out)
}

/// Gather rows prefetched ahead of the one being accumulated (measured
/// sweet spot for ~64–256-float rows: far enough to cover a DRAM fetch
/// behind one row's adds, near enough not to thrash L1 on short runs —
/// DESIGN.md §14).
pub const PREFETCH_DIST: usize = 4;

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::super::spmm::CsrMatrix;
    use super::PREFETCH_DIST;
    use core::arch::x86_64::*;

    /// Must match `blocked::LANE`: the accumulator region of the scalar
    /// kernel covers columns `0..f/LANE*LANE` and the SIMD kernel must
    /// cover exactly the same region with accumulators (the tail uses a
    /// different — direct — rounding association).
    const LANE: usize = 16;
    /// Cache-blocked macro tile: 64 floats (4 cache lines) of the
    /// destination live in 8 `ymm` accumulators across a whole run.
    const WIDE: usize = 64;

    #[inline]
    unsafe fn prefetch_row(h: &[f32], row: usize, f: usize) {
        // SAFETY: prefetch has no architectural effect; the address is
        // in-bounds for any valid gather row anyway.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(h.as_ptr().add(row * f) as *const i8) };
    }

    /// `dst += src`, 8-wide — the single-source fast path (per element
    /// one add, same as the scalar fused add).
    #[target_feature(enable = "avx2")]
    unsafe fn add_row(src: &[f32], dst: &mut [f32]) {
        let n = dst.len();
        let full = n / 8 * 8;
        let mut i = 0usize;
        while i < full {
            let d = dst.as_mut_ptr().add(i);
            let v = _mm256_add_ps(_mm256_loadu_ps(d), _mm256_loadu_ps(src.as_ptr().add(i)));
            _mm256_storeu_ps(d, v);
            i += 8;
        }
        for i in full..n {
            dst[i] += src[i];
        }
    }

    /// AVX2 twin of `blocked::accumulate_run` — three zones, identical
    /// per-element accumulation order (see module docs).
    #[target_feature(enable = "avx2")]
    unsafe fn accumulate_run(h: &[f32], f: usize, gathers: &[u32], dst: &mut [f32]) {
        if let [g] = gathers {
            let src = &h[*g as usize * f..(*g as usize + 1) * f];
            add_row(src, dst);
            return;
        }
        let full = f / LANE * LANE;
        let mut col = 0usize;
        // Cache-blocked macro chunks: fewer re-traversals of the gathered
        // source rows than the scalar kernel's 16-wide chunks, same
        // per-element add order (accumulator width is free).
        while col + WIDE <= full {
            let mut acc = [_mm256_setzero_ps(); WIDE / 8];
            for (k, &g) in gathers.iter().enumerate() {
                let base = g as usize * f + col;
                let src = &h[base..base + WIDE];
                if col == 0 && k + PREFETCH_DIST < gathers.len() {
                    prefetch_row(h, gathers[k + PREFETCH_DIST] as usize, f);
                }
                for (j, a) in acc.iter_mut().enumerate() {
                    *a = _mm256_add_ps(*a, _mm256_loadu_ps(src.as_ptr().add(8 * j)));
                }
            }
            let d = &mut dst[col..col + WIDE];
            for (j, a) in acc.iter().enumerate() {
                let p = d.as_mut_ptr().add(8 * j);
                _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), *a));
            }
            col += WIDE;
        }
        // LANE-wide chunks — the remainder of the scalar accumulator
        // region when f mod 64 ∈ {16, 32, 48}.
        while col < full {
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            for (k, &g) in gathers.iter().enumerate() {
                let base = g as usize * f + col;
                let src = &h[base..base + LANE];
                if col == 0 && k + PREFETCH_DIST < gathers.len() {
                    prefetch_row(h, gathers[k + PREFETCH_DIST] as usize, f);
                }
                a0 = _mm256_add_ps(a0, _mm256_loadu_ps(src.as_ptr()));
                a1 = _mm256_add_ps(a1, _mm256_loadu_ps(src.as_ptr().add(8)));
            }
            let d = dst[col..col + LANE].as_mut_ptr();
            _mm256_storeu_ps(d, _mm256_add_ps(_mm256_loadu_ps(d), a0));
            let d1 = d.add(8);
            _mm256_storeu_ps(d1, _mm256_add_ps(_mm256_loadu_ps(d1), a1));
            col += LANE;
        }
        // Scalar tail: direct accumulation, exactly the scalar kernel's
        // tail association (mixed acc/direct zones must match the twin).
        if col < f {
            for &g in gathers {
                let src = &h[g as usize * f..(g as usize + 1) * f];
                for i in col..f {
                    dst[i] += src[i];
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn segment_sum(h: &[f32], f: usize, gather: &[u32], seg: &[u32], out: &mut [f32]) {
        let m = gather.len();
        let mut run_start = 0usize;
        while run_start < m {
            let s = seg[run_start];
            let mut run_end = run_start + 1;
            while run_end < m && seg[run_end] == s {
                run_end += 1;
            }
            let dst = &mut out[s as usize * f..(s as usize + 1) * f];
            accumulate_run(h, f, &gather[run_start..run_end], dst);
            run_start = run_end;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn segment_sum_rows(
        h: &[f32],
        f: usize,
        gather: &[u32],
        seg_offsets: &[usize],
        rows: &[u32],
        out: &mut [f32],
    ) {
        for &r in rows {
            let s = r as usize;
            let (a, b) = (seg_offsets[s], seg_offsets[s + 1]);
            if a == b {
                continue;
            }
            accumulate_run(h, f, &gather[a..b], &mut out[s * f..(s + 1) * f]);
        }
    }

    /// AVX2 twin of `spmm::spmm_rows` over all rows: accumulators cover
    /// `0..f/LANE*LANE`, product rounded before the add (no FMA), direct
    /// scalar tail.
    #[target_feature(enable = "avx2")]
    pub unsafe fn spmm(a: &CsrMatrix, h: &[f32], f: usize, out: &mut [f32]) {
        let full = f / LANE * LANE;
        for r in 0..a.n_rows {
            let (s, e) = (a.row_ptr[r], a.row_ptr[r + 1]);
            if s == e {
                continue;
            }
            let o = &mut out[r * f..(r + 1) * f];
            let mut col = 0usize;
            while col + WIDE <= full {
                let mut acc = [_mm256_setzero_ps(); WIDE / 8];
                for i in s..e {
                    let c = a.col_idx[i] as usize;
                    let w = _mm256_set1_ps(a.weights[i]);
                    if col == 0 && i + PREFETCH_DIST < e {
                        prefetch_row(h, a.col_idx[i + PREFETCH_DIST] as usize, f);
                    }
                    let src = &h[c * f + col..c * f + col + WIDE];
                    for (j, aj) in acc.iter_mut().enumerate() {
                        let v = _mm256_loadu_ps(src.as_ptr().add(8 * j));
                        *aj = _mm256_add_ps(*aj, _mm256_mul_ps(w, v));
                    }
                }
                let d = &mut o[col..col + WIDE];
                for (j, aj) in acc.iter().enumerate() {
                    let p = d.as_mut_ptr().add(8 * j);
                    _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), *aj));
                }
                col += WIDE;
            }
            while col < full {
                let mut a0 = _mm256_setzero_ps();
                let mut a1 = _mm256_setzero_ps();
                for i in s..e {
                    let c = a.col_idx[i] as usize;
                    let w = _mm256_set1_ps(a.weights[i]);
                    if col == 0 && i + PREFETCH_DIST < e {
                        prefetch_row(h, a.col_idx[i + PREFETCH_DIST] as usize, f);
                    }
                    let src = &h[c * f + col..c * f + col + LANE];
                    a0 = _mm256_add_ps(a0, _mm256_mul_ps(w, _mm256_loadu_ps(src.as_ptr())));
                    a1 = _mm256_add_ps(a1, _mm256_mul_ps(w, _mm256_loadu_ps(src.as_ptr().add(8))));
                }
                let d = o[col..col + LANE].as_mut_ptr();
                _mm256_storeu_ps(d, _mm256_add_ps(_mm256_loadu_ps(d), a0));
                let d1 = d.add(8);
                _mm256_storeu_ps(d1, _mm256_add_ps(_mm256_loadu_ps(d1), a1));
                col += LANE;
            }
            if col < f {
                for i in s..e {
                    let c = a.col_idx[i] as usize;
                    let w = a.weights[i];
                    for j in col..f {
                        o[j] += w * h[c * f + j];
                    }
                }
            }
        }
    }

    /// AVX2 twin of `spmm::spmm_transpose`: per edge one fused
    /// `dst += w·src` row sweep (each element: round product, then add),
    /// keeping the scalar kernel's `w == 0` skip.
    #[target_feature(enable = "avx2")]
    pub unsafe fn spmm_t(a: &CsrMatrix, d: &[f32], f: usize, out: &mut [f32]) {
        let full = f / 8 * 8;
        for r in 0..a.n_rows {
            let src = &d[r * f..(r + 1) * f];
            for i in a.row_ptr[r]..a.row_ptr[r + 1] {
                let w = a.weights[i];
                if w == 0.0 {
                    continue;
                }
                let c = a.col_idx[i] as usize;
                if i + PREFETCH_DIST < a.row_ptr[r + 1] {
                    prefetch_row(out, a.col_idx[i + PREFETCH_DIST] as usize, f);
                }
                let dst = &mut out[c * f..(c + 1) * f];
                let wv = _mm256_set1_ps(w);
                let mut j = 0usize;
                while j < full {
                    let p = dst.as_mut_ptr().add(j);
                    let v = _mm256_mul_ps(wv, _mm256_loadu_ps(src.as_ptr().add(j)));
                    _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), v));
                    j += 8;
                }
                for j in full..f {
                    dst[j] += w * src[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::testutil::random_problem;
    use crate::agg::vanilla;
    use crate::graph::generate::rmat;
    use crate::util::rng::Rng;

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn isa_detection_is_stable() {
        assert_eq!(isa(), isa());
        assert!(!isa().name().is_empty());
        #[cfg(target_arch = "x86_64")]
        assert_eq!(simd_active(), is_x86_feature_detected!("avx2"));
    }

    #[test]
    fn segment_sum_matches_blocked_bitwise_across_widths() {
        // f sweeps every zone mix: tail-only (<16), LANE-exact, LANE+tail,
        // WIDE-exact, WIDE+LANE+tail.
        let mut rng = Rng::new(41);
        for &f in &[1usize, 7, 15, 16, 24, 33, 64, 80, 100, 256] {
            let (h, gather, seg) = random_problem(&mut rng, 60, 40, 500, f);
            let mut want = vec![0f32; 40 * f];
            blocked::segment_sum(&h, f, &gather, &seg, &mut want);
            let mut got = vec![0f32; 40 * f];
            segment_sum(&h, f, &gather, &seg, &mut got);
            assert_bits(&want, &got, &format!("segment_sum f={f}"));
        }
    }

    #[test]
    fn single_source_fast_path_matches_bitwise() {
        // One contribution per destination exercises the fast path; a
        // pre-filled out buffer checks the `+=` contract.
        let mut rng = Rng::new(5);
        let f = 37;
        let h: Vec<f32> = (0..20 * f).map(|_| rng.f32() - 0.5).collect();
        let gather: Vec<u32> = (0..12).map(|_| rng.index(20) as u32).collect();
        let seg: Vec<u32> = (0..12u32).collect();
        let init: Vec<f32> = (0..12 * f).map(|_| rng.f32()).collect();
        let mut want = init.clone();
        blocked::segment_sum(&h, f, &gather, &seg, &mut want);
        let mut got = init;
        segment_sum(&h, f, &gather, &seg, &mut got);
        assert_bits(&want, &got, "single-source");
    }

    #[test]
    fn empty_problem_is_noop() {
        let mut out = vec![1.5f32; 8];
        segment_sum(&[], 2, &[], &[], &mut out);
        assert_eq!(out, vec![1.5f32; 8]);
    }

    #[test]
    fn rows_subset_matches_blocked_bitwise() {
        let mut rng = Rng::new(13);
        let (n_seg, f) = (33, 19);
        let (h, gather, seg) = random_problem(&mut rng, 50, n_seg, 400, f);
        let off = blocked::segment_offsets(&seg, n_seg);
        let rows: Vec<u32> = (0..n_seg as u32).filter(|r| r % 3 != 1).collect();
        let mut want = vec![0f32; n_seg * f];
        blocked::segment_sum_rows(&h, f, &gather, &off, &rows, &mut want);
        let mut got = vec![0f32; n_seg * f];
        segment_sum_rows(&h, f, &gather, &off, &rows, &mut got);
        assert_bits(&want, &got, "segment_sum_rows");
    }

    #[test]
    fn spmm_matches_blocked_bitwise_across_widths() {
        let mut rng = Rng::new(3);
        let g = rmat(8, 6.0, 0.57, 0.19, 0.19, false, 9);
        let mut a = CsrMatrix::from_graph(&g);
        for w in &mut a.weights {
            *w = rng.f32() * 2.0 - 1.0;
        }
        for &f in &[1usize, 8, 16, 31, 64, 96, 130] {
            let h: Vec<f32> = (0..g.n * f).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0f32; g.n * f];
            spmm::spmm_blocked(&a, &h, f, &mut want);
            let mut got = vec![0f32; g.n * f];
            spmm(&a, &h, f, &mut got);
            assert_bits(&want, &got, &format!("spmm f={f}"));
        }
    }

    #[test]
    fn spmm_t_matches_transpose_bitwise_including_zero_weights() {
        let mut rng = Rng::new(7);
        let g = rmat(7, 5.0, 0.57, 0.19, 0.19, false, 2);
        let mut a = CsrMatrix::from_graph(&g);
        for (i, w) in a.weights.iter_mut().enumerate() {
            // Sprinkle exact zeros: the skip must match the scalar twin.
            *w = if i % 5 == 0 { 0.0 } else { rng.f32() - 0.5 };
        }
        for &f in &[3usize, 16, 40, 72] {
            let d: Vec<f32> = (0..g.n * f).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0f32; g.n * f];
            spmm::spmm_transpose(&a, &d, f, &mut want);
            let mut got = vec![0f32; g.n * f];
            spmm_t(&a, &d, f, &mut got);
            assert_bits(&want, &got, &format!("spmm_t f={f}"));
        }
    }

    #[test]
    fn agrees_with_vanilla_closely() {
        // Sanity beyond the bitwise twin: the simd rung is still the same
        // mathematical operator as the unoptimized scatter.
        let mut rng = Rng::new(19);
        let (h, gather, seg) = random_problem(&mut rng, 40, 25, 300, 21);
        let mut want = vec![0f32; 25 * 21];
        vanilla::segment_sum(&h, 21, &gather, &seg, &mut want);
        let mut got = vec![0f32; 25 * 21];
        segment_sum(&h, 21, &gather, &seg, &mut got);
        for (a, b) in want.iter().zip(got.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
