//! Sampling-regime comparison harness: full-batch training vs the
//! mini-batch producers (neighbor fan-out, GraphSAINT rw/node/edge,
//! Cluster-GCN) on the same dataset, worker count, and machine model —
//! one row per regime with accuracy, per-epoch comm volume, and modeled
//! epoch time (Eqn 2/5), FP32 and Int2 fetch variants.
//!
//! Expected shape: cluster/neighbor epochs move an order of magnitude
//! fewer bytes than full-batch halos; SAINT trades coverage for the
//! cheapest epochs; Int2 shrinks the fetched-row volume ~16x on top.
//!
//! A second table sweeps the remote-feature cache (DESIGN.md §16):
//! TTL in {0,1,2,4} x capacity in {0, 1%, 5% of remote rows} on the
//! neighbor sampler, reporting wire bytes, hit rate, and the
//! final-loss delta vs the TTL=0 identity.
//!
//!     cargo bench --bench sampling_regimes

use supergcn::datasets;
use supergcn::exp::{best_test_acc, steady_epoch_secs, train_minibatch, train_native, Table};
use supergcn::quant::Bits;
use supergcn::run::RunConfig;
use supergcn::sample::SamplerKind;
use supergcn::util::fmt_bytes;

fn main() {
    let spec = datasets::by_name("arxiv-s").unwrap();
    let k = 8;
    let epochs = 30;
    let mut t = Table::new(
        &format!(
            "sampling regimes: {} on {k} workers, {epochs} epochs",
            spec.name
        ),
        &[
            "regime",
            "quant",
            "best test acc",
            "epoch data",
            "epoch params",
            "modeled epoch (ms)",
        ],
    );

    for quant in [None, Some(Bits::Int2)] {
        let qname = quant.map(|b| b.name()).unwrap_or("fp32");

        // Full-batch baseline (the paper's loop).
        let tc = RunConfig {
            epochs,
            quant,
            ..Default::default()
        };
        let (stats, _tr) = train_native(&spec, k, tc.train_config(), Some(epochs)).unwrap();
        t.row(vec![
            "full-batch".into(),
            qname.into(),
            format!("{:.3}", best_test_acc(&stats)),
            fmt_bytes(stats[1].comm_data_bytes),
            fmt_bytes(stats[1].comm_param_bytes),
            format!("{:.3}", steady_epoch_secs(&stats, 10) * 1e3),
        ]);

        // Mini-batch regimes through the same comm accounting.
        for kind in [
            SamplerKind::Neighbor,
            SamplerKind::SaintRw,
            SamplerKind::SaintNode,
            SamplerKind::SaintEdge,
            SamplerKind::Cluster,
        ] {
            let rc = RunConfig {
                sampler: kind,
                epochs,
                quant,
                batch_size: 512,
                fanouts: vec![15, 10, 5],
                num_clusters: 4 * k,
                ..Default::default()
            };
            let (stats, _tr) = train_minibatch(
                &spec, k, kind, &rc.sampler_config(), rc.minibatch_config(), Some(epochs),
            )
            .unwrap();
            t.row(vec![
                kind.name().into(),
                qname.into(),
                format!("{:.3}", best_test_acc(&stats)),
                fmt_bytes(stats[1].comm_data_bytes),
                fmt_bytes(stats[1].comm_param_bytes),
                format!("{:.3}", steady_epoch_secs(&stats, 10) * 1e3),
            ]);
        }
    }
    t.print();

    // ---- feature-cache staleness sweep (DESIGN.md §16) ----------------
    // Neighbor fetch with the bounded-staleness row cache: TTL x capacity
    // grid on a lighter frontier than the table above (smaller batch and
    // a 2-hop fanout, so a few-percent capacity can actually cover the
    // hot set). fp32 rows are immutable, so every cached fp32 run keeps
    // the TTL=0 loss bits and the delta column isolates pure wire
    // savings; int4 rows reuse a dequantized row for up to TTL rounds,
    // so their delta is the staleness cost of skipping a freshly
    // re-quantized fetch.
    let cache_epochs = 12usize;
    let remote_rows = spec.n - spec.n / k; // rows outside a rank's own shard
    let sweep = |quant: Option<Bits>, rows: usize, ttl: usize| {
        let rc = RunConfig {
            sampler: SamplerKind::Neighbor,
            epochs: cache_epochs,
            quant,
            batch_size: 128,
            fanouts: vec![8, 4],
            feature_cache_rows: rows,
            feature_cache_ttl: ttl,
            ..Default::default()
        };
        let (stats, tr) = train_minibatch(
            &spec,
            k,
            SamplerKind::Neighbor,
            &rc.sampler_config(),
            rc.minibatch_config(),
            Some(cache_epochs),
        )
        .unwrap();
        (stats.last().unwrap().train_loss, tr.comm_stats.clone())
    };
    let mut ct = Table::new(
        &format!(
            "feature cache sweep: neighbor on {} @ {k} ranks, {cache_epochs} epochs \
             (capacity as % of the {remote_rows} remote rows)",
            spec.name
        ),
        &["quant", "ttl", "capacity", "epoch data", "hit rate", "wire saved", "loss vs ttl=0"],
    );
    for quant in [None, Some(Bits::Int4)] {
        let qname = quant.map(|b| b.name()).unwrap_or("fp32");
        let (base_loss, base_comm) = sweep(quant, 0, 0);
        ct.row(vec![
            qname.into(),
            "0".into(),
            "off".into(),
            fmt_bytes(base_comm.total_data_bytes() / cache_epochs as f64),
            "-".into(),
            "-".into(),
            "baseline".into(),
        ]);
        for ttl in [1usize, 2, 4] {
            for pct in [0usize, 1, 5] {
                let rows = remote_rows * pct / 100;
                let (loss, comm) = sweep(quant, rows, ttl);
                let c = &comm.cache;
                ct.row(vec![
                    qname.into(),
                    ttl.to_string(),
                    if pct == 0 {
                        "0 rows".into()
                    } else {
                        format!("{pct}% ({rows})")
                    },
                    fmt_bytes(comm.total_data_bytes() / cache_epochs as f64),
                    format!("{:.1}%", c.hit_rate() * 100.0),
                    fmt_bytes(c.total_saved_bytes()),
                    format!(
                        "{:+.3}%",
                        (loss as f64 - base_loss as f64) / (base_loss as f64).max(1e-12) * 100.0
                    ),
                ]);
            }
        }
    }
    ct.print();
}
