//! Simulated interconnect: `MPI_Alltoallv`-style halo exchange and ring
//! allreduce between the SPMD workers of the trainer, with byte-exact
//! volume accounting and modeled wire time (paper Eqn 2/5 via
//! `perfmodel`).
//!
//! Workers execute as SPMD ranks inside one process (the hardware gate —
//! see DESIGN.md §1) under one of two transports ([`transport`],
//! DESIGN.md §10): *sequential* (ranks step inside the driver thread —
//! modeled parallel time only) or *threaded* (one OS thread per rank,
//! payloads rendezvous through per-pair mailbox slots). In both,
//! payloads move by memcpy (so numerics are bit-exact end to end), while
//! *time* is charged analytically from the machine profile. `CommStats`
//! keeps both the measured local cost (pack/unpack, quantize) and the
//! modeled wire cost.

pub mod collective;
pub mod transport;

use crate::perfmodel::MachineProfile;
use crate::quant::Quantized;

/// One message on the simulated wire.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw FP32 rows (values).
    F32(Vec<f32>),
    /// Quantized rows + params.
    Quant(Quantized),
    /// Empty marker (no data between this pair).
    Empty,
}

impl Payload {
    /// Payload size in *bits* on the wire, split (data_bits, param_bits).
    pub fn wire_bits(&self) -> (f64, f64) {
        match self {
            Payload::F32(v) => (v.len() as f64 * 32.0, 0.0),
            Payload::Quant(q) => (
                q.payload_bytes() as f64 * 8.0,
                q.param_bytes() as f64 * 8.0,
            ),
            Payload::Empty => (0.0, 0.0),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            Payload::F32(v) => v.is_empty(),
            Payload::Quant(q) => q.rows == 0,
            Payload::Empty => true,
        }
    }
}

/// Accumulated communication accounting for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Wire bits per (src, dst) pair, data payload.
    pub data_bits: Vec<Vec<f64>>,
    /// Wire bits per (src, dst) pair, quantization params.
    pub param_bits: Vec<Vec<f64>>,
    /// Number of messages per pair.
    pub messages: Vec<Vec<usize>>,
    /// Modeled wire seconds (Eqn 2/5), accumulated per *sender*.
    pub modeled_send_secs: Vec<f64>,
}

impl CommStats {
    pub fn new(k: usize) -> Self {
        Self {
            data_bits: vec![vec![0.0; k]; k],
            param_bits: vec![vec![0.0; k]; k],
            messages: vec![vec![0; k]; k],
            modeled_send_secs: vec![0.0; k],
        }
    }

    pub fn k(&self) -> usize {
        self.modeled_send_secs.len()
    }

    pub fn total_data_bytes(&self) -> f64 {
        self.data_bits.iter().flatten().sum::<f64>() / 8.0
    }

    pub fn total_param_bytes(&self) -> f64 {
        self.param_bits.iter().flatten().sum::<f64>() / 8.0
    }

    /// Eqn-2-style bottleneck time: slowest sender's accumulated wire time.
    pub fn modeled_comm_secs(&self) -> f64 {
        self.modeled_send_secs.iter().fold(0.0, |a, &b| a.max(b))
    }

    /// Fold another accounting matrix into this one (sequential epoch
    /// totals; merging per-rank shards of the threaded transport — each
    /// shard only ever populates its own sender row, so the merge of all
    /// k shards is bit-identical to the sequential accounting).
    pub fn merge(&mut self, other: &CommStats) {
        let k = self.k();
        assert_eq!(other.k(), k, "CommStats rank-count mismatch");
        for i in 0..k {
            for j in 0..k {
                self.data_bits[i][j] += other.data_bits[i][j];
                self.param_bits[i][j] += other.param_bits[i][j];
                self.messages[i][j] += other.messages[i][j];
            }
            self.modeled_send_secs[i] += other.modeled_send_secs[i];
        }
    }

    pub(crate) fn charge(&mut self, from: usize, to: usize, p: &Payload, profile: &MachineProfile) {
        let (db, pb) = p.wire_bits();
        if db + pb <= 0.0 {
            return;
        }
        self.data_bits[from][to] += db;
        self.param_bits[from][to] += pb;
        self.messages[from][to] += 1;
        self.modeled_send_secs[from] += (db + pb) / profile.bw_comm + profile.latency;
    }
}

/// All-to-all personalized exchange: `sends[i][j]` is i's payload for j.
/// Returns `recvs` with `recvs[j][i]` = what j received from i, and charges
/// modeled wire time to `stats`.
pub fn alltoallv(
    sends: Vec<Vec<Payload>>,
    profile: &MachineProfile,
    stats: &mut CommStats,
) -> Vec<Vec<Payload>> {
    let k = sends.len();
    assert!(sends.iter().all(|row| row.len() == k), "square send matrix required");
    let mut recvs: Vec<Vec<Payload>> = (0..k)
        .map(|_| (0..k).map(|_| Payload::Empty).collect())
        .collect();
    for (i, row) in sends.into_iter().enumerate() {
        for (j, p) in row.into_iter().enumerate() {
            stats.charge(i, j, &p, profile);
            recvs[j][i] = p;
        }
    }
    recvs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fused, Bits};
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn alltoallv_routes_correctly() {
        let p = MachineProfile::abci();
        let mut stats = CommStats::new(3);
        let sends: Vec<Vec<Payload>> = (0..3)
            .map(|i| {
                (0..3)
                    .map(|j| Payload::F32(vec![(i * 10 + j) as f32]))
                    .collect()
            })
            .collect();
        let recvs = alltoallv(sends, &p, &mut stats);
        for j in 0..3 {
            for i in 0..3 {
                match &recvs[j][i] {
                    Payload::F32(v) => assert_eq!(v[0], (i * 10 + j) as f32),
                    _ => panic!("wrong payload"),
                }
            }
        }
        assert_eq!(stats.messages.iter().flatten().sum::<usize>(), 9);
    }

    #[test]
    fn conservation_bytes_sent_equals_received() {
        propcheck(16, |gen| {
            let k = gen.usize(1, 5);
            let p = MachineProfile::fugaku();
            let mut stats = CommStats::new(k);
            let mut sent_total = 0usize;
            let sends: Vec<Vec<Payload>> = (0..k)
                .map(|_| {
                    (0..k)
                        .map(|_| {
                            let n = gen.usize(0, 50);
                            sent_total += n;
                            Payload::F32(gen.vec_f32(n, -1.0, 1.0))
                        })
                        .collect()
                })
                .collect();
            let recvs = alltoallv(sends, &p, &mut stats);
            let recv_total: usize = recvs
                .iter()
                .flatten()
                .map(|p| match p {
                    Payload::F32(v) => v.len(),
                    _ => 0,
                })
                .sum();
            prop_assert(recv_total == sent_total, "value conservation")?;
            prop_assert(
                (stats.total_data_bytes() - sent_total as f64 * 4.0).abs() < 1e-9,
                "byte accounting",
            )
        });
    }

    #[test]
    fn quant_payload_is_16x_cheaper_on_wire() {
        let p = MachineProfile::abci();
        let x = vec![0.5f32; 64 * 128];
        let mut s_fp = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::F32(x.clone())],
                vec![Payload::Empty, Payload::Empty],
            ],
            &p,
            &mut s_fp,
        );
        let q = fused::quantize(&x, 64, 128, Bits::Int2, 1);
        let mut s_q = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::Quant(q)],
                vec![Payload::Empty, Payload::Empty],
            ],
            &p,
            &mut s_q,
        );
        let ratio = s_fp.total_data_bytes() / (s_q.total_data_bytes() + s_q.total_param_bytes());
        assert!(ratio > 14.0 && ratio <= 16.0, "ratio {ratio}");
        assert!(s_q.modeled_comm_secs() < s_fp.modeled_comm_secs());
    }

    #[test]
    fn empty_payloads_charge_nothing() {
        let p = MachineProfile::abci();
        let mut stats = CommStats::new(2);
        alltoallv(
            vec![
                vec![Payload::Empty, Payload::Empty],
                vec![Payload::Empty, Payload::F32(vec![])],
            ],
            &p,
            &mut stats,
        );
        assert_eq!(stats.modeled_comm_secs(), 0.0);
        assert_eq!(stats.messages.iter().flatten().sum::<usize>(), 0);
    }
}
