//! Explicitly vectorized quantization with runtime ISA dispatch — the
//! SIMD twin of [`super::fused`] (DESIGN.md §14).
//!
//! On AVX2 hosts the pack path sanitizes 8 lanes at a time, computes
//! `code = min(trunc((v − zero)·inv + noise), max_code)` in-register
//! (`_mm256_cvttps_epi32` + `_mm256_min_epi32`), and packs int2/4/8 bytes
//! from the spilled code lanes; the unpack path widens codes 8 at a time
//! and applies the `code·scale + zero` multiply-add in-register.
//! Elsewhere every entry point delegates to `fused` — no new
//! dependencies, offline build preserved.
//!
//! **Wire bit-identity.** The output `Quantized` is byte-for-byte (and
//! param-bit-for-bit) identical to `fused::quantize`:
//! - group stats go through the *same scalar* [`fused::minmax`] +
//!   [`fused::group_zero_scale`] (one definition ⇒ identical params; this
//!   also sidesteps the `min(a,b)` vs `min(b,a)` ±0 operand-order
//!   ambiguity a vectorized min/max reduction would introduce);
//! - the 8-lane sanitize `and(max(min(v, C), −C), cmp_ord(v, v))` maps
//!   every input class (finite, over-range, ±inf, NaN → +0.0, −0.0
//!   preserved) to exactly [`fused::sanitize`]'s output bits;
//! - each code is one `sub`, one `mul`, one `add` per lane — the same
//!   three IEEE ops as [`fused::code_of`] — and `t ≥ 0 < 2³¹` makes the
//!   vector truncation agree with the scalar `t as u32` cast exactly;
//! - noise lanes come from the same [`fused::noise4`] counter hash at the
//!   same flat indices (the vector loop strides 8 = two noise quads);
//! - the sub-8 remainder is packed by the *same* [`fused::pack_group`]
//!   the scalar path uses.
//!
//! Dequantization is likewise bitwise: integer widening is exact and the
//! per-element multiply-add matches the scalar association.

use super::fused;
use super::packing::packed_len;
use super::{Bits, Quantized, GROUP_ROWS};
use crate::agg::simd::{isa, SimdIsa};

/// SIMD [`fused::quantize_into`]: identical signature, bit-identical
/// output, vectorized on AVX2 hosts.
pub fn quantize_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    bits: Bits,
    seed: u64,
    params: &mut Vec<(f32, f32)>,
    data: &mut Vec<u8>,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::quantize_into(x, rows, cols, bits, seed, params, data) };
            return;
        }
    }
    fused::quantize_into(x, rows, cols, bits, seed, params, data)
}

/// Allocating wrapper around [`quantize_into`].
pub fn quantize(x: &[f32], rows: usize, cols: usize, bits: Bits, seed: u64) -> Quantized {
    let mut params = Vec::new();
    let mut data = Vec::new();
    quantize_into(x, rows, cols, bits, seed, &mut params, &mut data);
    Quantized {
        bits,
        rows,
        cols,
        params,
        data,
    }
}

/// SIMD [`fused::dequantize_into`]: bit-identical output, vectorized
/// unpack + multiply-add on AVX2 hosts.
pub fn dequantize_into(q: &Quantized, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if isa() == SimdIsa::Avx2 {
            // SAFETY: AVX2 presence was verified at runtime by `isa()`.
            unsafe { avx2::dequantize_into(q, out) };
            return;
        }
    }
    fused::dequantize_into(q, out)
}

/// Allocating wrapper around [`dequantize_into`].
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let mut out = vec![0f32; q.rows * q.cols];
    dequantize_into(q, &mut out);
    out
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::super::{fused, packing::packed_len, Bits, Quantized, GROUP_ROWS};
    use core::arch::x86_64::*;

    /// 8-lane [`fused::sanitize`]: `and(max(min(v, C), −C), cmp_ord(v, v))`.
    /// Finite in-range values pass through bitwise (±0.0 included);
    /// over-range and ±inf pin to ±C (MINPS/MAXPS return the second
    /// operand on unordered, so NaN survives the clamps as C); the
    /// ordered-compare mask then zeroes NaN lanes to +0.0 — exactly the
    /// scalar helper's `0.0`.
    #[target_feature(enable = "avx2")]
    unsafe fn sanitize_slice(raw: &[f32], sane: &mut [f32]) {
        let n = raw.len();
        let full = n / 8 * 8;
        let clamp = _mm256_set1_ps(fused::QUANT_CLAMP);
        let nclamp = _mm256_set1_ps(-fused::QUANT_CLAMP);
        let mut i = 0usize;
        while i < full {
            let v = _mm256_loadu_ps(raw.as_ptr().add(i));
            let c = _mm256_max_ps(_mm256_min_ps(v, clamp), nclamp);
            let ord = _mm256_cmp_ps::<_CMP_ORD_Q>(v, v);
            _mm256_storeu_ps(sane.as_mut_ptr().add(i), _mm256_and_ps(c, ord));
            i += 8;
        }
        for i in full..n {
            sane[i] = fused::sanitize(raw[i]);
        }
    }

    /// Vectorized twin of [`fused::pack_group`] over a pre-sanitized
    /// group slice: 8 codes per iteration (two noise quads), scalar
    /// packing from the spilled lanes, shared-scalar remainder.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    unsafe fn pack_codes(
        sane: &[f32],
        bits: Bits,
        seed: u64,
        base: u64,
        zero: f32,
        inv_scale: f32,
        mc: u32,
        data: &mut Vec<u8>,
    ) {
        let n = sane.len();
        let full = n / 8 * 8;
        let zv = _mm256_set1_ps(zero);
        let iv = _mm256_set1_ps(inv_scale);
        let mcv = _mm256_set1_epi32(mc as i32);
        let mut codes = [0u32; 8];
        let mut p = 0usize;
        while p < full {
            let n0 = fused::noise4(seed, base + p as u64);
            let n1 = fused::noise4(seed, base + p as u64 + 4);
            let nz = _mm256_setr_ps(n0[0], n0[1], n0[2], n0[3], n1[0], n1[1], n1[2], n1[3]);
            let v = _mm256_loadu_ps(sane.as_ptr().add(p));
            // Same three IEEE ops per lane as `code_of`: sub, mul, add.
            let t = _mm256_add_ps(_mm256_mul_ps(_mm256_sub_ps(v, zv), iv), nz);
            // t ≥ 0 and < 2³¹ ⇒ cvttps == the scalar `t as u32` cast.
            let c = _mm256_min_epi32(_mm256_cvttps_epi32(t), mcv);
            _mm256_storeu_si256(codes.as_mut_ptr() as *mut __m256i, c);
            match bits {
                Bits::Int2 => {
                    let lo = codes[0] | (codes[1] << 2) | (codes[2] << 4) | (codes[3] << 6);
                    let hi = codes[4] | (codes[5] << 2) | (codes[6] << 4) | (codes[7] << 6);
                    data.push(lo as u8);
                    data.push(hi as u8);
                }
                Bits::Int4 => {
                    data.push((codes[0] | (codes[1] << 4)) as u8);
                    data.push((codes[2] | (codes[3] << 4)) as u8);
                    data.push((codes[4] | (codes[5] << 4)) as u8);
                    data.push((codes[6] | (codes[7] << 4)) as u8);
                }
                Bits::Int8 => {
                    for &c in &codes {
                        data.push(c as u8);
                    }
                }
            }
            p += 8;
        }
        if full < n {
            // Sub-8 remainder: the scalar packer (same noise indices —
            // base + full stays quad-aligned since full % 8 == 0, and the
            // byte boundary is clean for every width since 8 codes fill
            // whole bytes at int2/4/8).
            let rem_base = base + full as u64;
            fused::pack_group(&sane[full..], bits, seed, rem_base, zero, inv_scale, mc, data);
        }
    }

    /// AVX2 [`fused::quantize_into`] — same group walk, shared scalar
    /// stats, vectorized sanitize + code/pack loops.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_into(
        x: &[f32],
        rows: usize,
        cols: usize,
        bits: Bits,
        seed: u64,
        params: &mut Vec<(f32, f32)>,
        data: &mut Vec<u8>,
    ) {
        assert_eq!(x.len(), rows * cols);
        params.clear();
        data.clear();
        params.reserve(rows.div_ceil(GROUP_ROWS));
        data.reserve(rows.div_ceil(GROUP_ROWS) * packed_len(GROUP_ROWS * cols, bits));
        let max_code = bits.max_code() as f32;
        let mut sbuf = vec![0f32; GROUP_ROWS * cols];
        for g in (0..rows).step_by(GROUP_ROWS) {
            let g_rows = GROUP_ROWS.min(rows - g);
            let raw = &x[g * cols..(g + g_rows) * cols];
            let sane = &mut sbuf[..raw.len()];
            sanitize_slice(raw, sane);
            // Scalar shared stats: params bit-identical to `fused` by
            // construction (one definition, same input bits).
            let (mn, mx) = fused::minmax(sane);
            let (zero, scale) = fused::group_zero_scale(mn, mx, max_code);
            debug_assert!(zero.is_finite() && scale.is_finite());
            params.push((zero, scale));
            let inv_scale = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            pack_codes(sane, bits, seed, (g * cols) as u64, zero, inv_scale, max_code as u32, data);
        }
    }

    /// AVX2 [`fused::dequantize_into`] — 8 codes widened per iteration,
    /// `code·scale + zero` in-register (mul then add, the scalar
    /// association), scalar tails identical to the fused kernel.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_into(q: &Quantized, out: &mut [f32]) {
        assert_eq!(out.len(), q.rows * q.cols);
        let mut data_off = 0usize;
        for (gi, &(zero, scale)) in q.params.iter().enumerate() {
            let g = gi * GROUP_ROWS;
            let g_rows = GROUP_ROWS.min(q.rows - g);
            let n = g_rows * q.cols;
            let bytes = &q.data[data_off..data_off + packed_len(n, q.bits)];
            data_off += bytes.len();
            let dst = &mut out[g * q.cols..g * q.cols + n];
            let zv = _mm256_set1_ps(zero);
            let sv = _mm256_set1_ps(scale);
            let full = n / 8 * 8;
            match q.bits {
                Bits::Int2 => {
                    let mut i = 0usize;
                    while i < full {
                        let b0 = bytes[i / 4];
                        let b1 = bytes[i / 4 + 1];
                        let lanes = [
                            (b0 & 0x3) as f32,
                            ((b0 >> 2) & 0x3) as f32,
                            ((b0 >> 4) & 0x3) as f32,
                            ((b0 >> 6) & 0x3) as f32,
                            (b1 & 0x3) as f32,
                            ((b1 >> 2) & 0x3) as f32,
                            ((b1 >> 4) & 0x3) as f32,
                            ((b1 >> 6) & 0x3) as f32,
                        ];
                        let v = _mm256_loadu_ps(lanes.as_ptr());
                        let r = _mm256_add_ps(_mm256_mul_ps(v, sv), zv);
                        _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
                        i += 8;
                    }
                    for i in full..n {
                        let b = bytes[i / 4];
                        dst[i] = ((b >> (2 * (i % 4))) & 0x3) as f32 * scale + zero;
                    }
                }
                Bits::Int4 => {
                    let mut i = 0usize;
                    while i < full {
                        let bb = &bytes[i / 2..i / 2 + 4];
                        let lanes = [
                            (bb[0] & 0xF) as f32,
                            (bb[0] >> 4) as f32,
                            (bb[1] & 0xF) as f32,
                            (bb[1] >> 4) as f32,
                            (bb[2] & 0xF) as f32,
                            (bb[2] >> 4) as f32,
                            (bb[3] & 0xF) as f32,
                            (bb[3] >> 4) as f32,
                        ];
                        let v = _mm256_loadu_ps(lanes.as_ptr());
                        let r = _mm256_add_ps(_mm256_mul_ps(v, sv), zv);
                        _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
                        i += 8;
                    }
                    for i in full..n {
                        let b = bytes[i / 2];
                        dst[i] = ((b >> (4 * (i % 2))) & 0xF) as f32 * scale + zero;
                    }
                }
                Bits::Int8 => {
                    let mut i = 0usize;
                    while i < full {
                        let b = _mm_loadl_epi64(bytes.as_ptr().add(i) as *const __m128i);
                        let v = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(b));
                        let r = _mm256_add_ps(_mm256_mul_ps(v, sv), zv);
                        _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
                        i += 8;
                    }
                    for i in full..n {
                        dst[i] = bytes[i] as f32 * scale + zero;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const ALL_BITS: [Bits; 3] = [Bits::Int2, Bits::Int4, Bits::Int8];

    fn assert_wire_identical(a: &Quantized, b: &Quantized, what: &str) {
        assert_eq!(a.bits.name(), b.bits.name(), "{what}: bits");
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
        assert_eq!(a.params.len(), b.params.len(), "{what}: params len");
        for (i, ((z1, s1), (z2, s2))) in a.params.iter().zip(b.params.iter()).enumerate() {
            assert_eq!(z1.to_bits(), z2.to_bits(), "{what}: zero bits at group {i}");
            assert_eq!(s1.to_bits(), s2.to_bits(), "{what}: scale bits at group {i}");
        }
        assert_eq!(a.data, b.data, "{what}: payload bytes");
    }

    #[test]
    fn wire_bit_identical_to_fused_across_shapes() {
        let mut rng = Rng::new(11);
        // rows not a multiple of GROUP_ROWS, odd cols, cols not a
        // multiple of 8 — every remainder path.
        for &(rows, cols) in &[(1usize, 1usize), (3, 7), (4, 8), (9, 33), (16, 50), (5, 64)] {
            let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32() * 20.0 - 10.0).collect();
            for bits in ALL_BITS {
                let seed = rng.next_u64();
                let a = quantize(&x, rows, cols, bits, seed);
                let b = fused::quantize(&x, rows, cols, bits, seed);
                assert_wire_identical(&a, &b, &format!("{}x{} {}", rows, cols, bits.name()));
            }
        }
    }

    #[test]
    fn wire_bit_identical_with_poison_inputs() {
        let mut rng = Rng::new(23);
        let (rows, cols) = (7, 21);
        let mut x: Vec<f32> = (0..rows * cols).map(|_| rng.f32() - 0.5).collect();
        for (i, p) in [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MAX,
            f32::MIN,
            -0.0,
        ]
        .iter()
        .enumerate()
        {
            x[i * 19] = *p;
        }
        for bits in ALL_BITS {
            let a = quantize(&x, rows, cols, bits, 99);
            let b = fused::quantize(&x, rows, cols, bits, 99);
            assert_wire_identical(&a, &b, &format!("poison {}", bits.name()));
        }
    }

    #[test]
    fn dequantize_bit_identical_to_fused() {
        let mut rng = Rng::new(31);
        for &(rows, cols) in &[(2usize, 5usize), (8, 32), (11, 17)] {
            let x: Vec<f32> = (0..rows * cols).map(|_| rng.f32() * 4.0 - 2.0).collect();
            for bits in ALL_BITS {
                let q = fused::quantize(&x, rows, cols, bits, 7);
                let a = dequantize(&q);
                let b = fused::dequantize(&q);
                for (i, (u, v)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "dequant {} at {i}: {u} vs {v}",
                        bits.name()
                    );
                }
            }
        }
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let x: Vec<f32> = (0..4 * 24).map(|i| (i as f32).cos()).collect();
        let mut params = vec![(1.0f32, 1.0f32); 9];
        let mut data = vec![7u8; 999];
        quantize_into(&x, 4, 24, Bits::Int4, 5, &mut params, &mut data);
        let q = quantize(&x, 4, 24, Bits::Int4, 5);
        assert_eq!(params, q.params);
        assert_eq!(data, q.data);
        let mut out = vec![0f32; 4 * 24];
        dequantize_into(&q, &mut out);
        assert_eq!(out, dequantize(&q));
    }
}
