//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! and the Rust runtime/trainer. Shapes here are the static padded dims
//! every worker's tensors must conform to.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Static shape configuration of one artifact set (mirrors aot.Config).
#[derive(Clone, Debug, PartialEq)]
pub struct ShapeConfig {
    pub name: String,
    /// Padded local nodes, incl. zero row (n_pad−2) and trash row (n_pad−1).
    pub n_pad: usize,
    pub f_in: usize,
    pub hidden: usize,
    pub classes: usize,
    pub e_local: usize,
    pub e_pre: usize,
    /// Pre segments incl. the trailing trash segment.
    pub p_pre: usize,
    pub r_pre: usize,
    /// Received post rows incl. the trailing zero row.
    pub r_post: usize,
    pub e_post: usize,
}

impl ShapeConfig {
    pub fn zero_row(&self) -> usize {
        self.n_pad - 2
    }
    pub fn trash_row(&self) -> usize {
        self.n_pad - 1
    }
    /// (fin, fout, relu) per layer — the 3-layer GraphSAGE of the paper.
    pub fn layer_dims(&self) -> [(usize, usize, bool); 3] {
        [
            (self.f_in, self.hidden, true),
            (self.hidden, self.hidden, true),
            (self.hidden, self.classes, false),
        ]
    }
    /// Number of usable local rows (excluding the two reserved).
    pub fn usable_rows(&self) -> usize {
        self.n_pad - 2
    }
}

/// One config entry: shapes + role → artifact-file map.
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub shapes: ShapeConfig,
    pub artifacts: HashMap<String, String>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub eb: usize,
    pub configs: Vec<ConfigEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("manifest is not valid JSON")?;
        let eb = v.req_usize("eb")?;
        let mut configs = Vec::new();
        for c in v
            .get("configs")
            .and_then(|c| c.as_arr())
            .context("manifest missing configs[]")?
        {
            let shapes = ShapeConfig {
                name: c.req_str("name")?.to_string(),
                n_pad: c.req_usize("n_pad")?,
                f_in: c.req_usize("f_in")?,
                hidden: c.req_usize("hidden")?,
                classes: c.req_usize("classes")?,
                e_local: c.req_usize("e_local")?,
                e_pre: c.req_usize("e_pre")?,
                p_pre: c.req_usize("p_pre")?,
                r_pre: c.req_usize("r_pre")?,
                r_post: c.req_usize("r_post")?,
                e_post: c.req_usize("e_post")?,
            };
            let mut artifacts = HashMap::new();
            for (role, meta) in c
                .get("artifacts")
                .and_then(|a| a.as_obj())
                .context("config missing artifacts{}")?
            {
                artifacts.insert(role.clone(), meta.req_str("file")?.to_string());
            }
            configs.push(ConfigEntry { shapes, artifacts });
        }
        Ok(Self { eb, configs })
    }

    pub fn config(&self, name: &str) -> Option<&ConfigEntry> {
        self.configs.iter().find(|c| c.shapes.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "eb": 128,
      "configs": [{
        "name": "tiny", "n_pad": 256, "f_in": 16, "hidden": 16, "classes": 4,
        "e_local": 1024, "e_pre": 256, "p_pre": 128, "r_pre": 128,
        "r_post": 128, "e_post": 256,
        "artifacts": {
          "loss_head": {"file": "tiny_loss_head.hlo.txt", "inputs": [], "outputs": []}
        }
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.eb, 128);
        let c = m.config("tiny").unwrap();
        assert_eq!(c.shapes.n_pad, 256);
        assert_eq!(c.shapes.zero_row(), 254);
        assert_eq!(c.shapes.trash_row(), 255);
        assert_eq!(c.artifacts["loss_head"], "tiny_loss_head.hlo.txt");
        let dims = c.shapes.layer_dims();
        assert_eq!(dims[0], (16, 16, true));
        assert_eq!(dims[2], (16, 4, false));
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"eb": 128, "configs": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.config("tiny").is_some());
            assert!(m.config("quickstart").is_some());
            for c in &m.configs {
                assert!(c.artifacts.contains_key("loss_head"));
                assert!(c.artifacts.len() >= 9);
            }
        }
    }
}
