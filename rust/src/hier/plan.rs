//! Halo-exchange plans: the preprocessing output consumed by the trainer
//! (paper Fig. 2 steps 1–2: partition, split into local / pre- / post-
//! aggregation graphs, exchange the pre-aggregation graph between workers).
//!
//! All node indices inside a plan are **local** to their owning worker;
//! the plan is the only place global ids are translated.

use super::prepost::{split_pair, PrePostSplit};
use super::volume::RemoteStrategy;
use super::{remote_pairs, RemotePair};
use crate::graph::GraphTopo;
use crate::partition::Partition;

/// What worker `w` sends to one peer each layer.
#[derive(Clone, Debug, Default)]
pub struct SendPlan {
    pub peer: usize,
    /// Pre-aggregation segment-sum spec over *local* node indices:
    /// `partial[pre_seg[i]] += H[pre_gather[i]]`.
    pub pre_gather: Vec<u32>,
    pub pre_seg: Vec<u32>,
    pub n_pre_segments: usize,
    /// Raw rows shipped for post-aggregation: local node index per row.
    pub post_rows: Vec<u32>,
}

impl SendPlan {
    /// Feature rows on the wire.
    pub fn rows(&self) -> usize {
        self.n_pre_segments + self.post_rows.len()
    }
}

/// What worker `w` receives from one peer each layer.
#[derive(Clone, Debug, Default)]
pub struct RecvPlan {
    pub peer: usize,
    /// Received partial `i` scatter-adds into local dst `pre_dst[i]`.
    pub pre_dst: Vec<u32>,
    /// Number of raw post rows received.
    pub n_post_rows: usize,
    /// Post aggregation edges: (received row index, local dst index).
    pub post_edges: Vec<(u32, u32)>,
}

impl RecvPlan {
    pub fn rows(&self) -> usize {
        self.pre_dst.len() + self.n_post_rows
    }
}

/// Everything one worker needs for training.
#[derive(Clone, Debug)]
pub struct WorkerPlan {
    pub worker: usize,
    /// Global ids of the nodes this worker owns (ascending). Local index
    /// `i` ↔ global id `local_nodes[i]`.
    pub local_nodes: Vec<u32>,
    /// Aggregation arcs with both endpoints local: (src_local, dst_local),
    /// sorted by dst (the §4 "clustering and sorting" step happens here,
    /// once, at preprocessing time).
    pub local_edges: Vec<(u32, u32)>,
    /// Full in-degree of each local node in the *global* graph (mean
    /// aggregation must divide by the true neighborhood size).
    pub degrees: Vec<u32>,
    pub sends: Vec<SendPlan>,
    pub recvs: Vec<RecvPlan>,
}

impl WorkerPlan {
    pub fn n_local(&self) -> usize {
        self.local_nodes.len()
    }

    /// Rows sent per layer (all peers).
    pub fn send_rows(&self) -> usize {
        self.sends.iter().map(|s| s.rows()).sum()
    }

    /// Rows received per layer (all peers).
    pub fn recv_rows(&self) -> usize {
        self.recvs.iter().map(|r| r.rows()).sum()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.n_local();
        for &(s, d) in &self.local_edges {
            anyhow::ensure!((s as usize) < n && (d as usize) < n, "local edge oob");
        }
        anyhow::ensure!(self.degrees.len() == n, "degrees length");
        for sp in &self.sends {
            anyhow::ensure!(sp.pre_gather.len() == sp.pre_seg.len(), "pre spec length");
            anyhow::ensure!(
                sp.pre_gather.iter().all(|&i| (i as usize) < n),
                "pre_gather oob"
            );
            anyhow::ensure!(
                sp.pre_seg.iter().all(|&s| (s as usize) < sp.n_pre_segments),
                "pre_seg oob"
            );
            // Every segment id must be used at least once.
            let mut used = vec![false; sp.n_pre_segments];
            for &s in &sp.pre_seg {
                used[s as usize] = true;
            }
            anyhow::ensure!(used.iter().all(|&u| u), "empty pre segment");
            anyhow::ensure!(sp.post_rows.iter().all(|&i| (i as usize) < n), "post_rows oob");
        }
        for rp in &self.recvs {
            anyhow::ensure!(rp.pre_dst.iter().all(|&d| (d as usize) < n), "pre_dst oob");
            for &(r, d) in &rp.post_edges {
                anyhow::ensure!((r as usize) < rp.n_post_rows, "post edge row oob");
                anyhow::ensure!((d as usize) < n, "post edge dst oob");
            }
        }
        Ok(())
    }
}

/// Build a split for a pair under any strategy, reusing the pre/post
/// containers (Raw is expressed as post with per-edge duplicate rows).
fn strategy_split(pair: &RemotePair, strategy: RemoteStrategy) -> PrePostSplit {
    match strategy {
        RemoteStrategy::Hybrid => split_pair(pair),
        RemoteStrategy::PreOnly => {
            let mut map: std::collections::BTreeMap<u32, Vec<u32>> = Default::default();
            for &(s, d) in &pair.edges {
                map.entry(d).or_default().push(s);
            }
            PrePostSplit {
                pre_groups: map
                    .into_iter()
                    .map(|(d, mut ss)| {
                        ss.sort_unstable();
                        (d, ss)
                    })
                    .collect(),
                post_srcs: vec![],
                post_edges: vec![],
            }
        }
        RemoteStrategy::PostOnly => {
            let mut post_edges = pair.edges.clone();
            post_edges.sort_unstable();
            let mut post_srcs: Vec<u32> = post_edges.iter().map(|e| e.0).collect();
            post_srcs.sort_unstable();
            post_srcs.dedup();
            PrePostSplit {
                pre_groups: vec![],
                post_srcs,
                post_edges,
            }
        }
        RemoteStrategy::Raw => {
            // One row per edge: duplicates allowed in post_srcs; the recv
            // side maps row i → edge i's dst.
            let post_edges = pair.edges.clone();
            let post_srcs = post_edges.iter().map(|e| e.0).collect();
            PrePostSplit {
                pre_groups: vec![],
                post_srcs,
                post_edges,
            }
        }
    }
}

/// Build all worker plans for `(graph, partition)` under `strategy`.
/// Generic over [`GraphTopo`]: the mmap-backed store and the in-memory
/// CSR run the identical code and produce identical plans (DESIGN.md
/// §17) — the parity the out-of-core training path rests on.
pub fn build_plans<G: GraphTopo + ?Sized>(
    g: &G,
    part: &Partition,
    strategy: RemoteStrategy,
) -> Vec<WorkerPlan> {
    let k = part.k;
    let nodes = part.part_nodes();
    // global → local index maps.
    let mut g2l = vec![u32::MAX; g.num_nodes()];
    for p in 0..k {
        for (i, &v) in nodes[p].iter().enumerate() {
            g2l[v as usize] = i as u32;
        }
    }
    let mut plans: Vec<WorkerPlan> = (0..k)
        .map(|w| WorkerPlan {
            worker: w,
            local_nodes: nodes[w].clone(),
            local_edges: Vec::new(),
            degrees: nodes[w].iter().map(|&v| g.in_degree(v as usize) as u32).collect(),
            sends: (0..k).map(|peer| SendPlan { peer, ..Default::default() }).collect(),
            recvs: (0..k).map(|peer| RecvPlan { peer, ..Default::default() }).collect(),
        })
        .collect();

    // Local edges, sorted by destination (clustering for §4 operators).
    for d in 0..g.num_nodes() {
        let pd = part.assign[d] as usize;
        for &s in g.in_neighbors(d) {
            if part.assign[s as usize] as usize == pd {
                plans[pd].local_edges.push((g2l[s as usize], g2l[d]));
            }
        }
    }
    for plan in &mut plans {
        plan.local_edges.sort_unstable_by_key(|&(s, d)| (d, s));
    }

    // Remote pairs → send/recv plans.
    for pair in remote_pairs(g, part) {
        let split = strategy_split(&pair, strategy);
        let p = pair.producer;
        let c = pair.consumer;
        // Producer send plan.
        {
            let sp = &mut plans[p].sends[c];
            for (seg, (_d, srcs)) in split.pre_groups.iter().enumerate() {
                for &s in srcs {
                    sp.pre_gather.push(g2l[s as usize]);
                    sp.pre_seg.push(seg as u32);
                }
            }
            sp.n_pre_segments = split.pre_groups.len();
            sp.post_rows = split.post_srcs.iter().map(|&s| g2l[s as usize]).collect();
        }
        // Consumer recv plan.
        {
            let rp = &mut plans[c].recvs[p];
            rp.pre_dst = split.pre_groups.iter().map(|(d, _)| g2l[*d as usize]).collect();
            rp.n_post_rows = split.post_srcs.len();
            // Map each post edge's src to its row index in post_srcs.
            rp.post_edges = split
                .post_edges
                .iter()
                .map(|&(s, d)| {
                    let row = if strategy == RemoteStrategy::Raw {
                        // raw: row i == edge i (post_srcs has duplicates)
                        split.post_edges.iter().position(|e| *e == (s, d)).unwrap() as u32
                    } else {
                        split.post_srcs.binary_search(&s).unwrap() as u32
                    };
                    (row, g2l[d as usize])
                })
                .collect();
        }
    }
    plans
}

/// Global sanity: sends and recvs agree pairwise; every cut arc is realized
/// exactly once across local edges, pre groups, and post edges.
pub fn validate_plans<G: GraphTopo + ?Sized>(
    g: &G,
    part: &Partition,
    plans: &[WorkerPlan],
) -> anyhow::Result<()> {
    let k = part.k;
    anyhow::ensure!(plans.len() == k, "plan count");
    for w in 0..k {
        plans[w].validate()?;
        for peer in 0..k {
            let sp = &plans[w].sends[peer];
            let rp = &plans[peer].recvs[w];
            anyhow::ensure!(
                sp.n_pre_segments == rp.pre_dst.len(),
                "pre segment count mismatch {w}→{peer}"
            );
            anyhow::ensure!(
                sp.post_rows.len() == rp.n_post_rows,
                "post row count mismatch {w}→{peer}"
            );
        }
    }
    // Edge conservation: count aggregation contributions per destination.
    // Every global arc must contribute exactly once to its dst.
    let mut contrib = vec![0usize; g.num_nodes()];
    for plan in plans {
        for &(_, d) in &plan.local_edges {
            contrib[plan.local_nodes[d as usize] as usize] += 1;
        }
        for rp in &plan.recvs {
            for &(_row, d) in &rp.post_edges {
                contrib[plan.local_nodes[d as usize] as usize] += 1;
            }
        }
        // Pre partials: each segment carries the producer's srcs for that dst.
        for sp in &plan.sends {
            let rp = &plans[sp.peer].recvs[plan.worker];
            let mut seg_count = vec![0usize; sp.n_pre_segments];
            for &s in &sp.pre_seg {
                seg_count[s as usize] += 1;
            }
            for (seg, &cnt) in seg_count.iter().enumerate() {
                let d_local = rp.pre_dst[seg];
                contrib[plans[sp.peer].local_nodes[d_local as usize] as usize] += cnt;
            }
        }
    }
    for v in 0..g.num_nodes() {
        // Dedup'd arcs: remote multi-arcs were collapsed, local kept.
        let mut ins: Vec<u32> = g.in_neighbors(v).to_vec();
        let pd = part.assign[v];
        let local: usize = ins.iter().filter(|&&s| part.assign[s as usize] == pd).count();
        ins.retain(|&s| part.assign[s as usize] != pd);
        ins.sort_unstable();
        ins.dedup();
        let expect = local + ins.len();
        anyhow::ensure!(
            contrib[v] == expect,
            "node {v}: {} contributions, expected {}",
            contrib[v],
            expect
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{rmat, sbm};
    use crate::graph::CsrGraph;
    use crate::partition::{multilevel::multilevel, multilevel::MultilevelOpts, random, vertex_weights};
    use crate::util::propcheck::propcheck;

    fn check_all_strategies(g: &CsrGraph, part: &Partition) {
        for strategy in [
            RemoteStrategy::PreOnly,
            RemoteStrategy::PostOnly,
            RemoteStrategy::Hybrid,
            RemoteStrategy::Raw,
        ] {
            let plans = build_plans(g, part, strategy);
            validate_plans(g, part, &plans)
                .unwrap_or_else(|e| panic!("{}: {e}", strategy.name()));
        }
    }

    #[test]
    fn plans_validate_on_sbm() {
        let lg = sbm(600, 4, 8.0, 0.85, 4, 0.5, 17);
        let w = vertex_weights(&lg.graph, None, 0);
        let part = multilevel(&lg.graph, 4, &w, &MultilevelOpts::default());
        check_all_strategies(&lg.graph, &part);
    }

    #[test]
    fn plans_validate_on_powerlaw_random_partition() {
        let g = rmat(9, 6.0, 0.57, 0.19, 0.19, true, 5);
        let part = random(g.n, 3, 11);
        check_all_strategies(&g, &part);
    }

    #[test]
    fn hybrid_send_rows_match_volume_report() {
        let g = rmat(10, 8.0, 0.57, 0.19, 0.19, true, 7);
        let part = random(g.n, 4, 3);
        let plans = build_plans(&g, &part, RemoteStrategy::Hybrid);
        let pairs = remote_pairs(&g, &part);
        let vol = super::super::volume::volume(4, &pairs, RemoteStrategy::Hybrid);
        let plan_total: usize = plans.iter().map(|p| p.send_rows()).sum();
        assert_eq!(plan_total, vol.total_rows());
        // send rows == recv rows globally
        let recv_total: usize = plans.iter().map(|p| p.recv_rows()).sum();
        assert_eq!(plan_total, recv_total);
    }

    #[test]
    fn prop_plans_validate_under_random_partitions() {
        propcheck(16, |gen| {
            let n = gen.usize(8, 150);
            let m = gen.usize(n, 600);
            let edges = gen.edges(n, m, false);
            let g = CsrGraph::from_edges(n, &edges);
            let k = gen.usize(2, 5);
            let part = random(n, k, gen.u64(0, 1 << 32));
            for strategy in [RemoteStrategy::PreOnly, RemoteStrategy::PostOnly, RemoteStrategy::Hybrid] {
                let plans = build_plans(&g, &part, strategy);
                validate_plans(&g, &part, &plans).map_err(|e| format!("{}: {e}", strategy.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_worker_plan_has_no_comm() {
        let g = rmat(8, 4.0, 0.5, 0.2, 0.2, true, 1);
        let part = Partition { k: 1, assign: vec![0; g.n] };
        let plans = build_plans(&g, &part, RemoteStrategy::Hybrid);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].send_rows(), 0);
        assert_eq!(plans[0].local_edges.len(), g.m());
        validate_plans(&g, &part, &plans).unwrap();
    }

    #[test]
    fn local_edges_sorted_by_dst() {
        let lg = sbm(200, 2, 6.0, 0.8, 4, 0.5, 9);
        let part = random(lg.graph.n, 2, 5);
        let plans = build_plans(&lg.graph, &part, RemoteStrategy::Hybrid);
        for p in &plans {
            for w in p.local_edges.windows(2) {
                assert!(w[0].1 <= w[1].1, "local edges not clustered by dst");
            }
        }
    }
}
