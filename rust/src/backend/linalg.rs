//! Dense f32 linear algebra for the native backend: blocked matmuls,
//! LayerNorm forward/backward, activations, reductions. Sizes are modest
//! (n_pad × ≤128), so simple register-blocked loops that auto-vectorize
//! are the right tool.

/// `c += a @ b`, a: m×k, b: k×n, row-major.
pub fn matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    // i-k-j loop order: unit-stride inner loop over both b and c.
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let ci = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in ai.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let bk = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                ci[j] += aik * bk[j];
            }
        }
    }
}

/// `c = a @ b` (overwrite).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    c.iter_mut().for_each(|x| *x = 0.0);
    matmul_acc(a, b, m, k, n, c);
}

/// `c += aᵀ @ b`, a: m×k (so aᵀ: k×m), b: m×n, c: k×n.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let bi = &b[i * n..(i + 1) * n];
        for (kk, &aik) in ai.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let ck = &mut c[kk * n..(kk + 1) * n];
            for j in 0..n {
                ck[j] += aik * bi[j];
            }
        }
    }
}

/// `c += a @ bᵀ`, a: m×k, b: n×k (so bᵀ: k×n), c: m×n.
pub fn matmul_nt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let ci = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let bj = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for kk in 0..k {
                acc += ai[kk] * bj[kk];
            }
            ci[j] += acc;
        }
    }
}

/// Add a row vector to every row: `x[i] += b`.
pub fn add_bias(x: &mut [f32], n_rows: usize, b: &[f32]) {
    let n = b.len();
    for i in 0..n_rows {
        let row = &mut x[i * n..(i + 1) * n];
        for (r, &bb) in row.iter_mut().zip(b.iter()) {
            *r += bb;
        }
    }
}

/// Column sums: `out[j] += Σ_i x[i][j]`.
pub fn col_sum_acc(x: &[f32], n_rows: usize, n_cols: usize, out: &mut [f32]) {
    assert_eq!(out.len(), n_cols);
    for i in 0..n_rows {
        let row = &x[i * n_cols..(i + 1) * n_cols];
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// `dx = d_out ⊙ (out > 0)` — ReLU backward via the saved output.
pub fn relu_bwd(d_out: &[f32], out: &[f32], dx: &mut [f32]) {
    for ((d, &o), x) in d_out.iter().zip(out.iter()).zip(dx.iter_mut()) {
        *x = if o > 0.0 { *d } else { 0.0 };
    }
}

pub const LN_EPS: f32 = 1e-5;

/// Row-wise non-affine LayerNorm, matching `kernels/layernorm.py` and
/// jnp exactly (mean/biased-variance).
pub fn layernorm(x: &[f32], n_rows: usize, f: usize, out: &mut [f32]) {
    for i in 0..n_rows {
        let row = &x[i * f..(i + 1) * f];
        let o = &mut out[i * f..(i + 1) * f];
        let mean = row.iter().sum::<f32>() / f as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / f as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (oo, &v) in o.iter_mut().zip(row.iter()) {
            *oo = (v - mean) * inv;
        }
    }
}

/// LayerNorm backward: `dx = inv/f · (f·dy − Σdy − x̂·Σ(dy·x̂))`.
pub fn layernorm_bwd(x: &[f32], dy: &[f32], n_rows: usize, f: usize, dx: &mut [f32]) {
    for i in 0..n_rows {
        let row = &x[i * f..(i + 1) * f];
        let dyr = &dy[i * f..(i + 1) * f];
        let dxr = &mut dx[i * f..(i + 1) * f];
        let mean = row.iter().sum::<f32>() / f as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / f as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let mut sum_dy = 0f32;
        let mut sum_dyx = 0f32;
        for (&d, &v) in dyr.iter().zip(row.iter()) {
            let xhat = (v - mean) * inv;
            sum_dy += d;
            sum_dyx += d * xhat;
        }
        let ff = f as f32;
        for ((dxo, &d), &v) in dxr.iter_mut().zip(dyr.iter()).zip(row.iter()) {
            let xhat = (v - mean) * inv;
            *dxo = (inv / ff) * (ff * d - sum_dy - xhat * sum_dyx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_close, propcheck};
    use crate::util::rng::Rng;

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        let mut c = vec![0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut c);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn prop_matmul_variants_agree() {
        propcheck(24, |gen| {
            let m = gen.usize(1, 20);
            let k = gen.usize(1, 20);
            let n = gen.usize(1, 20);
            let a = gen.vec_f32(m * k, -2.0, 2.0);
            let b = gen.vec_f32(k * n, -2.0, 2.0);
            let want = naive_matmul(&a, &b, m, k, n);
            let mut c = vec![0f32; m * n];
            matmul(&a, &b, m, k, n, &mut c);
            prop_close(&c, &want, 1e-4, 1e-4)?;
            // aᵀ via matmul_tn: (aᵀ)ᵀ @ b — transpose a into at: k×m.
            let mut at = vec![0f32; k * m];
            for i in 0..m {
                for kk in 0..k {
                    at[kk * m + i] = a[i * k + kk];
                }
            }
            let mut c2 = vec![0f32; m * n];
            matmul_tn_acc(&at, &b, k, m, n, &mut c2);
            prop_close(&c2, &want, 1e-4, 1e-4)?;
            // a @ bᵀᵀ via matmul_nt with bt: n×k.
            let mut bt = vec![0f32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    bt[j * k + kk] = b[kk * n + j];
                }
            }
            let mut c3 = vec![0f32; m * n];
            matmul_nt_acc(&a, &bt, m, k, n, &mut c3);
            prop_close(&c3, &want, 1e-4, 1e-4)
        });
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(5);
        let (n, f) = (10, 32);
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32() * 10.0 - 5.0).collect();
        let mut y = vec![0f32; n * f];
        layernorm(&x, n, f, &mut y);
        for i in 0..n {
            let row = &y[i * f..(i + 1) * f];
            let mean = row.iter().sum::<f32>() / f as f32;
            let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / f as f32;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_difference() {
        let mut rng = Rng::new(6);
        let (n, f) = (3, 8);
        let x: Vec<f32> = (0..n * f).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let dy: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let mut dx = vec![0f32; n * f];
        layernorm_bwd(&x, &dy, n, f, &mut dx);
        // finite differences of scalar L = Σ ln(x)·dy
        let eps = 1e-3f32;
        for idx in [0usize, 5, 13, 23] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let mut yp = vec![0f32; n * f];
            let mut ym = vec![0f32; n * f];
            layernorm(&xp, n, f, &mut yp);
            layernorm(&xm, n, f, &mut ym);
            let lp: f32 = yp.iter().zip(dy.iter()).map(|(a, b)| a * b).sum();
            let lm: f32 = ym.iter().zip(dy.iter()).map(|(a, b)| a * b).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - dx[idx]).abs() < 2e-2,
                "idx {idx}: fd {fd} vs analytic {}",
                dx[idx]
            );
        }
    }

    #[test]
    fn relu_and_bwd() {
        let mut x = vec![-1.0f32, 2.0, 0.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0]);
        let mut dx = vec![9f32; 3];
        relu_bwd(&[1.0, 1.0, 1.0], &x, &mut dx);
        assert_eq!(dx, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn bias_and_colsum() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, 2, &[10.0, 20.0]);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        let mut cs = vec![0f32; 2];
        col_sum_acc(&x, 2, 2, &mut cs);
        assert_eq!(cs, vec![24.0, 46.0]);
    }
}
