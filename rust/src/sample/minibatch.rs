//! The [`MiniBatch`] exchange format shared by every sampler and consumed
//! by `coordinator::minibatch::MiniBatchTrainer`.
//!
//! A batch is a small self-contained training problem: global node ids
//! (`n_id`), an induced CSR adjacency over the *local* ids `0..n_id.len()`,
//! per-arc aggregation weights (so sampled aggregation stays an unbiased
//! estimate of the full mean aggregation), and per-target loss weights
//! (GraphSAINT coverage normalization; 1.0 elsewhere).

use crate::graph::CsrGraph;

/// One sampled mini-batch.
#[derive(Clone, Debug)]
pub struct MiniBatch {
    /// Producing sampler (for logs / reports).
    pub sampler: &'static str,
    /// Global node ids; row `i` of every batch tensor is node `n_id[i]`.
    /// Ids are distinct; the first `n_target` rows are the loss/metric
    /// targets.
    pub n_id: Vec<u32>,
    /// Leading rows of `n_id` that carry loss and metrics.
    pub n_target: usize,
    /// Induced adjacency over local ids (CSR by destination, like the
    /// global graph: `in_neighbors(v)` are aggregation sources).
    pub adj: CsrGraph,
    /// Per-arc aggregation weight, aligned with `adj.col_idx`. For exact
    /// mean aggregation this is `1/deg`; fan-out sampling uses
    /// `1/fanout` so the sampled sum estimates the full mean.
    pub edge_weight: Vec<f32>,
    /// Per-target loss weight (len `n_target`).
    pub node_weight: Vec<f32>,
}

impl MiniBatch {
    /// Nodes in the batch.
    pub fn n(&self) -> usize {
        self.n_id.len()
    }

    /// Arcs in the batch.
    pub fn m(&self) -> usize {
        self.adj.m()
    }

    /// Structural invariants (used by tests and debug builds).
    pub fn validate(&self, n_global: usize) -> anyhow::Result<()> {
        self.adj.validate()?;
        anyhow::ensure!(self.adj.n == self.n_id.len(), "adj/n_id size mismatch");
        anyhow::ensure!(self.n_target <= self.n_id.len(), "n_target out of range");
        anyhow::ensure!(self.node_weight.len() == self.n_target, "node_weight length");
        anyhow::ensure!(self.edge_weight.len() == self.adj.m(), "edge_weight length");
        anyhow::ensure!(
            self.n_id.iter().all(|&v| (v as usize) < n_global),
            "n_id out of global range"
        );
        let mut ids = self.n_id.clone();
        ids.sort_unstable();
        ids.dedup();
        anyhow::ensure!(ids.len() == self.n_id.len(), "n_id contains duplicates");
        anyhow::ensure!(
            self.edge_weight.iter().all(|w| w.is_finite() && *w >= 0.0),
            "edge weights must be finite and non-negative"
        );
        Ok(())
    }
}

/// Build a weighted CSR-by-destination from arcs `(src, dst, weight)` in
/// local ids. Rows come out sorted by source (matching
/// [`CsrGraph::from_edges`]) with weights aligned to `col_idx`.
pub fn csr_with_weights(n: usize, arcs: &[(u32, u32, f32)]) -> (CsrGraph, Vec<f32>) {
    let mut order: Vec<usize> = (0..arcs.len()).collect();
    order.sort_unstable_by_key(|&i| (arcs[i].1, arcs[i].0));
    let mut row_ptr = vec![0usize; n + 1];
    for &(_, d, _) in arcs {
        row_ptr[d as usize + 1] += 1;
    }
    for v in 0..n {
        row_ptr[v + 1] += row_ptr[v];
    }
    let mut col_idx = Vec::with_capacity(arcs.len());
    let mut weights = Vec::with_capacity(arcs.len());
    for &i in &order {
        col_idx.push(arcs[i].0);
        weights.push(arcs[i].2);
    }
    (
        CsrGraph {
            n,
            row_ptr,
            col_idx,
        },
        weights,
    )
}

/// Exact mean-aggregation weights for an induced adjacency: `1/deg(v)`
/// for every in-arc of `v` (Cluster-GCN / SAINT aggregate over the
/// retained neighbors).
pub fn mean_edge_weights(adj: &CsrGraph) -> Vec<f32> {
    let mut w = vec![0f32; adj.m()];
    for v in 0..adj.n {
        let d = adj.in_degree(v);
        if d == 0 {
            continue;
        }
        let inv = 1.0 / d as f32;
        for x in &mut w[adj.row_ptr[v]..adj.row_ptr[v + 1]] {
            *x = inv;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_with_weights_matches_from_edges_layout() {
        let arcs = [(2u32, 0u32, 0.5f32), (1, 0, 0.25), (0, 2, 1.0), (1, 2, 2.0)];
        let (g, w) = csr_with_weights(3, &arcs);
        let plain: Vec<(u32, u32)> = arcs.iter().map(|&(s, d, _)| (s, d)).collect();
        let want = CsrGraph::from_edges(3, &plain);
        assert_eq!(g, want);
        // Weights follow the sorted-by-src row order.
        assert_eq!(g.in_neighbors(0), &[1, 2]);
        assert_eq!(&w[..2], &[0.25, 0.5]);
        assert_eq!(g.in_neighbors(2), &[0, 1]);
        assert_eq!(&w[2..], &[1.0, 2.0]);
    }

    #[test]
    fn mean_weights_sum_to_one_per_row() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (2, 1), (3, 1), (1, 2)]);
        let w = mean_edge_weights(&g);
        for v in 0..g.n {
            let s: f32 = w[g.row_ptr[v]..g.row_ptr[v + 1]].iter().sum();
            if g.in_degree(v) > 0 {
                assert!((s - 1.0).abs() < 1e-6, "row {v} sums to {s}");
            }
        }
    }

    #[test]
    fn validate_catches_duplicates() {
        let (adj, ew) = csr_with_weights(2, &[(0, 1, 1.0)]);
        let mut mb = MiniBatch {
            sampler: "test",
            n_id: vec![3, 3],
            n_target: 2,
            adj,
            edge_weight: ew,
            node_weight: vec![1.0, 1.0],
        };
        assert!(mb.validate(10).is_err());
        mb.n_id = vec![3, 4];
        mb.validate(10).unwrap();
        assert_eq!(mb.n(), 2);
        assert_eq!(mb.m(), 1);
    }
}
