//! Graph substrate: CSR storage, builders, generators, IO and statistics.
//!
//! All graphs in SuperGCN are directed in storage; "undirected" datasets
//! store both arcs. Node ids are `u32` (the largest graphs we instantiate
//! on this testbed stay well below 2^32 nodes); edge offsets are `usize`.

pub mod generate;
pub mod io;
pub mod stats;
pub mod store;
pub mod synth;

/// Read-only topology access — the trait `hier::remote_pairs`,
/// `hier::plan`, and the streaming partitioner are generic over, so the
/// identical planning code runs against the in-memory [`CsrGraph`] and
/// the mmap-backed [`store::GraphStore`] and produces identical plans by
/// construction (the bit-exactness contract of DESIGN.md §17).
pub trait GraphTopo {
    /// Node count.
    fn num_nodes(&self) -> usize;
    /// In-degree of `v`.
    fn in_degree(&self, v: usize) -> usize;
    /// In-neighbors (sources) of `v`, sorted ascending.
    fn in_neighbors(&self, v: usize) -> &[u32];
}

impl GraphTopo for CsrGraph {
    fn num_nodes(&self) -> usize {
        self.n
    }

    fn in_degree(&self, v: usize) -> usize {
        CsrGraph::in_degree(self, v)
    }

    fn in_neighbors(&self, v: usize) -> &[u32] {
        CsrGraph::in_neighbors(self, v)
    }
}

/// Compressed-sparse-row graph: for each node `v`, `row_ptr[v]..row_ptr[v+1]`
/// indexes `col_idx` with the **in-neighbors** of `v` (aggregation pulls
/// from sources into destinations, so CSR-by-destination is the layout the
/// aggregation operators of §4 want).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrGraph {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
}

impl CsrGraph {
    /// Build from an arc list `(src, dst)` — arcs aggregate src → dst.
    /// Duplicate arcs are kept (multi-edges add weight, matching
    /// index_add semantics).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut deg = vec![0usize; n];
        for &(_, d) in edges {
            deg[d as usize] += 1;
        }
        let mut row_ptr = vec![0usize; n + 1];
        for v in 0..n {
            row_ptr[v + 1] = row_ptr[v] + deg[v];
        }
        let mut cursor = row_ptr.clone();
        let mut col_idx = vec![0u32; edges.len()];
        for &(s, d) in edges {
            let c = &mut cursor[d as usize];
            col_idx[*c] = s;
            *c += 1;
        }
        // Sort each row's sources for deterministic layouts (and better
        // locality in the sequential-gather kernels).
        for v in 0..n {
            col_idx[row_ptr[v]..row_ptr[v + 1]].sort_unstable();
        }
        Self { n, row_ptr, col_idx }
    }

    /// Number of arcs.
    pub fn m(&self) -> usize {
        self.col_idx.len()
    }

    /// In-degree of `v`.
    #[inline]
    pub fn in_degree(&self, v: usize) -> usize {
        self.row_ptr[v + 1] - self.row_ptr[v]
    }

    /// In-neighbors (sources) of `v`.
    #[inline]
    pub fn in_neighbors(&self, v: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[v]..self.row_ptr[v + 1]]
    }

    /// Accumulate out-degree counts into `deg` (callers own the buffer, so
    /// chunked scans can fold many graphs/slices without reallocating).
    pub fn out_degrees_into(&self, deg: &mut [usize]) {
        assert!(deg.len() >= self.n, "out-degree buffer too small");
        for &s in &self.col_idx {
            deg[s as usize] += 1;
        }
    }

    /// Out-degrees (computed; not stored).
    pub fn out_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.n];
        self.out_degrees_into(&mut deg);
        deg
    }

    /// Lazy arc iterator `(src, dst)` in CSR order — no `Vec<(u32, u32)>`
    /// materialization, so edge scans stay O(1) memory on large graphs.
    pub fn edges_iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.rows(0..self.n).edges()
    }

    /// Flat arc list `(src, dst)` in CSR order.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        self.edges_iter().collect()
    }

    /// Borrow the CSR rows of `range` as a [`CsrRows`] view: the chunked
    /// access primitive the streaming partitioner and `graph::stats` scan
    /// with instead of materializing edge lists.
    pub fn rows(&self, range: std::ops::Range<usize>) -> CsrRows<'_> {
        assert!(range.end <= self.n, "row range past n");
        CsrRows {
            start: range.start,
            row_ptr: &self.row_ptr[range.start..range.end + 1],
            col_idx: &self.col_idx,
        }
    }

    /// The reverse graph (CSR over out-neighbors): needed by the backward
    /// pass, where cotangents flow dst → src.
    pub fn transpose(&self) -> CsrGraph {
        let rev: Vec<(u32, u32)> = self.edges_iter().map(|(s, d)| (d, s)).collect();
        CsrGraph::from_edges(self.n, &rev)
    }

    /// Make the graph symmetric (add every reverse arc, dedup) — the paper
    /// converts papers100M to undirected the same way.
    pub fn to_undirected(&self) -> CsrGraph {
        let mut es = self.edges();
        es.extend(self.edges().iter().map(|&(s, d)| (d, s)));
        es.sort_unstable();
        es.dedup();
        CsrGraph::from_edges(self.n, &es)
    }

    /// Induced subgraph over `nodes` (distinct global ids): a CSR over
    /// local ids `0..nodes.len()` in the given order, keeping exactly the
    /// arcs whose endpoints both lie in `nodes`. The workhorse of the
    /// Cluster-GCN / GraphSAINT samplers (`sample::`).
    pub fn induced(&self, nodes: &[u32]) -> CsrGraph {
        // Localization scales with the node set, not the graph: an
        // O(n_global) table here would dominate per-batch sampling cost
        // for small batches on large graphs.
        let mut loc: std::collections::HashMap<u32, u32> =
            std::collections::HashMap::with_capacity(nodes.len());
        for (i, &v) in nodes.iter().enumerate() {
            let prev = loc.insert(v, i as u32);
            debug_assert!(prev.is_none(), "duplicate node {v}");
        }
        let mut edges = Vec::new();
        for (i, &v) in nodes.iter().enumerate() {
            for &s in self.in_neighbors(v as usize) {
                if let Some(&ls) = loc.get(&s) {
                    edges.push((ls, i as u32));
                }
            }
        }
        CsrGraph::from_edges(nodes.len(), &edges)
    }

    /// Validate structural invariants — monotone `row_ptr` bracketing
    /// exactly `col_idx`, in-range sources, and sorted rows (every builder
    /// and every loader in `graph::io` / `graph::store` runs this, so a
    /// corrupt file can never reach the aggregation kernels).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.row_ptr.len() == self.n + 1,
            "row_ptr length {} != n+1 ({})",
            self.row_ptr.len(),
            self.n + 1
        );
        anyhow::ensure!(self.row_ptr[0] == 0, "row_ptr[0] = {} != 0", self.row_ptr[0]);
        anyhow::ensure!(
            *self.row_ptr.last().unwrap() == self.col_idx.len(),
            "row_ptr[-1] = {} != edge count {}",
            self.row_ptr.last().unwrap(),
            self.col_idx.len()
        );
        for v in 0..self.n {
            anyhow::ensure!(self.row_ptr[v] <= self.row_ptr[v + 1], "row_ptr monotone at {v}");
        }
        for v in 0..self.n {
            let row = self.in_neighbors(v);
            for w in row.windows(2) {
                anyhow::ensure!(w[0] <= w[1], "row {v} not sorted ({} after {})", w[1], w[0]);
            }
        }
        for &s in &self.col_idx {
            anyhow::ensure!((s as usize) < self.n, "col_idx {s} out of range (n={})", self.n);
        }
        Ok(())
    }
}

/// A borrowed view of a contiguous CSR row range (`GraphStore::rows` and
/// `CsrGraph::rows` both hand these out): chunked scans iterate row
/// ranges instead of materializing `edges()`.
#[derive(Clone, Copy)]
pub struct CsrRows<'a> {
    /// Global id of the first row in the view.
    pub start: usize,
    /// `len+1` offsets into the *global* `col_idx` (not rebased).
    pub row_ptr: &'a [usize],
    /// The full column array the offsets index.
    pub col_idx: &'a [u32],
}

impl<'a> CsrRows<'a> {
    /// Rows in the view.
    pub fn len(&self) -> usize {
        self.row_ptr.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// In-neighbors of the `i`-th row of the view (global id `start + i`).
    #[inline]
    pub fn in_neighbors(&self, i: usize) -> &'a [u32] {
        &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// In-degree of the `i`-th row of the view.
    #[inline]
    pub fn in_degree(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Lazy `(src, dst)` arcs of the view, dst ascending — the chunked
    /// replacement for `CsrGraph::edges()`.
    pub fn edges(self) -> impl Iterator<Item = (u32, u32)> + 'a {
        let start = self.start;
        (0..self.len()).flat_map(move |i| {
            self.in_neighbors(i)
                .iter()
                .map(move |&s| (s, (start + i) as u32))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_assert, propcheck};

    fn toy() -> CsrGraph {
        // Arcs: 0->1, 0->2, 1->2, 2->0, 2->0 (multi-edge)
        CsrGraph::from_edges(3, &[(0, 1), (0, 2), (1, 2), (2, 0), (2, 0)])
    }

    #[test]
    fn csr_basbasics() {
        let g = toy();
        assert_eq!(g.n, 3);
        assert_eq!(g.m(), 5);
        assert_eq!(g.in_degree(0), 2); // two copies of 2->0
        assert_eq!(g.in_neighbors(2), &[0, 1]);
        g.validate().unwrap();
    }

    #[test]
    fn transpose_involution() {
        let g = toy();
        let gt = g.transpose();
        assert_eq!(gt.in_neighbors(0), &[1, 2]); // out-neighbors of 0 were {1,2}
        let gtt = gt.transpose();
        assert_eq!(g, gtt);
    }

    #[test]
    fn out_degrees_match_edges() {
        let g = toy();
        let od = g.out_degrees();
        assert_eq!(od, vec![2, 1, 2]);
        assert_eq!(od.iter().sum::<usize>(), g.m());
        // The chunk-friendly accumulator folds into a caller buffer.
        let mut acc = vec![0usize; 3];
        g.out_degrees_into(&mut acc);
        g.out_degrees_into(&mut acc);
        assert_eq!(acc, vec![4, 2, 4]);
    }

    #[test]
    fn edges_iter_matches_materialized_edges() {
        let g = toy();
        let lazy: Vec<(u32, u32)> = g.edges_iter().collect();
        assert_eq!(lazy, g.edges());
    }

    #[test]
    fn rows_view_windows_the_csr() {
        let g = toy();
        let all = g.rows(0..g.n);
        assert_eq!(all.len(), 3);
        assert_eq!(all.in_neighbors(2), g.in_neighbors(2));
        let tail = g.rows(1..3);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail.start, 1);
        assert_eq!(tail.in_neighbors(0), g.in_neighbors(1));
        assert_eq!(tail.in_degree(1), g.in_degree(2));
        let arcs: Vec<(u32, u32)> = tail.edges().collect();
        let want: Vec<(u32, u32)> = g.edges_iter().filter(|&(_, d)| d >= 1).collect();
        assert_eq!(arcs, want);
        assert!(g.rows(2..2).is_empty());
    }

    #[test]
    fn validate_rejects_unsorted_rows() {
        let mut g = toy();
        g.validate().unwrap();
        // Swap two sources within one row: structurally fine, but the
        // sorted-rows invariant every builder establishes is broken.
        let (a, b) = (g.row_ptr[2], g.row_ptr[2] + 1);
        g.col_idx.swap(a, b);
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("not sorted"), "{err}");
    }

    #[test]
    fn undirected_is_symmetric() {
        let g = toy().to_undirected();
        for (s, d) in g.edges() {
            assert!(
                g.in_neighbors(s as usize).contains(&d),
                "missing reverse of ({s},{d})"
            );
        }
    }

    #[test]
    fn induced_subgraph_keeps_internal_arcs() {
        let g = toy();
        // Take nodes {0, 2}: internal arcs are 0->2 and the double 2->0.
        let sub = g.induced(&[0, 2]);
        assert_eq!(sub.n, 2);
        sub.validate().unwrap();
        assert_eq!(sub.in_neighbors(0), &[1, 1]); // two copies of 2->0
        assert_eq!(sub.in_neighbors(1), &[0]); // 0->2
        // Node order defines local ids.
        let sub2 = g.induced(&[2, 0]);
        assert_eq!(sub2.in_neighbors(0), &[1]);
        assert_eq!(sub2.in_neighbors(1), &[0, 0]);
        // Empty selection.
        assert_eq!(g.induced(&[]).n, 0);
    }

    #[test]
    fn prop_induced_matches_filter() {
        propcheck(24, |gen| {
            let n = gen.usize(2, 50);
            let m = gen.usize(0, 200);
            let edges = gen.edges(n, m, true);
            let g = CsrGraph::from_edges(n, &edges);
            let take = gen.usize(1, n);
            let picked = gen.rng.sample_indices(n, take);
            let nodes: Vec<u32> = picked.iter().map(|&v| v as u32).collect();
            let sub = g.induced(&nodes);
            let loc: std::collections::HashMap<u32, u32> = nodes
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i as u32))
                .collect();
            let mut want: Vec<(u32, u32)> = edges
                .iter()
                .filter_map(|&(s, d)| match (loc.get(&s), loc.get(&d)) {
                    (Some(&ls), Some(&ld)) => Some((ls, ld)),
                    _ => None,
                })
                .collect();
            want.sort_unstable();
            let mut got = sub.edges();
            got.sort_unstable();
            prop_assert(got == want, "induced arc multiset mismatch")
        });
    }

    #[test]
    fn prop_csr_roundtrip_and_invariants() {
        propcheck(48, |gen| {
            let n = gen.usize(1, 64);
            let m = gen.usize(0, 256);
            let mut edges = gen.edges(n, m, true);
            let g = CsrGraph::from_edges(n, &edges);
            g.validate().map_err(|e| e.to_string())?;
            prop_assert(g.m() == m, format!("edge count {} != {}", g.m(), m))?;
            // Round-trip through edges(): same multiset of arcs.
            let mut back = g.edges();
            edges.sort_unstable();
            back.sort_unstable();
            prop_assert(edges == back, "edge multiset mismatch")
        });
    }

    #[test]
    fn prop_transpose_preserves_arcs() {
        propcheck(32, |gen| {
            let n = gen.usize(1, 40);
            let m = gen.usize(0, 160);
            let edges = gen.edges(n, m, true);
            let g = CsrGraph::from_edges(n, &edges);
            let mut fwd = g.edges();
            let mut rev: Vec<(u32, u32)> =
                g.transpose().edges().iter().map(|&(s, d)| (d, s)).collect();
            fwd.sort_unstable();
            rev.sort_unstable();
            prop_assert(fwd == rev, "transpose lost arcs")
        });
    }
}
