//! Register-blocked destination-major segment sum — Fig. 3(b)+(c):
//! clustering (sorted segments) turns the scatter into runs; loop
//! reordering iterates runs destination-major; the inner kernel accumulates
//! a fixed-width feature block of the destination row in registers across
//! the whole run, writing it back once.
//!
//! The feature dimension is processed in `LANE`-wide chunks (64 B = one
//! cache line of f32), the "shape-adaptive inner kernel" of §4(3): the
//! chunk loop is branch-free and auto-vectorizes; remainders fall back to
//! a scalar tail.

const LANE: usize = 16; // 16 × f32 = 64-byte cache line / 512-bit vector

/// `out[seg[i]] += h[gather[i]]`, `seg` non-decreasing.
pub fn segment_sum(h: &[f32], f: usize, gather: &[u32], seg: &[u32], out: &mut [f32]) {
    assert_eq!(gather.len(), seg.len());
    debug_assert!(super::is_sorted_segs(seg));
    let m = gather.len();
    if m == 0 {
        return;
    }
    let mut run_start = 0usize;
    while run_start < m {
        let s = seg[run_start];
        let mut run_end = run_start + 1;
        while run_end < m && seg[run_end] == s {
            run_end += 1;
        }
        accumulate_run(h, f, &gather[run_start..run_end], &mut out[s as usize * f..(s as usize + 1) * f]);
        run_start = run_end;
    }
}

/// Accumulate `dst += Σ h[g]` for one destination run, feature-blocked.
/// `pub(crate)` so the subset/tiled drivers (`segment_sum_rows`,
/// `agg::parallel`) reuse the exact inner loop — per-destination bitwise
/// identity across entry points is what the overlap schedule's
/// bit-exactness rests on (DESIGN.md §11).
#[inline]
pub(crate) fn accumulate_run(h: &[f32], f: usize, gathers: &[u32], dst: &mut [f32]) {
    // §Perf: single-source runs are the common case on sparse graphs —
    // skip the register-block setup and stream one fused add.
    if let [g] = gathers {
        let src = &h[*g as usize * f..(*g as usize + 1) * f];
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d += s;
        }
        return;
    }
    let full = f / LANE * LANE;
    let mut col = 0usize;
    // Register-blocked main loop: LANE accumulators live across the whole
    // source run of this destination.
    while col < full {
        let mut acc = [0f32; LANE];
        for &g in gathers {
            let src = &h[g as usize * f + col..g as usize * f + col + LANE];
            for i in 0..LANE {
                acc[i] += src[i];
            }
        }
        let d = &mut dst[col..col + LANE];
        for i in 0..LANE {
            d[i] += acc[i];
        }
        col += LANE;
    }
    // Scalar tail.
    if col < f {
        for &g in gathers {
            let src = &h[g as usize * f..(g as usize + 1) * f];
            for i in col..f {
                dst[i] += src[i];
            }
        }
    }
}

/// Like [`segment_sum`] but over an explicit run range of segments
/// `[seg_lo, seg_hi)` given the positions `pos` where each segment's run
/// starts in `gather` (CSR-style). Used by the 2D-parallel driver.
pub fn segment_sum_range(
    h: &[f32],
    f: usize,
    gather: &[u32],
    seg_offsets: &[usize],
    seg_lo: usize,
    seg_hi: usize,
    out: &mut [f32],
) {
    for s in seg_lo..seg_hi {
        let (a, b) = (seg_offsets[s], seg_offsets[s + 1]);
        if a == b {
            continue;
        }
        accumulate_run(h, f, &gather[a..b], &mut out[s * f..(s + 1) * f]);
    }
}

/// Subset-restricted segment sum: accumulate only the destination rows
/// listed in `rows` (strictly increasing), given the CSR-style run
/// offsets of [`segment_offsets`]. Each selected destination is processed
/// by the same `accumulate_run` inner loop as a full [`segment_sum`]
/// pass, so — provided its `out` row starts untouched — its result is
/// bitwise identical to the full pass. A partition of `0..n_seg` into
/// disjoint row subsets therefore reproduces the full kernel exactly,
/// which is the overlap schedule's interior/boundary contract
/// (DESIGN.md §11). No sub-CSR is materialized.
pub fn segment_sum_rows(
    h: &[f32],
    f: usize,
    gather: &[u32],
    seg_offsets: &[usize],
    rows: &[u32],
    out: &mut [f32],
) {
    debug_assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must be strictly increasing");
    for &r in rows {
        let s = r as usize;
        let (a, b) = (seg_offsets[s], seg_offsets[s + 1]);
        if a == b {
            continue;
        }
        accumulate_run(h, f, &gather[a..b], &mut out[s * f..(s + 1) * f]);
    }
}

/// Build CSR-style segment offsets from a sorted `seg` array:
/// `offsets[s]..offsets[s+1]` is segment `s`'s run (possibly empty).
pub fn segment_offsets(seg: &[u32], n_seg: usize) -> Vec<usize> {
    debug_assert!(super::is_sorted_segs(seg));
    let mut off = vec![0usize; n_seg + 1];
    for &s in seg {
        off[s as usize + 1] += 1;
    }
    for s in 0..n_seg {
        off[s + 1] += off[s];
    }
    off
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::testutil::random_problem;
    use crate::agg::vanilla;
    use crate::util::propcheck::{prop_close, propcheck};
    use crate::util::rng::Rng;

    #[test]
    fn matches_vanilla_exactly_when_sorted() {
        let mut rng = Rng::new(12);
        for &(n_src, n_seg, m, f) in
            &[(50usize, 30usize, 200usize, 16usize), (10, 5, 40, 7), (100, 64, 500, 33), (4, 4, 8, 1)]
        {
            let (h, gather, seg) = random_problem(&mut rng, n_src, n_seg, m, f);
            let mut a = vec![0f32; n_seg * f];
            let mut b = vec![0f32; n_seg * f];
            vanilla::segment_sum(&h, f, &gather, &seg, &mut a);
            segment_sum(&h, f, &gather, &seg, &mut b);
            // Same per-segment accumulation order ⇒ bitwise equal.
            assert_eq!(a, b, "shape ({n_src},{n_seg},{m},{f})");
        }
    }

    #[test]
    fn range_api_matches_full() {
        let mut rng = Rng::new(3);
        let (h, gather, seg) = random_problem(&mut rng, 40, 20, 150, 24);
        let off = segment_offsets(&seg, 20);
        let mut a = vec![0f32; 20 * 24];
        segment_sum(&h, 24, &gather, &seg, &mut a);
        let mut b = vec![0f32; 20 * 24];
        segment_sum_range(&h, 24, &gather, &off, 0, 10, &mut b);
        segment_sum_range(&h, 24, &gather, &off, 10, 20, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn rows_subset_union_reproduces_full_kernel_bitwise() {
        // Any 2-way partition of the destination rows must reproduce the
        // full segment sum bit-for-bit (the interior/boundary contract).
        let mut rng = Rng::new(29);
        let (n_src, n_seg, m, f) = (50usize, 33usize, 400usize, 19usize);
        let (h, gather, seg) = random_problem(&mut rng, n_src, n_seg, m, f);
        let off = segment_offsets(&seg, n_seg);
        let mut full = vec![0f32; n_seg * f];
        segment_sum(&h, f, &gather, &seg, &mut full);
        // Interleaved split (worst case for contiguity assumptions).
        let a_rows: Vec<u32> = (0..n_seg as u32).filter(|r| r % 3 != 0).collect();
        let b_rows: Vec<u32> = (0..n_seg as u32).filter(|r| r % 3 == 0).collect();
        let mut split = vec![0f32; n_seg * f];
        segment_sum_rows(&h, f, &gather, &off, &a_rows, &mut split);
        segment_sum_rows(&h, f, &gather, &off, &b_rows, &mut split);
        assert_eq!(full, split, "subset union must be bitwise exact");
        // Empty subset is a no-op.
        let before = split.clone();
        segment_sum_rows(&h, f, &gather, &off, &[], &mut split);
        assert_eq!(before, split);
    }

    #[test]
    fn offsets_cover_runs() {
        let seg = vec![0, 0, 2, 2, 2, 5];
        let off = segment_offsets(&seg, 6);
        assert_eq!(off, vec![0, 2, 2, 5, 5, 5, 6]);
    }

    #[test]
    fn prop_blocked_equals_vanilla() {
        propcheck(32, |gen| {
            let n_src = gen.usize(1, 60);
            let n_seg = gen.usize(1, 40);
            let m = gen.usize(0, 300);
            let f = gen.usize(1, 70);
            let (h, gather, seg) = random_problem(&mut gen.rng, n_src, n_seg, m, f);
            let mut a = vec![0f32; n_seg * f];
            let mut b = vec![0f32; n_seg * f];
            vanilla::segment_sum(&h, f, &gather, &seg, &mut a);
            segment_sum(&h, f, &gather, &seg, &mut b);
            prop_close(&a, &b, 1e-6, 1e-6)
        });
    }
}
