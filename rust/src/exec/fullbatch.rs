//! [`GraphContext`] for the full-batch regime (paper Fig. 2): neighbor
//! features arrive through the hierarchical pre/post halo exchange over
//! the partition plans (`hier::plan` via `coordinator::planner`), with
//! optional `quant::fused` payloads and `delay_comm` staleness. The
//! reverse pass ships halo cotangents back to their producers, so the
//! distributed gradient equals the single-machine gradient to f32
//! round-off (`tests/trainer_equivalence.rs`).

use super::dispatch::AggDispatch;
use super::GraphContext;
use crate::comm::{alltoallv, CommStats, Payload};
use crate::coordinator::planner::WorkerCtx;
use crate::perfmodel::MachineProfile;
use crate::quant::{fused, Bits};
use crate::runtime::ShapeConfig;
use anyhow::Result;
use std::time::Instant;

/// Persistent halo state: received tensors survive across epochs so
/// `delay_comm > 1` (the DistGNN cd-N baseline) trains on stale halos
/// between exchange epochs, exactly like the paper's baseline.
pub struct FullBatchState {
    /// `recv_pre[layer][lane]`: received pre-aggregated partial rows.
    recv_pre: Vec<Vec<Vec<f32>>>,
    /// `recv_post[layer][lane]`: received raw post rows.
    recv_post: Vec<Vec<Vec<f32>>>,
    /// Send-side pre-aggregation partials (`p_pre × maxf` scratch).
    partials: Vec<Vec<f32>>,
    d_recv_pre: Vec<Vec<f32>>,
    d_recv_post: Vec<Vec<f32>>,
    d_partials: Vec<Vec<f32>>,
}

impl FullBatchState {
    pub fn new(shapes: &ShapeConfig, lanes: usize) -> Self {
        let dims = shapes.layer_dims();
        let maxf = shapes.f_in.max(shapes.hidden).max(shapes.classes);
        Self {
            recv_pre: (0..3)
                .map(|l| (0..lanes).map(|_| vec![0f32; shapes.r_pre * dims[l].0]).collect())
                .collect(),
            recv_post: (0..3)
                .map(|l| (0..lanes).map(|_| vec![0f32; shapes.r_post * dims[l].0]).collect())
                .collect(),
            partials: (0..lanes).map(|_| vec![0f32; shapes.p_pre * maxf]).collect(),
            d_recv_pre: (0..lanes).map(|_| vec![0f32; shapes.r_pre * maxf]).collect(),
            d_recv_post: (0..lanes).map(|_| vec![0f32; shapes.r_post * maxf]).collect(),
            d_partials: (0..lanes).map(|_| vec![0f32; shapes.p_pre * maxf]).collect(),
        }
    }
}

/// One epoch's view over the workers: borrows the static contexts and the
/// persistent halo state, charges communication to the epoch's
/// [`CommStats`].
pub struct FullBatchCtx<'a> {
    workers: &'a [WorkerCtx],
    shapes: &'a ShapeConfig,
    st: &'a mut FullBatchState,
    machine: &'a MachineProfile,
    quant: Option<Bits>,
    seed: u64,
    epoch: usize,
    /// Exchange halos this epoch? (`delay_comm` staleness policy —
    /// decided by the driver.)
    exchange: bool,
    comm: &'a mut CommStats,
}

impl<'a> FullBatchCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        workers: &'a [WorkerCtx],
        shapes: &'a ShapeConfig,
        st: &'a mut FullBatchState,
        machine: &'a MachineProfile,
        quant: Option<Bits>,
        seed: u64,
        epoch: usize,
        exchange: bool,
        comm: &'a mut CommStats,
    ) -> Self {
        Self {
            workers,
            shapes,
            st,
            machine,
            quant,
            seed,
            epoch,
            exchange,
            comm,
        }
    }

    fn k(&self) -> usize {
        self.workers.len()
    }

    fn empty_matrix(k: usize) -> Vec<Vec<Payload>> {
        (0..k).map(|_| (0..k).map(|_| Payload::Empty).collect()).collect()
    }

    /// Forward halo exchange for layer `l`: quantize → wire → dequantize,
    /// scattering into the persistent recv buffers.
    fn exchange_fwd(
        &mut self,
        l: usize,
        fin: usize,
        h: &[Vec<f32>],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        let mut sends = Self::empty_matrix(k);
        for w in 0..k {
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                let ctx = &self.workers[w];
                let (plo, phi) = ctx.send_pre_range[peer];
                let post = &ctx.send_post_rows[peer];
                let rows = (phi - plo) + post.len();
                if rows == 0 {
                    continue;
                }
                let mut buf = Vec::with_capacity(rows * fin);
                buf.extend_from_slice(&self.st.partials[w][plo * fin..phi * fin]);
                for &r in post {
                    buf.extend_from_slice(&h[w][r as usize * fin..(r as usize + 1) * fin]);
                }
                sends[w][peer] = match self.quant {
                    Some(bits) => {
                        let t = Instant::now();
                        let seed = (self.epoch as u64) << 32
                            | (w as u64) << 16
                            | (peer as u64) << 8
                            | l as u64;
                        let q = fused::quantize(&buf, rows, fin, bits, seed ^ self.seed);
                        quant_secs[w] += t.elapsed().as_secs_f64();
                        Payload::Quant(q)
                    }
                    None => Payload::F32(buf),
                };
            }
        }
        let recvs = alltoallv(sends, self.machine, &mut *self.comm);
        for w in 0..k {
            // Reset to zeros so stale pads never leak.
            self.st.recv_pre[l][w].iter_mut().for_each(|x| *x = 0.0);
            self.st.recv_post[l][w].iter_mut().for_each(|x| *x = 0.0);
            for peer in 0..k {
                let payload = &recvs[w][peer];
                if payload.is_empty() {
                    continue;
                }
                let ctx = &self.workers[w];
                let (plo, phi) = ctx.recv_pre_range[peer];
                let (qlo, qhi) = ctx.recv_post_range[peer];
                let rows = (phi - plo) + (qhi - qlo);
                let data: Vec<f32> = match payload {
                    Payload::F32(v) => v.clone(),
                    Payload::Quant(q) => {
                        let t = Instant::now();
                        let d = fused::dequantize(q);
                        quant_secs[w] += t.elapsed().as_secs_f64();
                        d
                    }
                    Payload::Empty => continue,
                };
                anyhow::ensure!(
                    data.len() == rows * fin,
                    "halo payload from {peer} to {w}: {} values, expected {}",
                    data.len(),
                    rows * fin
                );
                self.st.recv_pre[l][w][plo * fin..phi * fin]
                    .copy_from_slice(&data[..(phi - plo) * fin]);
                self.st.recv_post[l][w][qlo * fin..qhi * fin]
                    .copy_from_slice(&data[(phi - plo) * fin..]);
            }
        }
        Ok(())
    }

    /// Reverse exchange: consumers return halo cotangents (FP32 — the
    /// paper quantizes the forward feature communication only); producers
    /// fold them into `d_partials` / `d_h`.
    fn exchange_bwd(&mut self, fin: usize, d_h: &mut [Vec<f32>]) -> Result<()> {
        let k = self.k();
        let mut sends = Self::empty_matrix(k);
        for w in 0..k {
            let ctx = &self.workers[w];
            for peer in 0..k {
                if peer == w {
                    continue;
                }
                let (plo, phi) = ctx.recv_pre_range[peer];
                let (qlo, qhi) = ctx.recv_post_range[peer];
                let rows = (phi - plo) + (qhi - qlo);
                if rows == 0 {
                    continue;
                }
                let mut buf = Vec::with_capacity(rows * fin);
                buf.extend_from_slice(&self.st.d_recv_pre[w][plo * fin..phi * fin]);
                buf.extend_from_slice(&self.st.d_recv_post[w][qlo * fin..qhi * fin]);
                sends[w][peer] = Payload::F32(buf);
            }
        }
        let recvs = alltoallv(sends, self.machine, &mut *self.comm);
        for w in 0..k {
            for peer in 0..k {
                let payload = match &recvs[w][peer] {
                    Payload::F32(v) if !v.is_empty() => v,
                    _ => continue,
                };
                let ctx = &self.workers[w];
                let (plo, phi) = ctx.send_pre_range[peer];
                let post = &ctx.send_post_rows[peer];
                let pre_vals = (phi - plo) * fin;
                anyhow::ensure!(
                    payload.len() == pre_vals + post.len() * fin,
                    "reverse payload size mismatch"
                );
                self.st.d_partials[w][plo * fin..phi * fin].copy_from_slice(&payload[..pre_vals]);
                // d_h[post_row] += returned post cotangent.
                for (i, &r) in post.iter().enumerate() {
                    let src = &payload[pre_vals + i * fin..pre_vals + (i + 1) * fin];
                    let dst = &mut d_h[w][r as usize * fin..(r as usize + 1) * fin];
                    for (a, &x) in dst.iter_mut().zip(src.iter()) {
                        *a += x;
                    }
                }
            }
        }
        Ok(())
    }
}

impl GraphContext for FullBatchCtx<'_> {
    fn lanes(&self) -> usize {
        self.workers.len()
    }

    fn load_inputs(
        &mut self,
        x: &mut [Vec<f32>],
        secs: &mut [f64],
        _quant_secs: &mut [f64],
    ) -> Result<()> {
        for (w, ctx) in self.workers.iter().enumerate() {
            let t = Instant::now();
            x[w].copy_from_slice(&ctx.features);
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_fwd(
        &mut self,
        layer: usize,
        fin: usize,
        h: &[Vec<f32>],
        z: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
        quant_secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        let p_pre = self.shapes.p_pre;
        // Send-side pre-aggregation partials (§5: producer partially
        // aggregates covered destinations before shipping).
        for w in 0..k {
            let t = Instant::now();
            let ctx = &self.workers[w];
            let p = &mut self.st.partials[w][..p_pre * fin];
            p.iter_mut().for_each(|x| *x = 0.0);
            disp.segment_sum(&h[w], fin, &ctx.pre.gather, &ctx.pre.seg, p_pre, p);
            secs[w] += t.elapsed().as_secs_f64();
        }
        if self.exchange {
            self.exchange_fwd(layer, fin, h, quant_secs)?;
        }
        // Local aggregation + received-halo scatter + mean scaling.
        let n = self.shapes.n_pad;
        for w in 0..k {
            let t = Instant::now();
            let ctx = &self.workers[w];
            let zv = &mut z[w];
            zv.iter_mut().for_each(|x| *x = 0.0);
            disp.segment_sum(
                &h[w],
                fin,
                &ctx.spec.local.gather,
                &ctx.spec.local.seg,
                n,
                zv,
            );
            let rp = &self.st.recv_pre[layer][w];
            for (i, &d) in ctx.spec.rpre_dst.iter().enumerate() {
                let src = &rp[i * fin..(i + 1) * fin];
                let dst = &mut zv[d as usize * fin..(d as usize + 1) * fin];
                for (a, &b) in dst.iter_mut().zip(src.iter()) {
                    *a += b;
                }
            }
            let ro = &self.st.recv_post[layer][w];
            for (&row, &d) in ctx.spec.post_row.iter().zip(ctx.spec.post_dst.iter()) {
                let src = &ro[row as usize * fin..(row as usize + 1) * fin];
                let dst = &mut zv[d as usize * fin..(d as usize + 1) * fin];
                for (a, &b) in dst.iter_mut().zip(src.iter()) {
                    *a += b;
                }
            }
            for (i, &dv) in ctx.spec.deg_inv.iter().enumerate() {
                for v in &mut zv[i * fin..(i + 1) * fin] {
                    *v *= dv;
                }
            }
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }

    fn aggregate_bwd(
        &mut self,
        _layer: usize,
        fin: usize,
        dz: &mut [Vec<f32>],
        d_h: &mut [Vec<f32>],
        disp: &AggDispatch,
        secs: &mut [f64],
    ) -> Result<()> {
        let k = self.k();
        let n = self.shapes.n_pad;
        for w in 0..k {
            let t = Instant::now();
            let ctx = &self.workers[w];
            // Mean scaling folds into dZ.
            for (i, &dv) in ctx.spec.deg_inv.iter().enumerate() {
                for v in &mut dz[w][i * fin..(i + 1) * fin] {
                    *v *= dv;
                }
            }
            let dzv = &dz[w][..n * fin];
            // (1) local edges, transposed: d_h[src] += dz[dst].
            disp.segment_sum(
                dzv,
                fin,
                &ctx.spec.local_t.gather,
                &ctx.spec.local_t.seg,
                n,
                &mut d_h[w][..n * fin],
            );
            // (2) received partials: d_recv_pre[i] = dz[rpre_dst[i]].
            for (i, &d) in ctx.spec.rpre_dst.iter().enumerate() {
                self.st.d_recv_pre[w][i * fin..(i + 1) * fin]
                    .copy_from_slice(&dzv[d as usize * fin..(d as usize + 1) * fin]);
            }
            // (3) post rows: d_recv_post[row] += dz[dst] (transposed spec).
            let drp = &mut self.st.d_recv_post[w][..self.shapes.r_post * fin];
            drp.iter_mut().for_each(|x| *x = 0.0);
            disp.segment_sum(
                dzv,
                fin,
                &ctx.spec.post_t.gather,
                &ctx.spec.post_t.seg,
                self.shapes.r_post,
                drp,
            );
            secs[w] += t.elapsed().as_secs_f64();
        }
        for w in 0..k {
            self.st.d_partials[w][..self.shapes.p_pre * fin]
                .iter_mut()
                .for_each(|x| *x = 0.0);
        }
        if self.exchange {
            self.exchange_bwd(fin, d_h)?;
        }
        // Scatter returned partial cotangents back through the pre gather:
        // d_h[gather[i]] += d_partials[seg[i]].
        for w in 0..k {
            let t = Instant::now();
            let ctx = &self.workers[w];
            let dp = &self.st.d_partials[w];
            let dh = &mut d_h[w];
            for (&g, &s) in ctx.pre.gather.iter().zip(ctx.pre.seg.iter()) {
                let src = &dp[s as usize * fin..(s as usize + 1) * fin];
                let dst = &mut dh[g as usize * fin..(g as usize + 1) * fin];
                for (a, &b) in dst.iter_mut().zip(src.iter()) {
                    *a += b;
                }
            }
            secs[w] += t.elapsed().as_secs_f64();
        }
        Ok(())
    }
}
