//! Streaming synthetic graph generator (DESIGN.md §17): emits 100M+-edge
//! labelled graphs **directly to the on-disk store format** in bounded
//! memory, so the out-of-core pipeline (`supergcn synth` → `prepare` →
//! `train --graph-dir`) can be exercised at scales `graph::generate`
//! (which materializes everything on the heap) cannot reach.
//!
//! Every CSR row is a pure function of `(seed, dst)` — a per-node
//! `SplitMix64` stream draws the in-degree, then the sources — so the
//! generator can re-derive any row on demand and write the file in the
//! section order [`StoreWriter`] requires with three cheap hashing passes
//! (degrees → row_ptr, rows → col_idx, node data → features/labels/split)
//! instead of buffering the graph.
//!
//! Sources are drawn from a **locality window** around the destination
//! (plus a small long-range fraction), mirroring how real graph ids are
//! renumbered for locality. This matters beyond realism: the streaming
//! block partitioner assigns contiguous id ranges, so windowed sources
//! keep the edge cut — and with it each rank's halo plan — small. A
//! pure-random source distribution would cut nearly every edge and push
//! the planner's remote structures toward O(m).

use super::generate::{SPLIT_TRAIN, SPLIT_VAL};
use super::store::StoreWriter;
use crate::util::rng::{Rng, SplitMix64};
use anyhow::Result;
use std::path::Path;

/// Shape and distribution knobs for the streaming generator. Construct
/// with struct-update syntax over [`SynthConfig::default`].
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Node count.
    pub n: usize,
    /// Mean in-degree: per-node degree is uniform in `[1, 2·avg_deg)`.
    pub avg_deg: usize,
    /// Locality window: sources are drawn within `±window` of the
    /// destination (clamped to `[0, n)`), except the long-range fraction.
    pub window: usize,
    /// One source in `long_range_every` is drawn uniformly over all nodes
    /// (0 disables long-range edges entirely).
    pub long_range_every: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Per-feature Gaussian noise around the class center — features stay
    /// label-correlated, so training on the output actually learns.
    pub feat_noise: f32,
    pub train_frac: f64,
    pub val_frac: f64,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            n: 10_000,
            avg_deg: 8,
            window: 512,
            long_range_every: 16,
            feat_dim: 32,
            num_classes: 8,
            feat_noise: 2.0,
            train_frac: 0.6,
            val_frac: 0.2,
            seed: 42,
        }
    }
}

/// What the generator wrote (echoed by the CLI and the benches).
#[derive(Clone, Debug)]
pub struct SynthStats {
    pub n: usize,
    pub m: usize,
    pub file_bytes: u64,
}

/// Per-node hash stream: independent of every other node, so rows can be
/// re-derived in any pass without storing them.
fn node_stream(seed: u64, v: usize, stream: u64) -> SplitMix64 {
    let mut h = SplitMix64::new(seed ^ (v as u64).wrapping_mul(0xA076_1D64_78BD_642F));
    let k = h.next_u64() ^ stream.wrapping_mul(0xE703_7ED1_A0B4_28DB);
    SplitMix64::new(k)
}

/// The class a node belongs to — drives labels *and* feature centers, and
/// is block-structured over ids so the locality window also induces
/// homophily (neighbors tend to share a class).
fn node_class(cfg: &SynthConfig, v: usize) -> u32 {
    let blocks = cfg.num_classes.max(1);
    let block = v * blocks / cfg.n.max(1);
    // A minority of nodes get a hashed class so classes are not perfectly
    // separable by id alone.
    let mut s = node_stream(cfg.seed, v, 3);
    if s.next_u64() % 8 == 0 {
        (s.next_u64() % blocks as u64) as u32
    } else {
        block.min(blocks - 1) as u32
    }
}

/// The in-neighbors of `v`: sorted, deduplicated, derived only from
/// `(seed, v)`. Bounded by `2·avg_deg` elements.
pub fn row_sources(cfg: &SynthConfig, v: usize, buf: &mut Vec<u32>) {
    buf.clear();
    let mut s = node_stream(cfg.seed, v, 1);
    let span = (2 * cfg.avg_deg).max(2) as u64 - 1;
    let deg = 1 + (s.next_u64() % span) as usize;
    let n = cfg.n as u64;
    for i in 0..deg {
        let r = s.next_u64();
        let src = if cfg.long_range_every > 0 && i % cfg.long_range_every == cfg.long_range_every - 1
        {
            r % n
        } else {
            let w = (2 * cfg.window + 1) as u64;
            let off = (r % w) as i64 - cfg.window as i64;
            (v as i64 + off).clamp(0, cfg.n as i64 - 1) as u64
        };
        buf.push(src as u32);
    }
    buf.sort_unstable();
    buf.dedup();
}

/// Feature row of `v`: class center (a fixed hash of `(class, j)`) plus
/// per-node Gaussian noise.
fn feature_row_into(cfg: &SynthConfig, v: usize, out: &mut Vec<f32>) {
    let c = node_class(cfg, v);
    let mut noise = Rng::new(node_stream(cfg.seed, v, 2).next_u64());
    for j in 0..cfg.feat_dim {
        let mut ch = SplitMix64::new(
            cfg.seed ^ (c as u64) << 32 ^ (j as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        // Center in [-3, 3), noise scaled by feat_noise.
        let center = (ch.next_u64() >> 40) as f32 * (6.0 / (1u64 << 24) as f32) - 3.0;
        out.push(center + cfg.feat_noise * noise.normal() as f32);
    }
}

fn node_split(cfg: &SynthConfig, v: usize) -> u8 {
    let mut s = node_stream(cfg.seed, v, 4);
    let u = (s.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    if u < cfg.train_frac {
        SPLIT_TRAIN
    } else if u < cfg.train_frac + cfg.val_frac {
        SPLIT_VAL
    } else {
        super::generate::SPLIT_TEST
    }
}

/// Nodes per streaming chunk — the memory high-water mark of every pass
/// is `CHUNK × max(feat_dim, 2·avg_deg)` elements, independent of `n`.
const CHUNK: usize = 1 << 14;

/// Generate the configured graph straight into a store file at `path`.
/// Deterministic: the same config always produces a byte-identical file.
pub fn generate_to_store(cfg: &SynthConfig, path: &Path) -> Result<SynthStats> {
    anyhow::ensure!(cfg.n > 0, "synth graph needs n > 0");
    anyhow::ensure!(cfg.feat_dim > 0, "synth graph needs feat_dim > 0");
    anyhow::ensure!(cfg.num_classes > 0, "synth graph needs num_classes > 0");
    anyhow::ensure!(
        cfg.train_frac >= 0.0 && cfg.val_frac >= 0.0 && cfg.train_frac + cfg.val_frac <= 1.0,
        "synth split fractions must be non-negative and sum to <= 1"
    );

    // Pass 0: degrees → m (rows are re-derived, not stored).
    let mut row = Vec::with_capacity(2 * cfg.avg_deg + 1);
    let mut m = 0usize;
    for v in 0..cfg.n {
        row_sources(cfg, v, &mut row);
        m += row.len();
    }

    let mut w = StoreWriter::create(path, cfg.n, m, cfg.feat_dim, cfg.num_classes)?;

    // Pass 1: row_ptr.
    let mut chunk64: Vec<u64> = Vec::with_capacity(CHUNK + 1);
    let mut off = 0u64;
    chunk64.push(0);
    for v in 0..cfg.n {
        row_sources(cfg, v, &mut row);
        off += row.len() as u64;
        chunk64.push(off);
        if chunk64.len() >= CHUNK {
            w.row_ptr(&chunk64)?;
            chunk64.clear();
        }
    }
    w.row_ptr(&chunk64)?;

    // Pass 2: col_idx.
    let mut cols: Vec<u32> = Vec::with_capacity(CHUNK);
    for v in 0..cfg.n {
        row_sources(cfg, v, &mut row);
        cols.extend_from_slice(&row);
        if cols.len() >= CHUNK {
            w.col_idx(&cols)?;
            cols.clear();
        }
    }
    w.col_idx(&cols)?;

    // Pass 3: features, labels, split.
    let mut feats: Vec<f32> = Vec::with_capacity(CHUNK * cfg.feat_dim.min(64));
    for v in 0..cfg.n {
        feature_row_into(cfg, v, &mut feats);
        if feats.len() >= CHUNK {
            w.features(&feats)?;
            feats.clear();
        }
    }
    w.features(&feats)?;
    let mut labs: Vec<u32> = Vec::with_capacity(CHUNK);
    for v in 0..cfg.n {
        labs.push(node_class(cfg, v));
        if labs.len() >= CHUNK {
            w.labels(&labs)?;
            labs.clear();
        }
    }
    w.labels(&labs)?;
    let mut sp: Vec<u8> = Vec::with_capacity(CHUNK);
    for v in 0..cfg.n {
        sp.push(node_split(cfg, v));
        if sp.len() >= CHUNK {
            w.split(&sp)?;
            sp.clear();
        }
    }
    w.split(&sp)?;
    w.finish()?;
    let file_bytes = std::fs::metadata(path)?.len();
    Ok(SynthStats {
        n: cfg.n,
        m,
        file_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::store::GraphStore;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("supergcn_synth_{}_{name}", std::process::id()))
    }

    #[test]
    fn generates_a_valid_openable_store() {
        let cfg = SynthConfig {
            n: 3000,
            avg_deg: 6,
            window: 64,
            feat_dim: 12,
            num_classes: 5,
            ..Default::default()
        };
        let p = tmp("valid.sgcn");
        let st = generate_to_store(&cfg, &p).unwrap();
        assert_eq!(st.n, 3000);
        assert!(st.m >= 3000, "every node has at least one in-edge");
        let store = GraphStore::open(&p).unwrap();
        assert_eq!(store.n(), 3000);
        assert_eq!(store.m(), st.m);
        if let GraphStore::Mmap(g) = &store {
            g.validate_deep().unwrap();
        }
        // Splits all populated.
        let (tr, va, te) = store.count_split();
        assert!(tr > 0 && va > 0 && te > 0, "({tr}, {va}, {te})");
        // Labels cover several classes.
        let mut seen = std::collections::HashSet::new();
        for v in 0..store.n() {
            seen.insert(store.label(v));
        }
        assert!(seen.len() >= 3, "classes seen: {seen:?}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn deterministic_byte_identical_output() {
        let cfg = SynthConfig {
            n: 1500,
            seed: 77,
            ..Default::default()
        };
        let (p1, p2) = (tmp("det1.sgcn"), tmp("det2.sgcn"));
        generate_to_store(&cfg, &p1).unwrap();
        generate_to_store(&cfg, &p2).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        // A different seed changes the bytes.
        let p3 = tmp("det3.sgcn");
        generate_to_store(&SynthConfig { seed: 78, ..cfg }, &p3).unwrap();
        assert_ne!(std::fs::read(&p1).unwrap(), std::fs::read(&p3).unwrap());
        for p in [&p1, &p2, &p3] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn sources_stay_mostly_local() {
        let cfg = SynthConfig {
            n: 50_000,
            window: 128,
            ..Default::default()
        };
        let mut row = Vec::new();
        let (mut local, mut total) = (0usize, 0usize);
        for v in (0..cfg.n).step_by(97) {
            row_sources(&cfg, v, &mut row);
            for &s in &row {
                total += 1;
                if (s as i64 - v as i64).unsigned_abs() as usize <= cfg.window {
                    local += 1;
                }
            }
        }
        assert!(
            local as f64 >= 0.8 * total as f64,
            "only {local}/{total} sources within the window"
        );
    }

    #[test]
    fn rejects_bad_config() {
        let p = tmp("bad.sgcn");
        let err = generate_to_store(
            &SynthConfig {
                n: 0,
                ..Default::default()
            },
            &p,
        )
        .unwrap_err();
        assert!(err.to_string().contains("n > 0"), "{err}");
        let err = generate_to_store(
            &SynthConfig {
                train_frac: 0.9,
                val_frac: 0.3,
                ..Default::default()
            },
            &p,
        )
        .unwrap_err();
        assert!(err.to_string().contains("split fractions"), "{err}");
    }
}
