//! Deterministic PRNGs used throughout the stack.
//!
//! The build environment is offline (no `rand` crate), and the paper itself
//! motivates owning the RNG: §7.3(3) removes random-number generation from
//! the quantization kernel's dependency chain, so stochastic-rounding noise
//! is generated *outside* the hot kernel — here, by these generators.
//!
//! `SplitMix64` seeds `Xoshiro256StarStar`, the main engine (period 2^256−1,
//! passes BigCrush); both match the published reference outputs (unit-tested
//! below).

/// SplitMix64: used for seeding and cheap hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64 per the
    /// xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Raw generator state, for checkpointing (restore with
    /// [`Rng::from_state`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from a checkpointed [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar-free, two uniforms).
    pub fn normal(&mut self) -> f64 {
        // Guard u1 away from 0 so ln is finite.
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = u1.max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use a set-free selection over a dense
        // vec only when affordable; otherwise Floyd's algorithm.
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.index(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }
}

/// Xorshift32 — the minimal-state generator embedded in hot loops
/// (quantization noise), mirroring the paper's lightweight in-kernel noise
/// source being replaced by a precomputed stream.
#[derive(Clone, Copy, Debug)]
pub struct XorShift32 {
    state: u32,
}

impl XorShift32 {
    pub fn new(seed: u32) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B9 } else { seed },
        }
    }

    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// f32 in [0,1).
    #[inline(always)]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference outputs for seed 1234567 (from the canonical C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_well_spread() {
        let mut r = Rng::new(42);
        let xs: Vec<u64> = (0..1000).map(|_| r.next_u64()).collect();
        let mut r2 = Rng::new(42);
        let ys: Vec<u64> = (0..1000).map(|_| r2.next_u64()).collect();
        assert_eq!(xs, ys);
        // Different seeds diverge.
        let mut r3 = Rng::new(43);
        assert_ne!(xs[0], r3.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough_and_in_range() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100usize, 10usize), (50, 40), (1, 1), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn xorshift_period_smoke() {
        let mut x = XorShift32::new(1);
        let first = x.next_u32();
        let mut seen_first_again = false;
        for _ in 0..100_000 {
            if x.next_u32() == first {
                seen_first_again = true;
                break;
            }
        }
        assert!(!seen_first_again, "xorshift32 cycled way too early");
    }
}
