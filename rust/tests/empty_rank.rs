//! Empty-rank regression tests: with `--procs k` larger than the number
//! of non-empty partitions (tiny graphs at high k), a rank owning zero
//! rows must train cleanly — no panic in `partition::interior_split`,
//! `hier::plan::build_plans`, the planner, or the threaded `Fabric`
//! barriers (an empty rank still joins every collective with empty
//! payloads). Pinned here by hand-building a partition with an
//! intentionally empty part and training 2 epochs in both regimes, on
//! both transports, flat and grouped, blocking and overlapped.

use std::sync::Arc;
use supergcn::comm::transport::TransportKind;
use supergcn::coordinator::minibatch::{MiniBatchConfig, MiniBatchTrainer};
use supergcn::coordinator::planner::{build_worker_ctxs, fit_config};
use supergcn::coordinator::trainer::{TrainConfig, Trainer};
use supergcn::graph::generate::{sbm, LabelledGraph};
use supergcn::hier::plan::{build_plans, validate_plans};
use supergcn::hier::volume::RemoteStrategy;
use supergcn::partition::{interior_split, Partition};
use supergcn::sample::{SamplerConfig, SamplerKind};

fn graph() -> LabelledGraph {
    sbm(300, 4, 8.0, 0.85, 16, 0.6, 11)
}

/// 3 parts over the node set, part 2 intentionally empty.
fn partition_with_empty_part(n: usize) -> Partition {
    Partition {
        k: 3,
        assign: (0..n).map(|v| (v % 2) as u32).collect(),
    }
}

#[test]
fn planning_survives_an_empty_partition() {
    let lg = graph();
    let part = partition_with_empty_part(lg.graph.n);
    for strategy in [
        RemoteStrategy::Raw,
        RemoteStrategy::PreOnly,
        RemoteStrategy::PostOnly,
        RemoteStrategy::Hybrid,
    ] {
        let plans = build_plans(&lg.graph, &part, strategy);
        validate_plans(&lg.graph, &part, &plans)
            .unwrap_or_else(|e| panic!("{}: {e}", strategy.name()));
        assert_eq!(plans[2].n_local(), 0, "part 2 must be empty");
        assert_eq!(plans[2].send_rows(), 0);
        assert_eq!(plans[2].recv_rows(), 0);
    }
    // The empty worker's context still carries a well-formed
    // interior/boundary split over its padded row space.
    let plans = build_plans(&lg.graph, &part, RemoteStrategy::Hybrid);
    let cfg = fit_config("empty-rank", lg.feat_dim, 16, lg.num_classes, &plans);
    let ctxs = build_worker_ctxs(&lg, &plans, &cfg).unwrap();
    for ctx in &ctxs {
        assert_eq!(
            ctx.interior_rows.len() + ctx.boundary_rows.len(),
            cfg.n_pad,
            "worker {}: split must cover every padded row",
            ctx.worker
        );
    }
    assert_eq!(ctxs[2].n_real, 0);
}

#[test]
fn interior_split_handles_degenerate_masks() {
    // All-interior, all-boundary, and empty masks are all legal.
    let (i, b) = interior_split(&[false; 5]);
    assert_eq!(i.len(), 5);
    assert!(b.is_empty());
    let (i, b) = interior_split(&[true; 5]);
    assert!(i.is_empty());
    assert_eq!(b.len(), 5);
    let (i, b) = interior_split(&[]);
    assert!(i.is_empty() && b.is_empty());
}

#[test]
fn full_batch_trains_with_an_empty_rank_seq_and_threaded() {
    let lg = graph();
    let part = partition_with_empty_part(lg.graph.n);
    let plans = build_plans(&lg.graph, &part, RemoteStrategy::Hybrid);
    let cfg = fit_config("empty-rank", lg.feat_dim, 16, lg.num_classes, &plans);
    let ctxs = build_worker_ctxs(&lg, &plans, &cfg).unwrap();
    // Flat and grouped, blocking and overlapped, on both transports: the
    // empty rank must join every barrier/collective without panicking.
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        for (group_size, overlap) in [(1usize, false), (2, true)] {
            let tc = TrainConfig {
                epochs: 2,
                transport,
                group_size,
                overlap,
                ..Default::default()
            };
            let mut tr = Trainer::new(ctxs.clone(), cfg.clone(), tc);
            let stats = tr.run(false).unwrap_or_else(|e| {
                panic!(
                    "empty-rank run failed ({} g={group_size} overlap={overlap}): {e}",
                    transport.name()
                )
            });
            assert_eq!(stats.len(), 2);
            for s in &stats {
                assert!(s.train_loss.is_finite(), "loss must stay finite");
            }
        }
    }
}

/// Regression: the overlapped fetch charges `FETCH_REPLY_STAGE` per
/// sending lane. A lane that owns zero feature rows serves no replies,
/// so its reply-leg comm column must be *exactly* 0.0 — not a stale
/// delta read off the shared `CommStats` around the exchange.
#[test]
fn empty_rank_reply_leg_is_charged_exactly_zero() {
    let lg = Arc::new(graph());
    let part = partition_with_empty_part(lg.n());
    let scfg = SamplerConfig {
        batch_size: 64,
        fanouts: vec![5, 5, 5],
        seed: 7,
        ..Default::default()
    };
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        let mc = MiniBatchConfig {
            epochs: 1,
            transport,
            overlap: true,
            ..Default::default()
        };
        let mut tr = MiniBatchTrainer::with_partition(
            lg.clone(),
            part.clone(),
            SamplerKind::Neighbor,
            &scfg,
            mc,
        )
        .unwrap();
        let stats = tr.run(false).unwrap();
        let ledger = &stats[0].overlap;
        let reply: Vec<_> = ledger
            .stages
            .iter()
            .filter(|s| s.label == "fetch reply")
            .collect();
        assert!(
            !reply.is_empty(),
            "{}: overlap run must record fetch-reply stages",
            transport.name()
        );
        let mut others_served = false;
        for st in &reply {
            assert_eq!(
                st.comm[2],
                0.0,
                "{}: the row-less lane sent no replies, so its reply-leg \
                 comm must be exactly zero",
                transport.name()
            );
            if st.comm[0] > 0.0 || st.comm[1] > 0.0 {
                others_served = true;
            }
        }
        assert!(
            others_served,
            "{}: row-owning lanes must charge reply wire time (non-vacuous check)",
            transport.name()
        );
    }
}

#[test]
fn mini_batch_trains_with_an_empty_rank_seq_and_threaded() {
    let lg = Arc::new(graph());
    let part = partition_with_empty_part(lg.n());
    let scfg = SamplerConfig {
        batch_size: 64,
        fanouts: vec![5, 5, 5],
        seed: 7,
        ..Default::default()
    };
    for transport in [TransportKind::Sequential, TransportKind::Threaded] {
        for (group_size, overlap) in [(1usize, false), (2, true)] {
            let mc = MiniBatchConfig {
                epochs: 2,
                transport,
                group_size,
                overlap,
                ..Default::default()
            };
            let mut tr = MiniBatchTrainer::with_partition(
                lg.clone(),
                part.clone(),
                SamplerKind::Neighbor,
                &scfg,
                mc,
            )
            .unwrap();
            let stats = tr.run(false).unwrap_or_else(|e| {
                panic!(
                    "empty-rank mini-batch failed ({} g={group_size} overlap={overlap}): {e}",
                    transport.name()
                )
            });
            assert_eq!(stats.len(), 2);
            assert!(stats.iter().all(|s| s.train_loss.is_finite()));
        }
    }
}
