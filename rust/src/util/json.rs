//! Minimal JSON value, parser, and pretty-printer.
//!
//! Serde is unavailable offline; this module covers the two JSON needs of
//! the system: reading `artifacts/manifest.json` written by the AOT
//! pipeline, and emitting structured experiment/metric dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest only holds ints
/// small enough for exact f64 representation).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Convenience: required usize field.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field '{key}'"))
    }
    /// Convenience: required str field.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f, 0, false)
    }
}

/// Pretty-print with 2-space indent.
pub fn to_pretty(v: &Json) -> String {
    struct P<'a>(&'a Json);
    impl fmt::Display for P<'_> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write_json(self.0, f, 0, true)
        }
    }
    format!("{}", P(v))
}

fn write_json(v: &Json, f: &mut fmt::Formatter<'_>, indent: usize, pretty: bool) -> fmt::Result {
    let pad = |f: &mut fmt::Formatter<'_>, n: usize| -> fmt::Result {
        if pretty {
            writeln!(f)?;
            for _ in 0..n {
                write!(f, "  ")?;
            }
        }
        Ok(())
    };
    match v {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Json::Str(s) => write_escaped(s, f),
        Json::Arr(a) => {
            write!(f, "[")?;
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                pad(f, indent + 1)?;
                write_json(x, f, indent + 1, pretty)?;
            }
            if !a.is_empty() {
                pad(f, indent)?;
            }
            write!(f, "]")
        }
        Json::Obj(o) => {
            write!(f, "{{")?;
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                pad(f, indent + 1)?;
                write_escaped(k, f)?;
                write!(f, ":")?;
                if pretty {
                    write!(f, " ")?;
                }
                write_json(x, f, indent + 1, pretty)?;
            }
            if !o.is_empty() {
                pad(f, indent)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => anyhow::bail!(
                "expected '{}' at byte {}, got {:?}",
                b as char,
                self.pos,
                other.map(|c| c as char)
            ),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| anyhow::anyhow!("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad hex in \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => anyhow::bail!("bad escape {other:?}"),
                },
                Some(b) => {
                    // Re-sync multi-byte UTF-8: collect continuation bytes.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        self.pos = (start + width).min(self.bytes.len());
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|_| anyhow::anyhow!("invalid utf8 in string"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number '{s}': {e}"))?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => anyhow::bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                other => anyhow::bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let s = r#"{"configs":[{"name":"quickstart","n_pad":4097,"dims":[64,64,16]}],"version":1}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let cfgs = v.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(cfgs[0].req_str("name").unwrap(), "quickstart");
        assert_eq!(cfgs[0].req_usize("n_pad").unwrap(), 4097);
        let dims = cfgs[0].get("dims").unwrap().as_arr().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[1].as_usize(), Some(64));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        let v2 = Json::parse("\"\\u2603\"").unwrap();
        assert_eq!(v2.as_str(), Some("☃"));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("b", Json::obj(vec![("c", Json::Bool(true))])),
        ]);
        let pretty = to_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
