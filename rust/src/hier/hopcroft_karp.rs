//! Hopcroft–Karp maximum bipartite matching, O(E·√V) (paper §5.3 cites
//! [27]). Operates on a bipartite graph given as adjacency of the left
//! side U over right-side indices V.

/// Bipartite graph in left-adjacency form.
#[derive(Clone, Debug)]
pub struct Bipartite {
    pub nu: usize,
    pub nv: usize,
    /// adj[u] = right-neighbors of left vertex u.
    pub adj: Vec<Vec<u32>>,
}

impl Bipartite {
    pub fn from_edges(nu: usize, nv: usize, edges: &[(u32, u32)]) -> Self {
        let mut adj = vec![Vec::new(); nu];
        for &(u, v) in edges {
            debug_assert!((u as usize) < nu && (v as usize) < nv);
            adj[u as usize].push(v);
        }
        for a in &mut adj {
            a.sort_unstable();
            a.dedup();
        }
        Self { nu, nv, adj }
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }
}

/// Result of maximum matching: `match_u[u] = Some(v)` and vice versa.
#[derive(Clone, Debug)]
pub struct Matching {
    pub match_u: Vec<Option<u32>>,
    pub match_v: Vec<Option<u32>>,
}

impl Matching {
    pub fn size(&self) -> usize {
        self.match_u.iter().filter(|m| m.is_some()).count()
    }

    /// Validate: consistent, edges exist.
    pub fn validate(&self, g: &Bipartite) -> anyhow::Result<()> {
        for (u, m) in self.match_u.iter().enumerate() {
            if let Some(v) = m {
                anyhow::ensure!(
                    g.adj[u].binary_search(v).is_ok(),
                    "matched non-edge ({u},{v})"
                );
                anyhow::ensure!(
                    self.match_v[*v as usize] == Some(u as u32),
                    "inconsistent match at u={u}"
                );
            }
        }
        for (v, m) in self.match_v.iter().enumerate() {
            if let Some(u) = m {
                anyhow::ensure!(
                    self.match_u[*u as usize] == Some(v as u32),
                    "inconsistent match at v={v}"
                );
            }
        }
        Ok(())
    }
}

const INF: u32 = u32::MAX;

/// Hopcroft–Karp: repeated BFS layering + DFS augmentation along shortest
/// augmenting paths.
pub fn max_matching(g: &Bipartite) -> Matching {
    let nu = g.nu;
    let mut match_u: Vec<Option<u32>> = vec![None; nu];
    let mut match_v: Vec<Option<u32>> = vec![None; g.nv];
    let mut dist = vec![INF; nu];
    let mut queue = std::collections::VecDeque::new();

    loop {
        // BFS from all free U vertices.
        queue.clear();
        let mut found_free_v = false;
        for u in 0..nu {
            if match_u[u].is_none() {
                dist[u] = 0;
                queue.push_back(u as u32);
            } else {
                dist[u] = INF;
            }
        }
        let mut layer_limit = INF;
        while let Some(u) = queue.pop_front() {
            if dist[u as usize] >= layer_limit {
                continue;
            }
            for &v in &g.adj[u as usize] {
                match match_v[v as usize] {
                    None => {
                        // Found a shortest augmenting layer.
                        if layer_limit == INF {
                            layer_limit = dist[u as usize] + 1;
                        }
                        found_free_v = true;
                    }
                    Some(u2) => {
                        if dist[u2 as usize] == INF {
                            dist[u2 as usize] = dist[u as usize] + 1;
                            queue.push_back(u2);
                        }
                    }
                }
            }
        }
        if !found_free_v {
            break;
        }
        // DFS augmentation.
        fn dfs(
            u: usize,
            g: &Bipartite,
            dist: &mut [u32],
            match_u: &mut [Option<u32>],
            match_v: &mut [Option<u32>],
        ) -> bool {
            for i in 0..g.adj[u].len() {
                let v = g.adj[u][i] as usize;
                let ok = match match_v[v] {
                    None => true,
                    Some(u2) => {
                        dist[u2 as usize] == dist[u] + 1
                            && dfs(u2 as usize, g, dist, match_u, match_v)
                    }
                };
                if ok {
                    match_u[u] = Some(v as u32);
                    match_v[v] = Some(u as u32);
                    return true;
                }
            }
            dist[u] = INF;
            false
        }
        for u in 0..nu {
            if match_u[u].is_none() {
                dfs(u, g, &mut dist, &mut match_u, &mut match_v);
            }
        }
    }
    Matching { match_u, match_v }
}

/// Brute-force maximum matching size by recursion (test oracle; exponential,
/// only for tiny graphs).
#[cfg(test)]
pub fn brute_force_matching_size(g: &Bipartite) -> usize {
    fn go(u: usize, g: &Bipartite, used_v: &mut Vec<bool>) -> usize {
        if u == g.nu {
            return 0;
        }
        // Skip u.
        let mut best = go(u + 1, g, used_v);
        for &v in &g.adj[u] {
            if !used_v[v as usize] {
                used_v[v as usize] = true;
                best = best.max(1 + go(u + 1, g, used_v));
                used_v[v as usize] = false;
            }
        }
        best
    }
    go(0, g, &mut vec![false; g.nv])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{prop_assert, propcheck};

    #[test]
    fn figure4_matching_size_two() {
        // Paper Fig 4/5: U = {4,5,6} (srcs), V = {1,2,3} (dsts) with edges
        // 4-1, 4-2, 4-3, 5-2, 6-2. Max matching = 2 (e.g. 4-1, 5-2).
        let g = Bipartite::from_edges(
            3,
            3,
            &[(0, 0), (0, 1), (0, 2), (1, 1), (2, 1)], // u: 4,5,6 → v: 1,2,3
        );
        let m = max_matching(&g);
        m.validate(&g).unwrap();
        assert_eq!(m.size(), 2);
    }

    #[test]
    fn perfect_matching_on_cycle() {
        // Even cycle as bipartite: u_i — v_i and u_i — v_{i+1}.
        let n = 6;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            edges.push((i, i));
            edges.push((i, (i + 1) % n as u32));
        }
        let g = Bipartite::from_edges(n, n, &edges);
        let m = max_matching(&g);
        m.validate(&g).unwrap();
        assert_eq!(m.size(), n);
    }

    #[test]
    fn empty_and_degenerate() {
        let g = Bipartite::from_edges(0, 0, &[]);
        assert_eq!(max_matching(&g).size(), 0);
        let g = Bipartite::from_edges(3, 2, &[]);
        assert_eq!(max_matching(&g).size(), 0);
        let g = Bipartite::from_edges(1, 1, &[(0, 0)]);
        assert_eq!(max_matching(&g).size(), 1);
    }

    #[test]
    fn star_graph_matches_one() {
        let g = Bipartite::from_edges(1, 5, &[(0, 0), (0, 1), (0, 2), (0, 3), (0, 4)]);
        assert_eq!(max_matching(&g).size(), 1);
        let g2 = Bipartite::from_edges(5, 1, &[(0, 0), (1, 0), (2, 0), (3, 0), (4, 0)]);
        assert_eq!(max_matching(&g2).size(), 1);
    }

    #[test]
    fn prop_matches_brute_force() {
        propcheck(60, |gen| {
            let nu = gen.usize(1, 7);
            let nv = gen.usize(1, 7);
            let ne = gen.usize(0, 14);
            let edges: Vec<(u32, u32)> = (0..ne)
                .map(|_| (gen.rng.index(nu) as u32, gen.rng.index(nv) as u32))
                .collect();
            let g = Bipartite::from_edges(nu, nv, &edges);
            let m = max_matching(&g);
            m.validate(&g).map_err(|e| e.to_string())?;
            let bf = brute_force_matching_size(&g);
            prop_assert(
                m.size() == bf,
                format!("HK {} != brute force {} on {edges:?}", m.size(), bf),
            )
        });
    }

    #[test]
    fn prop_matching_valid_on_larger_graphs() {
        propcheck(24, |gen| {
            let nu = gen.usize(1, 80);
            let nv = gen.usize(1, 80);
            let ne = gen.usize(0, 400);
            let edges: Vec<(u32, u32)> = (0..ne)
                .map(|_| (gen.rng.index(nu) as u32, gen.rng.index(nv) as u32))
                .collect();
            let g = Bipartite::from_edges(nu, nv, &edges);
            let m = max_matching(&g);
            m.validate(&g).map_err(|e| e.to_string())?;
            prop_assert(m.size() <= nu.min(nv), "matching too large")
        });
    }
}
