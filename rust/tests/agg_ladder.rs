//! Cross-ladder bitwise parity: every rung of the §4 aggregation ladder
//! (`AggKernel::ALL`, including the runtime-dispatched `Simd` rung of
//! DESIGN.md §14) must produce `to_bits()`-identical output on the same
//! problem — ragged and empty segments, empty ranks, feature widths that
//! are not a multiple of the 16-lane accumulator, and the
//! subset-restricted `segment_sum_rows` entry point included. This is
//! the property that makes `--agg-kernel` a pure performance knob: no
//! choice of rung can move the training trajectory by a single ULP.

use supergcn::agg::blocked::segment_offsets;
use supergcn::agg::spmm::CsrMatrix;
use supergcn::exec::{AggDispatch, AggKernel};
use supergcn::graph::generate::rmat;
use supergcn::util::propcheck::{prop_assert, propcheck, PropResult};

fn dispatch(k: AggKernel) -> AggDispatch {
    // 3 threads exercises the parallel rung's real partitioned path.
    AggDispatch::default().with_kernel(k).with_threads(3)
}

fn assert_bits(base: &[f32], out: &[f32], what: &str) -> PropResult {
    prop_assert(base.len() == out.len(), format!("{what}: length mismatch"))?;
    for (i, (a, b)) in base.iter().zip(out.iter()).enumerate() {
        prop_assert(
            a.to_bits() == b.to_bits(),
            format!("{what} diverged at {i}: {a} vs {b}"),
        )?;
    }
    Ok(())
}

/// Sorted segment ids (ragged: duplicates and gaps arise naturally) plus
/// uniform gather indices — the post-exchange aggregation input shape.
fn random_problem(
    g: &mut supergcn::util::propcheck::Gen,
    n_src: usize,
    n_seg: usize,
    m: usize,
) -> (Vec<u32>, Vec<u32>) {
    let mut seg: Vec<u32> = (0..m).map(|_| g.rng.index(n_seg) as u32).collect();
    seg.sort_unstable();
    let gather: Vec<u32> = (0..m).map(|_| g.rng.index(n_src) as u32).collect();
    (gather, seg)
}

#[test]
fn ladder_segment_sum_bitwise_identical() {
    propcheck(48, |g| {
        // f sweeps through 1..=70: covers f < LANE, f == LANE, f % 16 != 0
        // and the scalar tail past the widest accumulator chunk.
        let f = g.usize(1, 70);
        let n_seg = g.usize(0, 40);
        let n_src = g.usize(1, 30);
        // n_seg == 0 is the empty-rank case: no segments, no output.
        let m = if n_seg == 0 { 0 } else { g.usize(0, 160) };
        let (gather, seg) = random_problem(g, n_src, n_seg, m);
        let h = g.vec_f32(n_src * f, -4.0, 4.0);
        let mut base = vec![0f32; n_seg * f];
        dispatch(AggKernel::Blocked).segment_sum(&h, f, &gather, &seg, n_seg, &mut base);
        for k in AggKernel::ALL {
            let mut out = vec![0f32; n_seg * f];
            dispatch(k).segment_sum(&h, f, &gather, &seg, n_seg, &mut out);
            assert_bits(&base, &out, k.name())?;
        }
        Ok(())
    });
}

#[test]
fn ladder_segment_sum_rows_subset_bitwise_identical() {
    propcheck(48, |g| {
        let f = g.usize(1, 50);
        let n_seg = g.usize(1, 40);
        let n_src = g.usize(1, 30);
        let m = g.usize(0, 160);
        let (gather, seg) = random_problem(g, n_src, n_seg, m);
        let offsets = segment_offsets(&seg, n_seg);
        let h = g.vec_f32(n_src * f, -4.0, 4.0);
        // A random strictly-increasing subset of destinations — the
        // overlap schedule's interior/boundary entry point. Case 0 keeps
        // it empty via g.bool()'s coin flips often enough; the full set
        // is covered explicitly below.
        let rows: Vec<u32> = (0..n_seg as u32).filter(|_| g.bool()).collect();
        for rows in [rows, Vec::new(), (0..n_seg as u32).collect()] {
            let mut base = vec![0f32; n_seg * f];
            dispatch(AggKernel::Blocked)
                .segment_sum_rows(&h, f, &gather, &offsets, &rows, &mut base);
            for k in AggKernel::ALL {
                let mut out = vec![0f32; n_seg * f];
                dispatch(k).segment_sum_rows(&h, f, &gather, &offsets, &rows, &mut out);
                assert_bits(&base, &out, k.name())?;
            }
        }
        Ok(())
    });
}

#[test]
fn ladder_spmm_and_transpose_bitwise_identical() {
    let g = rmat(7, 5.0, 0.57, 0.19, 0.19, false, 11);
    let a = CsrMatrix::from_graph(&g);
    let n = g.n;
    let mut rng = supergcn::util::rng::Rng::new(23);
    for f in [1usize, 7, 16, 33, 64] {
        let h: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let mut base = vec![0f32; n * f];
        let mut base_t = vec![0f32; n * f];
        dispatch(AggKernel::Blocked).spmm(&a, &h, f, &mut base);
        dispatch(AggKernel::Blocked).spmm_t(&a, &h, f, &mut base_t);
        for k in AggKernel::ALL {
            let mut out = vec![0f32; n * f];
            dispatch(k).spmm(&a, &h, f, &mut out);
            assert!(
                base.iter().zip(out.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "spmm {} diverged at f={f}",
                k.name()
            );
            out.iter_mut().for_each(|x| *x = 0.0);
            dispatch(k).spmm_t(&a, &h, f, &mut out);
            assert!(
                base_t.iter().zip(out.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "spmm_t {} diverged at f={f}",
                k.name()
            );
        }
    }
}
