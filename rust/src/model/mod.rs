//! Model state: GraphSAGE parameters, Glorot init, Adam/SGD optimizers,
//! and the masked-label-propagation embedding table (paper §2.5, §6.1(1)).

pub mod checkpoint;
pub mod labelprop;
pub mod optimizer;

use crate::runtime::ShapeConfig;
use crate::util::rng::Rng;

/// Parameters of one GraphSAGE layer: `out = act(h·w_self + z·w_neigh + b)`.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub fin: usize,
    pub fout: usize,
    pub w_self: Vec<f32>,
    pub w_neigh: Vec<f32>,
    pub b: Vec<f32>,
}

impl LayerParams {
    pub fn glorot(fin: usize, fout: usize, rng: &mut Rng) -> Self {
        let lim = (6.0 / (fin + fout) as f64).sqrt();
        let mut init = || {
            (0..fin * fout)
                .map(|_| ((rng.f64() * 2.0 - 1.0) * lim) as f32)
                .collect::<Vec<f32>>()
        };
        Self {
            fin,
            fout,
            w_self: init(),
            w_neigh: init(),
            b: vec![0f32; fout],
        }
    }

    pub fn zeros_like(&self) -> Self {
        Self {
            fin: self.fin,
            fout: self.fout,
            w_self: vec![0.0; self.fin * self.fout],
            w_neigh: vec![0.0; self.fin * self.fout],
            b: vec![0.0; self.fout],
        }
    }

    pub fn n_params(&self) -> usize {
        2 * self.fin * self.fout + self.fout
    }
}

/// Full model: 3 SAGE layers + the label-propagation embedding table
/// (`num_classes × f_in`, added to input features of selected nodes).
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub layers: Vec<LayerParams>,
    pub w_embed: Vec<f32>,
    pub num_classes: usize,
    pub f_in: usize,
}

impl ModelParams {
    pub fn init(cfg: &ShapeConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let layers = cfg
            .layer_dims()
            .iter()
            .map(|&(fin, fout, _)| LayerParams::glorot(fin, fout, &mut rng))
            .collect();
        // Embedding init small so LP starts as a gentle signal.
        let w_embed = (0..cfg.classes * cfg.f_in)
            .map(|_| ((rng.f64() * 2.0 - 1.0) * 0.05) as f32)
            .collect();
        Self {
            layers,
            w_embed,
            num_classes: cfg.classes,
            f_in: cfg.f_in,
        }
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum::<usize>() + self.w_embed.len()
    }

    /// Flatten all parameters (the gradient-allreduce wire format).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        for l in &self.layers {
            out.extend_from_slice(&l.w_self);
            out.extend_from_slice(&l.w_neigh);
            out.extend_from_slice(&l.b);
        }
        out.extend_from_slice(&self.w_embed);
        out
    }

    /// Inverse of [`flatten`].
    pub fn unflatten_into(&mut self, flat: &[f32]) {
        let mut off = 0;
        for l in &mut self.layers {
            let n = l.w_self.len();
            l.w_self.copy_from_slice(&flat[off..off + n]);
            off += n;
            let n = l.w_neigh.len();
            l.w_neigh.copy_from_slice(&flat[off..off + n]);
            off += n;
            let n = l.b.len();
            l.b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        let n = self.w_embed.len();
        self.w_embed.copy_from_slice(&flat[off..off + n]);
        off += n;
        assert_eq!(off, flat.len());
    }
}

/// Gradient accumulator with the same layout as [`ModelParams`].
#[derive(Clone, Debug)]
pub struct ModelGrads {
    pub layers: Vec<LayerParams>,
    pub w_embed: Vec<f32>,
}

impl ModelGrads {
    pub fn zeros(params: &ModelParams) -> Self {
        Self {
            layers: params.layers.iter().map(|l| l.zeros_like()).collect(),
            w_embed: vec![0.0; params.w_embed.len()],
        }
    }

    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.w_self.iter_mut().for_each(|x| *x = 0.0);
            l.w_neigh.iter_mut().for_each(|x| *x = 0.0);
            l.b.iter_mut().for_each(|x| *x = 0.0);
        }
        self.w_embed.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.extend_from_slice(&l.w_self);
            out.extend_from_slice(&l.w_neigh);
            out.extend_from_slice(&l.b);
        }
        out.extend_from_slice(&self.w_embed);
        out
    }
}

#[cfg(test)]
pub(crate) fn test_config() -> ShapeConfig {
    ShapeConfig {
        name: "t".into(),
        n_pad: 256,
        f_in: 16,
        hidden: 16,
        classes: 4,
        e_local: 1024,
        e_pre: 256,
        p_pre: 128,
        r_pre: 128,
        r_post: 128,
        e_post: 256,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let p = ModelParams::init(&test_config(), 1);
        assert_eq!(p.layers.len(), 3);
        assert_eq!(p.layers[0].w_self.len(), 16 * 16);
        assert_eq!(p.layers[2].w_neigh.len(), 16 * 4);
        assert_eq!(p.w_embed.len(), 4 * 16);
    }

    #[test]
    fn glorot_bounds() {
        let p = ModelParams::init(&test_config(), 2);
        let lim = (6.0f64 / 32.0).sqrt() as f32;
        assert!(p.layers[0].w_self.iter().all(|&w| w.abs() <= lim));
        // Not all zero.
        assert!(p.layers[0].w_self.iter().any(|&w| w.abs() > 1e-4));
    }

    #[test]
    fn flatten_roundtrip() {
        let p = ModelParams::init(&test_config(), 3);
        let flat = p.flatten();
        assert_eq!(flat.len(), p.n_params());
        let mut q = ModelParams::init(&test_config(), 99);
        q.unflatten_into(&flat);
        assert_eq!(q.flatten(), flat);
        assert_eq!(q.layers[1].w_neigh, p.layers[1].w_neigh);
    }

    #[test]
    fn grads_zero_and_clear() {
        let p = ModelParams::init(&test_config(), 4);
        let mut g = ModelGrads::zeros(&p);
        assert!(g.flatten().iter().all(|&x| x == 0.0));
        g.layers[0].b[0] = 5.0;
        g.clear();
        assert!(g.flatten().iter().all(|&x| x == 0.0));
    }
}
