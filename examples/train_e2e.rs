//! End-to-end three-layer validation driver (the EXPERIMENTS.md §E2E run):
//!
//!  1. executes the **AOT'd JAX/Pallas artifacts** through PJRT
//!     (build them first: `make artifacts`) and cross-checks each layer
//!     op against the native kernels on a real partitioned workload —
//!     proving L3 (Rust coordinator) ∘ L2 (JAX model) ∘ L1 (Pallas
//!     kernel) compose and agree;
//!  2. trains to convergence through the unified execution engine
//!     (`exec::Engine`, DESIGN.md §9) — the production hot path that the
//!     op-parity in phase 1 certifies.
//!
//!     make artifacts && cargo run --release --example train_e2e

use std::path::Path;
use supergcn::backend::native::NativeBackend;
use supergcn::comm::transport::TransportKind;
use supergcn::backend::xla::XlaBackend;
use supergcn::backend::Backend;
use supergcn::coordinator::planner::prepare;
use supergcn::exec::{AggDispatch, AggKernel};
use supergcn::run::RunConfig;
use supergcn::graph::generate::sbm;
use supergcn::graph::stats::stats;
use supergcn::hier::volume::RemoteStrategy;
use supergcn::model::ModelParams;
use supergcn::obs::{Telemetry, Tracer};
use supergcn::quant::Bits;
use supergcn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    // A dataset sized for the "quickstart" artifact config (n_pad 1536,
    // f=64, c=16, 4 workers).
    let lg = sbm(4000, 16, 7.0, 0.72, 64, 3.0, 1001);
    println!("dataset: {}", stats(&lg.graph));

    let rt = Runtime::load(artifacts, "quickstart")?;
    let shape_cfg = rt.config.clone();
    // One RunConfig describes the whole run (DESIGN.md §15) — the CLI,
    // benches, and this driver all construct trainers through it.
    let rc = RunConfig {
        epochs: 150,
        lr: 0.01,
        quant: Some(Bits::Int2),
        label_prop: true,
        strategy: RemoteStrategy::Hybrid,
        // Run the SPMD ranks on one OS thread each (real multi-core wall
        // clock; bit-exact with the sequential transport — DESIGN.md §10).
        // CLI equivalent: `supergcn train --transport threaded`
        // (`--rank-threads 0` = one thread per worker).
        transport: TransportKind::Threaded,
        // Post each layer's halo exchange before interior aggregation so
        // wire time hides behind compute; boundary rows finish after
        // receipt. Bit-exact with the blocking schedule — DESIGN.md §11.
        // CLI equivalent: `supergcn train --overlap on`.
        overlap: true,
        // Group the 4 ranks onto 2 simulated nodes: cross-node payloads
        // stage through per-node leaders, cutting inter-node messages
        // from O(P²) to O((P/g)²) while the staging hops ride the cheap
        // intra-node tier (CommStats::tiers). Bit-exact with the flat
        // exchange — DESIGN.md §12.
        // CLI equivalent: `supergcn train --group-size 2`.
        group_size: 2,
        // Aggregation + quant kernels route through the runtime-dispatched
        // SIMD rung (AVX2 when detected, scalar fallback otherwise) —
        // bit-exact with every other rung of the §4 ladder, so this is a
        // pure performance knob (DESIGN.md §14).
        // CLI equivalent: `supergcn train --agg-kernel simd`
        // (the default `auto` already prefers it when the ISA is there).
        agg: AggDispatch::default().with_kernel(AggKernel::Simd),
        // Fault tolerance (DESIGN.md §15) is off here, but the same
        // struct drives it: `checkpoint_every: 10` saves a resumable
        // checkpoint every 10 epochs, `resume: Some(path)` continues one
        // with bit-identical losses, and `chaos: Some(FaultSpec { .. })`
        // kills a rank mid-epoch to exercise the elastic re-plan.
        // CLI equivalents: `supergcn train --checkpoint-every 10
        // --checkpoint-path run.ckpt --resume run.ckpt
        // --chaos rank=1,epoch=3`.
        //
        // The remote-feature cache (DESIGN.md §16) also rides this
        // struct, but applies to the *mini-batch* fetch path — this
        // full-batch driver exchanges halos, not feature rows, and
        // `validate()` rejects a TTL here. On a sampler run,
        // `feature_cache_rows: 512, feature_cache_ttl: 2` caches fetched
        // remote rows per rank for 2 rounds, skipping both wire legs on
        // a hit; TTL=0 (the default) is byte-for-byte the uncached path.
        // CLI equivalent: `supergcn train --sampler neighbor
        // --feature-cache-rows 512 --feature-cache-ttl 2`.
        //
        // Out-of-core storage (DESIGN.md §17): `graph_dir: Some(dir)`
        // trains through the mmap-backed `graph::store::GraphStore`
        // instead of an in-process dataset — per-epoch losses stay
        // bit-identical, and `graph_dir` deliberately stays out of the
        // resume fingerprint (storage is not a numeric knob). This
        // driver builds its graph in memory, so it leaves the default.
        // CLI equivalents: `supergcn synth --out dir` streams a
        // synthetic graph to dir/graph.sgcn, `supergcn prepare
        // --graph-dir dir --workers 4` cuts per-rank shard files, and
        // `supergcn train --graph-dir dir [--store mem]` trains from
        // them (`--store mem` materializes the same bytes on the heap
        // as the memory-footprint reference).
        ..Default::default()
    };
    let (ctxs, cfg, _) = prepare(&lg, 4, rc.strategy, Some(shape_cfg), rc.seed)?;

    // Phase 1: the full three-layer stack through PJRT, op-for-op against
    // the native kernels on worker 0's real padded tensors.
    println!("\n-- phase 1: XLA artifact ops vs native kernels (PJRT) --");
    let params = ModelParams::init(&cfg, rc.seed);
    let mut xla = XlaBackend::new(rt);
    let mut native = NativeBackend::new(cfg.clone());
    let n = cfg.n_pad;
    let f = cfg.f_in;
    let ctx0 = &ctxs[0];
    let mut hn_x = vec![0f32; n * f];
    let mut pa_x = vec![0f32; cfg.p_pre * f];
    xla.pre_fwd(f, &ctx0.features, &ctx0.pre, &mut hn_x, &mut pa_x)?;
    let mut hn_n = vec![0f32; n * f];
    let mut pa_n = vec![0f32; cfg.p_pre * f];
    native.pre_fwd(f, &ctx0.features, &ctx0.pre, &mut hn_n, &mut pa_n)?;
    let recv_pre = vec![0f32; cfg.r_pre * f];
    let recv_post = vec![0f32; cfg.r_post * f];
    let mut out_x = vec![0f32; n * cfg.hidden];
    let mut out_n = vec![0f32; n * cfg.hidden];
    xla.layer_fwd(0, &hn_x, &recv_pre, &recv_post, &params.layers[0], &ctx0.spec, &mut out_x)?;
    native.layer_fwd(0, &hn_n, &recv_pre, &recv_post, &params.layers[0], &ctx0.spec, &mut out_n)?;
    let max_d = |a: &[f32], b: &[f32]| {
        a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    };
    let d_ln = max_d(&hn_x, &hn_n);
    let d_layer = max_d(&out_x, &out_n);
    println!("max |xla - native|: layernorm {d_ln:.2e}, layer-0 output {d_layer:.2e}");
    anyhow::ensure!(d_ln < 2e-4 && d_layer < 2e-3, "artifact ops diverged from native");

    // Phase 2: the unified engine to convergence on the same contexts.
    println!("\n-- phase 2: exec::Engine training to convergence --");
    let mut tr = rc.full_batch_trainer(ctxs, cfg);
    // Record per-rank spans for the whole run (DESIGN.md §13): pid =
    // rank, tid = lane; load the file at https://ui.perfetto.dev.
    // CLI equivalents: `supergcn train --trace trace_e2e.json
    // --metrics-json metrics_e2e.json`.
    let tracer = Tracer::new();
    tr.telemetry = Telemetry {
        tracer: Some(tracer.clone()),
        metrics: None,
    };
    let stats = tr.run(true)?;
    let last = stats.last().unwrap();
    println!(
        "converged: loss {:.4}, test acc {:.3} — three-layer stack validated",
        last.train_loss, last.test_acc
    );
    if !last.overlap.is_empty() {
        println!(
            "overlap model (last epoch): {:.6}s overlapped vs {:.6}s phase-serial \
             — same run, same bits (DESIGN.md §11)",
            last.overlap.modeled_overlap_secs(),
            last.overlap.modeled_serial_secs()
        );
    }
    tracer.write("trace_e2e.json")?;
    println!(
        "trace: {} spans -> trace_e2e.json (perfetto/chrome trace_event; DESIGN.md §13)",
        tracer.span_count()
    );
    Ok(())
}
