//! Integration: the padded `Backend` op engines (native and the AOT'd
//! JAX/Pallas artifact engine) and the unified `exec::Engine` must agree
//! on the same layer computation — this is the proof that all three
//! layers of the stack compose and agree, and that the engine refactor
//! preserved the op semantics.
//!
//! The engine-vs-native check always runs; the xla checks require
//! `make artifacts` (they no-op politely otherwise).

use std::path::{Path, PathBuf};
use supergcn::backend::native::NativeBackend;
use supergcn::backend::xla::XlaBackend;
use supergcn::backend::Backend;
use supergcn::comm::CommStats;
use supergcn::coordinator::planner::prepare;
use supergcn::exec::{
    AggDispatch, Engine, FullBatchCtx, FullBatchState, LossSpec, StageClock, SPLIT_NONE,
};
use supergcn::graph::generate::sbm;
use supergcn::hier::volume::RemoteStrategy;
use supergcn::model::ModelParams;
use supergcn::perfmodel::MachineProfile;
use supergcn::runtime::{Manifest, Runtime};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn tiny_dataset() -> supergcn::graph::generate::LabelledGraph {
    // Must fit the "tiny" artifact config: n_pad 256 (2 workers × ~125
    // nodes), f=16, classes=4.
    sbm(240, 4, 5.0, 0.85, 16, 0.6, 77)
}

/// The unified engine's whole epoch math — LayerNorm → aggregate → SAGE
/// update per layer, softmax/NLL loss, and the exact backward — must
/// reproduce the padded `Backend` op chain. Single worker, so no halo
/// traffic: empty recvs and zero `d_partials` make the op chain the
/// complete computation.
#[test]
fn engine_matches_backend_ops_full_epoch() {
    let lg = tiny_dataset();
    let (ctxs, cfg, _) = prepare(&lg, 1, RemoteStrategy::Hybrid, None, 5).unwrap();
    let params = ModelParams::init(&cfg, 5);
    let n = cfg.n_pad;
    let dims = cfg.layer_dims();
    let wc = &ctxs[0];
    let mask = &wc.train_mask_f;

    // ---- backend op chain (the pre-refactor trainer's per-worker math).
    let mut native = NativeBackend::new(cfg.clone());
    let mut h = wc.features.clone();
    let mut h_norms = Vec::new();
    let mut outs = Vec::new();
    for (l, &(fin, fout, _)) in dims.iter().enumerate() {
        let mut h_norm = vec![0f32; n * fin];
        let mut partials = vec![0f32; cfg.p_pre * fin];
        native
            .pre_fwd(fin, &h, &wc.pre, &mut h_norm, &mut partials)
            .unwrap();
        let recv_pre = vec![0f32; cfg.r_pre * fin];
        let recv_post = vec![0f32; cfg.r_post * fin];
        let mut out = vec![0f32; n * fout];
        native
            .layer_fwd(l, &h_norm, &recv_pre, &recv_post, &params.layers[l], &wc.spec, &mut out)
            .unwrap();
        h_norms.push(h_norm);
        outs.push(out.clone());
        h = out;
    }
    let logits = h;
    let lo = native.loss_head(&logits, &wc.labels_i32, mask).unwrap();
    let inv = 1.0 / lo.mask_sum;
    let mut grads_b = supergcn::model::ModelGrads::zeros(&params);
    let mut d_cur: Vec<f32> = lo.d_logits.iter().map(|&d| d * inv).collect();
    for l in (0..3).rev() {
        let (fin, fout, _) = dims[l];
        let recv_pre = vec![0f32; cfg.r_pre * fin];
        let recv_post = vec![0f32; cfg.r_post * fin];
        let mut d_h_norm = vec![0f32; n * fin];
        let mut d_recv_pre = vec![0f32; cfg.r_pre * fin];
        let mut d_recv_post = vec![0f32; cfg.r_post * fin];
        native
            .layer_bwd(
                l,
                &h_norms[l],
                &recv_pre,
                &recv_post,
                &params.layers[l],
                &wc.spec,
                &outs[l],
                &d_cur[..n * fout],
                &mut d_h_norm,
                &mut d_recv_pre,
                &mut d_recv_post,
                &mut grads_b.layers[l],
            )
            .unwrap();
        let h_in = if l == 0 { &wc.features } else { &outs[l - 1] };
        let d_partials = vec![0f32; cfg.p_pre * fin];
        let mut d_h = vec![0f32; n * fin];
        native
            .pre_bwd(fin, h_in, &wc.pre, &d_h_norm, &d_partials, &mut d_h)
            .unwrap();
        d_cur = d_h;
    }

    // ---- unified engine, same worker context.
    let engine = Engine::new(&cfg, true, AggDispatch::default());
    let mut st = FullBatchState::new(&cfg, 1);
    let mut comm = CommStats::new(1);
    let machine = MachineProfile::abci();
    let mut ctx = FullBatchCtx::new(
        &ctxs, &cfg, &mut st, &machine, None, 5, 0, true, false, &mut comm,
    );
    let mut tapes = engine.tapes(&[n], &params);
    let mut clock = StageClock::new(1);
    engine
        .forward(&params, &mut ctx, &mut tapes, None, &mut clock)
        .unwrap();
    assert_close(&tapes.h_tilde[0][0], &h_norms[0], 1e-6, "LayerNorm output");
    assert_close(&tapes.h[3][0], &logits, 1e-5, "logits");

    let tags: Vec<u8> = mask
        .iter()
        .map(|&m| if m > 0.0 { supergcn::graph::generate::SPLIT_TRAIN } else { SPLIT_NONE })
        .collect();
    let spec = LossSpec {
        score_rows: n,
        labels: &wc.labels,
        split: &tags,
        loss_w: mask,
    };
    let tot = engine.loss_all(&mut tapes, &[spec], &mut clock)[0];
    assert!(
        (tot.loss_sum - lo.loss_sum as f64).abs() < 1e-3 * (1.0 + lo.loss_sum.abs() as f64),
        "loss {} vs backend {}",
        tot.loss_sum,
        lo.loss_sum
    );
    assert_eq!(tot.wsum as f32, lo.mask_sum, "mask sum");
    let c = cfg.classes;
    assert_close(&tapes.d_cur[0][..n * c], &lo.d_logits, 1e-5, "d_logits");

    engine.scale_loss_grad(&mut tapes, &[inv]);
    engine
        .backward(&params, &mut ctx, &mut tapes, None, true, &mut clock)
        .unwrap();
    assert_close(
        &tapes.grads[0].flatten(),
        &grads_b.flatten(),
        1e-5,
        "parameter gradients",
    );
    assert_close(&tapes.d_cur[0][..n * cfg.f_in], &d_cur, 1e-5, "input cotangent");
    // No halo traffic for a single worker.
    assert_eq!(comm.total_data_bytes(), 0.0);
}

#[test]
fn xla_backend_single_forward_matches_native() {
    if !tiny_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let lg = tiny_dataset();
    let manifest = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
    let cfg = manifest.config("tiny").unwrap().shapes.clone();
    let (ctxs, cfg, plans) = prepare(&lg, 2, RemoteStrategy::Hybrid, Some(cfg), 9).unwrap();
    assert_eq!(plans.len(), 2);

    let mut native = NativeBackend::new(cfg.clone());
    let rt = Runtime::load(&artifacts_dir(), "tiny").unwrap();
    let mut xla = XlaBackend::new(rt);

    let ctx = &ctxs[0];
    let n = cfg.n_pad;
    let f = cfg.f_in;
    let h = ctx.features.clone();

    let mut hn_n = vec![0f32; n * f];
    let mut pa_n = vec![0f32; cfg.p_pre * f];
    native.pre_fwd(f, &h, &ctx.pre, &mut hn_n, &mut pa_n).unwrap();
    let mut hn_x = vec![0f32; n * f];
    let mut pa_x = vec![0f32; cfg.p_pre * f];
    xla.pre_fwd(f, &h, &ctx.pre, &mut hn_x, &mut pa_x).unwrap();
    assert_close(&hn_n, &hn_x, 2e-4, "h_norm");
    assert_close(&pa_n, &pa_x, 2e-3, "partials");

    // One full layer with empty recvs.
    let params = supergcn::model::LayerParams::glorot(f, cfg.hidden, &mut supergcn::util::rng::Rng::new(3));
    let recv_pre = vec![0f32; cfg.r_pre * f];
    let recv_post = vec![0f32; cfg.r_post * f];
    let mut out_n = vec![0f32; n * cfg.hidden];
    let mut out_x = vec![0f32; n * cfg.hidden];
    native
        .layer_fwd(0, &hn_n, &recv_pre, &recv_post, &params, &ctx.spec, &mut out_n)
        .unwrap();
    xla.layer_fwd(0, &hn_n, &recv_pre, &recv_post, &params, &ctx.spec, &mut out_x)
        .unwrap();
    assert_close(&out_n, &out_x, 2e-3, "layer output");

    // Backward of the same layer: cotangents and parameter grads.
    let mut rng = supergcn::util::rng::Rng::new(11);
    let d_out: Vec<f32> = (0..n * cfg.hidden).map(|_| rng.f32() - 0.5).collect();
    let mut run_bwd = |be: &mut dyn Backend| {
        let mut d_hn = vec![0f32; n * f];
        let mut d_rp = vec![0f32; cfg.r_pre * f];
        let mut d_ro = vec![0f32; cfg.r_post * f];
        let mut grads = params.zeros_like();
        be.layer_bwd(
            0, &hn_n, &recv_pre, &recv_post, &params, &ctx.spec, &out_n, &d_out, &mut d_hn,
            &mut d_rp, &mut d_ro, &mut grads,
        )
        .unwrap();
        let d_partials = vec![0f32; cfg.p_pre * f];
        let mut d_h = vec![0f32; n * f];
        be.pre_bwd(f, &h, &ctx.pre, &d_hn, &d_partials, &mut d_h)
            .unwrap();
        (d_hn, d_h, grads)
    };
    let (dhn_n, dh_n, g_n) = run_bwd(&mut native);
    let (dhn_x, dh_x, g_x) = run_bwd(&mut xla);
    assert_close(&dhn_n, &dhn_x, 2e-3, "d_h_norm");
    assert_close(&dh_n, &dh_x, 2e-3, "d_h (pre_bwd)");
    assert_close(&g_n.w_self, &g_x.w_self, 2e-2, "dW_self");
    assert_close(&g_n.w_neigh, &g_x.w_neigh, 2e-2, "dW_neigh");
    assert_close(&g_n.b, &g_x.b, 2e-2, "db");

    // Loss head on shared random logits.
    let logits: Vec<f32> = (0..n * cfg.classes).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let lo_n = native
        .loss_head(&logits, &ctx.labels_i32, &ctx.train_mask_f)
        .unwrap();
    let lo_x = xla
        .loss_head(&logits, &ctx.labels_i32, &ctx.train_mask_f)
        .unwrap();
    assert!(
        (lo_n.loss_sum - lo_x.loss_sum).abs() < 2e-2 * (1.0 + lo_n.loss_sum.abs()),
        "loss_sum {} vs {}",
        lo_n.loss_sum,
        lo_x.loss_sum
    );
    assert_eq!(lo_n.mask_sum, lo_x.mask_sum, "mask_sum");
    assert_close(&lo_n.d_logits, &lo_x.d_logits, 2e-3, "d_logits");
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0f32;
    let mut worst_i = 0usize;
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let d = (x - y).abs();
        if d > worst {
            worst = d;
            worst_i = i;
        }
    }
    assert!(
        worst <= tol,
        "{what}: max diff {worst} at {worst_i} ({} vs {})",
        a[worst_i],
        b[worst_i]
    );
}
