//! The `supergcn benchcmp` comparator: parse `benches/spmd_scaling.rs`
//! JSON records and gate threaded wall-clock regressions against the
//! committed `BENCH_seed.json` baseline.
//!
//! Library module (not inlined in `main.rs`) so the parse and compare
//! paths are unit-testable: a missing or corrupt record, and an **empty
//! run set**, must surface as clear errors — never a panic, and never a
//! silent "0 rows compared" pass.

use crate::util::json::Json;
use anyhow::Result;

/// One comparable bench row: `"regime@ranks"` → threaded wall seconds.
pub type BenchRow = (String, f64);

/// Load the comparable rows of one bench record. Errors (with the path in
/// the message) on: unreadable file, invalid JSON, a missing `rows[]`
/// array, an **empty** `rows[]` (an empty run set must fail the gate
/// loudly, not pass it vacuously), or a row missing its key fields.
///
/// Forward-compatibility contract: only the fields named here are read —
/// unknown top-level keys (e.g. the `obs` telemetry and feature-`cache`
/// blocks newer bench records carry) and unknown per-row keys are
/// ignored, so a grown record schema never fails the gate against an
/// older committed baseline.
pub fn load_rows(path: &str) -> Result<Vec<BenchRow>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{path}: missing rows[]"))?;
    anyhow::ensure!(
        !rows.is_empty(),
        "{path}: empty run set (rows[] has no entries) — refusing to compare; \
         regenerate the record with benches/spmd_scaling.rs"
    );
    rows.iter()
        .map(|r| {
            let regime = r.req_str("regime")?.to_string();
            let ranks = r.req_usize("ranks")?;
            let secs = r
                .get("threaded_wall_secs")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("{path}: missing threaded_wall_secs"))?;
            Ok((format!("{regime}@{ranks}"), secs))
        })
        .collect()
}

/// How one row fared against the baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Ok,
    Regression,
    /// Baseline below the noise floor — compared but never failed.
    NoiseFloor,
    /// Present only in the current record (a grown bench matrix) — gates
    /// once the baseline refreshes, never a failure now.
    NewRow,
    /// Present only in the baseline — reported, never a failure.
    MissingRow,
}

impl Verdict {
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::Regression => "REGRESSION",
            Verdict::NoiseFloor => "skip (noise floor)",
            Verdict::NewRow => "new (no baseline)",
            Verdict::MissingRow => "missing",
        }
    }
}

/// One line of the gate report.
#[derive(Clone, Debug)]
pub struct GateRow {
    pub key: String,
    pub baseline_secs: Option<f64>,
    pub current_secs: Option<f64>,
    pub verdict: Verdict,
}

impl GateRow {
    pub fn ratio(&self) -> Option<f64> {
        match (self.baseline_secs, self.current_secs) {
            (Some(b), Some(c)) => Some(c / b.max(1e-12)),
            _ => None,
        }
    }
}

/// Full comparison outcome: per-row verdicts (new rows first, then the
/// baseline's order, like the CLI table) plus the failure summaries.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    pub rows: Vec<GateRow>,
    pub failures: Vec<String>,
    /// Rows present on both sides (the "N rows compared" count).
    pub compared: usize,
}

/// Compare a current record against the committed baseline: fail rows
/// whose threaded wall seconds exceed the baseline by more than
/// `threshold_pct` percent, skip rows whose baseline is under `min_secs`
/// (timer noise), and report — without failing — rows present on only one
/// side (the bench matrix may grow or shrink between refreshes).
pub fn compare(
    baseline: &[BenchRow],
    current: &[BenchRow],
    threshold_pct: f64,
    min_secs: f64,
) -> GateReport {
    let threshold = 1.0 + threshold_pct / 100.0;
    let mut report = GateReport::default();
    for (key, cur_secs) in current {
        if !baseline.iter().any(|(k, _)| k == key) {
            report.rows.push(GateRow {
                key: key.clone(),
                baseline_secs: None,
                current_secs: Some(*cur_secs),
                verdict: Verdict::NewRow,
            });
        }
    }
    for (key, base_secs) in baseline {
        let Some((_, cur_secs)) = current.iter().find(|(k, _)| k == key) else {
            report.rows.push(GateRow {
                key: key.clone(),
                baseline_secs: Some(*base_secs),
                current_secs: None,
                verdict: Verdict::MissingRow,
            });
            continue;
        };
        report.compared += 1;
        let ratio = cur_secs / base_secs.max(1e-12);
        let verdict = if *base_secs < min_secs {
            Verdict::NoiseFloor
        } else if ratio > threshold {
            report.failures.push(format!(
                "{key}: {cur_secs:.4}s vs {base_secs:.4}s ({ratio:.2}x)"
            ));
            Verdict::Regression
        } else {
            Verdict::Ok
        };
        report.rows.push(GateRow {
            key: key.clone(),
            baseline_secs: Some(*base_secs),
            current_secs: Some(*cur_secs),
            verdict,
        });
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Unique temp path per test (no tempfile crate offline).
    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("supergcn-benchcmp-{}-{name}.json", std::process::id()));
        p
    }

    fn write(name: &str, content: &str) -> String {
        let p = tmp(name);
        std::fs::write(&p, content).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn record(rows: &str) -> String {
        format!("{{\"bench\": \"spmd_scaling\", \"rows\": [{rows}]}}")
    }

    fn row_json(regime: &str, ranks: usize, secs: f64) -> String {
        format!(
            "{{\"regime\": \"{regime}\", \"ranks\": {ranks}, \"threaded_wall_secs\": {secs}}}"
        )
    }

    #[test]
    fn missing_file_is_a_clear_error() {
        let err = load_rows("/nonexistent/BENCH_nope.json").unwrap_err();
        assert!(err.to_string().contains("cannot read"), "{err}");
    }

    #[test]
    fn corrupt_json_is_a_clear_error() {
        let p = write("corrupt", "{\"rows\": [");
        let err = load_rows(&p).unwrap_err();
        assert!(err.to_string().contains(&p), "path lost: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn record_without_rows_is_a_clear_error() {
        let p = write("norows", "{\"bench\": \"spmd_scaling\"}");
        let err = load_rows(&p).unwrap_err();
        assert!(err.to_string().contains("missing rows[]"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_run_set_errors_instead_of_silently_passing() {
        let p = write("empty", &record(""));
        let err = load_rows(&p).unwrap_err();
        assert!(err.to_string().contains("empty run set"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn row_missing_wall_secs_is_a_clear_error() {
        let p = write("nosecs", &record("{\"regime\": \"full-batch\", \"ranks\": 2}"));
        let err = load_rows(&p).unwrap_err();
        assert!(err.to_string().contains("threaded_wall_secs"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn well_formed_record_roundtrips() {
        let p = write(
            "ok",
            &record(&format!(
                "{}, {}",
                row_json("full-batch", 2, 0.5),
                row_json("mini-batch", 4, 1.25)
            )),
        );
        let rows = load_rows(&p).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "full-batch@2");
        assert_eq!(rows[0].1, 0.5);
        assert_eq!(rows[1].0, "mini-batch@4");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn unknown_keys_are_ignored_not_errors() {
        // A newer record carrying top-level `obs` telemetry, `cache`
        // (DESIGN.md §16), and `oocore` (DESIGN.md §17) blocks and extra
        // per-row keys must still load against the documented schema —
        // the comparator reads only the fields it names, so a grown
        // record never fails the gate against an older committed
        // baseline.
        let p = write(
            "forward-compat",
            "{\"bench\": \"spmd_scaling\", \
              \"obs\": {\"span_count\": 1234, \"trace\": \"trace_ci.json\"}, \
              \"cache\": {\"ttl\": 1, \"rows\": 512, \"hit_rate\": 0.4, \
                          \"saved_bytes\": 123456.0}, \
              \"oocore\": {\"ranks\": 4, \"edges\": 160000.0, \
                           \"mapped_bytes\": 1048576.0, \
                           \"peak_rss_bytes\": 2097152.0, \
                           \"losses_bit_exact\": true}, \
              \"rows\": [{\"regime\": \"full-batch\", \"ranks\": 2, \
                          \"threaded_wall_secs\": 0.5, \
                          \"span_count\": 99, \"future_field\": [1, 2]}]}",
        );
        let rows = load_rows(&p).unwrap();
        assert_eq!(rows, vec![("full-batch@2".to_string(), 0.5)]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn compare_flags_regressions_and_skips_noise() {
        let baseline = vec![
            ("full-batch@2".to_string(), 1.0),
            ("full-batch@4".to_string(), 1.0),
            ("tiny@1".to_string(), 0.001),
        ];
        let current = vec![
            ("full-batch@2".to_string(), 1.1),
            ("full-batch@4".to_string(), 1.5),
            ("tiny@1".to_string(), 1.0),
        ];
        let r = compare(&baseline, &current, 25.0, 0.005);
        assert_eq!(r.compared, 3);
        assert_eq!(r.failures.len(), 1, "{:?}", r.failures);
        assert!(r.failures[0].contains("full-batch@4"));
        let verdict_of = |key: &str| {
            r.rows
                .iter()
                .find(|row| row.key == key)
                .map(|row| row.verdict)
                .unwrap()
        };
        assert_eq!(verdict_of("full-batch@2"), Verdict::Ok);
        assert_eq!(verdict_of("full-batch@4"), Verdict::Regression);
        // Below the noise floor: a 1000x blowup still never fails.
        assert_eq!(verdict_of("tiny@1"), Verdict::NoiseFloor);
    }

    #[test]
    fn new_and_missing_rows_report_without_failing() {
        let baseline = vec![("old@2".to_string(), 1.0)];
        let current = vec![("new@2".to_string(), 9.0)];
        let r = compare(&baseline, &current, 25.0, 0.005);
        assert!(r.failures.is_empty());
        assert_eq!(r.compared, 0);
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].verdict, Verdict::NewRow);
        assert_eq!(r.rows[0].ratio(), None);
        assert_eq!(r.rows[1].verdict, Verdict::MissingRow);
    }
}
