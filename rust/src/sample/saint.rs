//! GraphSAINT subgraph sampling (node / edge / random-walk variants)
//! with sample-coverage loss normalization.
//!
//! Each batch draws a node set, induces its subgraph, and aggregates
//! with exact mean weights over the retained neighbors. Because nodes
//! appear in subgraphs at different rates (degree-biased node draws,
//! walk reachability), the loss is reweighted by inverse coverage: at
//! construction the sampler pre-draws `norm_batches` node sets with a
//! dedicated RNG stream, counts appearances `c_v`, and weights node `v`'s
//! loss by `mean_rate / c_v` (1.0 for never-covered nodes) — the
//! GraphSAINT `λ_v` estimator normalized so an average-rate node keeps
//! weight 1.

use super::minibatch::{mean_edge_weights, MiniBatch};
use super::{batch_rng, mix2, Sampler, SamplerConfig};
use crate::graph::store::GraphStore;
use crate::util::rng::Rng;

/// Which GraphSAINT subgraph distribution to draw from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SaintVariant {
    /// Degree-proportional node draws.
    Node,
    /// Uniform edge draws; the set is the drawn endpoints.
    Edge,
    /// Uniform roots + fixed-length random walks over in-neighbors.
    Walk,
}

impl SaintVariant {
    pub fn name(&self) -> &'static str {
        match self {
            SaintVariant::Node => "saint-node",
            SaintVariant::Edge => "saint-edge",
            SaintVariant::Walk => "saint-rw",
        }
    }
}

pub struct SaintSampler {
    store: GraphStore,
    variant: SaintVariant,
    batch_size: usize,
    walk_length: usize,
    seed: u64,
    /// Cumulative (in_degree + 1) prefix sums for degree-biased draws.
    cum_deg: Vec<u64>,
    /// Per-node inverse-coverage loss weight.
    loss_weight: Vec<f32>,
}

impl SaintSampler {
    pub fn new(store: GraphStore, variant: SaintVariant, cfg: &SamplerConfig) -> Self {
        assert!(cfg.batch_size >= 1);
        let n = store.n();
        let mut cum_deg = Vec::with_capacity(n + 1);
        cum_deg.push(0u64);
        for v in 0..n {
            cum_deg.push(cum_deg[v] + store.in_degree(v) as u64 + 1);
        }
        let mut s = Self {
            store,
            variant,
            batch_size: cfg.batch_size,
            walk_length: cfg.walk_length.max(1),
            seed: cfg.seed,
            cum_deg,
            loss_weight: vec![1.0; n],
        };
        // Scale the pre-draw count with n/batch_size so expected per-node
        // coverage stays ≳3 regardless of graph size — 20 draws on a
        // large graph would leave most nodes at c_v ∈ {0,1} and the
        // weights dominated by Monte-Carlo noise instead of inclusion
        // probability.
        let auto = (3 * n).div_ceil(s.batch_size.max(1));
        s.estimate_coverage(cfg.norm_batches.max(auto).max(1));
        s
    }

    /// Pre-draw `draws` node sets and set inverse-coverage loss weights.
    fn estimate_coverage(&mut self, draws: usize) {
        let n = self.store.n();
        let mut counts = vec![0u32; n];
        for d in 0..draws {
            let mut rng = Rng::new(mix2(mix2(self.seed, 0xC0_7E_0A6E), d as u64));
            for v in self.node_set(&mut rng) {
                counts[v as usize] += 1;
            }
        }
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        let mean_rate = total as f64 / n.max(1) as f64;
        for (w, &c) in self.loss_weight.iter_mut().zip(counts.iter()) {
            *w = if c > 0 {
                (mean_rate / c as f64) as f32
            } else {
                1.0
            };
        }
    }

    /// Draw one node set (sorted, distinct) according to the variant.
    fn node_set(&self, rng: &mut Rng) -> Vec<u32> {
        let g = &self.store;
        let n = g.n();
        let mut set: Vec<u32> = Vec::with_capacity(self.batch_size + 1);
        match self.variant {
            SaintVariant::Node => {
                let total = *self.cum_deg.last().unwrap();
                for _ in 0..self.batch_size {
                    let r = rng.below(total);
                    // First v with cum_deg[v+1] > r.
                    let v = self.cum_deg.partition_point(|&c| c <= r) - 1;
                    set.push(v as u32);
                }
            }
            SaintVariant::Edge => {
                let m = g.m();
                let draws = (self.batch_size / 2).max(1);
                if m == 0 {
                    for _ in 0..draws {
                        set.push(rng.index(n) as u32);
                    }
                } else {
                    for _ in 0..draws {
                        let e = rng.index(m);
                        set.push(g.edge_src(e));
                        set.push(g.edge_dst(e) as u32);
                    }
                }
            }
            SaintVariant::Walk => {
                let roots = (self.batch_size / (self.walk_length + 1)).max(1);
                for _ in 0..roots {
                    let mut cur = rng.index(n) as u32;
                    set.push(cur);
                    for _ in 0..self.walk_length {
                        let nbrs = g.in_neighbors(cur as usize);
                        if nbrs.is_empty() {
                            break;
                        }
                        cur = nbrs[rng.index(nbrs.len())];
                        set.push(cur);
                    }
                }
            }
        }
        set.sort_unstable();
        set.dedup();
        set
    }
}

impl Sampler for SaintSampler {
    fn name(&self) -> &'static str {
        self.variant.name()
    }

    fn batches_per_epoch(&self) -> usize {
        self.store.n().div_ceil(self.batch_size)
    }

    fn sample(&mut self, epoch: usize, batch: usize) -> MiniBatch {
        let mut rng = batch_rng(self.seed ^ 0x5A1_7, epoch, batch);
        let n_id = self.node_set(&mut rng);
        let adj = self.store.induced(&n_id);
        let edge_weight = mean_edge_weights(&adj);
        let node_weight = n_id.iter().map(|&v| self.loss_weight[v as usize]).collect();
        MiniBatch {
            sampler: self.variant.name(),
            n_target: n_id.len(),
            n_id,
            adj,
            edge_weight,
            node_weight,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::sbm;

    fn lg() -> GraphStore {
        GraphStore::from(sbm(500, 4, 10.0, 0.8, 8, 0.5, 21))
    }

    fn cfg(bs: usize) -> SamplerConfig {
        SamplerConfig {
            batch_size: bs,
            walk_length: 4,
            norm_batches: 10,
            seed: 13,
            ..Default::default()
        }
    }

    #[test]
    fn variants_draw_valid_batches() {
        for variant in [SaintVariant::Node, SaintVariant::Edge, SaintVariant::Walk] {
            let mut s = SaintSampler::new(lg(), variant, &cfg(100));
            let mb = s.sample(0, 0);
            mb.validate(500).unwrap();
            assert!(mb.n() > 0, "{}", variant.name());
            assert_eq!(mb.n_target, mb.n());
            assert_eq!(mb.sampler, variant.name());
        }
    }

    #[test]
    fn node_variant_is_degree_biased() {
        let lg = lg();
        let mut s = SaintSampler::new(lg.clone(), SaintVariant::Node, &cfg(80));
        let mut hits = vec![0u32; 500];
        for b in 0..50 {
            for &v in &s.sample(0, b).n_id {
                hits[v as usize] += 1;
            }
        }
        // Mean degree of drawn nodes exceeds the global mean degree.
        let mut drawn_deg = 0f64;
        let mut drawn = 0f64;
        for (v, &h) in hits.iter().enumerate() {
            drawn_deg += h as f64 * lg.in_degree(v) as f64;
            drawn += h as f64;
        }
        let global = lg.m() as f64 / 500.0;
        assert!(drawn_deg / drawn > global, "not degree biased");
    }

    #[test]
    fn coverage_weights_favor_rare_nodes() {
        let s = SaintSampler::new(lg(), SaintVariant::Node, &cfg(100));
        // Weights are positive and finite.
        assert!(s.loss_weight.iter().all(|w| w.is_finite() && *w > 0.0));
        // Degree-biased draws cover high-degree nodes more often, so the
        // top degree decile must carry smaller loss weights than the
        // bottom decile (aggregated so single-node noise cancels).
        let lg = lg();
        let mut by_deg: Vec<usize> = (0..500).collect();
        by_deg.sort_by_key(|&v| lg.in_degree(v));
        let mean_w = |vs: &[usize]| -> f64 {
            vs.iter().map(|&v| s.loss_weight[v] as f64).sum::<f64>() / vs.len() as f64
        };
        let low = mean_w(&by_deg[..50]);
        let high = mean_w(&by_deg[450..]);
        assert!(
            high < low,
            "high-degree decile weight {high} not below low-degree {low}"
        );
    }

    #[test]
    fn deterministic_across_instances() {
        for variant in [SaintVariant::Node, SaintVariant::Edge, SaintVariant::Walk] {
            let mut a = SaintSampler::new(lg(), variant, &cfg(60));
            let mut b = SaintSampler::new(lg(), variant, &cfg(60));
            let x = a.sample(2, 1);
            let y = b.sample(2, 1);
            assert_eq!(x.n_id, y.n_id);
            assert_eq!(x.adj, y.adj);
            assert_eq!(x.node_weight, y.node_weight);
        }
    }
}
