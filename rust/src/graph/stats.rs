//! Graph statistics: degree distribution summaries used by dataset
//! catalogs, bench headers, and the FLOPS-based load balancer.

use super::CsrGraph;

#[derive(Clone, Debug)]
pub struct GraphStats {
    pub n: usize,
    pub m: usize,
    pub avg_in_degree: f64,
    pub max_in_degree: usize,
    pub p99_in_degree: usize,
    pub median_in_degree: usize,
    pub isolated: usize,
    /// Gini coefficient of the in-degree distribution — the skew measure
    /// we report next to R-MAT configs (power-law graphs ≫ ER graphs).
    pub degree_gini: f64,
}

pub fn stats(g: &CsrGraph) -> GraphStats {
    // Degree scan through the chunked `rows()` view — the same access
    // pattern the streaming partitioner uses, so the scan touches the
    // CSR window by window instead of random-indexing the whole graph.
    const CHUNK: usize = 1 << 14;
    let mut degs: Vec<usize> = Vec::with_capacity(g.n);
    let mut lo = 0;
    while lo < g.n {
        let hi = (lo + CHUNK).min(g.n);
        let view = g.rows(lo..hi);
        for i in 0..view.len() {
            degs.push(view.in_degree(i));
        }
        lo = hi;
    }
    degs.sort_unstable();
    let m = g.m();
    let n = g.n.max(1);
    let isolated = degs.iter().take_while(|&&d| d == 0).count();
    let pct = |p: f64| -> usize {
        if degs.is_empty() {
            0
        } else {
            degs[((degs.len() - 1) as f64 * p) as usize]
        }
    };
    // Gini = sum_i (2i - n + 1) x_i / (n * sum x)
    let total: f64 = degs.iter().map(|&d| d as f64).sum();
    let gini = if total > 0.0 {
        let mut acc = 0.0;
        for (i, &d) in degs.iter().enumerate() {
            acc += (2.0 * i as f64 - n as f64 + 1.0) * d as f64;
        }
        acc / (n as f64 * total)
    } else {
        0.0
    };
    GraphStats {
        n: g.n,
        m,
        avg_in_degree: m as f64 / n as f64,
        max_in_degree: degs.last().copied().unwrap_or(0),
        p99_in_degree: pct(0.99),
        median_in_degree: pct(0.5),
        isolated,
        degree_gini: gini,
    }
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} m={} avg_deg={:.2} max_deg={} p99={} median={} isolated={} gini={:.3}",
            self.n,
            self.m,
            self.avg_in_degree,
            self.max_in_degree,
            self.p99_in_degree,
            self.median_in_degree,
            self.isolated,
            self.degree_gini
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{erdos_renyi, rmat};

    #[test]
    fn stats_small_known() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (2, 1), (3, 1), (0, 2)]);
        let s = stats(&g);
        assert_eq!(s.n, 4);
        assert_eq!(s.m, 4);
        assert_eq!(s.max_in_degree, 3);
        assert_eq!(s.isolated, 2); // nodes 0 and 3 have in-degree 0
        assert!((s.avg_in_degree - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rmat_more_skewed_than_er() {
        let er = erdos_renyi(1024, 8192, 3);
        let rm = rmat(10, 8.0, 0.57, 0.19, 0.19, false, 3);
        let s_er = stats(&er);
        let s_rm = stats(&rm);
        assert!(
            s_rm.degree_gini > s_er.degree_gini + 0.1,
            "rmat gini {} vs er gini {}",
            s_rm.degree_gini,
            s_er.degree_gini
        );
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_edges(0, &[]);
        let s = stats(&g);
        assert_eq!(s.n, 0);
        assert_eq!(s.m, 0);
        assert_eq!(s.degree_gini, 0.0);
    }
}
